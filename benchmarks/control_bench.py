"""Closed-loop control benchmark (ISSUE-6): detector-blind rule controller
vs an oracle-scheduled controller vs open loop, across the failure
scenarios.

Three arms, identical weighting (EAHES dynamic weights), identical
actuation surface (``ElasticSession.apply`` at chunk boundaries) — only
the *information* driving membership differs:

- ``open``   — no controller: failed slots stay in the pool and the
  dynamic weighting alone defends the master (the paper's own regime).
- ``oracle`` — :class:`OracleController`: ground-truth masks drive
  evict-at-onset / readmit-at-recovery. The best membership control this
  machinery can express; the reference the closed loop is scored against.
- ``closed`` — ``RunSpec(controller="rules", detector_blind=True)``: the
  ``repro.control`` loop running on observable telemetry only.

Per scenario the record carries each arm's final master eval loss, the
closed/oracle degradation, and the closed loop's recovery behaviour
(detector flag delays vs true onsets, evictions, probe readmissions).
Detection delay is measured *detector-side* (flag round − onset round) for
failure episodes that begin while the slot is live; episodes that start
while the slot is already evicted have no live telemetry to detect and are
counted separately (``dark_onsets``).

The run sizes mirror tests/test_control.py's acceptance runs: a
deliberately separable regime (α=0.5, τ=4 — strong pullback makes a
missing pullback visible; see repro/control/detector.py's calibration
notes).
"""
import numpy as np


class OracleController:
    """Ground-truth membership control through the public actuation path.

    Reads the scenario schedule's true fail mask (this file is a benchmark
    — the no-oracle rule binds ``repro/control/*``, not the reference arms
    that score it) and applies the ideal policy: evict a slot the chunk
    after its failure starts, readmit it the chunk after it clears, never
    emptying the pool.
    """

    def __init__(self, schedule):
        self.schedule = schedule
        self.evicted = set()
        self.log = []

    def on_round(self, record):
        pass

    def on_chunk_end(self, session):
        from repro.control.actions import ControlAction

        r = session.round - 1  # last completed round
        if r < 0 or session.round >= session.spec.rounds:
            return
        fail = np.asarray(self.schedule.fail[r], bool)
        act = np.asarray(session.active_mask, bool)
        down = [i for i in range(len(fail)) if fail[i] and act[i]]
        up = [i for i in sorted(self.evicted) if not fail[i]]
        live = int(act.sum())
        if down and live > 1:
            down = down[:live - 1]
            session.apply(ControlAction.evict(down, reason="oracle"))
            self.evicted.update(down)
            self.log.append((session.round, "evict", tuple(down)))
        if up:
            session.apply(ControlAction.readmit(up, reason="oracle"))
            self.evicted.difference_update(up)
            self.log.append((session.round, "readmit", tuple(up)))


def control_spec(scenario, seed, *, rounds=20, controller=None,
                 blind=False, **ecfg_kw):
    """The acceptance-regime RunSpec shared by bench and tests.

    ``ecfg_kw`` forwards extra ElasticConfig knobs — the adversarial sweep
    (ISSUE-9) uses it for byzantine_mode/byzantine_frac/score_clip.
    """
    from repro.api import RunSpec
    from repro.configs.base import ElasticConfig, OptimizerConfig

    ec = ElasticConfig(
        num_workers=4, capacity=4, tau=4, alpha=0.5,
        failure_prob=0.12, failure_scenario=scenario, crash_downtime=8,
        **ecfg_kw)
    return RunSpec(
        arch="paper-cnn", smoke=True, elastic=ec,
        optimizer=OptimizerConfig(name="sgd", lr=0.01),
        rounds=rounds, rounds_per_call=1, seed=seed,
        batch_size=4, n_data=96, n_test=32, eval_every=rounds,
        controller=controller, detector_blind=blind)


def final_eval(records):
    for r in reversed(records):
        if r.eval_loss is not None:
            return float(r.eval_loss)
    return float("nan")


def fail_episodes(schedule, rounds):
    """(slot, onset, end) of contiguous truly-failed runs in the truth
    masks (end exclusive, clipped at ``rounds``)."""
    f = np.asarray(schedule.fail[:rounds], bool)
    eps = []
    for i in range(f.shape[1]):
        r = 0
        while r < rounds:
            if f[r, i]:
                s = r
                while r < rounds and f[r, i]:
                    r += 1
                eps.append((i, s, r))
            else:
                r += 1
    return eps


def closed_loop_metrics(session, rounds):
    """Recovery metrics of a finished closed-loop session."""
    from repro.control.detector import FAILED_SUSPECT

    det = session.controller.detector
    applied = [a for a in session.controller.actuator.log if a.applied]
    evicts = [(a.round, s) for a in applied if a.action.kind == "evict"
              for s in a.action.slots]
    readmits = [(a.round, s) for a in applied if a.action.kind == "readmit"
                for s in a.action.slots]
    flags = [(r, slot) for (r, slot, v) in det.events
             if v == FAILED_SUSPECT]
    evicted_spans = []  # (slot, evict_round, readmit_round|rounds)
    open_ev = {}
    for r, s in evicts:
        open_ev[s] = r
    for r, s in readmits:
        if s in open_ev:
            evicted_spans.append((s, open_ev.pop(s), r))
    evicted_spans += [(s, r, rounds) for s, r in
                      ((s, r) for s, r in open_ev.items())]

    def dark_at(slot, r):
        return any(s == slot and a <= r < b for s, a, b in evicted_spans)

    eps = fail_episodes(session.schedule, rounds)
    delays, dark_onsets, missed = [], 0, 0
    for slot, onset, end in eps:
        if dark_at(slot, onset):
            dark_onsets += 1  # already out of the pool: nothing to detect
            continue
        hit = [r for r, s in flags if s == slot and onset <= r < end + 2]
        if hit:
            delays.append(hit[0] - onset)
        else:
            missed += 1
    return {
        "episodes": len(eps), "dark_onsets": dark_onsets,
        "missed": missed, "flag_delays": delays,
        "evictions": len(evicts), "readmissions": len(readmits),
        "final_live": int(session.num_active),
    }


def bench_control(scenarios=("iid", "burst", "correlated", "crash_restart",
                             "straggler"), seeds=(1, 2, 3), rounds=20):
    from repro.api import ElasticSession

    out = {"what": "control", "workers": 4, "tau": 4, "alpha": 0.5,
           "failure_prob": 0.12, "crash_downtime": 8, "rounds": rounds,
           "seeds": list(seeds), "scenarios": {}}
    for scenario in scenarios:
        rows = []
        for seed in seeds:
            sess_open = ElasticSession(control_spec(scenario, seed,
                                                    rounds=rounds))
            loss_open = final_eval(sess_open.run())

            sess_orc = ElasticSession(control_spec(scenario, seed,
                                                   rounds=rounds))
            orc = OracleController(sess_orc.schedule)
            sess_orc.add_observer(orc)
            loss_orc = final_eval(sess_orc.run())

            sess_cl = ElasticSession(control_spec(
                scenario, seed, rounds=rounds, controller="rules",
                blind=True))
            loss_cl = final_eval(sess_cl.run())
            met = closed_loop_metrics(sess_cl, rounds)
            met.update({
                "seed": seed, "loss_open": loss_open,
                "loss_oracle": loss_orc, "loss_closed": loss_cl,
                "deg_vs_oracle_pct": ((loss_cl - loss_orc)
                                      / abs(loss_orc) * 100
                                      if loss_orc else float("nan")),
                "oracle_actions": len(orc.log),
            })
            rows.append(met)
        mean_deg = float(np.mean([r["deg_vs_oracle_pct"] for r in rows]))
        all_delays = [d for r in rows for d in r["flag_delays"]]
        out["scenarios"][scenario] = {
            "runs": rows,
            "mean_deg_vs_oracle_pct": mean_deg,
            "max_flag_delay": (max(all_delays) if all_delays else None),
            "missed_total": sum(r["missed"] for r in rows),
        }
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(bench_control(), indent=1))
