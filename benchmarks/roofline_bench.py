"""Roofline benchmark: derive the three terms for every dry-run record
(results/dryrun.jsonl). Emits one row per (arch × shape × mesh)."""
import os


def bench():
    path = "results/dryrun.jsonl"
    if not os.path.exists(path):
        return [("roofline", 0.0, "no dryrun.jsonl — run "
                 "`python -m repro.launch.dryrun --arch all --shape all "
                 "--both-meshes --out results/dryrun.jsonl`")]
    from repro.analysis.roofline import load_records, roofline_from_record

    rows = []
    for rec in sorted(load_records(path),
                      key=lambda r: (r["arch"], r["shape"],
                                     r.get("multi_pod", False))):
        mesh = "2x16x16" if rec.get("multi_pod") else "16x16"
        name = f"roofline_{rec['arch']}_{rec['shape']}_{mesh}"
        if rec["status"] != "ok":
            rows.append((name, 0.0, rec["status"]))
            continue
        r = roofline_from_record(rec)
        rows.append((
            name, r.bound_s * 1e6,
            f"dom={r.dominant};compute={r.compute_s:.4f}s;"
            f"mem={r.memory_s:.4f}s;coll={r.collective_s:.4f}s;"
            f"model/hlo={r.flops_ratio:.2f}" if r.flops_ratio else
            f"dom={r.dominant}"))
    return rows
