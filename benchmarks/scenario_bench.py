"""Adversarial scenario-channel overhead (ISSUE-9, ``--what scenarios``).

What does it cost to *carry* the new schedule channels through the jitted
round? Three arms per worker count, one warmed-up session each:

- ``clean`` — no optional channels; the pre-ISSUE-9 trace (corrupt/speed
  are gated to None before RoundInputs, so this is also what any
  no-corruption scenario pays: nothing).
- ``byzantine`` — every round ships a (k,) corrupt mask and the local
  phase runs the masked sign-flip poison (`jnp.where` per gradient leaf)
  plus the score_clip quarantine pre-pass in comm.
- ``hetero`` — every round ships a (k,) speed row; the local phase
  composes the per-slot effective-τ live mask.

The interesting number is the ratio to clean: the corrupt mask costs one
select per gradient leaf, the speed row one compare per scan step — both
should be noise against the model compute. A regression here means the
None-specialization gate broke and the channels started reaching (or
worse, retracing) the jit unconditionally.
"""
import time


def bench_scenarios(rounds=6, ks=(4, 8)):
    from repro.api import ElasticSession, RunSpec
    from repro.configs.base import ElasticConfig, OptimizerConfig

    record = {"what": "scenarios", "arch": "paper-cnn", "tau": 2,
              "batch_size": 8, "rounds_timed": rounds, "workers": list(ks),
              "arms": {}}
    arms = {
        "clean": dict(failure_scenario="iid", failure_prob=0.2),
        "byzantine": dict(failure_scenario="byzantine",
                          byzantine_frac=0.5, score_clip=0.5),
        "hetero": dict(failure_scenario="hetero"),
    }
    for label, ekw in arms.items():
        per_k = {}
        for k in ks:
            spec = RunSpec(
                arch="paper-cnn",
                optimizer=OptimizerConfig(name="sgd", lr=0.01),
                elastic=ElasticConfig(num_workers=k, tau=2, **ekw),
                seed=1, batch_size=8, n_data=512, n_test=64,
                rounds=1 + rounds)
            sess = ElasticSession(spec)
            sess.run(1)  # compile outside the timed window
            t0 = time.perf_counter()
            sess.run(rounds)
            per_k[f"k{k}_ms_per_round"] = round(
                (time.perf_counter() - t0) / rounds * 1e3, 3)
            if label == "byzantine":
                assert sess.schedule.has_corruption, (
                    "byzantine arm drew no corrupt slots — overhead arm "
                    "would silently measure the clean path")
            if label == "hetero":
                assert sess.schedule.has_hetero
        record["arms"][label] = per_k
    for k in ks:
        key = f"k{k}_ms_per_round"
        clean = record["arms"]["clean"][key]
        record[f"byzantine_overhead_k{k}"] = round(
            record["arms"]["byzantine"][key] / clean, 3)
        record[f"hetero_overhead_k{k}"] = round(
            record["arms"]["hetero"][key] / clean, 3)
    return record
