"""Microbenchmarks for the paper's hot paths (CPU timings; the TPU story is
the roofline analysis in EXPERIMENTS.md §Roofline)."""
import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=20):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # µs


def bench_comm_modes(ks=(4, 8, 16, 32), n=1 << 14):
    """Sequential-scan vs fused-batched communication phase, sweeping the
    worker axis. Runs the real ``ElasticTrainer.comm_phase`` on a synthetic
    parameter tree (n floats/worker) — the sequential scan is k serially
    dependent score+update steps, so its time grows ~linearly in k, while
    fused is one batched scoring pass plus one batched update whose time
    should grow sublinearly in k."""
    from repro.configs.base import ElasticConfig, OptimizerConfig
    from repro.core.coordinator import ElasticTrainer

    rows, times = [], {}
    for k in ks:
        key = jax.random.key(k)
        state = {
            "workers": {"w": jax.random.normal(key, (k, n))},
            "master": {"w": jnp.zeros((n,))},
            "u_hist": jnp.full((k, 5), -1.0, jnp.float32),
            "round": jnp.zeros((), jnp.int32),
        }
        fail = jnp.zeros((k,), bool)
        for mode in ("sequential", "fused"):
            tr = ElasticTrainer(
                None, OptimizerConfig(name="sgd"),
                ElasticConfig(num_workers=k, comm_mode=mode))
            f = jax.jit(lambda s, t=tr, fl=fail: t.comm_phase(s, fl)[0])
            us = min(_time(f, state) for _ in range(3))  # CPU noise guard
            times[(mode, k)] = us
            rows.append((f"comm_phase_{mode}_k{k}", us, f"n={n}"))
    k0, k1 = ks[0], ks[-1]
    for mode in ("sequential", "fused"):
        growth = times[(mode, k1)] / times[(mode, k0)]
        rows.append((f"comm_phase_{mode}_growth_k{k0}to{k1}", growth,
                     f"{k1 // k0}x workers -> {growth:.2f}x time"))
    rows.append((f"comm_phase_fused_speedup_k{k1}",
                 times[("sequential", k1)] / times[("fused", k1)],
                 f"sequential/fused at k={k1}"))
    return rows


def bench():
    rows = []
    from repro.core.elastic import elastic_update
    from repro.kernels.elastic.ops import elastic_update_pallas

    tree = {"w": jax.random.normal(jax.random.key(0), (1024, 1024))}
    mtree = {"w": jax.random.normal(jax.random.key(1), (1024, 1024))}
    f_jnp = jax.jit(lambda w, m: elastic_update(w, m, 0.1, 0.1))
    us = _time(f_jnp, tree, mtree)
    rows.append(("elastic_update_jnp_1M", us, f"{8 * 2 ** 20 / us:.0f}B/us"))
    f_pal = lambda w, m: elastic_update_pallas(w, m, 0.1, 0.1)
    us = _time(f_pal, tree, mtree)
    rows.append(("elastic_update_pallas_interp_1M", us, "interpret-mode"))

    from repro.configs.base import OptimizerConfig
    from repro.kernels.adahessian.ref import adahessian_step_ref

    cfg = OptimizerConfig()
    n = 1 << 20
    args = [jax.random.normal(jax.random.key(i), (n,)) for i in range(4)]
    args.append(jnp.abs(jax.random.normal(jax.random.key(9), (n,))))
    f = jax.jit(lambda p, g, h, m, v: adahessian_step_ref(
        p, g, h, m, v, cfg, 3))
    us = _time(f, *args)
    rows.append(("adahessian_step_jnp_1M", us, ""))

    from repro.nn.flash import blockwise_attention, naive_attention

    B, S, H, D = 1, 1024, 4, 64
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    fb = jax.jit(lambda q, k, v: blockwise_attention(
        q, k, v, q_pos=pos, kv_pos=pos))
    us_b = _time(fb, q, k, v, iters=5)
    fn = jax.jit(lambda q, k, v: naive_attention(
        q, k, v, q_pos=pos, kv_pos=pos))
    us_n = _time(fn, q, k, v, iters=5)
    rows.append(("attn_blockwise_1k", us_b, f"naive={us_n:.0f}us"))

    from repro.nn.gla import gla_chunked, gla_ref

    B, T, Hh, N, P = 1, 512, 4, 32, 32
    ks = jax.random.split(jax.random.key(3), 4)
    q = jax.random.normal(ks[0], (B, T, Hh, N))
    k = jax.random.normal(ks[1], (B, T, Hh, N))
    v = jax.random.normal(ks[2], (B, T, Hh, P))
    lw = -jnp.abs(jax.random.normal(ks[3], (B, T, Hh))) * 0.1
    fc = jax.jit(lambda q, k, v, lw: gla_chunked(
        q, k, v, lw, chunk=64, scalar_decay=True)[0])
    us_c = _time(fc, q, k, v, lw, iters=5)
    fr = jax.jit(lambda q, k, v, lw: gla_ref(q, k, v, lw)[0])
    us_r = _time(fr, q, k, v, lw, iters=5)
    rows.append(("ssd_chunked_512", us_c, f"sequential={us_r:.0f}us "
                 f"speedup={us_r / us_c:.1f}x"))
    return rows
