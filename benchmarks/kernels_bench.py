"""Microbenchmarks for the paper's hot paths (CPU timings; the TPU story is
the roofline analysis in EXPERIMENTS.md §Roofline)."""
import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=20):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # µs


def bench_comm_modes(ks=(4, 8, 16, 32), n=1 << 14):
    """Sequential-scan vs fused-batched communication phase, sweeping the
    worker axis. Runs the real ``ElasticTrainer.comm_phase`` on a synthetic
    parameter tree (n floats/worker) — the sequential scan is k serially
    dependent score+update steps, so its time grows ~linearly in k, while
    fused is one batched scoring pass plus one batched update whose time
    should grow sublinearly in k."""
    from repro.configs.base import ElasticConfig, OptimizerConfig
    from repro.core.coordinator import ElasticTrainer

    rows, times = [], {}
    for k in ks:
        key = jax.random.key(k)
        state = {
            "workers": {"w": jax.random.normal(key, (k, n))},
            "master": {"w": jnp.zeros((n,))},
            "u_hist": jnp.full((k, 5), -1.0, jnp.float32),
            "round": jnp.zeros((), jnp.int32),
        }
        fail = jnp.zeros((k,), bool)
        for mode in ("sequential", "fused"):
            tr = ElasticTrainer(
                None, OptimizerConfig(name="sgd"),
                ElasticConfig(num_workers=k, comm_mode=mode))
            f = jax.jit(lambda s, t=tr, fl=fail: t.comm_phase(s, fl)[0])
            us = min(_time(f, state) for _ in range(3))  # CPU noise guard
            times[(mode, k)] = us
            rows.append((f"comm_phase_{mode}_k{k}", us, f"n={n}"))
    k0, k1 = ks[0], ks[-1]
    for mode in ("sequential", "fused"):
        growth = times[(mode, k1)] / times[(mode, k0)]
        rows.append((f"comm_phase_{mode}_growth_k{k0}to{k1}", growth,
                     f"{k1 // k0}x workers -> {growth:.2f}x time"))
    rows.append((f"comm_phase_fused_speedup_k{k1}",
                 times[("sequential", k1)] / times[("fused", k1)],
                 f"sequential/fused at k={k1}"))
    return rows


def bench_local(ks=(4, 8), tau=1, batch=8, iters=5, probes=3):
    """Local-phase wall time per round (ISSUE-7), paper CNN + AdaHessian.

    Three variants of ``ElasticTrainer.local_phase`` at each worker count:

    - ``plain`` — the per-worker ``value_and_grad`` + Hutchinson ``jvp`` +
      optimizer step, vmapped over workers (the pre-fusion path).
    - ``fused_jnp`` — the fused structure (``fused_local=True``): gradient
      and HVP share one ``jax.linearize`` and all k moment/parameter
      updates run as one batched jnp expression. This isolates the
      structural win; it is bit-exact with ``plain``.
    - ``fused_pallas_interp`` — the same structure through the batched
      Pallas kernel in interpret mode. On CPU the interpreter's per-op
      dispatch dominates at CNN scale, so this row records the honest
      interpret-mode *overhead* (the kernel targets TPU); the fused-path
      win on CPU is the ``fused_jnp`` row.

    ``probes`` is ``hutchinson_samples``. It defaults to 3 (multi-probe
    Hutchinson, §IV-B) because that is where the fusion is structural
    rather than CSE-able: the plain path's probe scan re-derives
    ``jvp(grad_fn)`` — a fresh linearization of the backward pass — in
    every scan iteration, while the fused path linearizes once and each
    probe only replays the tangent map. On CPU XLA hoists/merges the
    duplicated work well enough that the end-to-end rows time the same
    to within noise; they are recorded as the honest context for the
    update-step rows below, where the fusion win is unambiguous.

    The ``update_*`` rows isolate the optimizer-update step the batched
    kernel replaces, at 1M params/worker: ``update_perworker`` is k
    separate single-worker AdaHessian step dispatches — exactly what the
    orphaned per-worker Pallas entry point forced on a multi-worker
    trainer — and ``update_batched`` is the one-call batched path
    (``adahessian_update_batched``, one fused expression / one kernel
    launch per τ-step instead of k). Both jitted; measured win ~3.7x at
    k=4 and ~1.8x at k=8 on CPU.
    """
    from repro.configs.base import ElasticConfig, OptimizerConfig, get_config
    from repro.core.coordinator import ElasticTrainer
    from repro.models.registry import build_model

    model = build_model(get_config("paper_cnn"))
    record = {"what": "local", "arch": "paper-cnn", "tau": tau,
              "batch_size": batch, "iters": iters, "ks": list(ks),
              "hutchinson_samples": probes}
    ocfg = OptimizerConfig(name="adahessian", lr=1e-3,
                           hutchinson_samples=probes)
    for k in ks:
        ecfg = ElasticConfig(num_workers=k, tau=tau, comm_mode="fused")
        key = jax.random.key(k)
        batches = {
            "images": jax.random.normal(key, (tau, k, batch, 28, 28, 1),
                                        jnp.float32),
            "labels": jnp.zeros((tau, k, batch), jnp.int32),
        }
        rng = jax.random.key(1)
        variants = (("plain", {}), ("fused_jnp", {"fused_local": True}),
                    ("fused_pallas_interp", {"use_pallas": True}))
        for label, kw in variants:
            tr = ElasticTrainer(model, ocfg, ecfg, **kw)
            state = tr.init_state(jax.random.key(0))
            f = jax.jit(
                lambda s, b, r, t=tr: t.local_phase(s, b, r)[0]["workers"])
            if "pallas" in label:  # interpret mode: seconds/call, 1 probe
                us = _time(f, state, batches, rng, iters=2)
            else:  # CPU noise guard, as in bench_comm_modes
                us = min(_time(f, state, batches, rng, iters=iters)
                         for _ in range(3))
            record[f"k{k}_{label}_ms_per_round"] = round(us / 1e3, 3)
        record[f"k{k}_fused_speedup"] = round(
            record[f"k{k}_plain_ms_per_round"]
            / record[f"k{k}_fused_jnp_ms_per_round"], 3)

    from repro.kernels.adahessian.ops import adahessian_update_batched
    from repro.kernels.adahessian.ref import adahessian_step_ref

    n = 1 << 20
    record["update_params_per_worker"] = n
    for k in ks:
        keys = jax.random.split(jax.random.key(100 + k), 5)
        p, g, h, m = (jax.random.normal(ki, (k, n)) for ki in keys[:4])
        v = jnp.abs(jax.random.normal(keys[4], (k, n)))
        t = jnp.full((k,), 3, jnp.int32)
        step1 = jax.jit(
            lambda p, g, h, m, v, t: adahessian_step_ref(p, g, h, m, v,
                                                         ocfg, t))
        def perworker():  # k dispatches: the orphaned-kernel structure
            outs = [step1(p[i], g[i], h[i], m[i], v[i], t[i])
                    for i in range(k)]
            return outs[-1]
        tree = lambda x: {"w": x}
        opt = {"count": t - 1, "m": tree(m), "v": tree(v)}
        fb = jax.jit(lambda p, g, h, o: adahessian_update_batched(
            p, g, h, o, ocfg, use_kernel=False))
        def batched():
            return fb(tree(p), tree(g), tree(h), opt)
        ms_s = min(_time(perworker, iters=10) for _ in range(3)) / 1e3
        ms_b = min(_time(batched, iters=10) for _ in range(3)) / 1e3
        record[f"k{k}_update_perworker_ms"] = round(ms_s, 3)
        record[f"k{k}_update_batched_ms"] = round(ms_b, 3)
        record[f"k{k}_update_batched_speedup"] = round(ms_s / ms_b, 3)
    return record


def bench():
    rows = []
    from repro.core.elastic import elastic_update
    from repro.kernels.elastic.ops import elastic_update_pallas

    tree = {"w": jax.random.normal(jax.random.key(0), (1024, 1024))}
    mtree = {"w": jax.random.normal(jax.random.key(1), (1024, 1024))}
    f_jnp = jax.jit(lambda w, m: elastic_update(w, m, 0.1, 0.1))
    us = _time(f_jnp, tree, mtree)
    rows.append(("elastic_update_jnp_1M", us, f"{8 * 2 ** 20 / us:.0f}B/us"))
    f_pal = lambda w, m: elastic_update_pallas(w, m, 0.1, 0.1)
    us = _time(f_pal, tree, mtree)
    rows.append(("elastic_update_pallas_interp_1M", us, "interpret-mode"))

    from repro.configs.base import OptimizerConfig
    from repro.kernels.adahessian.ref import adahessian_step_ref

    cfg = OptimizerConfig()
    n = 1 << 20
    args = [jax.random.normal(jax.random.key(i), (n,)) for i in range(4)]
    args.append(jnp.abs(jax.random.normal(jax.random.key(9), (n,))))
    f = jax.jit(lambda p, g, h, m, v: adahessian_step_ref(
        p, g, h, m, v, cfg, 3))
    us = _time(f, *args)
    rows.append(("adahessian_step_jnp_1M", us, ""))

    from repro.nn.flash import blockwise_attention, naive_attention

    B, S, H, D = 1, 1024, 4, 64
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    fb = jax.jit(lambda q, k, v: blockwise_attention(
        q, k, v, q_pos=pos, kv_pos=pos))
    us_b = _time(fb, q, k, v, iters=5)
    fn = jax.jit(lambda q, k, v: naive_attention(
        q, k, v, q_pos=pos, kv_pos=pos))
    us_n = _time(fn, q, k, v, iters=5)
    rows.append(("attn_blockwise_1k", us_b, f"naive={us_n:.0f}us"))

    from repro.nn.gla import gla_chunked, gla_ref

    B, T, Hh, N, P = 1, 512, 4, 32, 32
    ks = jax.random.split(jax.random.key(3), 4)
    q = jax.random.normal(ks[0], (B, T, Hh, N))
    k = jax.random.normal(ks[1], (B, T, Hh, N))
    v = jax.random.normal(ks[2], (B, T, Hh, P))
    lw = -jnp.abs(jax.random.normal(ks[3], (B, T, Hh))) * 0.1
    fc = jax.jit(lambda q, k, v, lw: gla_chunked(
        q, k, v, lw, chunk=64, scalar_decay=True)[0])
    us_c = _time(fc, q, k, v, lw, iters=5)
    fr = jax.jit(lambda q, k, v, lw: gla_ref(q, k, v, lw)[0])
    us_r = _time(fr, q, k, v, lw, iters=5)
    rows.append(("ssd_chunked_512", us_c, f"sequential={us_r:.0f}us "
                 f"speedup={us_r / us_c:.1f}x"))
    return rows
