"""Session execution benchmarks: chunking axis and placement axis.

Times the *whole driver path* — host batching, mask slicing, jit dispatch,
device compute — through ``ElasticSession`` on the paper CNN at a size
where per-round Python/dispatch overhead is a visible fraction of the
round. Compilation is excluded by warming each session up over its first
chunk(s) before the timed window; each setting reuses one session (the jit
cache keys on the trainer instance, so a fresh session would recompile).

Three axes:

- ``bench_session()`` — ``rounds_per_call=1`` vs jit-scanned chunks
  (``--what session``).
- ``bench_session_placement()`` — single vs sharded placement at
  k ∈ {4, 8} (``--what placement``). Run it under a forced multi-device
  host (``XLA_FLAGS=--xla_force_host_platform_device_count=4``, as the CI
  step does) to actually spread the worker shards; on one device the
  sharded numbers measure pure shard_map overhead. Emulated CPU devices
  share the same cores, so this records dispatch/collective overhead, not
  a hardware speedup.
- ``bench_session_membership()`` — the price of capacity padding
  (``--what membership``): per-round time with k live workers in an
  exact-fit pool (capacity == k, the masking-free fixed-k trace) vs the
  same k live workers rattling around capacity ∈ {8, 16} padded pools
  (vacant slots are computed-then-masked in the local phase, frozen in
  comm). The overhead ratio is what a deployment pays for being able to
  scale up to capacity with zero recompiles.

Each returns a JSON-able record; ``bench()`` adapts the chunking record to
the CSV section format of the main harness.
"""
import time


def bench_session(rounds=8, chunk=4, warmup_rounds=None):
    from repro.api import ElasticSession, RunSpec
    from repro.configs.base import ElasticConfig, OptimizerConfig

    base = RunSpec(
        arch="paper-cnn",
        optimizer=OptimizerConfig(name="sgd", lr=0.01),
        elastic=ElasticConfig(num_workers=4, tau=1, dynamic=True),
        seed=0, batch_size=8, n_data=512, n_test=64)
    record = {"what": "session", "arch": base.arch,
              "workers": base.elastic.num_workers, "tau": base.elastic.tau,
              "batch_size": base.batch_size, "rounds_timed": rounds,
              "chunk": chunk}
    for label, rpc in (("per_round", 1), ("chunked", chunk)):
        warm = warmup_rounds or rpc
        sess = ElasticSession(base.replace(rounds_per_call=rpc,
                                           rounds=warm + rounds))
        sess.run(warm)  # compile + first-touch outside the timed window
        t0 = time.perf_counter()
        sess.run(rounds)
        ms = (time.perf_counter() - t0) / rounds * 1e3
        record[f"{label}_ms_per_round"] = round(ms, 3)
    record["speedup"] = round(record["per_round_ms_per_round"]
                              / record["chunked_ms_per_round"], 3)
    return record


def bench_session_placement(rounds=6, ks=(4, 8)):
    """Single vs sharded per-round wall time at each worker count.

    One session per (k, placement). Sharded runs on an explicit host mesh
    with pod = gcd(k, device_count) — the widest pod axis that divides k —
    so the benchmark works on any device count instead of crashing when it
    doesn't divide every k; the pod size used is recorded per k.
    """
    import math

    import jax

    from repro.api import ElasticSession, RunSpec
    from repro.configs.base import ElasticConfig, OptimizerConfig
    from repro.launch.mesh import make_host_mesh

    record = {"what": "session_placement", "arch": "paper-cnn",
              "devices": jax.device_count(), "tau": 1, "batch_size": 8,
              "rounds_timed": rounds, "workers": list(ks)}
    for k in ks:
        pod = math.gcd(k, jax.device_count())
        record[f"k{k}_pod"] = pod
        for placement in ("single", "sharded"):
            spec = RunSpec(
                arch="paper-cnn",
                optimizer=OptimizerConfig(name="sgd", lr=0.01),
                elastic=ElasticConfig(num_workers=k, tau=1, dynamic=True,
                                      comm_mode="fused",
                                      placement=placement),
                rounds=1 + rounds, seed=0, batch_size=8,
                n_data=512, n_test=64)
            mesh = (make_host_mesh(pod=pod) if placement == "sharded"
                    else None)
            sess = ElasticSession(spec, mesh=mesh)
            sess.run(1)  # compile + first-touch outside the timed window
            t0 = time.perf_counter()
            sess.run(rounds)
            ms = (time.perf_counter() - t0) / rounds * 1e3
            record[f"k{k}_{placement}_ms_per_round"] = round(ms, 3)
        record[f"k{k}_single_over_sharded"] = round(
            record[f"k{k}_single_ms_per_round"]
            / record[f"k{k}_sharded_ms_per_round"], 3)
    return record


def bench_session_membership(rounds=6, ks=(4, 8), capacities=(8, 16)):
    """Capacity-padding overhead: k live workers at capacity == k (exact
    fit, no masking) vs the same k live workers in a padded pool.

    One session per (k, capacity); capacities < k are skipped. The padded
    sessions run the static membership scenario — the mask stream exists,
    so this times the *whole* membership tax: mask slicing on the host,
    select/freeze ops in the graph, and the dead compute of vacant slots.
    """
    from repro.api import ElasticSession, RunSpec
    from repro.configs.base import ElasticConfig, OptimizerConfig

    record = {"what": "session_membership", "arch": "paper-cnn", "tau": 1,
              "batch_size": 8, "rounds_timed": rounds, "workers": list(ks),
              "capacities": list(capacities)}
    for k in ks:
        for cap in (k,) + tuple(c for c in capacities if c > k):
            spec = RunSpec(
                arch="paper-cnn",
                optimizer=OptimizerConfig(name="sgd", lr=0.01),
                elastic=ElasticConfig(num_workers=k,
                                      capacity=0 if cap == k else cap,
                                      tau=1, dynamic=True),
                rounds=1 + rounds, seed=0, batch_size=8,
                n_data=512, n_test=64)
            sess = ElasticSession(spec)
            sess.run(1)  # compile + first-touch outside the timed window
            t0 = time.perf_counter()
            sess.run(rounds)
            ms = (time.perf_counter() - t0) / rounds * 1e3
            label = "exact" if cap == k else f"cap{cap}"
            record[f"k{k}_{label}_ms_per_round"] = round(ms, 3)
            if cap != k:
                record[f"k{k}_cap{cap}_overhead"] = round(
                    ms / record[f"k{k}_exact_ms_per_round"], 3)
    return record


def bench():
    """CSV-section adapter for benchmarks/run.py."""
    r = bench_session()
    return [
        ("session_per_round", r["per_round_ms_per_round"] * 1e3,
         "ms_per_round*1e3=us"),
        (f"session_chunked_R{r['chunk']}",
         r["chunked_ms_per_round"] * 1e3, "ms_per_round*1e3=us"),
        ("session_chunk_speedup", r["speedup"],
         f"per_round/chunked at R={r['chunk']}"),
    ]
