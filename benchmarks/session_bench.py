"""Session execution benchmark: per-round dispatch vs jit-scanned chunks.

Times the *whole driver path* — host batching, mask slicing, jit dispatch,
device compute — through ``ElasticSession`` at ``rounds_per_call=1`` vs a
chunked setting, on the paper CNN at a size where per-round Python/dispatch
overhead is a visible fraction of the round. Compilation is excluded by
warming each session up over its first chunk(s) before the timed window;
both settings reuse one session (the jit cache keys on the trainer
instance, so a fresh session would recompile).

``bench_session()`` returns the JSON-able record consumed by
``benchmarks/run.py --what session``; ``bench()`` adapts it to the CSV
section format of the main harness.
"""
import time


def bench_session(rounds=8, chunk=4, warmup_rounds=None):
    from repro.api import ElasticSession, RunSpec
    from repro.configs.base import ElasticConfig, OptimizerConfig

    base = RunSpec(
        arch="paper-cnn",
        optimizer=OptimizerConfig(name="sgd", lr=0.01),
        elastic=ElasticConfig(num_workers=4, tau=1, dynamic=True),
        seed=0, batch_size=8, n_data=512, n_test=64)
    record = {"what": "session", "arch": base.arch,
              "workers": base.elastic.num_workers, "tau": base.elastic.tau,
              "batch_size": base.batch_size, "rounds_timed": rounds,
              "chunk": chunk}
    for label, rpc in (("per_round", 1), ("chunked", chunk)):
        warm = warmup_rounds or rpc
        sess = ElasticSession(base.replace(rounds_per_call=rpc,
                                           rounds=warm + rounds))
        sess.run(warm)  # compile + first-touch outside the timed window
        t0 = time.perf_counter()
        sess.run(rounds)
        ms = (time.perf_counter() - t0) / rounds * 1e3
        record[f"{label}_ms_per_round"] = round(ms, 3)
    record["speedup"] = round(record["per_round_ms_per_round"]
                              / record["chunked_ms_per_round"], 3)
    return record


def bench():
    """CSV-section adapter for benchmarks/run.py."""
    r = bench_session()
    return [
        ("session_per_round", r["per_round_ms_per_round"] * 1e3,
         "ms_per_round*1e3=us"),
        (f"session_chunked_R{r['chunk']}",
         r["chunked_ms_per_round"] * 1e3, "ms_per_round*1e3=us"),
        ("session_chunk_speedup", r["speedup"],
         f"per_round/chunked at R={r['chunk']}"),
    ]
