"""Session execution benchmarks: chunking axis and placement axis.

Times the *whole driver path* — host batching, mask slicing, jit dispatch,
device compute — through ``ElasticSession`` on the paper CNN at a size
where per-round Python/dispatch overhead is a visible fraction of the
round. Compilation is excluded by warming each session up over its first
chunk(s) before the timed window; each setting reuses one session (the jit
cache keys on the trainer instance, so a fresh session would recompile).

Three axes:

- ``bench_session()`` — ``rounds_per_call=1`` vs jit-scanned chunks
  (``--what session``).
- ``bench_session_placement()`` — single vs sharded placement at
  k ∈ {4, 8} (``--what placement``). Run it under a forced multi-device
  host (``XLA_FLAGS=--xla_force_host_platform_device_count=4``, as the CI
  step does) to actually spread the worker shards; on one device the
  sharded numbers measure pure shard_map overhead. Emulated CPU devices
  share the same cores, so this records dispatch/collective overhead, not
  a hardware speedup.
- ``bench_session_membership()`` — the price of capacity padding
  (``--what membership``): per-round time with k live workers in an
  exact-fit pool (capacity == k, the masking-free fixed-k trace) vs the
  same k live workers rattling around capacity ∈ {8, 16} padded pools
  (vacant slots are computed-then-masked in the local phase, frozen in
  comm). The overhead ratio is what a deployment pays for being able to
  scale up to capacity with zero recompiles.

Each returns a JSON-able record; ``bench()`` adapts the chunking record to
the CSV section format of the main harness.
"""
import time


def bench_session(rounds=8, chunk=4, warmup_rounds=None):
    from repro.api import ElasticSession, RunSpec
    from repro.configs.base import ElasticConfig, OptimizerConfig

    base = RunSpec(
        arch="paper-cnn",
        optimizer=OptimizerConfig(name="sgd", lr=0.01),
        elastic=ElasticConfig(num_workers=4, tau=1, dynamic=True),
        seed=0, batch_size=8, n_data=512, n_test=64)
    record = {"what": "session", "arch": base.arch,
              "workers": base.elastic.num_workers, "tau": base.elastic.tau,
              "batch_size": base.batch_size, "rounds_timed": rounds,
              "chunk": chunk}
    for label, rpc in (("per_round", 1), ("chunked", chunk)):
        warm = warmup_rounds or rpc
        sess = ElasticSession(base.replace(rounds_per_call=rpc,
                                           rounds=warm + rounds))
        sess.run(warm)  # compile + first-touch outside the timed window
        t0 = time.perf_counter()
        sess.run(rounds)
        ms = (time.perf_counter() - t0) / rounds * 1e3
        record[f"{label}_ms_per_round"] = round(ms, 3)
    record["speedup"] = round(record["per_round_ms_per_round"]
                              / record["chunked_ms_per_round"], 3)
    return record


def bench_session_placement(rounds=6, ks=(4, 8)):
    """Single vs sharded per-round wall time at each worker count.

    One session per (k, placement). Sharded runs on an explicit host mesh
    with pod = gcd(k, device_count) — the widest pod axis that divides k —
    so the benchmark works on any device count instead of crashing when it
    doesn't divide every k; the pod size used is recorded per k.
    """
    import math

    import jax

    from repro.api import ElasticSession, RunSpec
    from repro.configs.base import ElasticConfig, OptimizerConfig
    from repro.launch.mesh import make_host_mesh

    record = {"what": "session_placement", "arch": "paper-cnn",
              "devices": jax.device_count(), "tau": 1, "batch_size": 8,
              "rounds_timed": rounds, "workers": list(ks)}
    for k in ks:
        pod = math.gcd(k, jax.device_count())
        record[f"k{k}_pod"] = pod
        for placement in ("single", "sharded"):
            spec = RunSpec(
                arch="paper-cnn",
                optimizer=OptimizerConfig(name="sgd", lr=0.01),
                elastic=ElasticConfig(num_workers=k, tau=1, dynamic=True,
                                      comm_mode="fused",
                                      placement=placement),
                rounds=1 + rounds, seed=0, batch_size=8,
                n_data=512, n_test=64)
            mesh = (make_host_mesh(pod=pod) if placement == "sharded"
                    else None)
            sess = ElasticSession(spec, mesh=mesh)
            sess.run(1)  # compile + first-touch outside the timed window
            t0 = time.perf_counter()
            sess.run(rounds)
            ms = (time.perf_counter() - t0) / rounds * 1e3
            record[f"k{k}_{placement}_ms_per_round"] = round(ms, 3)
        record[f"k{k}_single_over_sharded"] = round(
            record[f"k{k}_single_ms_per_round"]
            / record[f"k{k}_sharded_ms_per_round"], 3)
    return record


def bench_session_membership(rounds=6, ks=(4, 8), capacities=(8, 16)):
    """Capacity-padding overhead: k live workers at capacity == k (exact
    fit, no masking) vs the same k live workers in a padded pool.

    One session per (k, capacity); capacities < k are skipped. The padded
    sessions run the static membership scenario — the mask stream exists,
    so this times the *whole* membership tax: mask slicing on the host,
    select/freeze ops in the graph, and the dead compute of vacant slots.
    """
    from repro.api import ElasticSession, RunSpec
    from repro.configs.base import ElasticConfig, OptimizerConfig

    record = {"what": "session_membership", "arch": "paper-cnn", "tau": 1,
              "batch_size": 8, "rounds_timed": rounds, "workers": list(ks),
              "capacities": list(capacities)}
    for k in ks:
        for cap in (k,) + tuple(c for c in capacities if c > k):
            spec = RunSpec(
                arch="paper-cnn",
                optimizer=OptimizerConfig(name="sgd", lr=0.01),
                elastic=ElasticConfig(num_workers=k,
                                      capacity=0 if cap == k else cap,
                                      tau=1, dynamic=True),
                rounds=1 + rounds, seed=0, batch_size=8,
                n_data=512, n_test=64)
            sess = ElasticSession(spec)
            sess.run(1)  # compile + first-touch outside the timed window
            t0 = time.perf_counter()
            sess.run(rounds)
            ms = (time.perf_counter() - t0) / rounds * 1e3
            label = "exact" if cap == k else f"cap{cap}"
            record[f"k{k}_{label}_ms_per_round"] = round(ms, 3)
            if cap != k:
                record[f"k{k}_cap{cap}_overhead"] = round(
                    ms / record[f"k{k}_exact_ms_per_round"], 3)
    return record


def bench_hierarchy(ks=(16, 32, 64), gps=(1, 2, 4), rack=4,
                    comm_rounds=12, e2e_rounds=6, e2e_k=16):
    """Hierarchical vs flat communication cost (ISSUE-10).

    Comm-only axis: times the jitted communication phase alone (no local
    phase — the hierarchy changes nothing there) at k slots, flat fused
    vs hierarchical with k/``rack`` rack groups at each global period in
    ``gps``. Per-round comm time drops as the sub-master ↔ master syncs
    amortize: gp=1 pays the rack reduction *plus* a full global scoring +
    reduction every round, while gp=4 touches the global master once per
    4 rounds (``lax.cond`` skips the whole global phase off-cycle).
    Global sync rounds are counted from the ``g_h2`` diagnostics and must
    come out to timed_rounds / gp — the "global-comm rounds reduced by
    global_period×" evidence. ``k*_gp*_global_bytes_per_round`` makes the
    same point in link traffic: what a deployment's cross-rack fabric
    carries per round (2 · G · params · 4 bytes per sync — every
    sub-master pulls the master distance and pushes its weighted diff),
    which falls exactly global_period× and is the cost the wall-clock
    numbers can only approximate on a single shared-memory host.

    End-to-end axis: whole-session ms/round at ``e2e_k`` workers on the
    host mesh (sharded over pod = gcd(k, device_count) when the host has
    multiple — typically forced — devices, single otherwise), flat fused
    vs hierarchical at the largest period, at both τ=1 (every round pays
    comm) and the paper-style τ=4. The hierarchy must not cost end-to-end
    round time (``e2e_tau*_hier_over_flat`` ≈ ≤ 1).
    """
    import math

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.api import ElasticSession, RunSpec
    from repro.configs.base import ElasticConfig, OptimizerConfig, get_config
    from repro.core.coordinator import ElasticTrainer
    from repro.models.registry import build_model

    model = build_model(get_config("paper_cnn"))
    opt = OptimizerConfig(name="sgd", lr=0.01)
    from repro.nn.param import init_tree
    n_params = sum(int(np.prod(p.shape)) for p in
                   jax.tree.leaves(init_tree(jax.random.key(0), model.spec)))
    record = {"what": "hierarchy", "arch": "paper-cnn",
              "devices": jax.device_count(), "rack": rack,
              "master_params": n_params,
              "global_periods": list(gps), "workers": list(ks),
              "comm_rounds_timed": comm_rounds, "e2e_rounds_timed": e2e_rounds}

    def time_comm(ecfg):
        tr = ElasticTrainer(model, opt, ecfg)
        state = tr.init_state(jax.random.key(0))
        # Desync the workers so scoring sees a realistic u spread.
        state["workers"] = jax.tree.map(
            lambda x: x + 0.01 * jax.random.normal(
                jax.random.key(1), x.shape, x.dtype), state["workers"])
        fail = jnp.zeros((ecfg.cap,), bool)
        comm = jax.jit(lambda s: tr.comm_phase(s, fail))
        state, m = comm(state)  # compile (cond traces both branches)
        jax.block_until_ready(state["master"])
        # two timed reps, keep the min — CPU wall clock is noisy at this
        # scale; sync rounds are counted once over the first rep via the
        # g_u diagnostics (zeroed by the lax.cond skip branch; a genuine
        # sync always records log-distances, which are never exactly 0)
        best_ms, g_us = None, []
        for rep in range(2):
            collected = []  # device arrays; counted after the timed window
            t0 = time.perf_counter()
            for _ in range(comm_rounds):
                state, m = comm(state)
                if "g_u" in m:
                    collected.append(m["g_u"])
            jax.block_until_ready(state["master"])
            ms = (time.perf_counter() - t0) / comm_rounds * 1e3
            best_ms = ms if best_ms is None else min(best_ms, ms)
            if rep == 0:
                g_us = collected
        syncs = (sum(int(np.any(np.asarray(g) != 0.0)) for g in g_us)
                 if g_us else comm_rounds)
        return round(best_ms, 3), syncs

    for k in ks:
        groups = max(1, k // rack)
        record[f"k{k}_groups"] = groups
        flat = ElasticConfig(num_workers=k, tau=1, dynamic=True,
                             comm_mode="fused")
        ms, syncs = time_comm(flat)
        record[f"k{k}_flat_comm_ms"] = ms
        record[f"k{k}_flat_global_syncs"] = syncs
        # Flat: every worker talks to the global master every round.
        record[f"k{k}_flat_global_bytes_per_round"] = 2 * k * n_params * 4
        for gp in gps:
            hier = ElasticConfig(num_workers=k, tau=1, dynamic=True,
                                 comm_mode="fused", groups=groups,
                                 global_period=gp)
            ms, syncs = time_comm(hier)
            record[f"k{k}_g{groups}_gp{gp}_comm_ms"] = ms
            record[f"k{k}_g{groups}_gp{gp}_global_syncs"] = syncs
            record[f"k{k}_g{groups}_gp{gp}_global_bytes_per_round"] = (
                2 * groups * n_params * 4 * syncs // comm_rounds)
        # Amortization evidence: every-round global sync vs the longest
        # period, within the same hierarchical topology.
        record[f"k{k}_gp{max(gps)}_over_gp{min(gps)}"] = round(
            record[f"k{k}_g{groups}_gp{max(gps)}_comm_ms"]
            / record[f"k{k}_g{groups}_gp{min(gps)}_comm_ms"], 3)

    pod = math.gcd(e2e_k, jax.device_count())
    placement = "sharded" if jax.device_count() > 1 else "single"
    e2e_groups = max(1, e2e_k // rack)
    record["e2e_k"] = e2e_k
    record["e2e_placement"] = placement
    record["e2e_pod"] = pod
    record["e2e_groups"] = e2e_groups
    for tau in (1, 4):
        for label, (g, gp) in (("flat", (1, 1)),
                               ("hier", (e2e_groups, max(gps)))):
            spec = RunSpec(
                arch="paper-cnn", optimizer=opt,
                elastic=ElasticConfig(num_workers=e2e_k, tau=tau,
                                      dynamic=True, comm_mode="fused",
                                      placement=placement,
                                      groups=g, global_period=gp),
                rounds=1 + 2 * e2e_rounds, seed=0, batch_size=8,
                n_data=512, n_test=64)
            mesh = None
            if placement == "sharded":
                from repro.launch.mesh import make_host_mesh
                mesh = make_host_mesh(pod=pod)
            sess = ElasticSession(spec, mesh=mesh)
            sess.run(1)  # compile + first-touch outside the timed window
            ms = None  # two timed reps, keep the min (see time_comm)
            for _ in range(2):
                t0 = time.perf_counter()
                sess.run(e2e_rounds)
                rep = (time.perf_counter() - t0) / e2e_rounds * 1e3
                ms = rep if ms is None else min(ms, rep)
            record[f"e2e_tau{tau}_{label}_ms_per_round"] = round(ms, 3)
        record[f"e2e_tau{tau}_hier_over_flat"] = round(
            record[f"e2e_tau{tau}_hier_ms_per_round"]
            / record[f"e2e_tau{tau}_flat_ms_per_round"], 3)
    return record


def bench():
    """CSV-section adapter for benchmarks/run.py."""
    r = bench_session()
    return [
        ("session_per_round", r["per_round_ms_per_round"] * 1e3,
         "ms_per_round*1e3=us"),
        (f"session_chunked_R{r['chunk']}",
         r["chunked_ms_per_round"] * 1e3, "ms_per_round*1e3=us"),
        ("session_chunk_speedup", r["speedup"],
         f"per_round/chunked at R={r['chunk']}"),
    ]
