"""Paper figure/table benchmarks: read the reproduction artifacts
(results/paper_repro/*.json, produced by repro.experiments.grid) and emit
one row per figure. If artifacts are missing, run a single fast in-process
mini version so `python -m benchmarks.run` is always self-contained."""
import glob
import json
import os
import time

RESULTS = "results/paper_repro"


def _rows_from(files, tag):
    rows = []
    for path in sorted(files):
        with open(path) as f:
            r = json.load(f)
        name = (f"{tag}_{r['method']}_k{r['k']}_tau{r['tau']}"
                if tag == "fig45" else f"{tag}_r{r['overlap_ratio']}")
        us = r["wall_s"] * 1e6 / max(1, r["rounds"])
        rows.append((name, us, f"final_acc={r['final_acc']:.3f}"))
    return rows


def bench_fig3():
    files = glob.glob(f"{RESULTS}/fig3_*.json")
    if files:
        return _rows_from(files, "fig3")
    return _mini("EAHES-O", overlap=0.25, tag="fig3_mini")


def bench_fig45():
    files = glob.glob(f"{RESULTS}/fig45_*.json")
    if files:
        return _rows_from(files, "fig45")
    rows = []
    for m in ("EASGD", "DEAHES-O"):
        rows += _mini(m, tag=f"fig45_mini_{m}")
    return rows


def _mini(method, overlap=None, tag="mini"):
    from repro.experiments.paper_repro import run_one

    t0 = time.time()
    r = run_one(method, 2, 1, rounds=4, n_data=1000, n_test=200,
                overlap_ratio=overlap)
    us = (time.time() - t0) * 1e6 / 4
    return [(tag, us, f"final_acc={r['final_acc']:.3f}")]
