"""Serving benchmark: continuous vs static batching under bursty traffic.

Both modes serve the *same* synthetic MMPP trace (``repro.serving.traffic``,
fixed seed) through the same pooled engine and jitted kernels, so the
record isolates the **scheduling policy**:

- ``continuous`` — ``Scheduler`` over ``ContinuousEngine``: requests join
  vacant slots the tick they arrive and leave the tick they finish.
- ``static`` — gang scheduling on the identical engine: requests are
  grouped FIFO into batches of ``capacity``, a batch starts only after
  its last member has arrived *and* the previous batch fully drained, and
  nothing joins mid-flight. This is the head-of-line behaviour of the
  classic static batch (``ServeEngine``) expressed on the pooled kernels
  (per-request tokens are bitwise identical either way — the parity tests
  prove it — so any latency/throughput delta is pure scheduling).

Time is virtual (the clock advances by measured wall durations of engine
calls; arrivals are trace timestamps), so the comparison is deterministic
in structure and does not sleep. Jit warmup happens on a throwaway
request before either timed replay.

Emitted by ``benchmarks/run.py --what serving`` as one JSON record with
sustained req/s and p50/p99 request latency per mode.
"""
import time

import numpy as np


def _percentiles(latencies):
    lat = np.asarray(latencies, float)
    return (round(float(np.percentile(lat, 50)) * 1e3, 3),
            round(float(np.percentile(lat, 99)) * 1e3, 3))


def _run_static_gang(engine, trace):
    """Replay the trace with gang scheduling on the pooled engine."""
    results = []  # (arrival, finished_at, num_tokens)
    vnow = 0.0
    i = 0
    while i < len(trace):
        batch = trace[i:i + engine.capacity]
        i += len(batch)
        vnow = max(vnow, batch[-1].arrival)  # wait for the full gang
        for req in batch:
            t0 = time.perf_counter()
            engine.admit(req.prompt, max_new=req.max_new,
                         eos_id=req.eos_id, rid=req.rid)
            vnow += time.perf_counter() - t0
        done = list(engine.drain_finished())
        while engine.num_active:
            t0 = time.perf_counter()
            finished = engine.step()
            vnow += time.perf_counter() - t0
            done.extend(finished)
        by_rid = {r.rid: r for r in trace}
        results.extend((by_rid[f.rid].arrival, vnow, f.num_tokens)
                       for f in done)
    return results, vnow


def bench_serving(num_requests=24, capacity=4, prompt_lens=(4, 8),
                  max_new=12, arch="qwen3-4b"):
    import jax

    from repro.configs.base import get_config
    from repro.models.registry import build_model
    from repro.nn.param import init_tree
    from repro.serving.continuous import ContinuousEngine
    from repro.serving.scheduler import Scheduler
    from repro.serving.traffic import TrafficConfig, synthetic_traffic

    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = init_tree(jax.random.key(0), model.spec)
    max_len = max(prompt_lens) + max_new + 1
    trace = synthetic_traffic(TrafficConfig(
        num_requests=num_requests, rate=8.0, burst_factor=8.0,
        prompt_lens=prompt_lens, max_new=max_new,
        vocab_size=cfg.vocab_size, seed=0))
    record = {"what": "serving", "arch": cfg.name,
              "num_requests": num_requests, "capacity": capacity,
              "prompt_lens": list(prompt_lens), "max_new": max_new,
              "traffic": "mmpp rate=8 burst=8x seed=0"}

    def fresh_engine():
        eng = ContinuousEngine(model, params, capacity=capacity,
                               max_len=max_len,
                               prefill_len=max(prompt_lens))
        # jit warmup outside both timed replays
        eng.admit(trace[0].prompt, max_new=2)
        eng.step()
        eng.step()
        eng.drain_finished()
        return eng

    sched = Scheduler(fresh_engine())
    results = sched.run(trace)
    toks = sum(r.num_tokens for r in results)
    p50, p99 = _percentiles([r.latency for r in results])
    record["continuous"] = {
        "req_per_s": round(len(results) / sched.vnow, 3),
        "tok_per_s": round(toks / sched.vnow, 1),
        "latency_p50_ms": p50, "latency_p99_ms": p99,
        "span_s": round(sched.vnow, 3)}

    static_res, span = _run_static_gang(fresh_engine(), trace)
    toks = sum(n for _, _, n in static_res)
    p50, p99 = _percentiles([f - a for a, f, _ in static_res])
    record["static"] = {
        "req_per_s": round(len(static_res) / span, 3),
        "tok_per_s": round(toks / span, 1),
        "latency_p50_ms": p50, "latency_p99_ms": p99,
        "span_s": round(span, 3)}

    record["continuous_over_static_req_per_s"] = round(
        record["continuous"]["req_per_s"] / record["static"]["req_per_s"],
        3)
    record["static_over_continuous_p99"] = round(
        record["static"]["latency_p99_ms"]
        / max(record["continuous"]["latency_p99_ms"], 1e-9), 3)
    return record
