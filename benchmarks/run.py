"""Benchmark harness — one section per paper table/figure plus the roofline,
kernel microbenches and the session-API driver benchmarks. Prints
``name,us_per_call,derived`` CSV; ``--what session`` instead emits a single
JSON record comparing per-round vs jit-chunked session wall time, and
``--what placement`` a JSON record comparing single vs sharded placement
per-round time at k ∈ {4, 8} (force a multi-device host with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the worker
shards actually spread), and ``--what membership`` a JSON record measuring
the capacity-padding overhead of the elastic worker pool (k ∈ {4, 8} live
workers at capacity ∈ {8, 16} vs an exact-fit pool), and ``--what
control`` a JSON record scoring the detector-blind closed-loop controller
against an oracle-scheduled controller and the open loop across the
failure scenarios (recovery delay, evictions/readmissions, master-loss
degradation), and ``--what serving`` a JSON record comparing continuous
(in-flight) vs static gang batching on the same bursty MMPP trace
(sustained req/s, p50/p99 request latency — ISSUE-8), and ``--what
local`` a JSON record comparing the plain
vmapped local phase against the fused local phase (ISSUE-7: shared
gradient/HVP linearization + batched multi-worker AdaHessian update) at
k ∈ {4, 8} — the jnp-fused row is the CPU win, the interpret-mode Pallas
row records that path's (expected, large) CPU overhead, and ``--what
scenarios`` a JSON record measuring what the ISSUE-9 adversarial schedule
channels cost per round (masked sign-flip corruption + score_clip
quarantine, per-slot speed masks) against the channel-free clean trace at
k ∈ {4, 8}, and ``--what hierarchy`` a JSON record comparing flat fused
vs two-level hierarchical communication (ISSUE-10) at k ∈ {16, 32, 64} —
per-round comm time drops as global sub-master↔master syncs amortize over
``global_period``, with a global-sync-count check and an end-to-end k=16
no-worse-than-flat session comparison."""
import argparse
import json


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--what", default="all",
                    choices=["all", "kernels", "comm_modes", "local",
                             "paper", "roofline", "session", "placement",
                             "membership", "control", "serving",
                             "scenarios", "hierarchy"])
    args = ap.parse_args(argv)

    if args.what == "local":
        from benchmarks import kernels_bench

        print(json.dumps(kernels_bench.bench_local()))
        return

    if args.what == "session":
        from benchmarks import session_bench

        print(json.dumps(session_bench.bench_session()))
        return

    if args.what == "placement":
        from benchmarks import session_bench

        print(json.dumps(session_bench.bench_session_placement()))
        return

    if args.what == "membership":
        from benchmarks import session_bench

        print(json.dumps(session_bench.bench_session_membership()))
        return

    if args.what == "control":
        from benchmarks import control_bench

        print(json.dumps(control_bench.bench_control()))
        return

    if args.what == "serving":
        from benchmarks import serving_bench

        print(json.dumps(serving_bench.bench_serving()))
        return

    if args.what == "scenarios":
        from benchmarks import scenario_bench

        print(json.dumps(scenario_bench.bench_scenarios()))
        return

    if args.what == "hierarchy":
        from benchmarks import session_bench

        print(json.dumps(session_bench.bench_hierarchy()))
        return

    from benchmarks import (kernels_bench, paper_figs, roofline_bench,
                            session_bench)

    sections = []
    if args.what in ("all", "kernels"):
        sections.append(("kernels", kernels_bench.bench))
    if args.what in ("all", "comm_modes"):
        sections.append(("comm_modes", kernels_bench.bench_comm_modes))
    if args.what in ("all", "paper"):
        sections.append(("paper_fig3_overlap", paper_figs.bench_fig3))
        sections.append(("paper_fig45_convergence", paper_figs.bench_fig45))
    if args.what in ("all", "roofline"):
        sections.append(("roofline", roofline_bench.bench))
    if args.what == "all":
        sections.append(("session", session_bench.bench))

    print("name,us_per_call,derived")
    for name, fn in sections:
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name},0,ERROR:{type(e).__name__}:{e}")
            continue
        for row_name, us, derived in rows:
            print(f"{row_name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
