"""Benchmark harness — one section per paper table/figure plus the roofline
and kernel microbenches. Prints ``name,us_per_call,derived`` CSV."""
import sys


def main() -> None:
    sections = []
    from benchmarks import kernels_bench, paper_figs, roofline_bench

    sections.append(("kernels", kernels_bench.bench))
    sections.append(("comm_modes", kernels_bench.bench_comm_modes))
    sections.append(("paper_fig3_overlap", paper_figs.bench_fig3))
    sections.append(("paper_fig45_convergence", paper_figs.bench_fig45))
    sections.append(("roofline", roofline_bench.bench))

    print("name,us_per_call,derived")
    for name, fn in sections:
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name},0,ERROR:{type(e).__name__}:{e}")
            continue
        for row_name, us, derived in rows:
            print(f"{row_name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
