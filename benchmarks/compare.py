"""Regression gate for the committed benchmark records (ISSUE-10 satellite).

Diffs a *fresh* benchmark run against the ``BENCH_*.json`` records
committed at the repo root: for every timing key shared by a committed
section and its fresh re-run, flag a regression when

    fresh > threshold · committed        (default threshold: 1.5x)

and exit non-zero if any section regressed. Committed files come in two
shapes and both are handled: bare JSON records carrying a ``what`` key
(the ``--what <x>`` outputs of benchmarks/run.py), and wrapper documents
``{"date", "host", "sections": {...}}`` whose sections are either JSON
records or ``name,us_per_call,derived`` CSV row lists. Nested records
(e.g. the scenarios arms) are flattened with dot-joined keys before
comparison; only keys with a timing suffix (``_ms``, ``_ms_per_round``,
``_us``, ``us_per_call``) are gated — counts, ratios and metadata are
never regressions.

Committed records were measured on whatever machine ran them — absolute
times are not portable across hosts, which is why the CI step that runs
this is non-blocking: the gate exists to catch structural regressions
(an accidentally serialized scatter, a lost jit cache, a recompile per
round), not 10% noise.

Usage::

    # fresh-run every section present in committed records and diff
    python benchmarks/compare.py [--threshold 1.5] [--records BENCH_x.json]

    # diff a pre-recorded fresh JSON record without running anything
    python benchmarks/compare.py --fresh new.json

Sections with no registered runner are skipped with a note.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

# JSON-record sections, keyed by the record's "what" field
JSON_RUNNERS = {
    "session": ("benchmarks.session_bench", "bench_session"),
    "session_placement": ("benchmarks.session_bench",
                          "bench_session_placement"),
    "session_membership": ("benchmarks.session_bench",
                           "bench_session_membership"),
    "hierarchy": ("benchmarks.session_bench", "bench_hierarchy"),
    "local": ("benchmarks.kernels_bench", "bench_local"),
    "serving": ("benchmarks.serving_bench", "bench_serving"),
    "scenarios": ("benchmarks.scenario_bench", "bench_scenarios"),
    "control": ("benchmarks.control_bench", "bench_control"),
}

# CSV-row sections, keyed by section name in the wrapper document
CSV_RUNNERS = {
    "kernels": ("benchmarks.kernels_bench", "bench"),
    "comm_modes": ("benchmarks.kernels_bench", "bench_comm_modes"),
    "roofline": ("benchmarks.roofline_bench", "bench"),
    "session": ("benchmarks.session_bench", "bench"),
}

TIMING_SUFFIXES = ("_ms", "_ms_per_round", "_us", "us_per_call")


def flatten(record, prefix=""):
    """Dot-join nested dict keys into one flat {key: number} mapping."""
    out = {}
    for key, val in record.items():
        name = f"{prefix}{key}"
        if isinstance(val, dict):
            out.update(flatten(val, prefix=f"{name}."))
        elif isinstance(val, (int, float)) and not isinstance(val, bool):
            out[name] = val
    return out


def rows_to_record(rows):
    """CSV row list [{"name", "us_per_call", ...}] -> flat timing record."""
    return {f"{r['name']}_us": r["us_per_call"] for r in rows
            if isinstance(r, dict) and isinstance(
                r.get("us_per_call"), (int, float))}


def committed_sections(doc):
    """Yield (kind, key, flat_record) from a committed BENCH document,
    where kind is 'json' (key = record's what) or 'csv' (key = section
    name)."""
    if isinstance(doc, dict) and "what" in doc:
        yield "json", doc["what"], flatten(doc)
        return
    for name, val in (doc.get("sections") or {}).items():
        if isinstance(val, dict) and "what" in val:
            yield "json", val["what"], flatten(val)
        elif isinstance(val, list):
            yield "csv", name, rows_to_record(val)


def run_fresh(kind, key):
    import importlib

    runners = JSON_RUNNERS if kind == "json" else CSV_RUNNERS
    mod_name, fn_name = runners[key]
    result = getattr(importlib.import_module(mod_name), fn_name)()
    return flatten(result) if kind == "json" else rows_to_record(result)


def compare_section(committed, fresh, threshold):
    """Yield (key, old, new, ratio, regressed) over shared timing keys."""
    for key in sorted(committed):
        if not key.endswith(TIMING_SUFFIXES) or key not in fresh:
            continue
        old, new = committed[key], fresh[key]
        if old <= 0:
            continue
        ratio = new / old
        yield key, old, new, ratio, ratio > threshold


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", nargs="*", default=None,
                    help="committed BENCH_*.json files (default: repo root)")
    ap.add_argument("--fresh", default=None,
                    help="pre-recorded fresh JSON record to diff instead of "
                         "re-running (matched to committed sections by what)")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="regression if fresh > threshold * committed")
    ap.add_argument("--verbose", action="store_true",
                    help="print every compared key, not just regressions")
    args = ap.parse_args(argv)

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    records = args.records if args.records is not None else sorted(
        glob.glob(os.path.join(root, "BENCH_*.json")))
    if not records:
        print("compare: no committed BENCH_*.json records found")
        return 0

    fresh_fixed = None
    if args.fresh:
        with open(args.fresh) as f:
            fresh_fixed = json.load(f)

    failures = 0
    fresh_cache = {}
    for path in records:
        with open(path) as f:
            try:
                doc = json.load(f)
            except json.JSONDecodeError:
                print(f"[skip] {os.path.basename(path)}: not valid JSON")
                continue
        for kind, key, committed in committed_sections(doc):
            label = f"{os.path.basename(path)}:{key}"
            if fresh_fixed is not None:
                if kind != "json" or fresh_fixed.get("what") != key:
                    continue
                fresh = flatten(fresh_fixed)
            elif (runners := (JSON_RUNNERS if kind == "json"
                              else CSV_RUNNERS)) and key in runners:
                if (kind, key) not in fresh_cache:
                    print(f"[run ] {label}", flush=True)
                    try:
                        fresh_cache[(kind, key)] = run_fresh(kind, key)
                    except Exception as e:  # noqa: BLE001 — dead bench = finding
                        print(f"[FAIL] {label}: fresh run raised "
                              f"{type(e).__name__}: {e}")
                        failures += 1
                        fresh_cache[(kind, key)] = None
                        continue
                fresh = fresh_cache[(kind, key)]
                if fresh is None:
                    continue
            else:
                print(f"[skip] {label}: no runner registered")
                continue

            section_bad = 0
            for k, old, new, ratio, regressed in compare_section(
                    committed, fresh, args.threshold):
                if regressed or args.verbose:
                    mark = "REGRESSED" if regressed else "ok"
                    print(f"  {k}: {old} -> {new}  ({ratio:.2f}x)  {mark}")
                section_bad += regressed
            if section_bad:
                print(f"[FAIL] {label}: {section_bad} timing key(s) over "
                      f"{args.threshold}x")
                failures += 1
            else:
                print(f"[ ok ] {label}")

    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
