"""Logical-axis sharding rules → physical ``NamedSharding`` trees.

The mapping is MaxText-style: every parameter/activation dimension carries a
*logical* name ('embed', 'mlp', 'heads', 'vocab', 'expert', 'batch', ...) and a
rule table maps logical names to mesh axes. Rules are *best effort*: a mesh
axis is dropped for a given tensor dimension when the dimension size is not
divisible by the mesh-axis extent (e.g. 8 KV heads on a 16-way 'model' axis →
replicated). This keeps one rule table valid across all 10 architectures and
all 4 input shapes.
"""
from __future__ import annotations

import math
from typing import Mapping, Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn.param import ParamSpec, tree_map_spec

MeshAxes = Union[None, str, tuple]

# Default rule table. 'data' doubles as the FSDP axis for parameters
# (embed/e_dim rows sharded over 'data'), 'model' is tensor parallel.
DEFAULT_RULES: dict = {
    # parameter axes
    "vocab": "model",
    "embed": "data",          # FSDP: shard the d_model dim of weights
    "embed_tp": "model",      # used where d_model is the TP-contracting dim
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "qkv": "model",
    "expert": "model",
    "expert_mlp": None,
    "conv": None,
    "state": None,
    "layers": None,
    "norm": None,
    # activation axes
    "batch": "data",
    "worker": "pod",
    "seq": None,
    "seq_shard": ("data", "model"),
    "act_embed": None,
    "act_heads": "model",
    "act_mlp": "model",
    "act_expert": "model",
    "cache_batch": "data",
    "cache_seq": None,
    "cache_heads": "model",
}


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _present(mesh: Mesh, axes: MeshAxes) -> Optional[MeshAxes]:
    """Filter out mesh axes that don't exist on this mesh (e.g. 'pod')."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.shape else None
    kept = tuple(a for a in axes if a in mesh.shape)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def physical_spec(
    shape: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Optional[Mapping[str, MeshAxes]] = None,
) -> P:
    """Map logical axes to a PartitionSpec, dropping non-divisible axes.

    A mesh axis may appear at most once in a PartitionSpec; first dimension
    (left to right) that claims an axis wins.
    """
    rules = dict(DEFAULT_RULES, **(rules or {}))
    used: set = set()
    out = []
    for dim, name in zip(shape, logical_axes):
        axes = _present(mesh, rules.get(name)) if name else None
        if axes is None:
            out.append(None)
            continue
        cand = (axes,) if isinstance(axes, str) else tuple(axes)
        cand = tuple(a for a in cand if a not in used)
        # greedily keep the prefix of axes whose product divides dim
        kept = []
        prod = 1
        for a in cand:
            if dim % (prod * mesh.shape[a]) == 0:
                kept.append(a)
                prod *= mesh.shape[a]
        if not kept:
            out.append(None)
            continue
        used.update(kept)
        out.append(tuple(kept) if len(kept) > 1 else kept[0])
    # trim trailing Nones (cosmetic)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named_sharding(
    spec: ParamSpec, mesh: Mesh, rules=None
) -> NamedSharding:
    return NamedSharding(mesh, physical_spec(spec.shape, spec.axes, mesh, rules))


def tree_shardings(spec_tree, mesh: Mesh, rules=None):
    """NamedSharding tree matching a ParamSpec tree."""
    return tree_map_spec(lambda s: named_sharding(s, mesh, rules), spec_tree)


def tree_pspecs(spec_tree, mesh: Mesh, rules=None):
    return tree_map_spec(
        lambda s: physical_spec(s.shape, s.axes, mesh, rules), spec_tree
    )


def logical_constraint(x: jax.Array, logical_axes, mesh: Optional[Mesh] = None,
                       rules=None) -> jax.Array:
    """with_sharding_constraint on activations via logical names.

    No-op when no mesh is active (CPU unit tests).
    """
    mesh = mesh or _current_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = physical_spec(x.shape, logical_axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _current_mesh() -> Optional[Mesh]:
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def batch_spec(global_batch: int, mesh: Mesh, extra=()) -> P:
    """Shard a batch dim over as many of ('pod','data') as divide it."""
    axes = []
    prod = 1
    for a in ("pod", "data"):
        if a in mesh.shape and global_batch % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    lead = tuple(axes) if len(axes) > 1 else (axes[0] if axes else None)
    return P(lead, *extra)
