"""Core transformer layers: norms, RoPE (standard/partial/M-RoPE), GQA
attention (causal / sliding-window / chunked / cross, with KV cache), MLPs.

All functions are pure; parameters arrive as dicts of arrays. ``*_specs``
builders produce the matching :class:`~repro.nn.param.ParamSpec` trees.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.param import ParamSpec, fan_in_init, normal_init, ones_init, zeros_init
from repro.nn.sharding import logical_constraint


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_specs(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    p = {"scale": ParamSpec((d,), jnp.float32, ones_init, ("norm",))}
    if cfg.norm == "layernorm":
        p["bias"] = ParamSpec((d,), jnp.float32, zeros_init, ("norm",))
    return p


def apply_norm(params, x, cfg: ModelConfig):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * params["scale"] + params["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(ms + cfg.norm_eps) * params["scale"]
    return y.astype(dtype)


def rms_norm(x, scale, eps=1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), -1, keepdims=True) + eps)
    return (y * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def _rot_dims(cfg: ModelConfig) -> int:
    rot = int(cfg.hd * cfg.rotary_pct)
    return rot - rot % 2


def rope_angles(positions: jax.Array, cfg: ModelConfig) -> jax.Array:
    """positions: (..., S) or (3, B, S) for M-RoPE → angles (..., S, rot/2)."""
    rot = _rot_dims(cfg)
    half = rot // 2
    inv_freq = 1.0 / (
        cfg.rope_theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )
    if cfg.rope_mode == "mrope":
        # positions: (3, B, S); mrope_sections sums to half.
        secs = cfg.mrope_sections
        assert sum(secs) == half, (secs, half)
        chan = jnp.concatenate(
            [jnp.full((s,), i, jnp.int32) for i, s in enumerate(secs)]
        )  # (half,) which position channel each freq uses
        pos = jnp.take(positions, chan, axis=0)  # (half, B, S)
        pos = jnp.moveaxis(pos, 0, -1)  # (B, S, half)
        return pos.astype(jnp.float32) * inv_freq
    return positions[..., None].astype(jnp.float32) * inv_freq


def apply_rope(x: jax.Array, angles: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, H, D); angles: (B, S, half)."""
    rot = _rot_dims(cfg)
    if rot == 0 or cfg.rope_mode == "none":
        return x
    half = rot // 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., :half], xr[..., half:]
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out, xp], axis=-1) if rot < x.shape[-1] else out


# ---------------------------------------------------------------------------
# Attention (GQA, causal / SWA / chunked / cross, cache-aware)
# ---------------------------------------------------------------------------

def attention_specs(cfg: ModelConfig, cross: bool = False):
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.kv_heads, cfg.hd
    p = {
        "wq": ParamSpec((d, h, hd), cfg.pdtype, fan_in_init(0),
                        ("embed", "heads", None)),
        "wk": ParamSpec((d, kvh, hd), cfg.pdtype, fan_in_init(0),
                        ("embed", "kv_heads", None)),
        "wv": ParamSpec((d, kvh, hd), cfg.pdtype, fan_in_init(0),
                        ("embed", "kv_heads", None)),
        "wo": ParamSpec((h, hd, d), cfg.pdtype, fan_in_init(1),
                        ("heads", None, "embed")),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = ParamSpec((hd,), jnp.float32, ones_init, ("norm",))
        p["k_norm"] = ParamSpec((hd,), jnp.float32, ones_init, ("norm",))
    return p


def _attn_mask(q_pos, kv_pos, cfg: ModelConfig, causal: bool):
    """q_pos: (B, Sq), kv_pos: (B, Skv) → bool (B, Sq, Skv)."""
    qp = q_pos[:, :, None]
    kp = kv_pos[:, None, :]
    mask = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        mask &= kp <= qp
    if cfg.sliding_window:
        mask &= (qp - kp) < cfg.sliding_window
    if cfg.attention_chunk:
        mask &= (qp // cfg.attention_chunk) == (kp // cfg.attention_chunk)
    return mask


def multihead_attention(
    params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    angles: Optional[jax.Array] = None,
    kv_x: Optional[jax.Array] = None,
    kv_angles: Optional[jax.Array] = None,
    q_pos: Optional[jax.Array] = None,
    kv_pos: Optional[jax.Array] = None,
    causal: bool = True,
    cache=None,
    cache_index=None,
    kv_precomputed=None,
):
    """General attention.

    - self-attention: ``kv_x is None``
    - cross-attention: ``kv_x`` is the encoder memory (no rope, no causal)
    - decode: ``cache = dict(k=(B,S,KVH,D), v=...)`` and ``cache_index``
      scalar; new K/V written at ``cache_index``, attends over full cache.
      ``cache_index`` may also be a (B,) / (B, 1) vector of *per-row*
      write positions (continuous batching: every request sits at its own
      decode offset) — each row's K/V then lands at its own index, and the
      caller is responsible for passing per-row ``q_pos``/rope positions
      to match (``DecoderLM._with_cache`` derives both from the same
      index, so a vector index stays consistent end to end).

    Returns (out, new_cache).
    """
    B, Sq, _ = x.shape
    cross = kv_x is not None or kv_precomputed is not None
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    if kv_precomputed is not None:
        k, v = kv_precomputed
    else:
        src = kv_x if cross else x
        k = jnp.einsum("bsd,dhk->bshk", src, params["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", src, params["wv"].astype(x.dtype))

    if cfg.qk_norm and not cross:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)

    if not cross and cfg.rope_mode != "none":
        if angles is not None:
            q = apply_rope(q, angles, cfg)
        ka = kv_angles if kv_angles is not None else angles
        if ka is not None:
            k = apply_rope(k, ka, cfg)

    new_cache = None
    if cache is not None:
        # write new kv at cache_index, then attend over the whole cache
        idx = cache_index
        if getattr(idx, "ndim", 0):
            # per-row write positions (continuous batching): row b's new
            # K/V lands at idx[b] of its own cache row
            row = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
                c, u, (i,) + (0,) * (c.ndim - 1)))
            idx_v = jnp.reshape(idx, (-1,))
            ck = row(cache["k"], k.astype(cache["k"].dtype), idx_v)
            cv = row(cache["v"], v.astype(cache["v"].dtype), idx_v)
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0)
            )
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0)
            )
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        Skv = k.shape[1]
        if kv_pos is None:
            kv_pos = jnp.broadcast_to(jnp.arange(Skv), (B, Skv))
    else:
        Skv = k.shape[1]

    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
    if kv_pos is None:
        kv_pos = jnp.broadcast_to(jnp.arange(Skv), (B, Skv))

    k = logical_constraint(k, ("batch", "cache_seq", "cache_heads", None))
    v = logical_constraint(v, ("batch", "cache_seq", "cache_heads", None))

    is_causal = causal and not cross
    if (cfg.use_pallas and Sq == k.shape[1] and Sq % 128 == 0
            and cfg.hd in (64, 128) and cfg.rotary_pct == 1.0):
        # Pallas TPU flash kernel (interpret-mode on CPU); full-seq paths
        from repro.kernels.flash_attention.ops import flash_attention_bshd

        out = flash_attention_bshd(
            q, k, v, causal=is_causal,
            window=cfg.sliding_window if is_causal else None,
            chunk=cfg.attention_chunk if is_causal else None,
            interpret=jax.default_backend() != "tpu")
    elif Sq >= 1024 and Sq % 512 == 0 and k.shape[1] % 512 == 0:
        # Blockwise (flash-style) path: O(block²) live memory; mandatory at
        # the assigned shapes. Skips dead blocks for SWA/chunked masks.
        from repro.nn.flash import blockwise_attention

        out = blockwise_attention(
            q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=is_causal,
            window=cfg.sliding_window if is_causal else None,
            chunk=cfg.attention_chunk if is_causal else None,
        )
    else:
        out = gqa_attention(
            q, k, v, _attn_mask(q_pos, kv_pos, cfg, is_causal)
        )
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    out = logical_constraint(out, ("batch", "seq", "act_embed"))
    return out, new_cache


def gqa_attention(q, k, v, mask):
    """q: (B,Sq,H,D), k/v: (B,Skv,KVH,D), mask: (B,Sq,Skv) → (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    q = q.reshape(B, Sq, KVH, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(D)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act in ("swiglu", "geglu"):
        return {
            "wi_gate": ParamSpec((d, f), cfg.pdtype, fan_in_init(0),
                                 ("embed", "mlp")),
            "wi_up": ParamSpec((d, f), cfg.pdtype, fan_in_init(0),
                               ("embed", "mlp")),
            "wo": ParamSpec((f, d), cfg.pdtype, fan_in_init(0),
                            ("mlp", "embed")),
        }
    return {
        "wi": ParamSpec((d, f), cfg.pdtype, fan_in_init(0), ("embed", "mlp")),
        "wo": ParamSpec((f, d), cfg.pdtype, fan_in_init(0), ("mlp", "embed")),
    }


def apply_mlp(params, x, cfg: ModelConfig):
    dt = x.dtype
    if cfg.act in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, params["wi_gate"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, params["wi_up"].astype(dt))
        g = jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)
        h = g * u
    else:
        h = jnp.einsum("bsd,df->bsf", x, params["wi"].astype(dt))
        h = jax.nn.gelu(h)
    h = logical_constraint(h, ("batch", "seq", "act_mlp"))
    return jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(dt))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_specs(cfg: ModelConfig):
    p = {
        "embedding": ParamSpec(
            (cfg.vocab_size, cfg.d_model), cfg.pdtype, normal_init(0.02),
            ("vocab", "embed"),
        )
    }
    if not cfg.tie_embeddings:
        p["unembed"] = ParamSpec(
            (cfg.d_model, cfg.vocab_size), cfg.pdtype, normal_init(0.02),
            ("embed", "vocab"),
        )
    return p


def embed(params, tokens, cfg: ModelConfig):
    x = jnp.take(params["embedding"], tokens, axis=0).astype(cfg.adtype)
    return logical_constraint(x, ("batch", "seq", "act_embed"))


def unembed(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        logits = jnp.einsum(
            "bsd,vd->bsv", x, params["embedding"].astype(x.dtype)
        )
    else:
        logits = jnp.einsum(
            "bsd,dv->bsv", x, params["unembed"].astype(x.dtype)
        )
    return logical_constraint(logits, ("batch", "seq", "act_heads"))
