"""Mixture-of-Experts layer (GShard/Switch-style capacity dispatch, TPU-native).

Design notes (TPU adaptation):
- Dispatch is *scatter/gather based* rather than the classic dense
  one-hot-einsum: routing tensors are O(tokens × experts) and the expert
  buffers are O(experts × capacity × d_model); no O(T·E·C) one-hot is ever
  materialized. This keeps the HLO memory footprint activation-sized on all
  assigned MoE configs (mixtral 8e, llama4-scout 16e, moonshot 64e).
- Experts shard over the 'model' mesh axis when divisible (expert parallel);
  otherwise the per-expert FFN dims shard over 'model' (tensor parallel
  within expert) — see `expert` / `expert_mlp` logical axes.
- Tokens over capacity are dropped (standard capacity-factor semantics);
  the router aux (load-balance) loss pushes toward uniform load.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.param import ParamSpec, fan_in_init, zeros_init
from repro.nn.sharding import logical_constraint


def moe_specs(cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.e_dff, cfg.num_experts
    expert_axis = "expert"
    p = {
        "router": ParamSpec((d, e), jnp.float32, fan_in_init(0),
                            ("embed", None)),
        "wi_gate": ParamSpec((e, d, f), cfg.pdtype, fan_in_init(1),
                             (expert_axis, "embed", "expert_mlp")),
        "wi_up": ParamSpec((e, d, f), cfg.pdtype, fan_in_init(1),
                           (expert_axis, "embed", "expert_mlp")),
        "wo": ParamSpec((e, f, d), cfg.pdtype, fan_in_init(1),
                        (expert_axis, "expert_mlp", "embed")),
    }
    if cfg.num_shared_experts:
        fs = cfg.e_dff * cfg.num_shared_experts
        p["shared"] = {
            "wi_gate": ParamSpec((d, fs), cfg.pdtype, fan_in_init(0),
                                 ("embed", "mlp")),
            "wi_up": ParamSpec((d, fs), cfg.pdtype, fan_in_init(0),
                               ("embed", "mlp")),
            "wo": ParamSpec((fs, d), cfg.pdtype, fan_in_init(0),
                            ("mlp", "embed")),
        }
    return p


def _capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor
            / cfg.num_experts)
    # round up to an MXU-friendly multiple of 8 and at least top_k
    c = max(c, cfg.top_k, 8)
    return -(-c // 8) * 8


def apply_moe(params, x: jax.Array, cfg: ModelConfig):
    """x: (B, S, d) → (y, aux_loss).

    Groups = batch dim (tokens route within their sequence's group), which
    keeps the dispatch local to the 'data' shards.
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    C = _capacity(S, cfg)
    dt = x.dtype

    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), params["router"]
    )
    probs = jax.nn.softmax(logits, axis=-1)  # (B,S,E) f32

    top_p, top_e = jax.lax.top_k(probs, K)  # (B,S,K)
    if cfg.top_k > 1:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # Load-balance aux loss (Switch): E * mean_e(frac_tokens_e * mean_prob_e)
    onehot_top1 = jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32)
    frac = jnp.mean(onehot_top1, axis=(0, 1))
    mean_p = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * mean_p)

    # Position-in-expert via cumsum over the (S*K) routing slots per batch.
    slot_e = top_e.reshape(B, S * K)  # (B, T) expert ids, T = S*K
    oh = jax.nn.one_hot(slot_e, E, dtype=jnp.int32)  # (B, T, E)
    pos = jnp.cumsum(oh, axis=1) - 1  # position within expert
    pos = jnp.sum(pos * oh, axis=-1)  # (B, T)
    keep = pos < C
    # dropped tokens get scatter-dropped via out-of-range index
    idx_e = jnp.where(keep, slot_e, E)
    idx_c = jnp.where(keep, pos, 0)

    xk = jnp.repeat(x, K, axis=1)  # (B, S*K, d) token per routing slot

    def scatter_one(xb, eb, cb):
        buf = jnp.zeros((E + 1, C, d), dt)
        return buf.at[eb, cb].add(xb)[:E]

    expert_in = jax.vmap(scatter_one)(xk, idx_e, idx_c)  # (B,E,C,d)
    expert_in = logical_constraint(expert_in, ("batch", "act_expert", None, None))

    g = jnp.einsum("becd,edf->becf", expert_in, params["wi_gate"].astype(dt))
    u = jnp.einsum("becd,edf->becf", expert_in, params["wi_up"].astype(dt))
    h = jax.nn.silu(g) * u
    eo = jnp.einsum("becf,efd->becd", h, params["wo"].astype(dt))  # (B,E,C,d)
    eo = logical_constraint(eo, ("batch", "act_expert", None, None))

    def gather_one(ob, eb, cb):
        padded = jnp.concatenate([ob, jnp.zeros((1, C, d), dt)], axis=0)
        return padded[eb, cb]  # (T, d)

    yk = jax.vmap(gather_one)(eo, idx_e, idx_c)  # (B, S*K, d)
    w = (top_p.reshape(B, S * K) * keep).astype(dt)
    y = jnp.sum((yk * w[..., None]).reshape(B, S, K, d), axis=2)

    if cfg.num_shared_experts:
        sp = params["shared"]
        gg = jnp.einsum("bsd,df->bsf", x, sp["wi_gate"].astype(dt))
        uu = jnp.einsum("bsd,df->bsf", x, sp["wi_up"].astype(dt))
        y = y + jnp.einsum(
            "bsf,fd->bsd", jax.nn.silu(gg) * uu, sp["wo"].astype(dt)
        )
    y = logical_constraint(y, ("batch", "seq", "act_embed"))
    return y, aux


def moe_ref_dense(params, x: jax.Array, cfg: ModelConfig):
    """O(E·T·d·f) dense oracle: every token through every expert, weighted.

    Used only in tests to validate the capacity dispatch path (with a high
    capacity factor so nothing is dropped).
    """
    dt = x.dtype
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    if cfg.top_k > 1:
        top_p = top_p / jnp.sum(top_p, -1, keepdims=True)
    gate = jnp.zeros_like(probs)
    gate = jax.vmap(jax.vmap(lambda g, e, p: g.at[e].set(p)))(gate, top_e, top_p)

    g = jnp.einsum("bsd,edf->bsef", x, params["wi_gate"].astype(dt))
    u = jnp.einsum("bsd,edf->bsef", x, params["wi_up"].astype(dt))
    h = jax.nn.silu(g) * u
    eo = jnp.einsum("bsef,efd->bsed", h, params["wo"].astype(dt))
    y = jnp.einsum("bsed,bse->bsd", eo, gate.astype(dt))
    if cfg.num_shared_experts:
        sp = params["shared"]
        gg = jnp.einsum("bsd,df->bsf", x, sp["wi_gate"].astype(dt))
        uu = jnp.einsum("bsd,df->bsf", x, sp["wi_up"].astype(dt))
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(gg) * uu,
                           sp["wo"].astype(dt))
    return y
