"""Parameter specification trees.

Models in this framework are *pure functions* over parameter pytrees. Each
model builder returns a nested dict of :class:`ParamSpec` leaves (the abstract
parameter tree) plus apply functions. From the spec tree we can derive

- ``jax.ShapeDtypeStruct`` trees (dry-run lowering, **no allocation**),
- materialized parameters (``init_tree``), and
- ``NamedSharding`` trees via logical-axis rules (:mod:`repro.nn.sharding`).

This mirrors how MaxText separates logical axes from physical meshes, without
depending on flax (everything here is stdlib + jax).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Initializer = Callable[[jax.Array, Sequence[int], Any], jax.Array]


# ---------------------------------------------------------------------------
# Initializers (jax.nn.initializers-compatible signatures).
# ---------------------------------------------------------------------------

def zeros_init(key, shape, dtype):
    del key
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype):
    del key
    return jnp.ones(shape, dtype)


def normal_init(stddev: float = 0.02) -> Initializer:
    def init(key, shape, dtype):
        return (stddev * jax.random.normal(key, shape)).astype(dtype)

    return init


def fan_in_init(axis: int = -2) -> Initializer:
    """LeCun-normal style: stddev = 1/sqrt(fan_in along ``axis``)."""

    def init(key, shape, dtype):
        fan_in = shape[axis] if shape else 1
        stddev = 1.0 / math.sqrt(max(1, fan_in))
        return (stddev * jax.random.normal(key, shape)).astype(dtype)

    return init


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Abstract description of one parameter tensor.

    ``axes`` holds one *logical axis name* (or None) per dimension; the
    sharding rules in :mod:`repro.nn.sharding` map logical names to mesh axes.
    """

    shape: tuple
    dtype: Any = jnp.bfloat16
    init: Initializer = fan_in_init()
    axes: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(int(s) for s in self.shape))
        axes = tuple(self.axes) if self.axes else (None,) * len(self.shape)
        if len(axes) != len(self.shape):
            raise ValueError(
                f"axes {axes} rank mismatch with shape {self.shape}"
            )
        object.__setattr__(self, "axes", axes)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def spec_leaves(tree):
    return jax.tree.leaves(tree, is_leaf=is_spec)


def tree_map_spec(fn, tree, *rest):
    return jax.tree.map(fn, tree, *rest, is_leaf=is_spec)


def abstract_tree(tree):
    """ShapeDtypeStruct tree for dry-run lowering. Zero allocation."""
    return tree_map_spec(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree
    )


def axes_tree(tree):
    return tree_map_spec(lambda s: s.axes, tree)


def param_count(tree) -> int:
    return sum(s.size for s in spec_leaves(tree))


def init_tree(rng: jax.Array, tree):
    """Materialize a parameter tree (used only for smoke-scale configs)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    vals = [s.init(k, s.shape, s.dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def stack_specs(spec_tree, n: int, axis_name: str = "layers"):
    """Add a leading stacked dimension (for lax.scan over layers)."""

    def stack(s: ParamSpec) -> ParamSpec:
        return ParamSpec(
            shape=(n,) + s.shape,
            dtype=s.dtype,
            init=_vmap_init(s.init, n),
            axes=(axis_name,) + s.axes,
        )

    return tree_map_spec(stack, spec_tree)


def _vmap_init(init: Initializer, n: int) -> Initializer:
    def stacked(key, shape, dtype):
        assert shape[0] == n, (shape, n)
        keys = jax.random.split(key, n)
        return jax.vmap(lambda k: init(k, shape[1:], dtype))(keys)

    return stacked
