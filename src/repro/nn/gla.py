"""Generalized gated linear attention (chunked, TPU-native).

One engine covers both assigned recurrent families:

- **Mamba2 / SSD** (zamba2-7b): scalar per-head decay  a_t = exp(A·dt_t),
  q=C_t, k=dt_t·B_t, v=x_t, *inclusive* read  y_t = q_t·S_t.
- **RWKV6** (rwkv6-3b): per-channel data-dependent decay w_t, *exclusive*
  read with bonus  y_t = r_t·(S_{t-1} + diag(u) k_t v_tᵀ).

Recurrence (per head; state S ∈ R^{N×P}):

    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ,   w_t = exp(logw_t) ∈ (0,1]

TPU adaptation: instead of a length-T sequential scan (latency-bound on the
VPU), training/prefill uses the *chunked* form — an (L×L) masked matmul per
chunk (MXU work) plus a T/L-length scan carrying the (N×P) state. Chunk size
is `cfg.scan_chunk` (default 256 = 2 MXU tiles). Cumulative log-decays are
clamped at −CLAMP to bound exp() in f32; the clamp only binds when the decay
has already zeroed the contribution (exp(−30) ≈ 1e-13).

A per-step sequential reference (`gla_ref`) is the oracle in tests; decoding
uses the O(1) `gla_decode_step`.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

CLAMP = 30.0


def _f32(*xs):
    return tuple(x.astype(jnp.float32) for x in xs)


def gla_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,
    *,
    chunk: int = 256,
    inclusive: bool = True,
    bonus: Optional[jax.Array] = None,
    initial_state: Optional[jax.Array] = None,
    scalar_decay: bool = False,
    decay_floor: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array]:
    """q,k: (B,T,H,N); v: (B,T,H,P); logw: (B,T,H,N) or (B,T,H) if
    ``scalar_decay`` → y (B,T,H,P), S (B,H,N,P).

    Numerics: the scalar-decay path (Mamba2/SSD) materializes the pairwise
    (L,L) within-chunk decay matrix — exponents are clipped to [−CLAMP, 0]
    *after* pairing, so it is exact to ~e^−30 for any decay strength and any
    chunk size. The vector-decay path (RWKV6) must factor the decay per
    channel (a pairwise matrix would be O(L²N)); correctness of the factored
    exponentials requires in-chunk cumulative log-decay ≥ −CLAMP, enforced
    by a per-step decay floor of −CLAMP/chunk (use small chunks for
    strongly-decaying recurrences; rwkv6 config uses chunk 16). The same
    floor must be applied at decode (``decay_floor`` of gla_decode_step).
    """
    B, T, H = q.shape[:3]
    N = q.shape[3]
    P = v.shape[-1]
    out_dtype = v.dtype
    if T % chunk != 0:
        pad = chunk - T % chunk
        zq = lambda x: jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
        q, k, v, logw = zq(q), zq(k), zq(v), zq(logw)
    Tp = q.shape[1]
    G, L = Tp // chunk, chunk
    q, k, v, logw = _f32(q, k, v, logw)
    logw = jnp.minimum(logw, 0.0)
    if not scalar_decay:
        # factored-path floor (length-independent if caller fixes it)
        floor = decay_floor if decay_floor is not None else -CLAMP / chunk
        assert floor * chunk >= -CLAMP - 1e-6, (floor, chunk)
        logw = jnp.maximum(logw, floor)

    def split(x):  # (B,Tp,H,·) -> (G,B,L,H,·)
        return jnp.moveaxis(x.reshape(B, G, L, *x.shape[2:]), 1, 0)

    qs, ks, vs, ws = split(q), split(k), split(v), split(logw)

    if initial_state is None:
        S0 = jnp.zeros((B, H, N, P), jnp.float32)
    else:
        S0 = initial_state.astype(jnp.float32)

    mask_val = jnp.tril(jnp.ones((L, L), bool), 0 if inclusive else -1)

    def step_scalar(S, inp):
        qc, kc, vc, wc = inp  # wc: (B,L,H)
        W = jnp.cumsum(wc, axis=1)  # (B,L,H)
        Wl = W[:, -1]  # (B,H)
        Wq = W if inclusive else W - wc
        # pairwise decay, clipped after pairing → exact
        D = jnp.exp(jnp.clip(Wq[:, :, None] - W[:, None, :], -CLAMP, 0.0))
        D = jnp.where(mask_val[None, :, :, None], D, 0.0)  # (B,L,M,H)
        qk = jnp.einsum("blhn,bmhn->blmh", qc, kc)
        y = jnp.einsum("blmh,bmhp->blhp", qk * D, vc)
        y = y + jnp.einsum(
            "blhn,bhnp->blhp",
            qc * jnp.exp(jnp.maximum(Wq, -CLAMP))[..., None], S)
        k_hat = kc * jnp.exp(
            jnp.clip(Wl[:, None] - W, -CLAMP, 0.0))[..., None]
        S1 = (jnp.exp(jnp.maximum(Wl, -CLAMP))[..., None, None] * S
              + jnp.einsum("blhn,blhp->bhnp", k_hat, vc))
        return S1, y

    def step_vector(S, inp):
        qc, kc, vc, wc = inp  # wc: (B,L,H,N)
        W = jnp.cumsum(wc, axis=1)  # ≥ −CLAMP by the floor
        Wl = W[:, -1]  # (B,H,N)
        Wq = W if inclusive else W - wc
        q_t = qc * jnp.exp(Wq)
        k_t = kc * jnp.exp(-W)  # bounded by e^CLAMP via the floor
        scores = jnp.einsum("blhn,bmhn->bhlm", q_t, k_t)
        scores = jnp.where(mask_val[None, None], scores, 0.0)
        y = jnp.einsum("bhlm,bmhp->blhp", scores, vc)
        y = y + jnp.einsum("blhn,bhnp->blhp", q_t, S)
        if bonus is not None:
            s = jnp.einsum("blhn,hn,blhn->blh", qc,
                           bonus.astype(jnp.float32), kc)
            y = y + s[..., None] * vc
        k_hat = kc * jnp.exp(jnp.clip(Wl[:, None] - W, -CLAMP, 0.0))
        S1 = (jnp.exp(Wl)[..., None] * S
              + jnp.einsum("blhn,blhp->bhnp", k_hat, vc))
        return S1, y

    step = step_scalar if scalar_decay else step_vector
    S_final, ys = jax.lax.scan(step, S0, (qs, ks, vs, ws))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Tp, H, P)[:, :T]
    return y.astype(out_dtype), S_final


def gla_ref(q, k, v, logw, *, inclusive=True, bonus=None, initial_state=None,
            decay_floor=None):
    """Sequential per-step oracle (lax.scan over T). logw: (B,T,H[,N])."""
    B, T, H, N = q.shape
    P = v.shape[-1]
    out_dtype = v.dtype
    if logw.ndim == 3:  # scalar per-head decay → broadcast over N
        logw = jnp.broadcast_to(logw[..., None], q.shape)
    q, k, v, logw = _f32(q, k, v, logw)
    logw = jnp.minimum(logw, 0.0)
    if decay_floor is not None:
        logw = jnp.maximum(logw, decay_floor)
    S0 = (jnp.zeros((B, H, N, P), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(S, inp):
        qt, kt, vt, wt = inp  # (B,H,·)
        S1 = jnp.exp(wt)[..., None] * S + jnp.einsum("bhn,bhp->bhnp", kt, vt)
        Sread = S1 if inclusive else S
        y = jnp.einsum("bhn,bhnp->bhp", qt, Sread)
        if bonus is not None:
            s = jnp.einsum("bhn,hn,bhn->bh", qt, bonus.astype(jnp.float32), kt)
            y = y + s[..., None] * vt
        return S1, y

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (q, k, v, logw))
    S_final, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(out_dtype), S_final


def gla_decode_step(state, q, k, v, logw, *, inclusive=True, bonus=None,
                    decay_floor=None):
    """One-token decode. q,k: (B,H,N); logw: (B,H[,N]); v: (B,H,P)."""
    out_dtype = v.dtype
    if logw.ndim == 2:
        logw = jnp.broadcast_to(logw[..., None], q.shape)
    q, k, v, logw = _f32(q, k, v, logw)
    logw = jnp.minimum(logw, 0.0)
    if decay_floor is not None:
        logw = jnp.maximum(logw, decay_floor)
    S = state.astype(jnp.float32)
    S1 = jnp.exp(logw)[..., None] * S + jnp.einsum("bhn,bhp->bhnp", k, v)
    y = jnp.einsum("bhn,bhnp->bhp", q, S1 if inclusive else S)
    if bonus is not None:
        s = jnp.einsum("bhn,hn,bhn->bh", q, bonus.astype(jnp.float32), k)
        y = y + s[..., None] * v
    return y.astype(out_dtype), S1.astype(state.dtype)


# ---------------------------------------------------------------------------
# Depthwise causal conv (Mamba front conv), with decode buffer.
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array, *, buffer=None):
    """x: (B,T,C), w: (K,C) depthwise. Returns (y, new_buffer).

    buffer: (B,K-1,C) previous inputs for decode (T small, usually 1).
    """
    K = w.shape[0]
    if buffer is None:
        ctx = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([buffer.astype(x.dtype), x], axis=1)
    # y_t = sum_k w[k] * ctx[t + k]
    T = x.shape[1]
    y = sum(
        ctx[:, i : i + T] * w[i].astype(x.dtype) for i in range(K)
    )
    new_buffer = ctx[:, -(K - 1):] if K > 1 else None
    return y, new_buffer
