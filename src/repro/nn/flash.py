"""Blockwise (FlashAttention-style) attention in pure JAX.

XLA-native online-softmax attention: a double ``lax.scan`` over query and KV
blocks keeps live memory O(block²) instead of O(seq²) — mandatory at the
assigned shapes (train_4k @ batch 256, prefill_32k). On TPU the Pallas kernel
in ``repro.kernels.flash_attention`` replaces this; numerics are identical
(both are validated against ``naive_attention``).

Sliding-window / chunked-causal masks *skip* fully-masked KV blocks via a
``lax.cond`` fast path (no MXU work for out-of-window blocks) — this is the
TPU adaptation of the paper-agnostic locality optimizations (see
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _pair_mask(qp, kp, causal, window, chunk):
    """qp: (..., bq, 1), kp: (..., 1, bk) → bool mask."""
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        m &= kp <= qp
    if window:
        m &= (qp - kp) < window
    if chunk:
        m &= (qp // chunk) == (kp // chunk)
    return m


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_pos: jax.Array,
    kv_pos: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: Optional[int] = None,
    block_q: int = 512,
    block_k: int = 512,
) -> jax.Array:
    """q: (B,Sq,H,D); k,v: (B,Skv,KVH,D); *_pos: (B,S) → (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv, block_q, block_k)
    nq, nk = Sq // block_q, Skv // block_k
    scale = 1.0 / math.sqrt(D)

    qb = jnp.moveaxis(q.reshape(B, nq, block_q, KVH, G, D), 1, 0)
    qpb = jnp.moveaxis(q_pos.reshape(B, nq, block_q), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, block_k, KVH, D), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, block_k, KVH, D), 1, 0)
    kpb = jnp.moveaxis(kv_pos.reshape(B, nk, block_k), 1, 0)

    def q_block(args):
        qi, qpi = args
        # carries: m (B,KVH,G,bq), l, acc (B,KVH,G,bq,D)
        m0 = jnp.full((B, KVH, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, KVH, G, block_q, D), jnp.float32)

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, vi, kpi = inp

            def compute(_):
                s = jnp.einsum(
                    "bqhgd,bkhd->bhgqk", qi, ki,
                    preferred_element_type=jnp.float32,
                ) * scale
                pm = _pair_mask(
                    qpi[:, None, None, :, None],
                    kpi[:, None, None, None, :],
                    causal, window, chunk,
                )
                s = jnp.where(pm, s, NEG_INF)
                m_new = jnp.maximum(m, jnp.max(s, -1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, -1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bhgqk,bkhd->bhgqd", p.astype(vi.dtype), vi
                ).astype(jnp.float32)
                return m_new, l_new, acc_new

            # Block-level skip: if no (q,k) pair in this block pair can be
            # live, bypass the matmuls entirely.
            q_lo, q_hi = jnp.min(qpi), jnp.max(qpi)
            k_lo, k_hi = jnp.min(kpi), jnp.max(kpi)
            live = jnp.array(True)
            if causal:
                live &= k_lo <= q_hi
            if window:
                live &= (q_lo - k_hi) < window
            if chunk:
                live &= (q_hi // chunk) >= (k_lo // chunk)
                live &= (q_lo // chunk) <= (k_hi // chunk)
            return jax.lax.cond(live, compute, lambda _: (m, l, acc), None), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kb, vb, kpb))
        l = jnp.where(l == 0.0, 1.0, l)
        out = (acc / l[..., None]).astype(q.dtype)  # (B,KVH,G,bq,D)
        return jnp.moveaxis(out, 3, 1).reshape(B, block_q, H, D)

    outs = jax.lax.map(jax.checkpoint(q_block), (qb, qpb))  # (nq,B,bq,H,D)
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, D)


def naive_attention(q, k, v, *, q_pos, kv_pos, causal=True, window=None,
                    chunk=None):
    """O(S²)-memory oracle for tests."""
    B, Sq, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qg = q.reshape(B, Sq, KVH, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    s /= math.sqrt(D)
    pm = _pair_mask(
        q_pos[:, None, None, :, None], kv_pos[:, None, None, None, :],
        causal, window, chunk,
    )
    s = jnp.where(pm, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no live key → zeros (matches blockwise l==0 guard)
    any_live = jnp.any(pm, -1)
    p = jnp.where(any_live[..., None], p, 0.0)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, D)
