"""Roofline terms from dry-run artifacts (TPU v5e-class target).

    compute term    = HLO_FLOPs_global    / (chips × peak_FLOP/s)
    memory term     = HLO_bytes_global    / (chips × HBM_bw)
    collective term = collective_bytes_global / (chips × link_bw)

``cost_analysis()`` on the post-SPMD module reports *per-device* FLOPs/bytes,
so global = per-device × chips and each term reduces to per-device / peak.
MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per the assignment; the
ratio MODEL_FLOPS/HLO_FLOPs measures how much compiled compute is "useful"
(AdaHessian's HVP legitimately adds ≈ one extra backward pass; remat and
dispatch overheads show up here too).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.configs.base import INPUT_SHAPES, ModelConfig, get_config

PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link


def active_param_count(cfg: ModelConfig) -> int:
    """N (dense) or N_active (MoE) — parameters touched per token."""
    from repro.models.registry import build_model
    from repro.nn.param import param_count, spec_leaves

    model = build_model(cfg)
    total = param_count(model.spec)
    if not cfg.moe:
        return total
    # subtract inactive experts: each routed expert has 3 matrices e_dff×d
    per_expert = 3 * cfg.e_dff * cfg.d_model
    n_moe_layers = cfg.num_layers - cfg.first_dense_layers
    inactive = n_moe_layers * (cfg.num_experts - cfg.top_k) * per_expert
    return total - inactive


def model_flops(cfg: ModelConfig, shape_name: str, kind: str) -> float:
    """6·N·D forward+backward estimate (D = tokens processed)."""
    shape = INPUT_SHAPES[shape_name]
    n = active_param_count(cfg)
    if kind.startswith("train"):
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if "prefill" in kind:
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens  # forward only
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_global: float
    flops_ratio: Optional[float]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=lambda k: terms[k] or 0.0)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_from_record(rec: Dict) -> Optional[Roofline]:
    if rec.get("status") != "ok":
        return None
    la = rec.get("loop_aware") or {}
    if la.get("flops_multiplier"):
        # calibrated: XLA's per-op cost model × the parser's loop multiplier
        # (cost_analysis visits while bodies once — analysis/hlo_cost.py)
        flops_d = ((rec.get("flops_per_device") or 0.0)
                   * la["flops_multiplier"])
        bytes_d = ((rec.get("bytes_per_device") or 0.0)
                   * (la.get("bytes_multiplier") or 1.0))
        coll_d = la.get("collective_total_per_device") or 0.0
    elif la.get("dot_flops_per_device"):
        flops_d = la["dot_flops_per_device"]
        bytes_d = la.get("bytes_per_device") or 0.0
        coll_d = la.get("collective_total_per_device") or 0.0
    else:
        flops_d = rec.get("flops_per_device") or 0.0
        bytes_d = rec.get("bytes_per_device") or 0.0
        coll = rec.get("collective_bytes_per_device") or {}
        coll_d = coll.get("total") or 0.0
    n = rec["devices"]
    cfg = get_config(rec["arch"])
    mf = model_flops(cfg, rec["shape"], rec.get("lowered_kind", "train"))
    # multi-pod elastic round trains k workers' sub-batches = same global D
    hlo_global = flops_d * n
    return Roofline(
        compute_s=flops_d / PEAK_FLOPS,
        memory_s=bytes_d / HBM_BW,
        collective_s=coll_d / ICI_BW,
        model_flops=mf,
        hlo_flops_global=hlo_global,
        flops_ratio=(mf / hlo_global) if hlo_global else None,
    )


def load_records(path: str):
    recs = []
    with open(path) as f:
        for line in f:
            recs.append(json.loads(line))
    # dedupe keep-last
    seen = {}
    for r in recs:
        seen[(r["arch"], r["shape"], r.get("multi_pod", False))] = r
    return list(seen.values())


def render_table(path: str, multi_pod: bool = False) -> str:
    rows = []
    head = ("| arch | shape | compute s | memory s | collective s | "
            "dominant | MODEL/HLO | suggestion |")
    sep = "|" + "---|" * 8
    rows.append(head)
    rows.append(sep)
    for rec in sorted(load_records(path),
                      key=lambda r: (r["arch"], r["shape"])):
        if rec.get("multi_pod", False) != multi_pod:
            continue
        if rec["status"] == "skipped":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"skipped | — | {rec.get('reason','')} |")
            continue
        if rec["status"] != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"ERROR | — | {rec.get('error','')[:60]} |")
            continue
        r = roofline_from_record(rec)
        sug = SUGGESTIONS.get(r.dominant, "")
        ratio = f"{r.flops_ratio:.2f}" if r.flops_ratio else "—"
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {r.compute_s:.4f} | "
            f"{r.memory_s:.4f} | {r.collective_s:.4f} | {r.dominant} | "
            f"{ratio} | {sug} |")
    return "\n".join(rows)


SUGGESTIONS = {
    "compute": "cut redundant FLOPs (remat policy, HVP fusion) or raise "
               "MODEL/HLO toward 1",
    "memory": "increase arithmetic intensity: fuse elementwise chains, "
              "larger per-device tiles, bf16 caches",
    "collective": "reshard to cut all-gathers (sequence-parallel residual, "
                  "expert-parallel dispatch) or overlap collectives",
}
