"""Loop-aware HLO cost model (text parser).

``compiled.cost_analysis()`` visits a ``while`` body **once**, so scanned
layer stacks (all our models scan layers; hybrid scans groups-of-scans)
undercount FLOPs/bytes/collectives by ~L×. This parser rebuilds the three
roofline numerators from the optimized HLO text with while-loop
multiplication:

- **dot FLOPs**: 2 · |result| · (contracted extent) per ``dot`` op
  (elementwise FLOPs are ignored — documented; matmuls dominate every
  assigned model).
- **bytes**: Σ over top-level ops of operand+result bytes (fusions count as
  single ops — the same granularity XLA's own model uses for HBM traffic);
  bookkeeping ops (tuple plumbing, constants, bitcasts) are skipped.
- **collective bytes**: per category, as in :mod:`repro.analysis.hlo`.

Each ``while`` op contributes ``trips × cost(body) + cost(cond)``; trips is
read from the loop condition's comparison constant. Nested whiles recurse.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE = re.compile(
    r"([a-z]+[0-9]+(?:e[0-9]+m[0-9]+(?:fn)?)?|pred)\[([0-9,]*)\]")
_COMP_HEADER = re.compile(r"^(%?[\w.\-]+) \(.*?\) -> .+ \{\s*$", re.M)
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+) = (.+?) ([\w\-]+)\((.*?)\)", re.M)
_OPERANDS = re.compile(r"%[\w.\-]+")
_WHILE_ATTR = re.compile(r"condition=(%[\w.\-]+), body=(%[\w.\-]+)")
_CONST_S32 = re.compile(r"s32\[\] constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
            "after-all", "iota", "copy-start", "copy-done"}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_elems_bytes(type_str: str):
    total_b = 0
    total_e = 0
    for dtype, dims in _SHAPE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES.get(dtype, 4)
    return total_e, total_b


def _split_computations(txt: str):
    comps = {}
    pos = 0
    for m in _COMP_HEADER.finditer(txt):
        end = txt.find("\n}", m.end())
        comps[m.group(1).lstrip("%")] = txt[m.end():end]
    # entry computation: "ENTRY %main ... {"
    em = re.search(r"^ENTRY (%?[\w.\-]+)", txt, re.M)
    entry = None
    if em:
        name = em.group(1).lstrip("%")
        start = txt.find("{", em.end())
        end = txt.find("\n}", start)
        comps[name] = txt[start:end]
        entry = name
    return comps, entry


_OP_LINE_FULL = _OP_LINE
_CALLS = re.compile(r"calls=(%[\w.\-]+)")
_PARAM_IDX = re.compile(r"parameter\((\d+)\)")


def _sliced_params(body: str):
    """param index → slice bytes, for fusion params consumed via
    dynamic-slice/gather *inside* the fused computation (the fusion reads
    only the slice from HBM, not the whole operand)."""
    name_to_idx = {}
    for line in body.split("\n"):
        m = _OP_LINE_FULL.match(line)
        if not m:
            continue
        name, rtype, op, args = m.groups()
        if op == "parameter":
            pm = _PARAM_IDX.search(line)
            if pm:
                name_to_idx[name] = int(pm.group(1))
    out = {}
    for line in body.split("\n"):
        m = _OP_LINE_FULL.match(line)
        if not m:
            continue
        name, rtype, op, args = m.groups()
        if op in ("dynamic-slice", "gather"):
            ops_ = _OPERANDS.findall(args)
            if ops_ and ops_[0] in name_to_idx:
                _, sb = _shape_elems_bytes(rtype)
                idx = name_to_idx[ops_[0]]
                out[idx] = out.get(idx, 0) + sb
    return out


def analyse_module(txt: str):
    comps, entry = _split_computations(txt)
    slice_maps = {name: _sliced_params(body) for name, body in comps.items()}
    parsed = {}
    for name, body in comps.items():
        dims: Dict[str, list] = {}
        dot_flops = 0.0
        bytes_accessed = 0.0
        coll = defaultdict(float)
        whiles = []
        for line in body.split("\n"):
            m = _OP_LINE_FULL.match(line)
            if not m:
                continue
            oname, rtype, op, args = m.groups()
            shp = _SHAPE.findall(rtype)
            dims[oname] = shp
            _, rbytes = _shape_elems_bytes(rtype)
            if op in SKIP_OPS:
                continue
            if op == "while":
                wm = _WHILE_ATTR.search(line)
                if wm:
                    whiles.append((wm.group(1).lstrip("%"),
                                   wm.group(2).lstrip("%")))
                continue
            operands = _OPERANDS.findall(args)

            def _obytes(name_):
                return _shape_elems_bytes(
                    " ".join(f"{d}[{s}]" for d, s in dims.get(name_, [])))[1]

            # per-op HBM-traffic model (mirrors HloCostAnalysis):
            # slicing ops touch only the slice, not the whole buffer
            if op in ("dynamic-slice", "slice", "gather"):
                bytes_accessed += 2 * rbytes
            elif op == "dynamic-update-slice":
                upd = _obytes(operands[1]) if len(operands) > 1 else rbytes
                bytes_accessed += 2 * upd
            elif op in ("scatter", "select-and-scatter"):
                upd = _obytes(operands[-1]) if operands else rbytes
                bytes_accessed += rbytes + 2 * upd
            elif op == "fusion":
                # fusion reads each operand once — except operands whose
                # only in-fusion consumer is a dynamic-slice/gather, which
                # read slice-sized traffic (scan xs!)
                cm = _CALLS.search(line)
                smap = slice_maps.get(
                    cm.group(1).lstrip("%") if cm else "", {})
                total = rbytes
                for i, o in enumerate(operands):
                    total += smap.get(i, _obytes(o)) if i in smap else (
                        _obytes(o))
                bytes_accessed += total
            else:
                bytes_accessed += rbytes + sum(_obytes(o) for o in operands)
            if op == "dot":
                cm = _CONTRACT.search(line)
                contracted = 1
                if cm and operands and dims.get(operands[0]):
                    lhs_dims = dims[operands[0]][0][1]
                    lhs_sizes = ([int(x) for x in lhs_dims.split(",")]
                                 if lhs_dims else [])
                    if cm.group(1):
                        for di in cm.group(1).split(","):
                            di = int(di)
                            if di < len(lhs_sizes):
                                contracted *= lhs_sizes[di]
                result_elems = _shape_elems_bytes(rtype)[0]
                dot_flops += 2.0 * result_elems * contracted
            for c in COLLECTIVES:
                if op.startswith(c) and "-done" not in op:
                    coll[c] += rbytes
        parsed[name] = {
            "dot_flops": dot_flops, "bytes": bytes_accessed,
            "coll": dict(coll), "whiles": whiles, "body": body,
        }
    return parsed, entry


def _trip_count(parsed, cond_name: str) -> int:
    body = parsed.get(cond_name, {}).get("body", "")
    consts = [int(x) for x in _CONST_S32.findall(body)]
    return max(consts) if consts else 1


def _total(parsed, name: str, memo: Optional[dict] = None,
           force_trips: Optional[int] = None):
    memo = memo if memo is not None else {}
    if name in memo:
        return memo[name]
    memo[name] = {"dot_flops": 0.0, "bytes": 0.0, "coll": {}}  # cycle guard
    node = parsed.get(name)
    if node is None:
        return memo[name]
    flops = node["dot_flops"]
    bts = node["bytes"]
    coll = defaultdict(float, node["coll"])
    for cond, body in node["whiles"]:
        trips = force_trips if force_trips else _trip_count(parsed, cond)
        sub = _total(parsed, body, memo, force_trips)
        flops += trips * sub["dot_flops"]
        bts += trips * sub["bytes"]
        for k, v in sub["coll"].items():
            coll[k] += trips * v
    out = {"dot_flops": flops, "bytes": bts, "coll": dict(coll)}
    memo[name] = out
    return out


def loop_aware_costs(hlo_text: str) -> dict:
    """Per-device numerators with while-loop multiplication.

    Also returns the same totals with every trip count forced to 1
    (``*_trip1``): dividing gives the loop multiplier, which callers use to
    *calibrate* XLA's own cost_analysis numbers (this parser's per-op byte
    convention over-counts unfused elementwise chains; cost_analysis models
    HBM traffic better but visits loop bodies once — the product of the two
    is the best of both).
    """
    parsed, entry = analyse_module(hlo_text)
    if entry is None:
        return {"dot_flops": 0.0, "bytes": 0.0, "coll": {},
                "coll_total": 0.0, "dot_flops_trip1": 0.0,
                "bytes_trip1": 0.0, "coll_total_trip1": 0.0}
    out = _total(parsed, entry)
    out["coll_total"] = sum(out["coll"].values())
    t1 = _total(parsed, entry, memo={}, force_trips=1)
    out["dot_flops_trip1"] = t1["dot_flops"]
    out["bytes_trip1"] = t1["bytes"]
    out["coll_total_trip1"] = sum(t1["coll"].values())
    return out
