"""HLO text parsing: collective bytes per category.

``compiled.cost_analysis()`` has FLOPs/bytes but no collective traffic, so
we parse the (post-SPMD-partitioning) HLO for all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops and sum their operand
sizes. Shapes are parsed from the op's result type annotation, e.g.

    %all-reduce.1 = bf16[1024,512]{1,0} all-reduce(...)

Tuple results (e.g. fused all-reduces) contribute every element.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# one shape token like bf16[8,128]{1,0} or f32[] — captures dtype + dims
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9]+m[0-9]+(?:fn)?)?|pred)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Total result bytes per collective category (skip -done duplicates)."""
    out: Dict[str, int] = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        line = hlo_text[m.start():hlo_text.find("\n", m.start())]
        if "-done(" in line:  # async pair: count only the -start
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def count_ops(hlo_text: str, name: str) -> int:
    return len(re.findall(rf"\b{re.escape(name)}\b", hlo_text))
