"""qwen3-4b [dense] — qk-norm + GQA, explicit head_dim=128.

36L d_model=2560 32H (kv=8) d_ff=9728 vocab=151936. [hf:Qwen/Qwen3-8B family]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense",
    num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=9728, vocab_size=151936,
    qk_norm=True, rope_theta=1000000.0, tie_embeddings=True,
    source="hf:Qwen/Qwen3-8B",
)

SMOKE = CONFIG.replace(
    name="qwen3-smoke", num_layers=2, d_model=128, num_heads=4,
    num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=256,
)
