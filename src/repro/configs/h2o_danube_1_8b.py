"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (kv=8) d_ff=6912 vocab=32000, SWA 4096.
[arXiv:2401.16818]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=6912, vocab_size=32000,
    sliding_window=4096, rope_theta=10000.0,
    source="arXiv:2401.16818",
)

SMOKE = CONFIG.replace(
    name="danube-smoke", num_layers=2, d_model=128, num_heads=4,
    num_kv_heads=2, d_ff=256, vocab_size=256, sliding_window=32,
)
