"""seamless-m4t-large-v2 [audio] — enc-dec multimodal backbone.

24L(enc)+24L(dec) d_model=1024 16H (kv=16) d_ff=8192 vocab=256206. The audio
frontend (mel + conv feature extractor) is a STUB: the batch carries
precomputed frame embeddings. [arXiv:2308.11596]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    num_layers=48, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    enc_layers=24, dec_layers=24, enc_seq_ratio=8,
    act="geglu", frontend="audio",
    source="arXiv:2308.11596",
)

SMOKE = CONFIG.replace(
    name="seamless-smoke", num_layers=4, d_model=128, num_heads=4,
    num_kv_heads=4, d_ff=256, vocab_size=256, enc_layers=2, dec_layers=2,
)
