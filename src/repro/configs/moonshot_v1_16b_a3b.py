"""moonshot-v1-16b-a3b [moe] — Moonlight-16B-A3B (DeepSeek-V3-like MoE).

48L d_model=2048 16H (kv=16) vocab=163840; 64 routed experts top-6 (+2
shared), expert d_ff=1408 (assignment's d_ff), first layer dense (d_ff
11264 = 8×1408 per the Moonlight card). [hf:moonshotai/Moonlight-16B-A3B]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=11264, vocab_size=163840,
    num_experts=64, top_k=6, num_shared_experts=2, expert_d_ff=1408,
    first_dense_layers=1, rope_theta=50000.0,
    source="hf:moonshotai/Moonlight-16B-A3B",
)

SMOKE = CONFIG.replace(
    name="moonshot-smoke", num_layers=3, d_model=128, num_heads=4,
    num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=256, num_experts=4,
    top_k=2, num_shared_experts=1, expert_d_ff=64, first_dense_layers=1,
)
