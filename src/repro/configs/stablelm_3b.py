"""stablelm-3b [dense] — LayerNorm + partial rotary (25%).

32L d_model=2560 32H (kv=32) d_ff=6912 vocab=50304.
[hf:stabilityai/stablelm-2-1_6b family config, scaled per assignment]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=6912, vocab_size=50304,
    norm="layernorm", rotary_pct=0.25, rope_theta=10000.0,
    source="hf:stabilityai/stablelm-2-1_6b",
)

SMOKE = CONFIG.replace(
    name="stablelm-smoke", num_layers=2, d_model=128, num_heads=4,
    num_kv_heads=4, d_ff=256, vocab_size=256,
)
