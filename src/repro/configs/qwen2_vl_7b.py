"""qwen2-vl-7b [vlm] — M-RoPE + dynamic resolution; vision frontend STUBBED.

28L d_model=3584 28H (kv=4) d_ff=18944 vocab=152064, head_dim=128,
mrope_sections=(16,24,24). [arXiv:2409.12191]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064,
    rope_mode="mrope", mrope_sections=(16, 24, 24), rope_theta=1000000.0,
    num_patch_tokens=1024, frontend="vision",
    source="arXiv:2409.12191",
)

SMOKE = CONFIG.replace(
    name="qwen2-vl-smoke", num_layers=2, d_model=128, num_heads=4,
    num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=256,
    mrope_sections=(6, 5, 5), num_patch_tokens=16,
)
