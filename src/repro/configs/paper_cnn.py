"""The paper's own experimental model (§VI): 2-conv-layer CNN, 28×28, 10-way."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paper-cnn", family="cnn",
    num_layers=2, d_model=128, num_heads=1, d_ff=128, vocab_size=10,
    source="paper §VI (PyTorch MNIST example CNN)",
)

SMOKE = CONFIG
