"""Config system: model / train / elastic / shape / mesh dataclasses + registry.

Every assigned architecture gets one module in ``repro.configs`` exporting
``CONFIG: ModelConfig`` (exact public numbers, cited) and ``SMOKE: ModelConfig``
(reduced same-family variant for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | rwkv | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    vocab_size: int
    num_kv_heads: Optional[int] = None
    head_dim: Optional[int] = None
    # norms / activations
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    act: str = "swiglu"  # swiglu | gelu | geglu
    qk_norm: bool = False
    # rope
    rope_mode: str = "standard"  # standard | mrope | none
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0
    mrope_sections: Tuple[int, ...] = ()  # head_dim/2 split for (t, h, w)
    # attention locality
    sliding_window: Optional[int] = None
    attention_chunk: Optional[int] = None  # llama4-style chunked causal
    # embeddings
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    top_k: int = 1
    num_shared_experts: int = 0
    expert_d_ff: Optional[int] = None
    capacity_factor: float = 1.25
    first_dense_layers: int = 0
    router_aux_weight: float = 0.01
    # SSM / hybrid (zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_head_dim: int = 64
    attn_every: int = 0  # hybrid: shared attn block every N ssm layers
    # rwkv
    rwkv_head_dim: int = 64
    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    enc_seq_ratio: int = 8  # decoder_len / encoder_len for shape derivation
    # modality stubs
    frontend: Optional[str] = None  # 'audio' | 'vision' | None
    num_patch_tokens: int = 0  # vlm: patch embeddings prepended per sample
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # misc
    source: str = ""  # citation
    # pallas kernels on/off (TPU path) for model-internal kernels (flash
    # attention). Inside an ElasticSession, RunSpec.use_pallas is the single
    # source of truth: the session coerces this field to match the spec, so
    # one flag drives both the model and the trainer kernel paths (ISSUE-7).
    use_pallas: bool = False
    # sequence-mix chunk size for SSD/RWKV chunked scans
    scan_chunk: int = 256

    @property
    def kv_heads(self) -> int:
        return self.num_kv_heads or self.num_heads

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def moe(self) -> bool:
        return self.num_experts > 0

    @property
    def e_dff(self) -> int:
        return self.expert_d_ff or self.d_ff

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# Failure scenario catalogue (generators live in repro/core/scenarios.py;
# kept here so ElasticConfig can validate without a circular import).
# "hetero" (persistent per-slot speeds) and "byzantine" (corrupt-gradient
# slots) are adversarial extensions beyond the paper's §VI fault model;
# trace replay is deliberately NOT in this catalogue — a recorded trace is
# loaded with `TraceScenario`/`read_trace` and attached via
# `RunSpec.schedule` (CLI: `--trace`), since it carries its own
# rounds/capacity and ignores the generator knobs below.
FAILURE_SCENARIOS = ("iid", "burst", "correlated", "straggler",
                     "crash_restart", "hetero", "byzantine")

# Membership scenario catalogue (planned worker-pool resize streams; the
# generators live next to the failure scenarios in repro/core/scenarios.py).
MEMBERSHIP_SCENARIOS = ("static", "scale_up", "scale_down",
                        "preempt_rejoin", "plan")


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """Paper Section V hyper-parameters."""

    num_workers: int = 4
    # Worker-pool capacity (ISSUE-5). Every device-side worker-axis array is
    # sized at `cap` slots and an active mask selects the live ones, so
    # membership (join/leave/resize) can change between chunks with zero
    # recompiles — shapes are fixed at capacity. 0 means "exactly
    # num_workers" (the pre-elastic fixed-k regime, masking-free when the
    # membership scenario is static).
    capacity: int = 0
    tau: int = 1                      # communication period
    alpha: float = 0.1                # EASGD moving rate (best grid value, §VII)
    score_window: int = 5             # p most-recent u values kept (p-1 diffs)
    score_weights: Tuple[float, ...] = (0.5, 0.25, 0.15, 0.10)  # c_0 (newest) .. c_{p-2}
    score_k: float = -0.05            # threshold k < 0 in h1/h2
    overlap_ratio: float = 0.25       # r = o/n (paper: .25 @ k=4, .125 @ k=8)
    failure_prob: float = 1.0 / 3.0   # comm suppressed 1/3 of the time (§VI)
    dynamic: bool = True              # False → fixed-α EASGD behaviour
    oracle: bool = False              # EAHES-OM: oracle failure knowledge
    # Communication backend. "sequential" preserves the paper's event-ordered
    # single-device simulation (lax.scan over workers, master updated between
    # workers). "fused" batches all k syncs: one vmapped scoring pass and one
    # multi-worker elastic kernel; the master reduction uses the exact
    # event-order-equivalent weights, workers sync against the round-start
    # master (delayed averaging à la DaSGD).
    comm_mode: str = "sequential"     # sequential | fused
    # Delayed averaging depth (DaSGD; ISSUE-7). 0 = sync against the
    # round-start master (today's fused semantics, bit-exact with the
    # pre-staleness trajectories). 1 = workers score and pull toward the
    # *previous* round's master snapshot (``master_prev``), so round r's
    # elastic exchange depends only on state known before round r−1's
    # master reduction lands — the comm phase of round r can overlap the
    # local phase of round r+1. Fused-mode only: the sequential backend is
    # the paper's event-ordered live-master scan, where staleness has no
    # consistent meaning.
    staleness: int = 0                # 0 | 1
    # Execution placement (repro/core/coordinator.py). "single" simulates all
    # k workers on one device (vmap over the worker axis). "sharded" places
    # the worker axis over the mesh's 'pod' axis via shard_map: the local
    # phase runs fully parallel per shard and the fused comm phase scores
    # per-shard, reducing into the master with an event-order-equivalent
    # cross-pod collective. Requires comm_mode="fused" — the sequential
    # backend is an event-ordered scan over workers (each sync reads the
    # master the previous worker just wrote) and cannot shard.
    placement: str = "single"         # single | sharded
    # Failure scenario engine (repro/core/scenarios.py). "iid" is the paper's
    # Bernoulli model; the other regimes reuse failure_prob as their
    # stationary fault rate plus the knobs below.
    failure_scenario: str = "iid"
    burst_recover_prob: float = 0.25  # burst/straggler: P(bad→good)/round
    fault_groups: int = 2             # correlated: number of co-failing racks
    crash_downtime: int = 3           # crash_restart: rounds down per crash
    straggler_tau_scale: float = 0.5  # straggler: fraction of τ it completes
    # "hetero": persistent per-slot speed distribution. Each slot draws one
    # speed in (0, 1] at schedule time and keeps it for the whole run; the
    # local phase gives slot i max(1, round(speed_i * tau)) steps per round
    # (distinct from transient straggler masks, which also stale the score).
    hetero_dist: str = "lognormal"    # lognormal | bimodal
    hetero_sigma: float = 0.6         # lognormal: speed = min(1, exp(sigma·z))
    hetero_slow_frac: float = 0.25    # bimodal: P(slot is slow)
    hetero_slow_scale: float = 0.25   # bimodal: speed of slow slots
    # "byzantine": persistent corrupt-gradient slots. Each slot is byzantine
    # with prob byzantine_frac (at least one slot stays honest); honest slots
    # still suffer iid comm failures at failure_prob, so the corrupt and fail
    # masks are disjoint by construction. The coordinator applies the
    # corruption to gradients inside the jitted local phase.
    byzantine_frac: float = 0.25      # P(slot is corrupt) — persistent
    byzantine_mode: str = "sign_flip"  # sign_flip | scale | noise
    byzantine_scale: float = 5.0      # scale factor / noise std
    # Robustness clamp for dynamic weighting (beyond-paper; see
    # docs/paper_map.md deviation #10). The paper's h2 map gives *full*
    # weight alpha to any worker whose score is positive — including a
    # byzantine slot running away from the master — so a diverging poisoned
    # worker pollutes the master at the same rate as a healthy one. With
    # score_clip > 0, the master refuses the pull (w2 = 0) from any worker
    # whose raw score exceeds +score_clip. 0 disables the clamp and is
    # bit-identical to the paper's maps. Applies to both comm backends
    # (the clamp lives in dynamic_weight.weights_for).
    score_clip: float = 0.0
    # Absolute-distance containment (beyond-paper; ROADMAP item 5 /
    # docs/paper_map.md deviation #10). score_clip clamps the distance
    # *trend*, so an attack that parks a worker at a huge-but-static
    # distance (noise-mode corruption under AdaHessian's
    # curvature-normalized steps) has a raw score ≈ 0 and sails under the
    # clip. With u_zclip > 0 the master additionally refuses (w2 = 0) any
    # worker whose log-distance u sits more than u_zclip robust z-scores
    # (median / 1.4826·MAD) above the live pool's u distribution — a
    # cross-sectional term, so it lives in the batched scoring paths
    # (fused + hierarchical comm; the sequential scan computes u one
    # worker at a time against an evolving master and has no pool
    # snapshot to stand on). 0 disables it, bit-identically.
    u_zclip: float = 0.0
    # Hierarchical elastic averaging (tree-EASGD; the extension §VI of
    # Zhang et al.'s EASGD sketches and this repo builds). The
    # capacity-padded worker axis is partitioned into `groups` contiguous
    # rack-sized groups, each owning a *sub-master*: workers
    # elastic-average against their group's sub-master every round (τ
    # local steps), and the sub-masters elastic-average against the
    # global master every `global_period` rounds (τ_g = global_period·τ)
    # with their own h1/h2 dynamic weights — a dead rack is down-weighted
    # at the global level exactly as a dead worker is at the rack level.
    # groups=1, global_period=1 is the flat topology (sub-master ≡
    # master, bit-exact with the non-hierarchical fused coordinator).
    # Requires comm_mode="fused" when non-trivial.
    groups: int = 1
    global_period: int = 1
    # Membership scenario engine (repro/core/scenarios.py): a planned
    # (rounds, capacity) active-mask stream riding alongside the failure
    # masks. "static" keeps the initial num_workers slots live; scale_up /
    # scale_down resize the pool once at membership_round; preempt_rejoin
    # takes membership_k workers out for crash_downtime rounds; "plan" runs
    # the explicit (round, k) resize steps in membership_plan.
    membership_scenario: str = "static"
    membership_k: int = 0             # resize target / preempted count (0 = scenario default)
    membership_round: int = 0         # when the membership event fires (0 = rounds//2)
    membership_plan: Tuple[Tuple[int, int], ...] = ()  # "plan": (round, k) steps

    @property
    def cap(self) -> int:
        """Padded worker-axis length: ``capacity`` slots (>= num_workers),
        or exactly ``num_workers`` when capacity is left at 0."""
        return self.capacity or self.num_workers

    @property
    def hierarchical(self) -> bool:
        """True when the two-level coordinator is non-trivially configured
        (more than one rack, or an amortized global sync period). The
        trivial (1, 1) topology runs the flat coordinator — bit-exactly —
        unless a trainer forces the hierarchical state on for proofs."""
        return self.groups > 1 or self.global_period > 1

    def __post_init__(self):
        if self.comm_mode not in ("sequential", "fused"):
            raise ValueError(
                f"comm_mode must be 'sequential' or 'fused', "
                f"got {self.comm_mode!r}")
        if self.placement not in ("single", "sharded"):
            raise ValueError(
                f"placement must be 'single' or 'sharded', "
                f"got {self.placement!r}")
        if self.placement == "sharded" and self.comm_mode != "fused":
            raise ValueError(
                "placement='sharded' requires comm_mode='fused': the "
                "sequential backend is an event-ordered scan over workers "
                "and cannot be placed on disjoint mesh shards")
        if self.staleness not in (0, 1):
            raise ValueError(
                f"staleness must be 0 or 1, got {self.staleness!r}")
        if self.staleness and self.comm_mode != "fused":
            raise ValueError(
                "staleness=1 (delayed averaging) requires comm_mode='fused':"
                " the sequential backend is the paper's event-ordered scan "
                "against the live master, where a stale sync target has no "
                "consistent meaning")
        if self.failure_scenario not in FAILURE_SCENARIOS:
            raise ValueError(
                f"failure_scenario must be one of {FAILURE_SCENARIOS}, "
                f"got {self.failure_scenario!r}")
        if self.num_workers < 1:
            raise ValueError(
                f"num_workers must be >= 1, got {self.num_workers}")
        if self.capacity and self.capacity < self.num_workers:
            raise ValueError(
                f"capacity={self.capacity} must be >= "
                f"num_workers={self.num_workers} (capacity pads the worker "
                "axis; it cannot truncate the initial membership)")
        if self.hetero_dist not in ("lognormal", "bimodal"):
            raise ValueError(
                f"hetero_dist must be 'lognormal' or 'bimodal', "
                f"got {self.hetero_dist!r}")
        if self.hetero_sigma <= 0:
            raise ValueError(
                f"hetero_sigma must be > 0, got {self.hetero_sigma}")
        if not 0.0 <= self.hetero_slow_frac <= 1.0:
            raise ValueError(
                f"hetero_slow_frac must be in [0, 1], "
                f"got {self.hetero_slow_frac}")
        if not 0.0 < self.hetero_slow_scale <= 1.0:
            raise ValueError(
                f"hetero_slow_scale must be in (0, 1], "
                f"got {self.hetero_slow_scale}")
        if not 0.0 <= self.byzantine_frac < 1.0:
            raise ValueError(
                f"byzantine_frac must be in [0, 1) — at least one slot "
                f"must stay honest — got {self.byzantine_frac}")
        if self.byzantine_mode not in ("sign_flip", "scale", "noise"):
            raise ValueError(
                f"byzantine_mode must be 'sign_flip', 'scale' or 'noise', "
                f"got {self.byzantine_mode!r}")
        if self.byzantine_scale <= 0:
            raise ValueError(
                f"byzantine_scale must be > 0, got {self.byzantine_scale}")
        if self.score_clip < 0:
            raise ValueError(
                f"score_clip must be >= 0 (0 disables the clamp), "
                f"got {self.score_clip}")
        if self.u_zclip < 0:
            raise ValueError(
                f"u_zclip must be >= 0 (0 disables the absolute-distance "
                f"containment), got {self.u_zclip}")
        if self.groups < 1:
            raise ValueError(f"groups must be >= 1, got {self.groups}")
        if self.global_period < 1:
            raise ValueError(
                f"global_period must be >= 1, got {self.global_period}")
        if self.groups > self.cap:
            raise ValueError(
                f"groups={self.groups} exceeds the worker capacity "
                f"{self.cap} — a rack needs at least one slot")
        if self.hierarchical and self.comm_mode != "fused":
            raise ValueError(
                "hierarchical averaging (groups > 1 or global_period > 1) "
                "requires comm_mode='fused': the group sync reuses the "
                "batched scoring + event-order-equivalent reduction, and "
                "the sequential backend's serial master dependency has no "
                "per-rack meaning")
        if self.hierarchical and self.staleness:
            raise ValueError(
                "hierarchical averaging does not compose with staleness=1 "
                "(delayed averaging references the previous global master; "
                "under a hierarchy the workers' sync target is their "
                "sub-master, which has no one-round-stale snapshot)")
        if self.membership_scenario not in MEMBERSHIP_SCENARIOS:
            raise ValueError(
                f"membership_scenario must be one of {MEMBERSHIP_SCENARIOS},"
                f" got {self.membership_scenario!r}")
        if self.membership_scenario == "plan" and not self.membership_plan:
            raise ValueError(
                "membership_scenario='plan' needs a non-empty "
                "membership_plan of (round, k) steps")
        for step in self.membership_plan:
            r, k = step
            if r < 0 or not 1 <= k <= self.cap:
                raise ValueError(
                    f"membership_plan step {step}: need round >= 0 and "
                    f"1 <= k <= capacity ({self.cap})")


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adahessian"  # sgd | momentum | adam | adahessian
    lr: float = 0.01
    momentum: float = 0.5
    betas: Tuple[float, float] = (0.9, 0.999)
    eps: float = 1e-8
    weight_decay: float = 0.0
    hutchinson_samples: int = 1
    spatial_block: int = 128   # spatial-averaging block on last dim
    hessian_power: float = 1.0
    # Beyond-paper (§Perf): refresh the Hutchinson diagonal every h steps
    # (curvature moves slowly; AdaHessian's own delayed-Hessian discussion).
    # 1 = paper-faithful (every step).
    hessian_every: int = 1


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    remat: str = "none"  # none | full | dots
    seed: int = 0
    log_every: int = 10


ARCH_IDS = (
    "zamba2_7b",
    "llama4_scout_17b_a16e",
    "stablelm_3b",
    "h2o_danube_1_8b",
    "seamless_m4t_large_v2",
    "qwen3_4b",
    "mixtral_8x22b",
    "qwen2_vl_7b",
    "moonshot_v1_16b_a3b",
    "rwkv6_3b",
)

# CLI ids (hyphenated, as assigned) -> module names
ARCH_ALIASES = {
    "zamba2-7b": "zamba2_7b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "stablelm-3b": "stablelm_3b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "qwen3-4b": "qwen3_4b",
    "mixtral-8x22b": "mixtral_8x22b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "rwkv6-3b": "rwkv6_3b",
    "paper-cnn": "paper_cnn",
}


def normalize_arch(arch: str) -> str:
    return ARCH_ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{normalize_arch(arch)}")
    return mod.SMOKE if smoke else mod.CONFIG


def list_archs():
    return list(ARCH_IDS)


# long_500k eligibility (see DESIGN.md §Arch-applicability): sub-quadratic
# or windowed-context architectures only.
LONG_CONTEXT_OK = {
    "zamba2_7b",
    "rwkv6_3b",
    "h2o_danube_1_8b",
    "mixtral_8x22b",
    "llama4_scout_17b_a16e",
}


def shape_supported(arch: str, shape: str) -> bool:
    arch = normalize_arch(arch)
    if shape == "long_500k":
        return arch in LONG_CONTEXT_OK
    return True
