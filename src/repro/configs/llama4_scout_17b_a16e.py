"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert.

48L d_model=5120 40H (kv=8) d_ff=8192 vocab=202048; chunked causal attention
(8192) for long context (iRoPE-style). [hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    num_experts=16, top_k=1, num_shared_experts=1, expert_d_ff=8192,
    attention_chunk=8192, rope_theta=500000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)

SMOKE = CONFIG.replace(
    name="llama4-scout-smoke", num_layers=2, d_model=128, num_heads=4,
    num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=256, num_experts=4,
    expert_d_ff=64, attention_chunk=32,
)
