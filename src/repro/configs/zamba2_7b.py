"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000 ssm_state=64.
[arXiv:2411.15242 — Zamba2 technical report]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_conv_width=4, ssm_head_dim=64,
    attn_every=6, rope_theta=10000.0, tie_embeddings=True,
    source="arXiv:2411.15242",
)

SMOKE = CONFIG.replace(
    name="zamba2-smoke", num_layers=5, d_model=128, num_heads=4,
    num_kv_heads=4, d_ff=256, vocab_size=256, ssm_state=16, ssm_head_dim=32,
    attn_every=2, scan_chunk=16,
)
