"""mixtral-8x22b [moe] — 8 experts top-2, SWA.

56L d_model=6144 48H (kv=8) d_ff=16384 vocab=32768. [arXiv:2401.04088]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768,
    num_experts=8, top_k=2, expert_d_ff=16384,
    sliding_window=4096, rope_theta=1000000.0,
    source="arXiv:2401.04088",
)

SMOKE = CONFIG.replace(
    name="mixtral-smoke", num_layers=2, d_model=128, num_heads=4,
    num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=256, num_experts=4,
    expert_d_ff=64, sliding_window=32,
)
