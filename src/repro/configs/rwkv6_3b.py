"""rwkv6-3b [ssm] — RWKV-6 "Finch", data-dependent decay, attention-free.

32L d_model=2560 d_ff=8960 vocab=65536, head_dim 64. [arXiv:2404.05892]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="rwkv",
    num_layers=32, d_model=2560, num_heads=40,  # 2560/64 wkv heads
    d_ff=8960, vocab_size=65536,
    rwkv_head_dim=64, rope_mode="none", norm="layernorm",
    scan_chunk=16,  # vector-decay factored path needs small chunks (gla.py)
    source="arXiv:2404.05892",
)

SMOKE = CONFIG.replace(
    name="rwkv6-smoke", num_layers=2, d_model=128, num_heads=2, d_ff=256,
    vocab_size=256, scan_chunk=16,
)
