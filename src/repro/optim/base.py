"""Optimizer interface (optax-like, built from scratch — no optax dependency).

An optimizer is a pair of pure functions:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params, extras)

``extras`` carries optional second-order information (the Hutchinson Hessian
diagonal for AdaHessian). ``apply_updates`` adds updates to params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, extras) -> (updates, state)
    needs_hessian: bool = False


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(
            p.dtype),
        params, updates)


def tree_zeros_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    from repro.optim import adahessian, firstorder

    if cfg.name == "sgd":
        return firstorder.sgd(cfg)
    if cfg.name == "momentum":
        return firstorder.momentum(cfg)
    if cfg.name == "adam":
        return firstorder.adam(cfg)
    if cfg.name == "adahessian":
        return adahessian.adahessian(cfg)
    raise ValueError(f"unknown optimizer {cfg.name!r}")
