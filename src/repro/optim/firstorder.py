"""First-order optimizers: SGD, Momentum (paper's EAMSGD local rule), Adam."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.optim.base import Optimizer, tree_zeros_f32


def sgd(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None, extras=None):
        updates = jax.tree.map(lambda g: -cfg.lr * g.astype(jnp.float32),
                               grads)
        return updates, {"count": state["count"] + 1}

    return Optimizer(init, update)


def momentum(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32), "m": tree_zeros_f32(params)}

    def update(grads, state, params=None, extras=None):
        m = jax.tree.map(
            lambda v, g: cfg.momentum * v - cfg.lr * g.astype(jnp.float32),
            state["m"], grads)
        return m, {"count": state["count"] + 1, "m": m}

    return Optimizer(init, update)


def adam(cfg: OptimizerConfig) -> Optimizer:
    b1, b2 = cfg.betas

    def init(params):
        return {"count": jnp.zeros((), jnp.int32),
                "m": tree_zeros_f32(params), "v": tree_zeros_f32(params)}

    def update(grads, state, params=None, extras=None):
        t = state["count"] + 1
        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(
                g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        upd = jax.tree.map(
            lambda m_, v_: -cfg.lr * (m_ / bc1) / (
                jnp.sqrt(v_ / bc2) + cfg.eps),
            m, v)
        return upd, {"count": t, "m": m, "v": v}

    return Optimizer(init, update)
