"""AdaHessian (Yao et al., AAAI 2021) — the paper's worker-local optimizer.

Three components (paper §IV-B):
1. Hutchinson diagonal-Hessian estimate (see :mod:`repro.optim.hutchinson`);
   arrives via ``extras["hess_diag"]``.
2. Spatial averaging of the diagonal over neighbouring parameters (blocks of
   ``spatial_block`` along the last axis) to reduce variance.
3. Adam-style moments with the gradient second moment replaced by the
   (spatially averaged) Hessian diagonal, optionally raised to
   ``hessian_power``.

The fused elementwise update also exists as a Pallas TPU kernel
(``repro.kernels.adahessian``); this module is the jnp path / oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.optim.base import Optimizer, tree_zeros_f32


def spatial_average(h: jax.Array, block: int) -> jax.Array:
    """Average |h| within blocks along the last axis (AdaHessian eq. 9).

    For tensors whose last dim is smaller than ``block`` (biases, scales),
    averages the whole axis. Conv-style kernels average the leading spatial
    axes naturally since they fold into the last-axis blocks after reshape.
    """
    h = jnp.abs(h.astype(jnp.float32))
    if h.ndim == 0:
        return h
    d = h.shape[-1]
    b = min(block, d)
    if d % b != 0:
        b = 1
        for cand in range(min(block, d), 0, -1):
            if d % cand == 0:
                b = cand
                break
    shape = h.shape[:-1] + (d // b, b)
    hb = h.reshape(shape)
    return jnp.broadcast_to(
        jnp.mean(hb, axis=-1, keepdims=True), shape).reshape(h.shape)


def moment_update(cfg: OptimizerConfig, grads, state, params, hs):
    """Moments + bias-corrected step from an already spatially averaged
    Hessian diagonal ``hs``. Returns ``(updates, new_state)``.

    This is ``adahessian().update`` minus the spatial averaging — split out
    so the fused local phase (repro/core/coordinator.py), which averages
    per worker before stacking, can reuse the exact update expression. The
    batched Pallas kernel (``repro.kernels.adahessian``) mirrors these ops
    one-for-one; keep them in sync or interpret-mode bit-exactness breaks.
    """
    b1, b2 = cfg.betas
    t = state["count"] + 1
    m = jax.tree.map(
        lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
        state["m"], grads)
    v = jax.tree.map(
        lambda v_, h: b2 * v_ + (1 - b2) * jnp.square(h), state["v"], hs)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)
    denom_pow = cfg.hessian_power / 2.0

    def upd_fn(m_, v_):
        denom = jnp.power(v_ / bc2 + 1e-30, denom_pow) + cfg.eps
        u = -cfg.lr * (m_ / bc1) / denom
        if cfg.weight_decay:
            return u  # decoupled decay applied by caller if needed
        return u

    upd = jax.tree.map(upd_fn, m, v)
    if cfg.weight_decay and params is not None:
        upd = jax.tree.map(
            lambda u, p: u - cfg.lr * cfg.weight_decay * p.astype(
                jnp.float32), upd, params)
    return upd, {"count": t, "m": m, "v": v}


def adahessian(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32),
                "m": tree_zeros_f32(params), "v": tree_zeros_f32(params)}

    def update(grads, state, params=None, extras=None):
        assert extras is not None and "hess_diag" in extras, (
            "adahessian requires extras['hess_diag'] (Hutchinson estimate)")
        hs = jax.tree.map(
            lambda h: spatial_average(h, cfg.spatial_block),
            extras["hess_diag"])
        return moment_update(cfg, grads, state, params, hs)

    return Optimizer(init, update, needs_hessian=True)
