"""Hutchinson estimator for the Hessian diagonal (paper §IV-B / AdaHessian).

    diag(H) ≈ (1/n) Σ_i  z_i ⊙ (H z_i),   z_i ~ Rademacher

The Hessian-vector product uses forward-over-reverse AD:
``jvp(grad(loss))`` — one extra backprop-equivalent per probe, exactly the
cost the paper cites. Fully shardable: the probe z lives on the parameter
sharding, so the HVP's collectives mirror the gradient's.

Multi-probe accumulation runs as a ``lax.scan`` over the probe keys: the
jaxpr stays constant-size in ``num_samples`` (the old Python loop unrolled
one full HVP per probe). The scan threads the accumulator through the same
left-to-right ``jnp.add`` sequence, so the result is bit-exact with the
unrolled form (``tests/test_optim.py`` holds that line).

``hessian_diag_with_grad`` is the fused local phase's entry point
(repro/core/coordinator.py): one ``jax.linearize`` of ``grad_fn`` yields
the gradient as the primal *and* a cheap re-playable tangent map for every
probe, instead of evaluating ``value_and_grad`` and then re-deriving the
gradient inside each ``jvp``. The primal of ``jvp(grad_fn)`` is the same
computation as ``grad_fn`` itself, so the returned gradient is bit-exact
with the ``value_and_grad`` path.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def rademacher_like(rng: jax.Array, params):
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(rng, len(leaves))
    zs = [
        (2.0 * jax.random.bernoulli(k, 0.5, p.shape).astype(jnp.float32)
         - 1.0).astype(p.dtype)
        for k, p in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, zs)


def hvp(grad_fn: Callable, params, z):
    """H @ z via forward-over-reverse."""
    return jax.jvp(grad_fn, (params,), (z,))[1]


def _probe_scan(one: Callable, keys):
    """Left-fold ``one`` over ``keys[1:]`` starting from ``one(keys[0])`` —
    the same accumulation order as the unrolled loop, constant jaxpr size."""
    acc0 = one(keys[0])

    def step(acc, k):
        return jax.tree.map(jnp.add, acc, one(k)), None

    acc, _ = jax.lax.scan(step, acc0, keys[1:])
    return acc


def hessian_diag(grad_fn: Callable, params, rng: jax.Array,
                 num_samples: int = 1):
    """Hutchinson estimate of diag(H); returns an f32 pytree like params."""

    def one(rng_i):
        z = rademacher_like(rng_i, params)
        hz = hvp(grad_fn, params, z)
        return jax.tree.map(
            lambda a, b: (a.astype(jnp.float32) * b.astype(jnp.float32)),
            z, hz)

    if num_samples == 1:
        return one(rng)
    keys = jax.random.split(rng, num_samples)
    acc = _probe_scan(one, keys)
    return jax.tree.map(lambda x: x / num_samples, acc)


def hessian_diag_with_grad(grad_fn: Callable, params, rng: jax.Array,
                           num_samples: int = 1):
    """(grad, Hutchinson diag) sharing one linearization of ``grad_fn``.

    ``jax.linearize`` evaluates ``grad_fn`` once (the primal — bit-exact
    with ``value_and_grad``'s gradient) and returns the tangent map that
    every probe's HVP replays, so the gradient's backward pass is not
    re-derived per probe the way ``value_and_grad`` + ``jvp(grad_fn)``
    re-derives it.
    """
    grads, f_jvp = jax.linearize(grad_fn, params)

    def one(rng_i):
        z = rademacher_like(rng_i, params)
        hz = f_jvp(z)
        return jax.tree.map(
            lambda a, b: (a.astype(jnp.float32) * b.astype(jnp.float32)),
            z, hz)

    if num_samples == 1:
        return grads, one(rng)
    keys = jax.random.split(rng, num_samples)
    acc = _probe_scan(one, keys)
    return grads, jax.tree.map(lambda x: x / num_samples, acc)
