"""Hutchinson estimator for the Hessian diagonal (paper §IV-B / AdaHessian).

    diag(H) ≈ (1/n) Σ_i  z_i ⊙ (H z_i),   z_i ~ Rademacher

The Hessian-vector product uses forward-over-reverse AD:
``jvp(grad(loss))`` — one extra backprop-equivalent per probe, exactly the
cost the paper cites. Fully shardable: the probe z lives on the parameter
sharding, so the HVP's collectives mirror the gradient's.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def rademacher_like(rng: jax.Array, params):
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(rng, len(leaves))
    zs = [
        (2.0 * jax.random.bernoulli(k, 0.5, p.shape).astype(jnp.float32)
         - 1.0).astype(p.dtype)
        for k, p in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, zs)


def hvp(grad_fn: Callable, params, z):
    """H @ z via forward-over-reverse."""
    return jax.jvp(grad_fn, (params,), (z,))[1]


def hessian_diag(grad_fn: Callable, params, rng: jax.Array,
                 num_samples: int = 1):
    """Hutchinson estimate of diag(H); returns an f32 pytree like params."""

    def one(rng_i):
        z = rademacher_like(rng_i, params)
        hz = hvp(grad_fn, params, z)
        return jax.tree.map(
            lambda a, b: (a.astype(jnp.float32) * b.astype(jnp.float32)),
            z, hz)

    if num_samples == 1:
        return one(rng)
    keys = jax.random.split(rng, num_samples)
    acc = one(keys[0])
    for k in keys[1:]:
        acc = jax.tree.map(jnp.add, acc, one(k))
    return jax.tree.map(lambda x: x / num_samples, acc)
