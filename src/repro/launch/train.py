"""Training launcher — a thin argv shim over ``repro.api.ElasticSession``.

Two modes:
- ``--elastic``: the paper's system — k workers, τ-periodic dynamic-weight
  elastic sync, failure injection (this is the default and the point of the
  framework).
- plain: single-worker training (the k=1 limit), useful as a control.

On real hardware this runs under the production mesh; on CPU it runs the
same code on the host mesh. ``--arch`` takes any assigned architecture id
(smoke variant with ``--smoke``) or ``paper-cnn``. ``--rounds-per-call R``
executes R rounds per jit call (``ElasticTrainer.round_chunk``) —
bit-identical to per-round execution, but the per-round driver overhead is
paid once per chunk. ``--placement sharded`` (with ``--comm-mode fused``)
places the worker axis over the mesh's 'pod' axis via shard_map instead of
simulating all k workers on one device — master params stay bit-exact with
single placement; force a multi-device CPU host with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` to exercise it
without TPUs (with one device, sharded runs on a 1-way pod axis).
``--capacity C`` pads the worker axis to C slots so the pool can resize
live (``--membership-scenario`` / ``--membership-plan "2:2,4:6"``) with
zero recompiles; under sharded placement capacity is padded to a multiple
of the pod axis and the extra slots stay inactive.

Closed-loop control (ISSUE-6): ``--controller rules`` attaches the
detector→policy→actuator loop (``repro.control``) — suspect slots are
evicted and probed back in at chunk boundaries, from observable telemetry
only. ``--detector-blind`` additionally zeroes the ground-truth event masks
echoed into the printed records, so what you see is exactly what the
controller saw.

Hierarchy & scale-out (ISSUE-10): ``--groups G`` partitions the slot axis
into G rack-sized groups, each owning a sub-master that its workers
elastic-average against every round; ``--global-period P`` syncs the
sub-masters with the global master only every P rounds (τ_g = P·τ), so the
global barrier amortizes P× (``repro.core.coordinator._comm_phase_hier``).
``--coordinator-address host:port --num-processes N --process-id i`` spans
the mesh across N processes via ``jax.distributed`` (sharded placement
only; on CPU each process falls back to a local mesh — see
``make_distributed_mesh``). Only process 0 prints rounds; every process
prints the final master l2 for cross-process agreement checks.

Trace replay (ISSUE-9): ``--dump-trace run.jsonl`` records the exact
fail/straggle/restart/corrupt/speed/membership stream the run executed
(including controller-applied resizes) as a JSON-lines scenario trace;
``--trace run.jsonl`` replays a recorded trace instead of drawing a fresh
schedule — rounds/capacity are coerced to the recorded shape, so the replay
is bit-identical given the same seed and model flags. Adversarial knobs:
``--failure-scenario byzantine`` plus ``--byzantine-*`` injects gradient
corruption into a persistent subset of slots, and ``--score-clip`` arms the
robustness clamp that lets the master refuse their pulls
(``repro.core.dynamic_weight``); ``--failure-scenario hetero`` plus
``--hetero-*`` gives each slot a persistent step-rate drawn once per run.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.api import ElasticSession, RunSpec
from repro.configs.base import (FAILURE_SCENARIOS, MEMBERSHIP_SCENARIOS,
                                ElasticConfig, OptimizerConfig)
from repro.core.scenarios import (parse_membership_plan, read_trace,
                                  write_trace)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-cnn")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config of the arch family")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--rounds-per-call", type=int, default=1,
                    help="rounds executed inside one jit call (lax.scan "
                         "chunking; 1 = per-round dispatch)")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=0,
                    help="worker-slot capacity (>= --workers; 0 = exactly "
                         "--workers). Shapes are fixed at capacity, so "
                         "membership can resize up to it with zero "
                         "recompiles; under --placement sharded it is "
                         "padded up to a multiple of the pod axis")
    ap.add_argument("--membership-scenario", default="static",
                    choices=MEMBERSHIP_SCENARIOS,
                    help="planned worker-pool resize stream "
                         "(repro/core/scenarios.py); 'plan' runs "
                         "--membership-plan")
    ap.add_argument("--membership-k", type=int, default=0,
                    help="resize target (scale_up/scale_down) or preempted "
                         "count (preempt_rejoin); 0 = scenario default")
    ap.add_argument("--membership-round", type=int, default=0,
                    help="round the membership event fires (0 = mid-run)")
    ap.add_argument("--membership-plan", default="",
                    help="explicit resize steps 'round:k,round:k' (e.g. "
                         "'2:2,4:6'); implies --membership-scenario plan")
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--optimizer", default="adahessian")
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--overlap", type=float, default=0.25)
    ap.add_argument("--failure-prob", type=float, default=1 / 3)
    ap.add_argument("--failure-scenario", default="iid",
                    choices=FAILURE_SCENARIOS,
                    help="failure regime injected into the run "
                         "(see repro/core/scenarios.py)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="replay a recorded scenario trace (JSON-lines, "
                         "see repro.core.scenarios.read_trace) instead of "
                         "drawing a schedule; --rounds/--workers/--capacity "
                         "are coerced to the recorded shape")
    ap.add_argument("--dump-trace", default=None, metavar="PATH",
                    help="after the run, write the executed schedule "
                         "(including controller-applied membership) as a "
                         "replayable JSON-lines trace")
    ap.add_argument("--score-clip", type=float, default=0.0,
                    help="robustness clamp: raw scores above this give the "
                         "worker zero master weight and re-anchor it if it "
                         "diverged past float32 range; 0 = paper behaviour "
                         "(repro.core.dynamic_weight)")
    ap.add_argument("--u-zclip", type=float, default=0.0,
                    help="absolute-distance containment: refuse (w2=0) any "
                         "worker whose log-distance sits more than this "
                         "many robust z-scores (median/MAD over the live "
                         "pool) above the pool — catches attackers parked "
                         "at a static distance that score_clip's trend "
                         "clamp misses; 0 = off")
    ap.add_argument("--byzantine-frac", type=float, default=0.25,
                    help="fraction of slots drawn corrupt under "
                         "--failure-scenario byzantine")
    ap.add_argument("--byzantine-mode", default="sign_flip",
                    choices=("sign_flip", "scale", "noise"),
                    help="gradient corruption applied to corrupt slots")
    ap.add_argument("--byzantine-scale", type=float, default=5.0,
                    help="magnitude for the scale/noise corruption modes")
    ap.add_argument("--hetero-dist", default="lognormal",
                    choices=("lognormal", "bimodal"),
                    help="per-slot persistent speed distribution under "
                         "--failure-scenario hetero")
    ap.add_argument("--hetero-sigma", type=float, default=0.6,
                    help="lognormal sigma for --hetero-dist lognormal")
    ap.add_argument("--hetero-slow-frac", type=float, default=0.25,
                    help="fraction of slow slots for --hetero-dist bimodal")
    ap.add_argument("--hetero-slow-scale", type=float, default=0.25,
                    help="step-rate of slow slots for --hetero-dist bimodal")
    ap.add_argument("--no-dynamic", action="store_true")
    ap.add_argument("--comm-mode", default="sequential",
                    choices=("sequential", "fused"),
                    help="communication backend: event-ordered scan "
                         "(paper) or fused batched sync")
    ap.add_argument("--staleness", type=int, default=0, choices=(0, 1),
                    help="delayed averaging depth (DaSGD): 1 scores and "
                         "pulls against the previous round's master "
                         "snapshot so round r's exchange can overlap round "
                         "r+1's local compute (requires --comm-mode fused)")
    ap.add_argument("--use-pallas", action="store_true",
                    help="run the fused Pallas kernel paths (elastic comm, "
                         "batched AdaHessian local phase, model-internal "
                         "flash attention); interpret mode off-TPU. One "
                         "flag drives every kernel path (RunSpec is the "
                         "single source of truth)")
    ap.add_argument("--placement", default="single",
                    choices=("single", "sharded"),
                    help="worker placement: simulate all k workers on one "
                         "device, or shard_map the worker axis over the "
                         "mesh's 'pod' axis (requires --comm-mode fused; "
                         "k must divide over the device count)")
    ap.add_argument("--groups", type=int, default=1,
                    help="hierarchical averaging (ISSUE-10): partition the "
                         "slot axis into this many rack-sized groups, each "
                         "owning a sub-master that workers elastic-average "
                         "against every round; 1 = the flat topology "
                         "(requires --comm-mode fused when > 1)")
    ap.add_argument("--global-period", type=int, default=1,
                    help="rounds between sub-master↔global-master syncs "
                         "(τ_g = global_period·τ); the global master is "
                         "touched only every this many rounds")
    ap.add_argument("--coordinator-address", default=None, metavar="HOST:PORT",
                    help="multi-process mesh: jax.distributed coordinator "
                         "(process 0's address); launch one process per "
                         "host with matching --num-processes/--process-id")
    ap.add_argument("--num-processes", type=int, default=1,
                    help="total processes in the multi-process mesh")
    ap.add_argument("--process-id", type=int, default=0,
                    help="this process's index in 0..num_processes-1")
    ap.add_argument("--controller", default="none",
                    choices=("none", "rules"),
                    help="closed-loop membership control (repro.control): "
                         "'rules' runs the failure detector + rule policy "
                         "and applies evict/readmit at chunk boundaries")
    ap.add_argument("--detector-blind", action="store_true",
                    help="echo a mask-zeroed schedule view into records "
                         "(the controller never sees ground truth anyway; "
                         "this blinds the printed records too)")
    ap.add_argument("--elastic", action="store_true", default=True)
    ap.add_argument("--plain", dest="elastic", action="store_false")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-seed", type=int, default=0,
                    help="synthetic dataset generation seed; fixed by "
                         "default so --seed sweeps vary only init/batching/"
                         "schedule on identical data (the §VI convention)")
    ap.add_argument("--save", default=None)
    args = ap.parse_args(argv)

    membership = args.membership_scenario
    plan = ()
    if args.membership_plan:
        membership = "plan"
        plan = parse_membership_plan(args.membership_plan)
    capacity = args.capacity
    schedule = None
    if args.trace:
        schedule = read_trace(args.trace)
        rounds, cap = schedule.fail.shape
        if (args.rounds, capacity or args.workers) != (rounds, cap):
            print(f"[train] trace {args.trace}: coercing rounds/capacity "
                  f"to the recorded ({rounds}, {cap})")
        args.rounds, capacity = rounds, cap
        args.workers = (int(schedule.active[0].sum())
                        if schedule.active is not None else cap)
        membership, plan = "static", ()  # the trace carries membership
    if membership != "static" and not capacity:
        # resize needs headroom: default the slot pool to the largest
        # worker count the scheduled stream ever reaches; a scale_up with
        # no explicit target grows into its headroom, so give it some
        capacity = max([args.workers, args.membership_k]
                       + [k for _, k in plan])
        if membership == "scale_up" and not args.membership_k:
            capacity = 2 * args.workers
    mesh = None
    if args.num_processes > 1 or args.coordinator_address:
        # multi-process mesh (ISSUE-10): initialize jax.distributed and
        # span the pod axis over every process's devices (process-local
        # fallback on CPU — see make_distributed_mesh)
        if args.placement != "sharded":
            raise SystemExit(
                "--coordinator-address/--num-processes need "
                "--placement sharded (the worker axis must live on the "
                "mesh for a multi-process run to mean anything)")
        from repro.launch.mesh import make_distributed_mesh

        mesh = make_distributed_mesh(
            coordinator_address=args.coordinator_address,
            num_processes=args.num_processes, process_id=args.process_id)
    if args.placement == "sharded":
        # the slot axis partitions evenly over the pod axis; pad capacity
        # up and leave the extra slots permanently inactive (uneven-shard
        # masking: shards hold equal slots, not equal live workers)
        import jax

        from repro.core.coordinator import padded_capacity

        n_pod = mesh.shape["pod"] if mesh is not None else jax.device_count()
        padded = padded_capacity(capacity or args.workers, n_pod)
        if padded != (capacity or args.workers):
            print(f"[train] padding capacity {capacity or args.workers} -> "
                  f"{padded} (multiple of the {n_pod}-way pod "
                  "axis; extra slots stay inactive)")
            capacity = padded
    ecfg = ElasticConfig(
        num_workers=args.workers, capacity=capacity, tau=args.tau,
        alpha=args.alpha, overlap_ratio=args.overlap,
        failure_prob=args.failure_prob,
        dynamic=not args.no_dynamic, comm_mode=args.comm_mode,
        staleness=args.staleness, placement=args.placement,
        failure_scenario=args.failure_scenario,
        score_clip=args.score_clip, u_zclip=args.u_zclip,
        byzantine_frac=args.byzantine_frac,
        byzantine_mode=args.byzantine_mode,
        byzantine_scale=args.byzantine_scale,
        hetero_dist=args.hetero_dist, hetero_sigma=args.hetero_sigma,
        hetero_slow_frac=args.hetero_slow_frac,
        hetero_slow_scale=args.hetero_slow_scale,
        groups=args.groups, global_period=args.global_period,
        membership_scenario=membership, membership_k=args.membership_k,
        membership_round=args.membership_round, membership_plan=plan)
    spec = RunSpec(
        schedule=schedule,
        arch=args.arch, smoke=args.smoke,
        optimizer=OptimizerConfig(name=args.optimizer, lr=args.lr),
        elastic=ecfg, rounds=args.rounds,
        rounds_per_call=args.rounds_per_call, seed=args.seed,
        plain=not args.elastic, batch_size=args.batch_size,
        seq_len=args.seq_len, n_data=8000, n_test=1000,
        data_seed=args.data_seed, save_path=args.save,
        use_pallas=args.use_pallas,
        controller=(None if args.controller == "none" else args.controller),
        detector_blind=args.detector_blind)
    sess = ElasticSession(spec, mesh=mesh)

    # multi-process runs: only process 0 narrates rounds (every process
    # still executes them; the final master-l2 line prints everywhere so a
    # launcher can assert cross-process agreement)
    is_main = args.process_id == 0
    t0 = time.time()
    if is_main and not spec.plain and sess.schedule.has_hetero:
        print(f"[train] persistent slot speeds: "
              f"{np.asarray(sess.schedule.speed[0]).round(3).tolist()}",
              flush=True)
    for rec in sess.run_iter():
        if not is_main:
            continue
        if spec.plain:
            print(f"step {rec.round}: loss={rec.loss:.4f}", flush=True)
            continue
        extra = ""
        if sess.schedule.has_membership or sess.controller is not None:
            extra += f" k={rec.num_active}/{sess.capacity}"
        if sess.schedule.has_stragglers:
            extra += f" straggle={rec.straggle.astype(int).tolist()}"
        if sess.schedule.has_restarts:
            extra += f" restart={rec.restart.astype(int).tolist()}"
        if sess.schedule.has_corruption:
            extra += f" corrupt={rec.corrupt.astype(int).tolist()}"
        if rec.g_h2 is not None and np.any(rec.g_h2):
            extra += f" g_h2={np.asarray(rec.g_h2).round(3).tolist()}"
        print(f"round {rec.round}: loss={rec.loss:.4f} "
              f"fails={rec.fail.astype(int).tolist()} "
              f"score={np.asarray(rec.score).round(3).tolist()} "
              f"h2={np.asarray(rec.h2).round(3).tolist()}{extra} "
              f"({time.time()-t0:.1f}s)", flush=True)
    # every process prints this (deterministic cross-process agreement
    # check for the distributed smoke: identical programs → identical l2)
    import jax

    l2 = float(np.sqrt(sum(
        float(np.sum(np.square(np.asarray(x, np.float64))))
        for x in jax.tree.leaves(sess.master_params))))
    print(f"[train] final master l2={l2:.10e}", flush=True)
    if sess.controller is not None:
        applied = [a for a in sess.controller.actuator.log if a.applied]
        print(f"[control] {len(applied)} membership action(s) applied:")
        for a in applied:
            print(f"[control]   round {a.round}: {a.action.describe()} "
                  f"-> {a.live_after} live")
    if args.dump_trace and sess.schedule is not None:
        write_trace(args.dump_trace, sess.schedule)
        print(f"[train] wrote scenario trace to {args.dump_trace}")
    if args.save:
        print(f"saved master params to {args.save}")


if __name__ == "__main__":
    main()
