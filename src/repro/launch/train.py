"""Training launcher.

Two modes:
- ``--elastic``: the paper's system — k workers, τ-periodic dynamic-weight
  elastic sync, failure injection (this is the default and the point of the
  framework).
- plain: single-worker training (the k=1 limit), useful as a control.

On real hardware this runs under the production mesh; on CPU it runs the
same code on the host mesh. ``--arch`` takes any assigned architecture id
(smoke variant with ``--smoke``) or ``paper-cnn``.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint
from repro.configs.base import (FAILURE_SCENARIOS, ElasticConfig,
                                OptimizerConfig, ShapeConfig, get_config)
from repro.core.coordinator import ElasticTrainer
from repro.core.scenarios import make_scenario
from repro.data.pipeline import TokenWorkerBatcher, WorkerBatcher
from repro.data.synthetic import SyntheticImages, SyntheticTokens
from repro.models.registry import build_model
from repro.train.steps import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-cnn")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config of the arch family")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--optimizer", default="adahessian")
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--overlap", type=float, default=0.25)
    ap.add_argument("--failure-prob", type=float, default=1 / 3)
    ap.add_argument("--failure-scenario", default="iid",
                    choices=FAILURE_SCENARIOS,
                    help="failure regime injected into the run "
                         "(see repro/core/scenarios.py)")
    ap.add_argument("--no-dynamic", action="store_true")
    ap.add_argument("--comm-mode", default="sequential",
                    choices=("sequential", "fused"),
                    help="communication backend: event-ordered scan "
                         "(paper) or fused batched sync")
    ap.add_argument("--elastic", action="store_true", default=True)
    ap.add_argument("--plain", dest="elastic", action="store_false")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    ocfg = OptimizerConfig(name=args.optimizer, lr=args.lr)

    if cfg.family == "cnn":
        ds = SyntheticImages(n=8000, n_test=1000)
        make_batcher = lambda ecfg: WorkerBatcher(
            ds.images, ds.labels, ecfg, batch_size=args.batch_size,
            seed=args.seed)
    else:
        toks = SyntheticTokens(vocab=cfg.vocab_size, n_tokens=100_000,
                               seed=args.seed)
        ds = None
        make_batcher = lambda ecfg: TokenWorkerBatcher(
            toks.tokens, ecfg, batch_size=args.batch_size,
            seq_len=args.seq_len, seed=args.seed)

    if not args.elastic:
        state = init_train_state(model, ocfg, jax.random.key(args.seed))
        step = jax.jit(make_train_step(model, ocfg))
        ecfg = ElasticConfig(num_workers=1, tau=1, overlap_ratio=0.0,
                             failure_prob=0.0)
        wb = make_batcher(ecfg)
        for r in range(args.rounds):
            b = {k: jnp.asarray(v[0, 0]) for k, v in
                 wb.round_batches().items()}
            state, m = step(state, b, jax.random.key(r))
            print(f"step {r}: loss={float(m['loss']):.4f}", flush=True)
        if args.save:
            checkpoint.save(args.save, state["params"])
        return

    ecfg = ElasticConfig(
        num_workers=args.workers, tau=args.tau, alpha=args.alpha,
        overlap_ratio=args.overlap, failure_prob=args.failure_prob,
        dynamic=not args.no_dynamic, comm_mode=args.comm_mode,
        failure_scenario=args.failure_scenario)
    trainer = ElasticTrainer(model, ocfg, ecfg)
    state = trainer.init_state(jax.random.key(args.seed))
    wb = make_batcher(ecfg)
    sched = make_scenario(ecfg).schedule(args.seed + 7, args.rounds,
                                         args.workers)
    t0 = time.time()
    for r in range(args.rounds):
        batches = {k: jnp.asarray(v) for k, v in wb.round_batches().items()}
        fail = jnp.asarray(sched.fail[r])
        recent = jnp.asarray(sched.failed_recent(r, ecfg.score_window))
        # keep the None fast path (single trace) when a mask never fires
        straggle = (jnp.asarray(sched.straggle[r])
                    if sched.has_stragglers else None)
        restart = (jnp.asarray(sched.restart[r])
                   if sched.has_restarts else None)
        state, m = trainer.round_step(
            state, batches, jax.random.key(args.seed * 997 + r), fail,
            recent, straggle, restart)
        extra = ""
        if sched.has_stragglers:
            extra += f" straggle={sched.straggle[r].astype(int).tolist()}"
        if sched.has_restarts:
            extra += f" restart={sched.restart[r].astype(int).tolist()}"
        print(f"round {r}: loss={float(m['loss']):.4f} "
              f"fails={sched.fail[r].astype(int).tolist()} "
              f"score={np.asarray(m['score']).round(3).tolist()} "
              f"h2={np.asarray(m['h2']).round(3).tolist()}{extra} "
              f"({time.time()-t0:.1f}s)", flush=True)
    if args.save:
        checkpoint.save(args.save, state["master"],
                        metadata={"rounds": args.rounds})
        print(f"saved master params to {args.save}")


if __name__ == "__main__":
    main()
