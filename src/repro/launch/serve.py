"""Serving launcher: batched greedy generation with any --arch.

On real TPU hardware this would run under make_production_mesh(); on CPU it
serves the reduced family variant. decode_32k / long_500k production
lowering is exercised by launch/dryrun.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import checkpoint
from repro.configs.base import get_config
from repro.models.registry import build_model
from repro.nn.param import init_tree, param_count
from repro.serving.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--restore", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = init_tree(jax.random.key(0), model.spec)
    if args.restore:
        # check the manifest before paying for (or crashing inside) the
        # restore: a genuinely different arch fails on missing params, and
        # the warning tells the user why
        meta = checkpoint.read_metadata(args.restore)
        ck_arch = meta.get("arch")
        if ck_arch is not None and ck_arch != cfg.name:
            print(f"[serve] WARNING: checkpoint {args.restore!r} was saved "
                  f"from arch {ck_arch!r} but --arch resolves to "
                  f"{cfg.name!r} — the restore below will fail unless the "
                  "parameter trees happen to match; double-check the flags")
        params, _ = checkpoint.restore(args.restore, like=params)
        if meta.get("rounds") is not None:
            print(f"[serve] restored {args.restore} "
                  f"(arch={ck_arch or '?'}, rounds={meta['rounds']})")
    print(f"serving {cfg.name}: {param_count(model.spec):,} params")
    engine = ServeEngine(model, params,
                         max_len=args.prompt_len + args.steps + 1)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype("int32")
    for trial in range(2):
        t0 = time.time()
        out = engine.generate(prompts, steps=args.steps)
        dt = time.time() - t0
        print(f"trial {trial}: {out.size} tokens in {dt:.2f}s "
              f"({out.size/dt:.0f} tok/s)")


if __name__ == "__main__":
    main()
