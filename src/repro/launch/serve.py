"""Serving launcher: static reference batches or continuous batching.

Two modes share the arch/restore plumbing:

- **static** (default): one fixed batch through ``ServeEngine.generate``,
  two timed trials (trial 0 is labelled — it includes jit compile).
  Throughput counts *real* generated tokens: with ``--eos-id`` set, a
  row's EOS-pinned padding positions are excluded.
- **continuous** (``--traffic N``): N synthetic bursty requests replayed
  through ``Scheduler`` + ``ContinuousEngine`` on the virtual clock,
  reporting sustained req/s and p50/p99 latency. ``--watch DIR`` attaches
  a ``CheckpointWatcher`` so a running ``ElasticSession`` saving into DIR
  hot-swaps the served params mid-run.

On real TPU hardware this would run under make_production_mesh(); on CPU
it serves the reduced family variant. decode_32k / long_500k production
lowering is exercised by launch/dryrun.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import checkpoint
from repro.configs.base import get_config
from repro.models.registry import build_model
from repro.nn.param import init_tree, param_count
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import ServeEngine
from repro.serving.hotswap import CheckpointWatcher
from repro.serving.scheduler import Scheduler
from repro.serving.traffic import TrafficConfig, synthetic_traffic


def generated_tokens(out: np.ndarray, eos_id=None) -> int:
    """Real generated-token count for a ``ServeEngine.generate`` output:
    positions after a row's first EOS are pinned padding, not throughput."""
    if eos_id is None:
        return int(out.size)
    total = 0
    for row in np.asarray(out):
        hits = np.flatnonzero(row == eos_id)
        total += int(hits[0]) + 1 if hits.size else row.size
    return total


def _serve_static(model, params, args, vocab_size: int) -> None:
    engine = ServeEngine(model, params,
                         max_len=args.prompt_len + args.steps + 1)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, vocab_size,
                           (args.batch, args.prompt_len)).astype("int32")
    for trial in range(2):
        t0 = time.time()
        out = engine.generate(prompts, steps=args.steps,
                              eos_id=args.eos_id)
        dt = time.time() - t0
        toks = generated_tokens(out, args.eos_id)
        label = " (incl. jit compile)" if trial == 0 else ""
        print(f"trial {trial}{label}: {toks} tokens in {dt:.2f}s "
              f"({toks/dt:.0f} tok/s)")


def _serve_continuous(model, params, args, vocab_size: int) -> None:
    engine = ContinuousEngine(
        model, params, capacity=args.capacity,
        max_len=args.prompt_len + args.steps + 1,
        prefill_len=args.prompt_len, eos_id=args.eos_id)
    watcher = None
    if args.watch:
        watcher = CheckpointWatcher(engine, args.watch)
        print(f"[serve] watching {args.watch} for new checkpoints "
              f"(arch guard: {watcher.expect_arch})")
    sched = Scheduler(engine, watcher=watcher,
                      poll_every=args.poll_every)
    trace = synthetic_traffic(TrafficConfig(
        num_requests=args.traffic,
        prompt_lens=tuple(sorted({max(1, args.prompt_len // 2),
                                  args.prompt_len})),
        max_new=args.steps, vocab_size=vocab_size,
        eos_id=args.eos_id, seed=0))
    results = sched.run(trace)
    served = [r for r in results if r.reason != "rejected"]
    lat = np.array([r.latency for r in served]) if served else np.zeros(1)
    toks = sum(r.num_tokens for r in served)
    span = max(sched.vnow, 1e-9)
    print(f"served {len(served)}/{len(results)} requests, {toks} tokens "
          f"over {span:.2f}s virtual ({len(served)/span:.1f} req/s, "
          f"{toks/span:.0f} tok/s)")
    print(f"latency p50 {np.percentile(lat, 50)*1e3:.0f}ms "
          f"p99 {np.percentile(lat, 99)*1e3:.0f}ms")
    if watcher is not None:
        print(f"[serve] hot-swaps applied: {watcher.swaps_applied}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--restore", default=None)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="token id that ends a generation (static mode "
                         "pins finished rows; continuous mode frees the "
                         "slot)")
    ap.add_argument("--capacity", type=int, default=8,
                    help="continuous mode: request-slot pool size")
    ap.add_argument("--traffic", type=int, default=0, metavar="N",
                    help="serve N synthetic bursty requests through the "
                         "continuous engine (0 = static reference mode)")
    ap.add_argument("--watch", default=None, metavar="DIR",
                    help="continuous mode: hot-swap params from new "
                         "checkpoints appearing in DIR")
    ap.add_argument("--poll-every", type=int, default=8,
                    help="decode ticks between --watch polls")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = init_tree(jax.random.key(0), model.spec)
    if args.restore:
        # check the manifest before paying for (or crashing inside) the
        # restore: a genuinely different arch fails on missing params, and
        # the warning tells the user why
        meta = checkpoint.read_metadata(args.restore)
        ck_arch = meta.get("arch")
        if ck_arch is not None and ck_arch != cfg.name:
            print(f"[serve] WARNING: checkpoint {args.restore!r} was saved "
                  f"from arch {ck_arch!r} but --arch resolves to "
                  f"{cfg.name!r} — the restore below will fail unless the "
                  "parameter trees happen to match; double-check the flags")
        params, _ = checkpoint.restore(args.restore, like=params)
        if meta.get("rounds") is not None:
            print(f"[serve] restored {args.restore} "
                  f"(arch={ck_arch or '?'}, rounds={meta['rounds']})")
    print(f"serving {cfg.name}: {param_count(model.spec):,} params")
    if args.traffic > 0:
        _serve_continuous(model, params, args, cfg.vocab_size)
    else:
        _serve_static(model, params, args, cfg.vocab_size)


if __name__ == "__main__":
    main()
