"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

MUST be the very first two lines (jax locks the device count on first init):
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis.hlo import collective_bytes  # noqa: E402
from repro.configs.base import (INPUT_SHAPES, OptimizerConfig,  # noqa: E402
                                get_config, list_archs, normalize_arch,
                                shape_supported)
from repro.core.coordinator import (ElasticTrainer, RoundInputs,  # noqa: E402
                                    padded_capacity)
from repro.configs.base import ElasticConfig  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.registry import build_model  # noqa: E402
from repro.nn.param import (ParamSpec, abstract_tree, stack_specs,  # noqa: E402
                            tree_map_spec)
from repro.nn.sharding import physical_spec, tree_pspecs  # noqa: E402
from repro.train.steps import (abstract_train_state,  # noqa: E402
                               make_serve_step, make_train_step,
                               train_state_pspecs)


# §Perf hillclimb rule-set overrides (see EXPERIMENTS.md §Perf)
RULE_SETS = {
    "baseline": None,
    # Megatron-style sequence parallelism: shard the residual stream's
    # sequence dim over 'model' (norm/elementwise run on S/16 tokens; GSPMD
    # gathers at attention/MLP entry, reduce-scatters at exit)
    "seqpar": {"seq": "model"},
    # tensor-parallel expert FFNs for MoE archs whose expert count does not
    # divide the model axis (mixtral 8e on a 16-way axis)
    "expert_tp": {"expert_mlp": "model"},
    "seqpar_expert_tp": {"seq": "model", "expert_mlp": "model"},
    # keep MoE dispatch buffers data-local (no expert-sharded activation
    # constraint): expert weights are all-gathered per layer instead of
    # resharding the (B,E,C,d) token buffers — wins when weight bytes ≪
    # token-buffer bytes (moonshot: 64 small experts)
    "moe_local": {"act_expert": None},
    "moe_local_seqpar": {"act_expert": None, "seq": "model"},
}


def _adapt_cfg(cfg, shape_name):
    """Shape-specific faithful adjustments (DESIGN.md §long_500k)."""
    if shape_name == "long_500k" and cfg.family == "hybrid":
        # zamba2's shared attention block runs SWA at 500k context
        cfg = cfg.replace(sliding_window=4096)
    return cfg


def _named(tree_pspec, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_pspec,
        is_leaf=lambda x: isinstance(x, P))


def _abstract_pod(spec_tree, mesh, pod_dim=0):
    """ParamSpec pytree → ShapeDtypeStructs sharded over 'pod' on axis
    ``pod_dim`` (the worker axis) and replicated elsewhere — the layouts the
    fully-manual sharded round holds its state in (coordinator
    ``_round_sharded``: per-worker tensors are replicated over any
    'data'/'model' axes until XLA's partial-auto partitioner can take
    them)."""
    def struct(st):
        sh = NamedSharding(mesh, P(*([None] * pod_dim), "pod"))
        return jax.ShapeDtypeStruct(st.shape, st.dtype, sharding=sh)

    return jax.tree.map(struct, abstract_tree(spec_tree))


def _abstract_inputs(model, shape, mesh, rules=None):
    specs = model.input_specs(shape)
    structs = {k: jax.ShapeDtypeStruct(s.shape, s.dtype)
               for k, s in specs.items()}
    shardings = {
        k: NamedSharding(mesh, physical_spec(s.shape, s.axes, mesh, rules))
        for k, s in specs.items()}
    return structs, shardings


def _analyse(lowered, compiled, mesh, elapsed):
    n_dev = mesh.devices.size
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):  # older jax returns [dict] per device
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception:
        mem_d = {}
    try:
        hlo_text = compiled.as_text()
        coll = collective_bytes(hlo_text)
    except Exception:
        hlo_text, coll = "", {"total": None}
    # loop-aware re-accounting: XLA's cost_analysis visits while bodies
    # once, undercounting scanned layer stacks ~L× (see analysis/hlo_cost)
    try:
        from repro.analysis.hlo_cost import loop_aware_costs

        la = loop_aware_costs(hlo_text)
    except Exception as e:  # noqa: BLE001
        la = {"dot_flops": None, "bytes": None, "coll": {},
              "coll_total": None, "error": str(e)}
    return {
        "devices": int(n_dev),
        "flops_per_device": cost.get("flops"),
        "bytes_per_device": cost.get("bytes accessed"),
        "collective_bytes_per_device": coll,
        "loop_aware": {
            "dot_flops_per_device": la.get("dot_flops"),
            "bytes_per_device": la.get("bytes"),
            "collective_bytes_per_device": la.get("coll"),
            "collective_total_per_device": la.get("coll_total"),
            # loop multipliers (with-loops ÷ trip1) for calibrating
            # cost_analysis numbers — see analysis/hlo_cost.py
            "flops_multiplier": (la["dot_flops"] / la["dot_flops_trip1"]
                                 if la.get("dot_flops_trip1") else None),
            "bytes_multiplier": (la["bytes"] / la["bytes_trip1"]
                                 if la.get("bytes_trip1") else None),
            "coll_multiplier": (la["coll_total"] / la["coll_total_trip1"]
                                if la.get("coll_total_trip1") else None),
        },
        "memory": mem_d,
        "compile_s": round(elapsed, 1),
    }


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               opt_name: str = "adahessian", remat: str = "none",
               rules=None, elastic_workers: int = 2,
               elastic_capacity: int = 0, groups: int = 1,
               global_period: int = 1):
    arch = normalize_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    if not shape_supported(arch, shape_name):
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped",
                "reason": "full-attention arch at 500k (DESIGN.md)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = _adapt_cfg(get_config(arch), shape_name)
    model = build_model(cfg)
    opt_cfg = OptimizerConfig(name=opt_name)
    t0 = time.time()

    if shape.kind == "train" and multi_pod:
        # The paper's technique in production form, through the *real*
        # sharded backend (ISSUE-4): ElasticTrainer's shard_mapped round —
        # worker axis manual over 'pod', per-worker model left to GSPMD on
        # the ('data', 'model') auto axes. Identical code to what
        # `--placement sharded` executes on a host mesh; no dryrun-private
        # lowering of the round anymore.
        k = elastic_workers
        # the slot axis is capacity-padded to a multiple of the pod axis
        # (uneven-shard masking, ISSUE-5); with no --elastic-capacity the
        # pool is exactly k slots, as before
        cap = padded_capacity(elastic_capacity or k, mesh.shape["pod"])
        ecfg = ElasticConfig(num_workers=k,
                             capacity=(0 if cap == k else cap),
                             tau=1, comm_mode="fused", placement="sharded",
                             groups=groups, global_period=global_period)
        trainer = ElasticTrainer(model, opt_cfg, ecfg, mesh=mesh)
        wspec = stack_specs(model.spec, cap, "worker")
        f32spec = tree_map_spec(
            lambda s: ParamSpec(s.shape, jnp.float32, s.init, s.axes), wspec)
        mspec = tree_map_spec(
            lambda s: ParamSpec(s.shape, jnp.float32, s.init, s.axes),
            model.spec)
        in_specs = model.input_specs(shape)
        per_worker = {
            name: ParamSpec((1, cap, s.shape[0] // cap) + s.shape[1:],
                            s.dtype, axes=(None, "worker") + s.axes)
            for name, s in in_specs.items()}
        rep = NamedSharding(mesh, P())
        state = {
            "workers": _abstract_pod(wspec, mesh),
            "opt": {"count": _abstract_pod(
                        ParamSpec((cap,), jnp.int32, axes=("worker",)),
                        mesh),
                    "m": _abstract_pod(f32spec, mesh),
                    "v": _abstract_pod(f32spec, mesh)},
            "master": jax.tree.map(
                lambda st: jax.ShapeDtypeStruct(st.shape, st.dtype,
                                                sharding=rep),
                abstract_tree(mspec)),
            "u_hist": _abstract_pod(
                ParamSpec((cap, ecfg.score_window), jnp.float32), mesh),
            "round": jax.ShapeDtypeStruct((), jnp.int32, sharding=rep),
        }
        state["master_prev"] = state["master"]
        if trainer._hier:
            # hierarchical lowering (ISSUE-10): replicated (G, ...)
            # sub-master trees + rack-level history, like the master
            G = trainer._n_groups
            state["submasters"] = jax.tree.map(
                lambda st: jax.ShapeDtypeStruct((G,) + st.shape, st.dtype,
                                                sharding=rep),
                abstract_tree(mspec))
            state["g_u_hist"] = jax.ShapeDtypeStruct(
                (G, ecfg.score_window), jnp.float32, sharding=rep)
        slot_mask = lambda: _abstract_pod(ParamSpec((cap,), jnp.bool_), mesh)
        inputs = RoundInputs(
            batches=_abstract_pod(per_worker, mesh, pod_dim=1),
            rng=jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=rep),
            fail=slot_mask(),
            failed_recent=slot_mask(),
            # capacity-padded pools lower the masked round (live-membership
            # select + join re-seat in the graph); exact-fit pools keep the
            # fixed-k specialized trace
            active=slot_mask() if cap > k else None,
            join=slot_mask() if cap > k else None)
        jitted = jax.jit(
            lambda s, i: trainer._round_sharded(s, i, chunk=False),
            donate_argnums=(0,))
        # no `with mesh:` here — the sharded round carries its own mesh via
        # shard_map, and an *active* mesh context would turn the model's
        # internal logical_constraints into manual-axis violations (they
        # no-op at runtime too; the session never enters a mesh context)
        lowered = jitted.lower(state, inputs)
        compiled = lowered.compile()
        out = _analyse(lowered, compiled, mesh, time.time() - t0)
        out["lowered_kind"] = "elastic_round_step_sharded"

    elif shape.kind == "train":
        from repro.configs.base import TrainConfig

        if opt_name == "adahessian_stale":
            # beyond-paper lazy-Hessian off-refresh step (§Perf)
            from repro.train.steps import make_train_step_stale_hessian

            opt_cfg = OptimizerConfig(name="adahessian")
            train_step = make_train_step_stale_hessian(
                model, opt_cfg, TrainConfig(remat=remat))
        else:
            train_step = make_train_step(model, opt_cfg,
                                         TrainConfig(remat=remat))
        state = abstract_train_state(model, opt_cfg)
        state_sh = _named(train_state_pspecs(model, opt_cfg, mesh, rules),
                          mesh)
        batch, batch_sh = _abstract_inputs(model, shape, mesh, rules)
        rep = NamedSharding(mesh, P())
        jitted = jax.jit(train_step, in_shardings=(state_sh, batch_sh, rep),
                         donate_argnums=(0,))
        rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
        with mesh:
            lowered = jitted.lower(state, batch, rng)
            compiled = lowered.compile()
        out = _analyse(lowered, compiled, mesh, time.time() - t0)
        out["lowered_kind"] = "train_step"

    else:
        # serving: prefill or decode
        params = abstract_tree(model.spec)
        params_sh = _named(tree_pspecs(model.spec, mesh, rules), mesh)
        cache_len = shape.seq_len
        B = shape.global_batch
        cache_spec = model.cache_spec(B, cache_len)
        cache = abstract_tree(cache_spec)
        cache_sh = _named(tree_pspecs(cache_spec, mesh, rules), mesh)
        batch, batch_sh = _abstract_inputs(model, shape, mesh, rules)
        rep = NamedSharding(mesh, P())
        if shape.kind == "prefill":
            step = make_serve_step(model, "prefill")
            jitted = jax.jit(step,
                             in_shardings=(params_sh, batch_sh, cache_sh),
                             donate_argnums=(2,))
            args = (params, batch, cache)
        else:
            step = make_serve_step(model, "decode")
            jitted = jax.jit(
                step, in_shardings=(params_sh, batch_sh, cache_sh, rep),
                donate_argnums=(2,))
            args = (params, batch, cache,
                    jax.ShapeDtypeStruct((), jnp.int32))
        with mesh:
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
        out = _analyse(lowered, compiled, mesh, time.time() - t0)
        out["lowered_kind"] = f"serve_step/{shape.kind}"

    out.update({"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "ok", "optimizer": opt_name, "remat": remat,
                "rules": rules or {}})
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--opt", default="adahessian")
    ap.add_argument("--remat", default="none", choices=["none", "full"])
    ap.add_argument("--rules", default="baseline",
                    choices=sorted(RULE_SETS))
    ap.add_argument("--elastic-workers", type=int, default=2,
                    help="initial live workers in the multi-pod elastic "
                         "train lowering")
    ap.add_argument("--capacity", type=int, default=0,
                    help="worker-slot capacity for the elastic lowering "
                         "(0 = exactly --elastic-workers); padded up to a "
                         "multiple of the pod axis, extra slots inactive — "
                         "capacities > workers lower the membership-masked "
                         "round")
    ap.add_argument("--groups", type=int, default=1,
                    help="hierarchical elastic lowering (ISSUE-10): rack "
                         "count for the sub-master level; 1 = flat")
    ap.add_argument("--global-period", type=int, default=1,
                    help="rounds between sub-master↔master global syncs "
                         "in the hierarchical lowering")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    pods = [False, True] if args.both_meshes else [args.multi_pod]

    done = set()
    if args.skip_existing and args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if r.get("status") in ("ok", "skipped"):
                    done.add((r["arch"], r["shape"], r["multi_pod"]))

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                if (normalize_arch(arch), shape, mp) in done:
                    continue
                tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
                try:
                    r = dryrun_one(arch, shape, multi_pod=mp,
                                   opt_name=args.opt, remat=args.remat,
                                   rules=RULE_SETS[args.rules],
                                   elastic_workers=args.elastic_workers,
                                   elastic_capacity=args.capacity,
                                   groups=args.groups,
                                   global_period=args.global_period)
                except Exception as e:  # noqa: BLE001
                    r = {"arch": normalize_arch(arch), "shape": shape,
                         "multi_pod": mp, "status": "error",
                         "error": f"{type(e).__name__}: {e}",
                         "trace": traceback.format_exc()[-2000:]}
                results.append(r)
                status = r["status"]
                extra = ""
                if status == "ok":
                    fl = r.get("flops_per_device")
                    extra = (f" flops/dev={fl:.3e}" if fl else "") + \
                        f" compile={r['compile_s']}s"
                print(f"[dryrun] {tag}: {status}{extra}", flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(r) + "\n")
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, "
          f"{len(results) - n_ok - n_skip} failed")
    return results


if __name__ == "__main__":
    main()
