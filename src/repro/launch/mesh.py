"""Mesh builders for every execution placement (infrastructure, no direct
paper analogue — the paper simulates k workers on one device; these meshes
are where the reproduction's *sharded* placement puts them on hardware).

Axis convention (shared with ``repro.core.coordinator``):

- ``'pod'`` — hosts the paper's elastic *workers* under
  ``ElasticConfig.placement = "sharded"``: the (k, ...) worker axis of the
  trainer state is partitioned over it via ``shard_map``
  (k % pod_size == 0), one master reduction crossing it per round.
- ``'data'`` / ``'model'`` — ordinary GSPMD axes for sharding each worker's
  model replica *within* a pod; the sharded coordinator leaves them in
  ``shard_map``'s ``auto`` set.

Production: single pod (16, 16) = 256 chips, axes ('data', 'model');
multi-pod (2, 16, 16) = 512 chips, axes ('pod', 'data', 'model').

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests run with the
default single device).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The target hardware meshes (requires that many real/forced devices).

    ``multi_pod=False``: (16, 16) axes ('data', 'model') — one worker, the
    single-placement regime at scale. ``multi_pod=True``: (2, 16, 16) axes
    ('pod', 'data', 'model') — one elastic worker per pod, the mesh the
    sharded coordinator and ``launch/dryrun.py`` lower against.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1, pod: int = 1) -> Mesh:
    """Small ('pod', 'data', 'model') mesh over the host's devices — for
    tests, CPU smoke runs and the sharded-placement default
    (``ElasticSession`` builds ``make_host_mesh(pod=jax.device_count())``).
    Always carries all three axes (size-1 axes are free) so host meshes and
    the multi-pod production mesh expose the same axis names; uses the
    first pod·data·model visible devices (emulate a multi-device CPU host
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set before
    jax initializes — that exact spelling; jax reads no other env var for
    this).
    """
    return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
