"""Mesh builders for every execution placement (infrastructure, no direct
paper analogue — the paper simulates k workers on one device; these meshes
are where the reproduction's *sharded* placement puts them on hardware).

Axis convention (shared with ``repro.core.coordinator``):

- ``'pod'`` — hosts the paper's elastic *workers* under
  ``ElasticConfig.placement = "sharded"``: the (k, ...) worker axis of the
  trainer state is partitioned over it via ``shard_map``
  (k % pod_size == 0), one master reduction crossing it per round.
- ``'data'`` / ``'model'`` — ordinary GSPMD axes for sharding each worker's
  model replica *within* a pod; the sharded coordinator leaves them in
  ``shard_map``'s ``auto`` set.

Production: single pod (16, 16) = 256 chips, axes ('data', 'model');
multi-pod (2, 16, 16) = 512 chips, axes ('pod', 'data', 'model').

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests run with the
default single device).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The target hardware meshes (requires that many real/forced devices).

    ``multi_pod=False``: (16, 16) axes ('data', 'model') — one worker, the
    single-placement regime at scale. ``multi_pod=True``: (2, 16, 16) axes
    ('pod', 'data', 'model') — one elastic worker per pod, the mesh the
    sharded coordinator and ``launch/dryrun.py`` lower against.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1, pod: int = 1) -> Mesh:
    """Small ('pod', 'data', 'model') mesh over the host's devices — for
    tests, CPU smoke runs and the sharded-placement default
    (``ElasticSession`` builds ``make_host_mesh(pod=jax.device_count())``).
    Always carries all three axes (size-1 axes are free) so host meshes and
    the multi-pod production mesh expose the same axis names; uses the
    first pod·data·model visible devices (emulate a multi-device CPU host
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set before
    jax initializes — that exact spelling; jax reads no other env var for
    this).
    """
    return jax.make_mesh((pod, data, model), ("pod", "data", "model"))


def make_distributed_mesh(*, coordinator_address=None, num_processes: int = 1,
                          process_id: int = 0, data: int = 1, model: int = 1,
                          pod: int = 0) -> Mesh:
    """Multi-process ('pod', 'data', 'model') mesh (ISSUE-10): one mesh
    spanning every process's devices, so the sharded coordinator's worker
    axis tiles across hosts instead of one host's forced device pool.

    With ``num_processes > 1`` this calls ``jax.distributed.initialize``
    (exactly once — safe to call when the runtime is already initialized)
    using the ``--coordinator-address/--num-processes/--process-id``
    plumbing from ``launch/train.py``; process 0 must host the coordinator
    at ``coordinator_address`` (``host:port``). After init, every process
    sees the *global* device list and builds the identical mesh over it.

    CPU caveat: jax's CPU backend supports distributed *initialization*
    (global device visibility, process_index, multihost utils) but not
    cross-process XLA computations ("Multiprocess computations aren't
    implemented on the CPU backend"), so on CPU each process falls back to
    a mesh over its **local** devices — the processes run the same
    deterministic program side by side (the 2-process CI smoke asserts
    they agree bit-for-bit on the final master). On TPU/GPU the mesh is
    genuinely global.

    ``pod = 0`` (default) sizes the pod axis to use every selected device:
    ``device_count // (data · model)``.
    """
    if num_processes > 1:
        if not coordinator_address:
            raise ValueError(
                "make_distributed_mesh: num_processes > 1 needs a "
                "coordinator_address (host:port of process 0)")
        try:
            jax.distributed.initialize(
                coordinator_address=coordinator_address,
                num_processes=num_processes, process_id=process_id)
        except RuntimeError as e:  # already initialized: keep going
            if "already" not in str(e).lower():
                raise
    devices = list(jax.devices())
    if num_processes > 1 and jax.default_backend() == "cpu":
        print("[mesh] CPU backend: cross-process XLA computations are "
              "unsupported — falling back to a process-local mesh "
              f"({len(jax.local_devices())} local of {len(devices)} global "
              "devices)", flush=True)
        devices = list(jax.local_devices())
    if pod <= 0:
        pod = max(1, len(devices) // (data * model))
    n = pod * data * model
    if n > len(devices):
        raise ValueError(
            f"make_distributed_mesh: pod·data·model = {n} exceeds the "
            f"{len(devices)} available devices")
    grid = np.asarray(devices[:n]).reshape(pod, data, model)
    return Mesh(grid, ("pod", "data", "model"))
