"""Production meshes.

Single pod: (16, 16) = 256 chips, axes ('data', 'model').
Multi-pod:  (2, 16, 16) = 512 chips, axes ('pod', 'data', 'model') — the
'pod' axis hosts the paper's elastic *workers* (one worker per pod).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests run with the
default single device).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1, pod: int = 1) -> Mesh:
    """Small mesh over however many (host) devices exist — for tests."""
    axes, shape = [], []
    if pod > 1:
        axes.append("pod")
        shape.append(pod)
    axes += ["data", "model"]
    shape += [data, model]
    return jax.make_mesh(tuple(shape), tuple(axes))
