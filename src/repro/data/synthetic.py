"""Deterministic synthetic datasets.

MNIST is not available offline, so the paper reproduction uses a synthetic
28×28 10-class dataset with MNIST-like difficulty: each class is a smooth
random template; samples add template mixing, per-sample affine jitter
(shift) and pixel noise. All generation is seeded numpy — fully
reproducible. The LM pipeline generates Zipf-distributed token streams with
a planted bigram structure so that loss decrease is meaningful.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _smooth(rng, shape, passes=3):
    x = rng.standard_normal(shape)
    for _ in range(passes):
        x = (x + np.roll(x, 1, -1) + np.roll(x, -1, -1)
             + np.roll(x, 1, -2) + np.roll(x, -1, -2)) / 5.0
    return x


@dataclasses.dataclass
class SyntheticImages:
    """10-class 28×28 classification set (MNIST proxy)."""

    n: int = 12000
    n_test: int = 2000
    seed: int = 0
    noise: float = 0.35
    max_shift: int = 2

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.templates = _smooth(rng, (10, 28, 28)).astype(np.float32)
        self.templates /= np.abs(self.templates).max(axis=(1, 2),
                                                     keepdims=True)
        self.images, self.labels = self._gen(rng, self.n)
        self.test_images, self.test_labels = self._gen(rng, self.n_test)

    def _gen(self, rng, n):
        labels = rng.integers(0, 10, n)
        base = self.templates[labels]
        # per-sample random shift (affine jitter)
        sx = rng.integers(-self.max_shift, self.max_shift + 1, n)
        sy = rng.integers(-self.max_shift, self.max_shift + 1, n)
        imgs = np.empty((n, 28, 28), np.float32)
        for i in range(n):
            imgs[i] = np.roll(np.roll(base[i], sx[i], 0), sy[i], 1)
        imgs += self.noise * rng.standard_normal(imgs.shape).astype(
            np.float32)
        return imgs[..., None], labels.astype(np.int32)

    def test_batch(self, size=None):
        size = size or self.n_test
        return {"images": self.test_images[:size],
                "labels": self.test_labels[:size]}


@dataclasses.dataclass
class SyntheticTokens:
    """Token stream with planted bigram transitions (vocab-sized Markov)."""

    vocab: int = 256
    n_tokens: int = 200_000
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse deterministic successor table + noise
        self.succ = rng.integers(0, self.vocab, self.vocab)
        toks = np.empty(self.n_tokens, np.int32)
        toks[0] = 0
        noise = rng.random(self.n_tokens) < 0.2
        rand = rng.integers(0, self.vocab, self.n_tokens)
        for i in range(1, self.n_tokens):
            toks[i] = rand[i] if noise[i] else self.succ[toks[i - 1]]
        self.tokens = toks

    def batch(self, rng: np.random.Generator, batch_size: int, seq_len: int):
        starts = rng.integers(0, self.n_tokens - seq_len - 1, batch_size)
        idx = starts[:, None] + np.arange(seq_len + 1)
        chunk = self.tokens[idx]
        return {"tokens": chunk[:, :-1], "targets": chunk[:, 1:]}
