"""Overlap-aware multi-worker batch pipeline (paper §V-A → training rounds).

Given a dataset of n examples and a worker pool, builds the D_j = O ∪ S_j
partition over the *live* workers and yields per-round batch stacks shaped
(τ, cap, B, ...) for the coordinator's local phase — ``cap`` is the slot
capacity (``ElasticConfig.cap``), so the device-side shapes never change
when membership does. Vacant slots are padded with zero batches (their
local phase is frozen by the active mask; the pad is never trained on).

Membership (ISSUE-5): ``set_active(slots)`` re-partitions the data over a
new live set. The shared overlap O depends only on (n, ratio, seed) — not
on the worker count — so it is stable across resizes; only the unique
shards S_j are redealt. Each *slot* keeps its own persistent RNG stream,
so a run's batch sequence is deterministic given (seed, membership path).

Deterministic per (seed, round); with the full capacity live this emits
exactly the fixed-k stacks the pre-membership pipeline did.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

import numpy as np

from repro.configs.base import ElasticConfig
from repro.core.overlap import worker_datasets


class _SlotMixin:
    """Shared slot bookkeeping: which of the ``cap`` slots are live, one
    persistent RNG per slot, and zero-padding for vacant slots."""

    def _init_slots(self, rng_base: int):
        self.cap = self.ecfg.cap
        self.rngs = [np.random.default_rng(self.seed + rng_base + j)
                     for j in range(self.cap)]
        self._pad = None
        self.active = ()
        self.set_active(range(self.ecfg.num_workers))

    def set_active(self, slots: Sequence[int]):
        """Re-partition D over the live slots (ascending order). O stays
        fixed; the unique shards are redealt ``worker_datasets``-style over
        ``len(slots)`` workers, assigned to the live slots in order."""
        slots = tuple(sorted(int(s) for s in slots))
        if not slots:
            raise ValueError("at least one live slot required")
        if slots[0] < 0 or slots[-1] >= self.cap:
            raise ValueError(f"slots {slots} outside capacity {self.cap}")
        self.active = slots
        self._repartition()

    def set_active_mask(self, mask: np.ndarray):
        self.set_active(np.flatnonzero(np.asarray(mask, bool)))

    def _zero_batch(self, like: Dict[str, np.ndarray]):
        if self._pad is None:
            self._pad = {key: np.zeros_like(v) for key, v in like.items()}
        return self._pad

    def _stack_round(self, tau: int) -> Dict[str, np.ndarray]:
        """(τ, cap, B, ...) stacks: live slots draw real batches in slot
        order, vacant slots carry the zero pad."""
        live = set(self.active)
        outs = [[self._slot_batch(j) if j in live else None
                 for j in range(self.cap)] for _ in range(tau)]
        pad = self._zero_batch(next(b for b in outs[0] if b is not None))
        return {
            key: np.stack([np.stack([(outs[t][j] or pad)[key]
                                     for j in range(self.cap)])
                           for t in range(tau)])
            for key in pad
        }


@dataclasses.dataclass
class WorkerBatcher(_SlotMixin):
    """Classification pipeline over (images, labels)."""

    images: np.ndarray
    labels: np.ndarray
    ecfg: ElasticConfig
    batch_size: int = 64
    seed: int = 0

    def __post_init__(self):
        self._init_slots(rng_base=100)

    def _repartition(self):
        parts = worker_datasets(len(self.images), len(self.active),
                                self.ecfg.overlap_ratio, self.seed)
        self.indices = {}
        self.cursors = {}
        for slot, part in zip(self.active, parts):
            idx = part.copy()
            self.rngs[slot].shuffle(idx)
            self.indices[slot] = idx
            self.cursors[slot] = 0

    def _slot_batch(self, j: int):
        idx = self.indices[j]
        b = self.batch_size
        if self.cursors[j] + b > len(idx):
            self.rngs[j].shuffle(idx)
            self.cursors[j] = 0
        sel = idx[self.cursors[j]:self.cursors[j] + b]
        self.cursors[j] += b
        return {"images": self.images[sel], "labels": self.labels[sel]}

    def round_batches(self) -> Dict[str, np.ndarray]:
        """(τ, cap, B, ...) stacks for one communication round."""
        return self._stack_round(self.ecfg.tau)


@dataclasses.dataclass
class TokenWorkerBatcher(_SlotMixin):
    """LM pipeline over a token stream, overlap on window starts."""

    tokens: np.ndarray
    ecfg: ElasticConfig
    batch_size: int = 8
    seq_len: int = 128
    seed: int = 0

    def __post_init__(self):
        self._init_slots(rng_base=200)

    def _repartition(self):
        n_windows = len(self.tokens) - self.seq_len - 1
        parts = worker_datasets(n_windows, len(self.active),
                                self.ecfg.overlap_ratio, self.seed)
        self.starts = dict(zip(self.active, parts))

    def _slot_batch(self, j):
        sel = self.rngs[j].choice(self.starts[j], self.batch_size)
        idx = sel[:, None] + np.arange(self.seq_len + 1)
        chunk = self.tokens[idx]
        return {"tokens": chunk[:, :-1], "targets": chunk[:, 1:]}

    def round_batches(self):
        return self._stack_round(self.ecfg.tau)
