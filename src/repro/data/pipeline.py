"""Overlap-aware multi-worker batch pipeline (paper §V-A → training rounds).

Given a dataset of n examples and k workers, builds the D_j = O ∪ S_j
partition and yields per-round batch stacks shaped (τ, k, B, ...) for the
coordinator's local phase. Deterministic per (seed, round).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.configs.base import ElasticConfig
from repro.core.overlap import worker_datasets


@dataclasses.dataclass
class WorkerBatcher:
    """Classification pipeline over (images, labels)."""

    images: np.ndarray
    labels: np.ndarray
    ecfg: ElasticConfig
    batch_size: int = 64
    seed: int = 0

    def __post_init__(self):
        n = len(self.images)
        self.indices = worker_datasets(
            n, self.ecfg.num_workers, self.ecfg.overlap_ratio, self.seed)
        self.cursors = [0] * self.ecfg.num_workers
        self.rngs = [np.random.default_rng(self.seed + 100 + j)
                     for j in range(self.ecfg.num_workers)]
        for j, rng in enumerate(self.rngs):
            rng.shuffle(self.indices[j])

    def _next_worker_batch(self, j: int):
        idx = self.indices[j]
        b = self.batch_size
        if self.cursors[j] + b > len(idx):
            self.rngs[j].shuffle(idx)
            self.cursors[j] = 0
        sel = idx[self.cursors[j]:self.cursors[j] + b]
        self.cursors[j] += b
        return {"images": self.images[sel], "labels": self.labels[sel]}

    def round_batches(self) -> Dict[str, np.ndarray]:
        """(τ, k, B, ...) stacks for one communication round."""
        tau, k = self.ecfg.tau, self.ecfg.num_workers
        outs = [[self._next_worker_batch(j) for j in range(k)]
                for _ in range(tau)]
        return {
            key: np.stack([np.stack([outs[t][j][key] for j in range(k)])
                           for t in range(tau)])
            for key in outs[0][0]
        }


@dataclasses.dataclass
class TokenWorkerBatcher:
    """LM pipeline over a token stream, overlap on window starts."""

    tokens: np.ndarray
    ecfg: ElasticConfig
    batch_size: int = 8
    seq_len: int = 128
    seed: int = 0

    def __post_init__(self):
        n_windows = len(self.tokens) - self.seq_len - 1
        self.starts = worker_datasets(
            n_windows, self.ecfg.num_workers, self.ecfg.overlap_ratio,
            self.seed)
        self.rngs = [np.random.default_rng(self.seed + 200 + j)
                     for j in range(self.ecfg.num_workers)]

    def _one(self, j):
        sel = self.rngs[j].choice(self.starts[j], self.batch_size)
        idx = sel[:, None] + np.arange(self.seq_len + 1)
        chunk = self.tokens[idx]
        return {"tokens": chunk[:, :-1], "targets": chunk[:, 1:]}

    def round_batches(self):
        tau, k = self.ecfg.tau, self.ecfg.num_workers
        outs = [[self._one(j) for j in range(k)] for _ in range(tau)]
        return {
            key: np.stack([np.stack([outs[t][j][key] for j in range(k)])
                           for t in range(tau)])
            for key in outs[0][0]
        }
