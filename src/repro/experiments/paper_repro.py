"""Paper §VI–§VII reproduction: one (method, k, τ, seed) run.

Methods (paper §VI):
    EASGD     — async EASGD            (SGD local steps, fixed α)
    EAMSGD    — EASGD + momentum       (momentum local steps, fixed α)
    EAHES     — elastic AdaHessian     (fixed α, no overlap)
    EAHES-O   — EAHES + data overlap
    EAHES-OM  — EAHES-O + oracle α schedule (knows the failure schedule)
    DEAHES-O  — EAHES-O + dynamic weighting (the paper's method)

Failure model: worker↔master communication suppressed w.p. 1/3 per round by
default; ``--failure-scenario`` swaps in any regime from the scenario engine
(``repro.core.scenarios``): bursty, rack-correlated, stragglers, crash/restart.
Dataset: synthetic MNIST proxy (MNIST unavailable offline — see DESIGN.md),
model: the paper's 2-conv CNN. Metrics per communication round: master
train-loss and master test-accuracy, written as JSON.

The run itself is one ``ElasticSession`` (``repro.api``); this module only
maps method names onto configs and collects eval-round records into the
figure curves. ``--rounds-per-call`` chunks execution without changing any
number.
"""
from __future__ import annotations

import json
import os
import time
from typing import Optional

import numpy as np

from repro.api import ElasticSession, RunSpec
from repro.configs.base import (FAILURE_SCENARIOS, ElasticConfig,
                                OptimizerConfig)

METHODS = {
    # name: (optimizer, dynamic, oracle, use_overlap)
    "EASGD": ("sgd", False, False, False),
    "EAMSGD": ("momentum", False, False, False),
    "EAHES": ("adahessian", False, False, False),
    "EAHES-O": ("adahessian", False, False, True),
    "EAHES-OM": ("adahessian", False, True, True),
    "DEAHES-O": ("adahessian", True, False, True),
}

# paper §VII: best grid α = 0.1; lr 0.01; momentum 0.5; betas (0.9, 0.999)
LR = 0.01
ALPHA = 0.1


def paper_overlap_ratio(k: int) -> float:
    return 0.25 if k <= 4 else 0.125


def run_one(
    method: str,
    k: int,
    tau: int,
    seed: int = 0,
    rounds: int = 30,
    batch_size: int = 32,
    n_data: int = 8000,
    n_test: int = 600,
    failure_prob: float = 1.0 / 3.0,
    overlap_ratio: Optional[float] = None,
    eval_every: int = 2,
    out_path: Optional[str] = None,
    score_k: float = -0.05,
    failure_scenario: str = "iid",
    rounds_per_call: int = 1,
    score_clip: float = 0.0,
    byzantine_frac: float = 0.25,
    byzantine_mode: str = "sign_flip",
):
    opt_name, dynamic, oracle, use_overlap = METHODS[method]
    r = (overlap_ratio if overlap_ratio is not None
         else (paper_overlap_ratio(k) if use_overlap else 0.0))
    # score_clip only bites in dynamic mode (weights_for); fixed-α/oracle
    # arms keep the paper's maps even when the sweep passes it for all arms
    ecfg = ElasticConfig(
        num_workers=k, tau=tau, alpha=ALPHA, overlap_ratio=r,
        failure_prob=failure_prob, dynamic=dynamic, oracle=oracle,
        score_k=score_k, failure_scenario=failure_scenario,
        score_clip=score_clip, byzantine_frac=byzantine_frac,
        byzantine_mode=byzantine_mode)
    ocfg = OptimizerConfig(name=opt_name, lr=LR, momentum=0.5,
                           betas=(0.9, 0.999), hutchinson_samples=1)
    # data_seed=0: same dataset ∀ (method, seed) runs, as §VI compares;
    # the oracle's failed_recent feed is the canonical previous-round
    # definition (ScenarioSchedule.failed_recent) via the session.
    spec = RunSpec(
        arch="paper-cnn", optimizer=ocfg, elastic=ecfg, rounds=rounds,
        rounds_per_call=rounds_per_call, seed=seed, batch_size=batch_size,
        n_data=n_data, n_test=n_test, data_seed=0, eval_every=eval_every)
    sess = ElasticSession(spec)

    curves = {"round": [], "train_loss": [], "test_loss": [], "test_acc": [],
              "score": [], "h2": []}
    t0 = time.time()
    for rec in sess.run_iter():
        if rec.eval_loss is None:
            continue
        curves["round"].append(rec.round)
        curves["train_loss"].append(rec.loss)
        curves["test_loss"].append(rec.eval_loss)
        curves["test_acc"].append(rec.eval_acc)
        curves["score"].append(np.asarray(rec.score).tolist())
        curves["h2"].append(np.asarray(rec.h2).tolist())

    result = {
        "method": method, "k": k, "tau": tau, "seed": seed,
        "rounds": rounds, "overlap_ratio": r, "alpha": ALPHA,
        "failure_prob": failure_prob, "failure_scenario": failure_scenario,
        "curves": curves,
        "final_acc": curves["test_acc"][-1],
        "wall_s": round(time.time() - t0, 1),
    }
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(result, f)
    return result


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--method", required=True, choices=sorted(METHODS))
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--rounds-per-call", type=int, default=1)
    ap.add_argument("--overlap-ratio", type=float, default=None)
    ap.add_argument("--failure-scenario", default="iid",
                    choices=FAILURE_SCENARIOS)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = run_one(args.method, args.k, args.tau, args.seed,
                  rounds=args.rounds, overlap_ratio=args.overlap_ratio,
                  out_path=args.out, failure_scenario=args.failure_scenario,
                  rounds_per_call=args.rounds_per_call)
    print(json.dumps({k: v for k, v in res.items() if k != "curves"}))


if __name__ == "__main__":
    main()
