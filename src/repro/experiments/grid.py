"""Paper figs 4–5 grid driver: 6 methods × k∈{4,8} × τ∈{1,2,4} (+ seeds),
run as a bounded pool of subprocesses (XLA-CPU underutilizes cores for this
model size, so process-level parallelism ≈ free wall-clock).

Also fig 3: overlap-ratio sweep {0, .125, .25, .375, .5} on EAHES-O, and a
beyond-paper scenario axis (``--what scenarios``): every failure regime from
``repro.core.scenarios`` × {EASGD, EAHES-O, DEAHES-O} at k=4/τ=1.

Results land in results/paper_repro/*.json; summarize() renders the tables
consumed by EXPERIMENTS.md §Repro.
"""
from __future__ import annotations

import glob
import itertools
import json
import os
import subprocess
import sys
import time

RESULTS = "results/paper_repro"


def job_cmd(method, k, tau, seed, rounds, out, overlap=None, scenario=None,
            rounds_per_call=1):
    cmd = [sys.executable, "-m", "repro.experiments.paper_repro",
           "--method", method, "--k", str(k), "--tau", str(tau),
           "--seed", str(seed), "--rounds", str(rounds), "--out", out,
           "--rounds-per-call", str(rounds_per_call)]
    if overlap is not None:
        cmd += ["--overlap-ratio", str(overlap)]
    if scenario is not None:
        cmd += ["--failure-scenario", scenario]
    return cmd


def run_pool(jobs, max_procs=5):
    """Run jobs as a bounded subprocess pool; returns the list of failed job
    names (empty when everything exited 0)."""
    procs = []
    t0 = time.time()
    pending = list(jobs)
    done = 0
    total = len(pending)
    failed = []
    while pending or procs:
        while pending and len(procs) < max_procs:
            name, cmd = pending.pop(0)
            env = dict(os.environ)
            env["PYTHONPATH"] = "src"
            procs.append((name, subprocess.Popen(
                cmd, env=env, stdout=subprocess.DEVNULL,
                stderr=subprocess.DEVNULL)))
        still = []
        for name, p in procs:
            if p.poll() is None:
                still.append((name, p))
            else:
                done += 1
                status = "ok" if p.returncode == 0 else f"FAIL({p.returncode})"
                if p.returncode != 0:
                    failed.append(name)
                print(f"[{time.time()-t0:7.1f}s] {done}/{total} {name}: "
                      f"{status}", flush=True)
        procs = still
        time.sleep(2.0)
    return failed


# Communication-round budget per τ (single-core container: τ=4 costs 4×
# the local compute per round, so the high-τ panels get fewer rounds).
ROUNDS_BY_TAU = {1: 16, 2: 12, 4: 8}


def grid_jobs(rounds=None, seeds=(0,), methods=None, ks=(4, 8),
              taus=(1, 2, 4), rounds_per_call=1):
    from repro.experiments.paper_repro import METHODS

    methods = methods or sorted(METHODS)
    jobs = []
    # τ-major order: complete (τ=1) panels land first so partial runs still
    # yield full method comparisons
    for tau, k, m, s in itertools.product(taus, ks, methods, seeds):
        r = rounds or ROUNDS_BY_TAU[tau]
        out = f"{RESULTS}/fig45_{m}_k{k}_tau{tau}_s{s}.json"
        if os.path.exists(out):
            continue
        jobs.append((f"{m} k={k} τ={tau} s={s}",
                     job_cmd(m, k, tau, s, r, out,
                             rounds_per_call=rounds_per_call)))
    return jobs


def scenario_jobs(rounds=12, seeds=(0,), scenarios=None,
                  methods=("EASGD", "EAHES-O", "DEAHES-O"), k=4, tau=1,
                  rounds_per_call=1):
    """Failure-regime axis: every scenario from the engine × the headline
    methods, at the paper's k=4/τ=1 operating point."""
    from repro.configs.base import FAILURE_SCENARIOS

    scenarios = scenarios or FAILURE_SCENARIOS
    jobs = []
    for sc, m, s in itertools.product(scenarios, methods, seeds):
        out = f"{RESULTS}/scen_{sc}_{m}_k{k}_tau{tau}_s{s}.json"
        if os.path.exists(out):
            continue
        jobs.append((f"{m} scen={sc} s={s}",
                     job_cmd(m, k, tau, s, rounds, out, scenario=sc,
                             rounds_per_call=rounds_per_call)))
    return jobs


def overlap_jobs(rounds=16, seeds=(0,), ratios=(0.0, 0.125, 0.25, 0.375, 0.5),
                 rounds_per_call=1):
    jobs = []
    for r, s in itertools.product(ratios, seeds):
        out = f"{RESULTS}/fig3_r{r}_s{s}.json"
        if os.path.exists(out):
            continue
        jobs.append((f"overlap r={r} s={s}",
                     job_cmd("EAHES-O", 4, 1, s, rounds, out, overlap=r,
                             rounds_per_call=rounds_per_call)))
    return jobs


def summarize(pattern=f"{RESULTS}/*.json"):
    rows = []
    for path in sorted(glob.glob(pattern)):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=None,
                    help="override the per-τ round budget")
    ap.add_argument("--rounds-per-call", type=int, default=1,
                    help="jit-scan chunk size passed to every job (the "
                         "session API guarantees numbers are unchanged)")
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--max-procs", type=int, default=1)
    ap.add_argument("--what", default="all",
                    choices=["all", "fig45", "fig3", "scenarios"])
    args = ap.parse_args()
    seeds = tuple(range(args.seeds))
    rpc = args.rounds_per_call
    jobs = []
    if args.what in ("all", "fig45"):
        jobs += grid_jobs(args.rounds, seeds, rounds_per_call=rpc)
    if args.what in ("all", "fig3"):
        jobs += overlap_jobs(args.rounds or 16, seeds, rounds_per_call=rpc)
    if args.what in ("all", "scenarios"):
        jobs += scenario_jobs(args.rounds or 12, seeds, rounds_per_call=rpc)
    print(f"{len(jobs)} jobs")
    failed = run_pool(jobs, args.max_procs)
    if failed:
        print(f"{len(failed)} job(s) failed: " + ", ".join(failed),
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
