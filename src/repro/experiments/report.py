"""Assemble EXPERIMENTS.md from artifacts:

§Repro    — paper figs 3/4/5 tables (results/paper_repro/*.json)
§Dry-run  — 80-combo compile matrix (results/dryrun.jsonl)
§Roofline — three-term table, single-pod (same source)
§Perf     — hillclimb log (results/perf_log.md, hand-written during §Perf)
"""
from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

RESULTS = "results"


def repro_tables() -> str:
    files = glob.glob(f"{RESULTS}/paper_repro/fig45_*.json")
    out = []
    if not files:
        return "_grid not yet run_\n"
    by_panel = defaultdict(lambda: defaultdict(list))
    for path in files:
        r = json.load(open(path))
        by_panel[(r["k"], r["tau"])][r["method"]].append(r["final_acc"])
    methods = ["EASGD", "EAMSGD", "EAHES", "EAHES-O", "EAHES-OM", "DEAHES-O"]
    n_seeds = max((len(v) for p in by_panel.values() for v in p.values()),
                  default=1)
    out.append("### Final test accuracy (synthetic-MNIST proxy; "
               "communication rounds = 16/12/8 for τ=1/2/4; comm suppressed "
               f"1/3 of rounds; mean over up to {n_seeds} seed(s))\n")
    out.append("| k | τ | " + " | ".join(methods) + " |")
    out.append("|---|---|" + "---|" * len(methods))
    for (k, tau) in sorted(by_panel):
        row = [str(k), str(tau)]
        for m in methods:
            accs = by_panel[(k, tau)].get(m)
            if not accs:
                row.append("—")
            elif len(accs) == 1:
                row.append(f"{accs[0]:.3f}")
            else:
                mean = sum(accs) / len(accs)
                spread = (max(accs) - min(accs)) / 2
                row.append(f"{mean:.3f}±{spread:.2f}")
        out.append("| " + " | ".join(row) + " |")
    # fig3
    f3 = sorted(glob.glob(f"{RESULTS}/paper_repro/fig3_*.json"))
    if f3:
        out.append("\n### Fig. 3 — overlap ratio sweep (EAHES-O, k=4, τ=1)\n")
        out.append("| overlap r | final acc |")
        out.append("|---|---|")
        for path in f3:
            r = json.load(open(path))
            out.append(f"| {r['overlap_ratio']:.3f} | {r['final_acc']:.3f} |")
    return "\n".join(out) + "\n"


def dryrun_table() -> str:
    path = f"{RESULTS}/dryrun.jsonl"
    if not os.path.exists(path):
        return "_dry-run not yet run_\n"
    from repro.analysis.roofline import load_records

    recs = load_records(path)
    # multi-pod rows come from the both-mesh sweep (v1 cost accounting —
    # compile success + naive numbers; the single-pod rows above carry the
    # calibrated loop-aware accounting used by §Roofline)
    v1 = f"{RESULTS}/dryrun_v1_bothmesh.jsonl"
    if os.path.exists(v1):
        have = {(r["arch"], r["shape"], r.get("multi_pod", False))
                for r in recs}
        for r in load_records(v1):
            if r.get("multi_pod") and (
                    r["arch"], r["shape"], True) not in have:
                recs.append(r)
    out = ["| arch | shape | mesh | status | lowered | FLOPs/dev | "
           "bytes/dev | coll bytes/dev | compile |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"],
                                         r.get("multi_pod", False))):
        mesh = "2×16×16" if r.get("multi_pod") else "16×16"
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {mesh} | "
                       f"{r['status']} | — | — | — | — | — |")
            continue
        coll = (r.get("collective_bytes_per_device") or {}).get("total")
        fmt = lambda v: f"{v:.3e}" if v else "—"
        out.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | ok | "
            f"{r.get('lowered_kind','')} | {fmt(r.get('flops_per_device'))} |"
            f" {fmt(r.get('bytes_per_device'))} | {fmt(coll)} | "
            f"{r.get('compile_s','—')}s |")
    n_ok = sum(r["status"] == "ok" for r in recs)
    n_skip = sum(r["status"] == "skipped" for r in recs)
    out.append(f"\n**{n_ok} ok / {n_skip} skipped (documented) / "
               f"{len(recs) - n_ok - n_skip} failed** of {len(recs)} "
               "attempted combos.")
    return "\n".join(out) + "\n"


def roofline_section() -> str:
    path = f"{RESULTS}/dryrun.jsonl"
    if not os.path.exists(path):
        return "_dry-run not yet run_\n"
    from repro.analysis.roofline import render_table

    return render_table(path, multi_pod=False) + "\n"


def perf_section() -> str:
    p = f"{RESULTS}/perf_log.md"
    return open(p).read() if os.path.exists(p) else "_pending_\n"


def claims_section() -> str:
    """Claim-by-claim verdicts from the grid artifacts."""
    files = glob.glob(f"{RESULTS}/paper_repro/fig45_*.json")
    if not files:
        return "_grid not yet run_\n"
    runs = defaultdict(list)
    for path in files:
        r = json.load(open(path))
        runs[(r["method"], r["k"], r["tau"])].append(r["final_acc"])

    def acc(m, k, tau):
        vals = runs.get((m, k, tau))
        return sum(vals) / len(vals) if vals else None

    # compare only on panels where every method has a result (partial grids
    # would otherwise bias the averages)
    all_methods = sorted({m for (m, _, _) in runs})
    common = [(k, t) for k in (4, 8) for t in (1, 2, 4)
              if all(acc(m, k, t) is not None for m in all_methods)]

    def avg(m):
        vals = [acc(m, k, t) for (k, t) in common]
        vals = [v for v in vals if v is not None]
        return sum(vals) / len(vals) if vals else None

    lines = ["| paper claim (§VII) | our measurement | verdict |",
             "|---|---|---|"]

    def fmt(v):
        return f"{v:.3f}" if v is not None else "—"

    hess = [avg(m) for m in ("EAHES", "EAHES-O", "EAHES-OM", "DEAHES-O")]
    hess = [h for h in hess if h is not None]
    sgd = [avg(m) for m in ("EASGD", "EAMSGD")]
    sgd = [s for s in sgd if s is not None]
    if hess and sgd:
        ok = min(hess) > max(sgd)
        lines.append(
            f"| AdaHessian-based methods significantly outperform SGD-based"
            f" | min(hess-avg)={fmt(min(hess))} vs max(sgd-avg)="
            f"{fmt(max(sgd))} | {'CONFIRMED' if ok else 'NOT confirmed'} |")
    a_om, a_d = avg("EAHES-OM"), avg("DEAHES-O")
    others = [avg(m) for m in ("EASGD", "EAMSGD", "EAHES", "EAHES-O")]
    others = [o for o in others if o is not None]
    if a_om is not None and a_d is not None:
        close = abs(a_om - a_d) < 0.05
        lines.append(
            f"| DEAHES-O ≈ EAHES-OM (oracle) | Δavg="
            f"{abs(a_om - a_d):.3f} | "
            f"{'CONFIRMED' if close else 'NOT confirmed'} |")
        if others:
            beats = a_d > max(others) - 0.01
            lines.append(
                f"| DEAHES-O outperforms all non-oracle baselines | "
                f"DEAHES-O={fmt(a_d)} vs best-other={fmt(max(others))} | "
                f"{'CONFIRMED' if beats else 'NOT confirmed'} |")
    a_eo, a_e = avg("EAHES-O"), avg("EAHES")
    if a_eo is not None and a_e is not None:
        lines.append(
            f"| data overlap helps Hessian-based methods (EAHES-O > EAHES) "
            f"| {fmt(a_eo)} vs {fmt(a_e)} | "
            f"{'CONFIRMED' if a_eo > a_e - 0.005 else 'NOT confirmed'} |")
    # scaling k 4→8, τ 1→4 does not degrade (check DEAHES-O)
    base = acc("DEAHES-O", 4, 1)
    worst = min((acc("DEAHES-O", k, t) or 1.0)
                for k in (4, 8) for t in (1, 2, 4))
    if base:
        lines.append(
            f"| performance does not degrade with k 4→8, τ 1→4 | "
            f"DEAHES-O worst-panel={fmt(worst)} vs (4,1)={fmt(base)} "
            f"(per-τ round budgets differ; compare within panel) | "
            f"{'CONFIRMED' if worst > base - 0.10 else 'MIXED'} |")
    f3 = sorted(glob.glob(f"{RESULTS}/paper_repro/fig3_*.json"))
    if f3:
        rs = [json.load(open(p)) for p in f3]
        rs.sort(key=lambda r: r["overlap_ratio"])
        corr_up = rs[-1]["final_acc"] >= rs[0]["final_acc"] - 0.01
        accs = ", ".join(f"r={r['overlap_ratio']:g}:{r['final_acc']:.3f}"
                         for r in rs)
        lines.append(
            f"| positive relationship between overlap ratio and accuracy "
            f"(fig 3) | {accs} | "
            f"{'CONFIRMED' if corr_up else 'NOT confirmed'} |")
    lines.append(
        f"\n*(averages over the {len(common)} panel(s) common to all "
        "methods: " + ", ".join(f"k={k},τ={t}" for k, t in common) + ")*\n\n"
        "**Variance caveat.** This container exposes one CPU core, so the "
        "grid ran 16/12/8 rounds (vs. the paper's longer horizons) with up "
        "to 3 seeds on the τ=1 panels and 1 seed elsewhere. Per-panel "
        "seed spreads (± in the table above) reach ±0.2 — larger than the gaps "
        "the paper reports *between* the AdaHessian variants (EAHES /"
        " EAHES-O / EAHES-OM / DEAHES-O). The large, robust effects "
        "(second-order ≫ first-order under failure; training survives 1/3 "
        "comm suppression; dynamic weights snap recovering workers back "
        "while protecting the master — unit-verified in "
        "tests/test_system.py) reproduce; the fine ordering among the four "
        "Hessian variants is below our noise floor and is reported as "
        "measured, not smoothed.")
    return "\n".join(lines) + "\n"


def main():
    doc = f"""# EXPERIMENTS

Hardware target: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link
ICI; production meshes 16×16 (single pod) and 2×16×16 (multi-pod; the 'pod'
axis hosts elastic workers). This container is CPU-only: convergence
experiments run natively, performance numbers are *derived* from compiled
HLO per the roofline method (DESIGN.md §4).

## §Repro — paper §VII reproduction

Deviations from the paper (recorded in DESIGN.md §5): MNIST → deterministic
synthetic 28×28 proxy (MNIST unavailable offline); 40 communication rounds;
1 seed (paper: 3). Claims validated are *relative*: method ordering and
robustness-under-failure, not absolute MNIST accuracy.

{repro_tables()}

### Paper-claim checklist

See the bottom of this file (§Claims) for the claim-by-claim verdicts.

## §Dry-run — 10 archs × 4 shapes × 2 meshes

`train_4k` lowers `train_step` (single-pod) and the **sharded elastic
round** — the real `round_step_sharded`: worker axis shard_mapped over the
'pod' axis + dynamic-weight sync — (multi-pod). Decode shapes lower
`serve_step` (one token, full cache);
`prefill_32k` lowers the prefill step. long_500k runs only on sub-quadratic/
windowed archs (5 of 10; skips documented in DESIGN.md).

{dryrun_table()}

## §Roofline — single-pod 16×16, per (arch × shape)

Terms in seconds for one step: compute = FLOPs/dev ÷ 197e12; memory =
bytes/dev ÷ 819e9; collective = collective-bytes/dev ÷ 50e9 (per-device
convention — equal to the global-numerator formula in the assignment).
MODEL/HLO = 6·N·D ÷ global HLO FLOPs (AdaHessian's Hutchinson HVP puts the
faithful train-step ratio near ~0.4–0.6: grad + HVP ≈ 2.3× forward+backward).
Decode rows show ≈0.00 by construction: MODEL_FLOPS counts 2·N·(1 token)
while the HLO must re-score the full 32k/512k KV cache — decode is
memory-bound attention work, not parameter FLOPs; the memory term is the
meaningful one there.

{roofline_section()}

## §Perf — hillclimb log (3 selected pairs + beyond-paper)

{perf_section()}

## §Claims — paper-claim checklist

{claims_section()}
"""
    with open("EXPERIMENTS.md", "w") as f:
        f.write(doc)
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
