"""Checkpointing: npz-sharded save/restore for parameter/optimizer pytrees.

No orbax dependency — flat key/value npz files plus a JSON manifest holding
the tree structure, dtypes and (optionally) elastic-coordinator metadata.
Shards are bounded at ``MAX_SHARD_BYTES``: leaves are packed until a shard
fills, and a single leaf larger than the bound is *split* into flat chunks
spread across consecutive shards (manifest ``parts`` entries), so no one
npz file exceeds the bound by more than one chunk; restore reassembles
parts and is lazy per shard.

Elastic-membership manifests (ISSUE-5): :func:`elastic_manifest` records
the worker pool's per-slot active mask and u-history next to the master
params, and :func:`reseat_u_hist` re-seats those histories into a pool of
a *different* capacity — live slots carry their histories across in order,
new slots cold-start blank (their params come from the master, EASGD
style). ``ElasticSession.save`` / ``restore`` drive both.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"
MAX_SHARD_BYTES = 1 << 30  # 1 GiB per npz shard
U_HIST_FILL = -30.0  # blank u-history entry (matches ElasticTrainer.init_state)


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k1, v in sorted(node.items()):
                walk(f"{prefix}{_SEP}{k1}" if prefix else str(k1), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}{_SEP}{i}", v)
        else:
            flat[prefix] = node

    walk("", tree)
    return flat


def _to_numpy(x):
    a = np.asarray(x)
    if a.dtype not in (np.float64, np.float32, np.float16, np.int64,
                       np.int32, np.int16, np.int8, np.uint8, np.uint16,
                       np.uint32, np.uint64, np.bool_):
        # npz can't hold ml_dtypes (bfloat16, fp8): store widened, the
        # manifest records the true dtype and restore() casts back.
        a = a.astype(np.float32)
    return a


def _leaf_parts(arr: np.ndarray) -> List[np.ndarray]:
    """Split a leaf bigger than ``MAX_SHARD_BYTES`` into flat chunks (each
    at most one shard's worth); smaller leaves pass through whole."""
    if arr.nbytes <= MAX_SHARD_BYTES:
        return [arr]
    per = max(1, MAX_SHARD_BYTES // max(arr.itemsize, 1))
    flat = arr.reshape(-1)
    return [flat[i:i + per] for i in range(0, flat.size, per)]


def save(path: str, tree, *, metadata: Optional[dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    orig_dtypes = {k: str(np.asarray(v).dtype)
                   for k, v in _flatten_with_paths(tree).items()}
    flat = _flatten_with_paths(jax.tree.map(_to_numpy, tree))
    keys_info: Dict[str, dict] = {}
    shards: List[dict] = []
    cur, cur_bytes = {}, 0

    def place(npz_key, arr):
        nonlocal cur, cur_bytes
        if cur_bytes + arr.nbytes > MAX_SHARD_BYTES and cur:
            shards.append(cur)
            cur, cur_bytes = {}, 0
        cur[npz_key] = arr
        cur_bytes += arr.nbytes
        return len(shards)  # index this npz_key will land in

    for key, arr in flat.items():
        parts = _leaf_parts(arr)
        info = {"dtype": orig_dtypes[key], "shape": list(arr.shape)}
        if len(parts) == 1:
            info["shard"] = place(_sanitize(key), arr)
        else:  # oversized leaf: flat chunks across consecutive shards
            info["parts"] = [place(f"{_sanitize(key)}#p{j}", p)
                             for j, p in enumerate(parts)]
        keys_info[key] = info
    if cur:
        shards.append(cur)
    manifest = {
        "num_shards": len(shards),
        "keys": keys_info,
        "metadata": metadata or {},
    }
    for i, shard in enumerate(shards):
        np.savez(os.path.join(path, f"shard_{i:05d}.npz"), **shard)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def _sanitize(key: str) -> str:
    return key.replace(_SEP, "__")


def read_metadata(path: str) -> dict:
    """The checkpoint's metadata alone — no shard I/O. Lets callers check
    compatibility (arch, capacity) before paying for a full restore."""
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["metadata"]


def read_fingerprint(path: str) -> Optional[str]:
    """Cheap change-detection token for pollers (serving hot-swap): the
    manifest's mtime_ns and size, no shard I/O. ``save`` writes shards
    before the manifest, so a new fingerprint implies the shards it
    indexes are already complete on disk. ``None`` while no checkpoint
    exists yet (or mid-save, before the manifest lands)."""
    try:
        st = os.stat(os.path.join(path, "manifest.json"))
    except OSError:
        return None
    return f"{st.st_mtime_ns}:{st.st_size}"


def restore(path: str, like=None):
    """Restore; if ``like`` given, unflatten into its treedef and dtypes.
    Leaves that were split across shards (manifest ``parts``) are
    reassembled transparently."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    # shard index → [(npz key, manifest key, part index | None)]
    by_shard: Dict[int, list] = {}
    parts: Dict[str, list] = {}
    for k, info in manifest["keys"].items():
        if "parts" in info:
            parts[k] = [None] * len(info["parts"])
            for j, s in enumerate(info["parts"]):
                by_shard.setdefault(s, []).append(
                    (f"{_sanitize(k)}#p{j}", k, j))
        else:
            by_shard.setdefault(info["shard"], []).append(
                (_sanitize(k), k, None))

    def cast(arr, key):
        want = manifest["keys"][key]["dtype"]
        if str(arr.dtype) != want:
            arr = np.asarray(jnp.asarray(arr).astype(want))
        return arr

    flat: Dict[str, np.ndarray] = {}
    for i, entries in by_shard.items():
        with np.load(os.path.join(path, f"shard_{i:05d}.npz")) as z:
            for npz_key, k, j in entries:
                if j is None:
                    flat[k] = cast(z[npz_key], k)
                else:
                    parts[k][j] = z[npz_key]
    for k, chunks in parts.items():
        whole = np.concatenate(chunks).reshape(manifest["keys"][k]["shape"])
        flat[k] = cast(whole, k)
    if like is None:
        return _unflatten_paths(flat), manifest["metadata"]
    flat_like = _flatten_with_paths(like)
    out = {p: jnp.asarray(flat[p], flat_like[p].dtype) for p in flat_like}
    return _unflatten_into(like, out), manifest["metadata"]


# ---------------------------------------------------------------------------
# elastic worker-pool membership manifests (ISSUE-5)
# ---------------------------------------------------------------------------

def elastic_manifest(active, u_hist, *, groups: Optional[int] = None,
                     global_period: Optional[int] = None,
                     g_u_hist=None) -> dict:
    """JSON-able per-slot membership record stored in checkpoint metadata:
    capacity, the live mask, and each slot's u-history window (what a
    restore re-seats; worker params are deliberately *not* stored — a
    restore is a pool-wide rejoin from the master).

    Hierarchical runs (ISSUE-10) additionally record the topology
    (``groups``/``global_period``) and the rack-level distance histories
    ``g_u_hist`` — sub-master *params* live in a sibling sub-checkpoint
    (``ElasticSession.save``), not in metadata."""
    active = np.asarray(active, bool)
    u_hist = np.asarray(u_hist, np.float32)
    assert u_hist.shape[0] == active.shape[0]
    out = {"capacity": int(active.shape[0]),
           "active": active.astype(int).tolist(),
           "u_hist": [[float(v) for v in row] for row in u_hist]}
    if groups is not None:
        out["groups"] = int(groups)
        out["global_period"] = int(global_period or 1)
        if g_u_hist is not None:
            out["g_u_hist"] = [[float(v) for v in row]
                               for row in np.asarray(g_u_hist, np.float32)]
    return out


def reseat_u_hist(elastic_meta: Optional[dict], capacity: int, active_now,
                  window: int, fill: float = U_HIST_FILL) -> np.ndarray:
    """Re-seat a checkpoint's per-slot u-histories into a pool of (possibly
    different) ``capacity``: the checkpoint's live slots map onto the
    currently active slots in order, carrying their histories across; any
    remaining slots — joiners, vacancies, overflow when the new pool is
    smaller — get blank (``fill``) histories. History windows are aligned
    on the newest entries when the score window changed. Returns the
    (capacity, window) float32 u-history for ``ElasticTrainer`` state."""
    out = np.full((capacity, window), fill, np.float32)
    if not elastic_meta:
        return out
    saved_active = np.asarray(elastic_meta.get("active", ()), bool)
    saved_hist = np.asarray(elastic_meta.get("u_hist", ()), np.float32)
    if saved_hist.ndim != 2 or saved_active.size != saved_hist.shape[0]:
        return out
    live = saved_hist[saved_active]
    # align windows on the newest (rightmost) entries
    w = min(window, live.shape[1]) if live.size else 0
    targets = np.flatnonzero(np.asarray(active_now, bool))
    m = min(len(live), len(targets))
    if m and w:
        out[targets[:m], window - w:] = live[:m, live.shape[1] - w:]
    return out


def reseat_group_hist(g_u_hist, n_groups: int, window: int,
                      fill: float = U_HIST_FILL) -> np.ndarray:
    """Re-seat a checkpoint's rack-level u-histories (ISSUE-10) into a
    hierarchy of possibly different group count: the first
    ``min(saved, n_groups)`` racks carry their histories across (group
    assignment is contiguous-by-slot-order under any count, so low racks
    map onto low racks); extra racks cold-start blank. Windows align on
    the newest entries like :func:`reseat_u_hist`. ``None``/malformed
    input (a flat checkpoint) yields all-blank."""
    out = np.full((n_groups, window), fill, np.float32)
    if g_u_hist is None:
        return out
    g_u_hist = np.asarray(g_u_hist, np.float32)
    if g_u_hist.ndim != 2:
        return out
    g = min(n_groups, g_u_hist.shape[0])
    w = min(window, g_u_hist.shape[1])
    if g and w:
        out[:g, window - w:] = g_u_hist[:g, g_u_hist.shape[1] - w:]
    return out


def reseat_submasters(saved, master, n_groups: int):
    """Re-seat saved sub-master params into ``n_groups`` racks: rack g
    takes the saved rack g's sub-master for g < saved count, and a master
    copy otherwise (a new rack joins like a new worker — cold-started from
    the global master). ``saved=None`` (a flat checkpoint restored into a
    hierarchical session) seats every rack from the master. Returns a
    float32 pytree with leading (n_groups,) axes."""
    def from_master(m):
        m = jnp.asarray(m, jnp.float32)
        return jnp.broadcast_to(m, (n_groups,) + m.shape).copy()

    if saved is None:
        return jax.tree.map(from_master, master)

    def seat(sm, m):
        sm = jnp.asarray(sm, jnp.float32)
        g = min(n_groups, sm.shape[0])
        return from_master(m).at[:g].set(sm[:g])

    return jax.tree.map(seat, saved, master)


def _unflatten_paths(flat: Dict[str, np.ndarray]):
    root: dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return _listify(root)


def _listify(node):
    if not isinstance(node, dict):
        return node
    keys = list(node.keys())
    if keys and all(re.fullmatch(r"\d+", k) for k in keys):
        return [_listify(node[str(i)]) for i in range(len(keys))]
    return {k: _listify(v) for k, v in node.items()}


def _unflatten_into(like, flat_by_path):
    def walk(prefix, node):
        if isinstance(node, dict):
            return {k: walk(f"{prefix}{_SEP}{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            vals = [walk(f"{prefix}{_SEP}{i}", v) for i, v in enumerate(node)]
            return type(node)(vals)
        return flat_by_path[prefix]

    return walk("", like)
