"""Checkpointing: npz-sharded save/restore for parameter/optimizer pytrees.

No orbax dependency — flat key/value npz files plus a JSON manifest holding
the tree structure, dtypes and (optionally) elastic-coordinator metadata
(round index, u-history). Large leaves are chunked across multiple npz
shards to bound file size; restore is lazy per shard.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"
MAX_SHARD_BYTES = 1 << 30  # 1 GiB per npz shard


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k1, v in sorted(node.items()):
                walk(f"{prefix}{_SEP}{k1}" if prefix else str(k1), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}{_SEP}{i}", v)
        else:
            flat[prefix] = node

    walk("", tree)
    return flat


def _to_numpy(x):
    a = np.asarray(x)
    if a.dtype not in (np.float64, np.float32, np.float16, np.int64,
                       np.int32, np.int16, np.int8, np.uint8, np.uint16,
                       np.uint32, np.uint64, np.bool_):
        # npz can't hold ml_dtypes (bfloat16, fp8): store widened, the
        # manifest records the true dtype and restore() casts back.
        a = a.astype(np.float32)
    return a


def save(path: str, tree, *, metadata: Optional[dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    orig_dtypes = {k: str(np.asarray(v).dtype)
                   for k, v in _flatten_with_paths(tree).items()}
    flat = _flatten_with_paths(jax.tree.map(_to_numpy, tree))
    shards, cur, cur_bytes = [], {}, 0
    for key, arr in flat.items():
        if cur_bytes + arr.nbytes > MAX_SHARD_BYTES and cur:
            shards.append(cur)
            cur, cur_bytes = {}, 0
        cur[key] = arr
        cur_bytes += arr.nbytes
    if cur:
        shards.append(cur)
    manifest = {
        "num_shards": len(shards),
        "keys": {k: {"shard": i, "dtype": orig_dtypes[k],
                     "shape": list(v.shape)}
                 for i, shard in enumerate(shards) for k, v in shard.items()},
        "metadata": metadata or {},
    }
    for i, shard in enumerate(shards):
        np.savez(os.path.join(path, f"shard_{i:05d}.npz"),
                 **{_sanitize(k): v for k, v in shard.items()})
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def _sanitize(key: str) -> str:
    return key.replace(_SEP, "__")


def restore(path: str, like=None):
    """Restore; if ``like`` given, unflatten into its treedef and dtypes."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat: Dict[str, np.ndarray] = {}
    by_shard: Dict[int, list] = {}
    for k, info in manifest["keys"].items():
        by_shard.setdefault(info["shard"], []).append(k)
    for i, keys in by_shard.items():
        with np.load(os.path.join(path, f"shard_{i:05d}.npz")) as z:
            for k in keys:
                arr = z[_sanitize(k)]
                want = manifest["keys"][k]["dtype"]
                if str(arr.dtype) != want:
                    arr = np.asarray(jnp.asarray(arr).astype(want))
                flat[k] = arr
    if like is None:
        return _unflatten_paths(flat), manifest["metadata"]
    leaves, treedef = jax.tree.flatten(like)
    paths = sorted(_flatten_with_paths(like).keys())
    flat_like = _flatten_with_paths(like)
    out = {p: jnp.asarray(flat[p], flat_like[p].dtype) for p in flat_like}
    return _unflatten_into(like, out), manifest["metadata"]


def _unflatten_paths(flat: Dict[str, np.ndarray]):
    root: dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return _listify(root)


def _listify(node):
    if not isinstance(node, dict):
        return node
    keys = list(node.keys())
    if keys and all(re.fullmatch(r"\d+", k) for k in keys):
        return [_listify(node[str(i)]) for i in range(len(keys))]
    return {k: _listify(v) for k, v in node.items()}


def _unflatten_into(like, flat_by_path):
    def walk(prefix, node):
        if isinstance(node, dict):
            return {k: walk(f"{prefix}{_SEP}{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            vals = [walk(f"{prefix}{_SEP}{i}", v) for i, v in enumerate(node)]
            return type(node)(vals)
        return flat_by_path[prefix]

    return walk("", like)
