"""Pallas TPU kernel: FlashAttention (causal / sliding-window / chunked).

TPU-native design:
- grid = (batch·q_heads, n_q_blocks, n_kv_blocks) with the KV dimension
  innermost; the (m, l, acc) online-softmax state lives in VMEM scratch and
  persists across the KV sweep for a fixed (head, q-block).
- BlockSpecs tile Q/K/V/O as (block_q|block_k, head_dim) VMEM tiles with
  head_dim as the lane dimension (128-aligned for the MXU); GQA is handled
  in the K/V index_map (q-head → kv-head = h // group_size) without
  materializing repeated KV.
- fully-masked (q-block, kv-block) pairs (outside the causal triangle /
  sliding window / chunk diagonal) are skipped with ``pl.when`` — predicated
  out, no MXU work.

Validated in interpret mode against ``ref.mha_reference`` over
shape/dtype/mask sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q, block_k, n_k, scale, causal, window, chunk):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    # block-level liveness (positions are the row/col indices)
    live = True
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + block_q - 1)
    if window is not None:
        live = jnp.logical_and(
            live, q_start - (k_start + block_k - 1) < window)
    if chunk is not None:
        live = jnp.logical_and(
            live, (q_start + block_q - 1) // chunk >= k_start // chunk)
        live = jnp.logical_and(
            live, q_start // chunk <= (k_start + block_k - 1) // chunk)

    @pl.when(live)
    def _compute():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones(s.shape, jnp.bool_)
        if causal:
            mask &= kp <= qp
        if window is not None:
            mask &= (qp - kp) < window
        if chunk is not None:
            mask &= (qp // chunk) == (kp // chunk)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, 1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, 1)
        m_ref[...] = m_new
        v = v_ref[...].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "chunk", "block_q", "block_k",
                     "interpret"))
def flash_attention(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, KVH, Skv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window=None,
    chunk=None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, H, Sq, D = q.shape
    KVH, Skv = k.shape[1], k.shape[2]
    G = H // KVH
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    n_q, n_k = Sq // block_q, Skv // block_k
    scale = 1.0 / math.sqrt(D)

    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * KVH, Skv, D)
    vf = v.reshape(B * KVH, Skv, D)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, n_k=n_k,
        scale=scale, causal=causal, window=window, chunk=chunk)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((None, block_k, D),
                         lambda b, iq, ik, G=G: (b // G, ik, 0)),
            pl.BlockSpec((None, block_k, D),
                         lambda b, iq, ik, G=G: (b // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, D),
                               lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # m (running max)
            pltpu.VMEM((block_q,), jnp.float32),       # l (running denom)
            pltpu.VMEM((block_q, D), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, D)
