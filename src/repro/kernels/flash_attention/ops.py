"""jit'd wrapper: BSHD-layout flash attention (matches nn.layers layout)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention


def flash_attention_bshd(q, k, v, *, causal=True, window=None, chunk=None,
                         block_q=128, block_k=128, interpret=True):
    """q: (B,S,H,D), k/v: (B,S,KVH,D) → (B,S,H,D)."""
    out = flash_attention(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        causal=causal, window=window, chunk=chunk, block_q=block_q,
        block_k=block_k, interpret=interpret)
    return jnp.moveaxis(out, 1, 2)
