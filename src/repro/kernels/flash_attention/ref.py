"""Pure-jnp oracle for the flash-attention kernel (BHSD layout)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.nn.flash import naive_attention


def mha_reference(q, k, v, *, causal=True, window=None, chunk=None):
    """q: (B,H,S,D), k/v: (B,KVH,S,D) → (B,H,S,D)."""
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    qp = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Skv), (B, Skv))
    out = naive_attention(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        q_pos=qp, kv_pos=kp, causal=causal, window=window, chunk=chunk)
    return jnp.moveaxis(out, 2, 1)
