"""jit'd wrapper: fused AdaHessian step over flat (rows,128) views."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.kernels.adahessian.kernel import (BLOCK_ROWS, LANES,
                                             adahessian_update_flat)


def pack_scalars(cfg: OptimizerConfig, t: jax.Array) -> jax.Array:
    b1, b2 = cfg.betas
    tf = t.astype(jnp.float32)
    return jnp.stack([
        jnp.float32(cfg.lr), jnp.float32(b1), jnp.float32(b2),
        1.0 - b1 ** tf, 1.0 - b2 ** tf,
        jnp.float32(cfg.hessian_power / 2.0), jnp.float32(cfg.eps),
    ])


def adahessian_step_pallas(p, g, h, m, v, cfg: OptimizerConfig, t,
                           *, interpret: bool = True):
    """p,g,h,m,v: 1-D same-length f32 arrays (pre-flattened). Returns
    (p', m', v') with padding handled internally."""
    n = p.shape[0]
    tile = BLOCK_ROWS * LANES
    pad = (-n) % tile
    r2 = lambda x: jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(-1, LANES)
    # pad v with 1s so the fractional power sees a benign value
    vp = jnp.pad(v.astype(jnp.float32), (0, pad), constant_values=1.0)
    p2, m2, v2 = adahessian_update_flat(
        r2(p), r2(g), r2(h), r2(m), vp.reshape(-1, LANES),
        pack_scalars(cfg, jnp.asarray(t)), interpret=interpret)
    unr = lambda x: x.reshape(-1)[:n]
    return unr(p2), unr(m2), unr(v2)
