"""jit'd wrappers: fused AdaHessian step over flat / stacked pytree views."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.kernels.adahessian.kernel import (BLOCK_ROWS, LANES,
                                             adahessian_update_batched_flat,
                                             adahessian_update_flat,
                                             batched_block_rows)
from repro.kernels.flatten import flatten_stacked, unflatten_stacked
from repro.optim.base import apply_updates
from repro.optim.adahessian import moment_update


def pack_scalars(cfg: OptimizerConfig, t: jax.Array) -> jax.Array:
    b1, b2 = cfg.betas
    tf = t.astype(jnp.float32)
    return jnp.stack([
        jnp.float32(cfg.lr), jnp.float32(b1), jnp.float32(b2),
        1.0 - b1 ** tf, 1.0 - b2 ** tf,
        jnp.float32(cfg.hessian_power / 2.0), jnp.float32(cfg.eps),
    ])


def adahessian_step_pallas(p, g, h, m, v, cfg: OptimizerConfig, t,
                           *, interpret: bool = True):
    """p,g,h,m,v: 1-D same-length f32 arrays (pre-flattened). Returns
    (p', m', v') with padding handled internally."""
    n = p.shape[0]
    tile = BLOCK_ROWS * LANES
    pad = (-n) % tile
    r2 = lambda x: jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(-1, LANES)
    # pad v with 1s so the fractional power sees a benign value
    vp = jnp.pad(v.astype(jnp.float32), (0, pad), constant_values=1.0)
    p2, m2, v2 = adahessian_update_flat(
        r2(p), r2(g), r2(h), r2(m), vp.reshape(-1, LANES),
        pack_scalars(cfg, jnp.asarray(t)), interpret=interpret)
    unr = lambda x: x.reshape(-1)[:n]
    return unr(p2), unr(m2), unr(v2)


def adahessian_update_batched(worker_params, grads, hs, opt_state,
                              cfg: OptimizerConfig, *,
                              use_kernel: bool = True,
                              interpret: bool = True):
    """Batched AdaHessian step for all k workers in one pass (ISSUE-7).

    ``worker_params`` / ``grads`` / ``hs`` are stacked pytrees with a
    leading (k,) worker axis; ``hs`` is the *already spatially averaged*
    Hutchinson diagonal (averaging is per-worker — it must happen before
    stacking, or scalar leaves would average across workers).
    ``opt_state`` is the vmapped AdaHessian state ({count: (k,), m, v});
    per-worker counts may differ (straggler freezing), so the bias
    corrections are per-worker prefetch scalars. Returns
    ``(new_params, new_opt_state)``.

    ``use_kernel=False`` runs the same update as a vmapped
    ``repro.optim.adahessian.moment_update`` — the path used per shard
    under sharded placement (mirroring the elastic comm kernel's
    single-device-only gating) and by the local-phase benchmark; both
    branches execute identical elementwise ops and agree bitwise in
    interpret mode.
    """
    b1, b2 = cfg.betas
    t = opt_state["count"] + 1  # (k,) int32

    if not use_kernel:
        def one(p, count, m, v, g, h):
            upd, o2 = moment_update(
                cfg, g, {"count": count, "m": m, "v": v}, p, h)
            return apply_updates(p, upd), o2

        return jax.vmap(one)(worker_params, opt_state["count"],
                             opt_state["m"], opt_state["v"], grads, hs)

    tf = t.astype(jnp.float32)
    bc1 = 1 - b1 ** tf
    bc2 = 1 - b2 ** tf
    k = t.shape[0]
    tile = batched_block_rows(k)
    pf, p_leaves, p_def, n = flatten_stacked(worker_params, tile)
    gf = flatten_stacked(grads, tile)[0]
    hf = flatten_stacked(hs, tile)[0]
    mf, m_leaves, m_def, _ = flatten_stacked(opt_state["m"], tile)
    # pad v with 1s so the fractional power sees a benign value
    vf = flatten_stacked(opt_state["v"], tile, pad_value=1.0)[0]
    p2, m2, v2 = adahessian_update_batched_flat(
        pf, gf, hf, mf, vf, bc1, bc2,
        lr=cfg.lr, b1=b1, b2=b2, denom_pow=cfg.hessian_power / 2.0,
        eps=cfg.eps, lrwd=cfg.lr * cfg.weight_decay,
        interpret=interpret, block_rows=tile)
    return (unflatten_stacked(p2, p_leaves, p_def, n),
            {"count": t,
             "m": unflatten_stacked(m2, m_leaves, m_def, n),
             "v": unflatten_stacked(v2, m_leaves, m_def, n)})
