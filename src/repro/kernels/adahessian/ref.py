"""Pure-jnp oracles for the fused AdaHessian kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


def adahessian_step_ref(p, g, h, m, v, cfg: OptimizerConfig, t):
    b1, b2 = cfg.betas
    tf = jnp.asarray(t, jnp.float32)
    m1 = b1 * m + (1 - b1) * g
    v1 = b2 * v + (1 - b2) * jnp.square(h)
    bc1 = 1 - b1 ** tf
    bc2 = 1 - b2 ** tf
    denom = jnp.power(v1 / bc2 + 1e-30, cfg.hessian_power / 2.0) + cfg.eps
    p1 = p - cfg.lr * (m1 / bc1) / denom
    return p1, m1, v1


def adahessian_step_batched_ref(p, g, h, m, v, cfg: OptimizerConfig, t):
    """Oracle for the multi-worker kernel: the single-worker step vmapped
    over a leading (k,) axis with per-worker step counts ``t`` (k,).
    The op order mirrors the kernel exactly (decoupled weight decay folded
    into the update ``u`` *before* the single parameter add) so comparisons
    can be bitwise when both sides run under jit. Compare under ``jax.jit``:
    eager per-op dispatch contracts mul+add differently than a fused jit
    body, which perturbs the last bit."""
    b1, b2 = cfg.betas

    def one(p_, g_, h_, m_, v_, t_):
        tf = jnp.asarray(t_, jnp.float32)
        m1 = b1 * m_ + (1 - b1) * g_.astype(jnp.float32)
        v1 = b2 * v_ + (1 - b2) * jnp.square(h_)
        bc1 = 1 - b1 ** tf
        bc2 = 1 - b2 ** tf
        denom = jnp.power(v1 / bc2 + 1e-30, cfg.hessian_power / 2.0) + cfg.eps
        u = -cfg.lr * (m1 / bc1) / denom
        if cfg.weight_decay:
            u = u - cfg.lr * cfg.weight_decay * p_.astype(jnp.float32)
        p1 = (p_.astype(jnp.float32) + u).astype(p_.dtype)
        return p1, m1, v1

    return jax.vmap(one)(p, g, h, m, v, jnp.asarray(t))
