"""Pure-jnp oracle for the fused AdaHessian kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


def adahessian_step_ref(p, g, h, m, v, cfg: OptimizerConfig, t):
    b1, b2 = cfg.betas
    tf = jnp.asarray(t, jnp.float32)
    m1 = b1 * m + (1 - b1) * g
    v1 = b2 * v + (1 - b2) * jnp.square(h)
    bc1 = 1 - b1 ** tf
    bc2 = 1 - b2 ** tf
    denom = jnp.power(v1 / bc2 + 1e-30, cfg.hessian_power / 2.0) + cfg.eps
    p1 = p - cfg.lr * (m1 / bc1) / denom
    return p1, m1, v1
