"""Pallas TPU kernel: fused AdaHessian moment + parameter update.

Per element (f32 accumulation):

    m ← β1·m + (1−β1)·g
    v ← β2·v + (1−β2)·h²          (h = spatially averaged Hessian diagonal)
    p ← p − lr · (m/bc1) / ((v/bc2)^{κ/2} + ε)

Five HBM reads + three writes fused into one pass over (BLOCK_ROWS × 128)
VMEM tiles; the jnp path (repro.optim.adahessian) performs the same update
as ~6 separate elementwise HLO ops. Scalars (lr, β, bias corrections, κ, ε)
arrive in a small prefetch vector.

Two variants live here:

- ``adahessian_update_flat`` — the original single-worker kernel (one
  (rows, 128) view, all scalars prefetched).
- ``adahessian_update_batched_flat`` — the multi-worker local-phase kernel
  (ISSUE-7): p/g/h/m/v carry a leading worker axis (k, rows, 128) and one
  grid pass over row tiles updates every worker's moments and parameters
  together — one HBM round-trip per τ-step for the whole pool, mirroring
  the elastic comm kernel's layout. Only the per-worker bias corrections
  are runtime scalars (straggler-frozen workers have diverging step
  counts); the config constants (lr, β, κ/2, ε, lr·wd) are baked into the
  kernel as Python floats so the traced ops are *identical* to the jnp
  oracle's (`repro.optim.adahessian.moment_update`) — with a traced
  exponent, e.g., ``jnp.power(x, 0.5)`` could no longer constant-fold the
  way the oracle's does, and interpret-mode bit-exactness would be lost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_ROWS = 256
LANES = 128


def _kernel(s_ref, p_ref, g_ref, h_ref, m_ref, v_ref,
            p_out, m_out, v_out):
    lr, b1, b2, bc1, bc2, half_k, eps = (s_ref[i] for i in range(7))
    g = g_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * h * h
    denom = jnp.exp(half_k * jnp.log(v / bc2 + 1e-30)) + eps
    p = p_ref[...].astype(jnp.float32) - lr * (m / bc1) / denom
    p_out[...] = p.astype(p_out.dtype)
    m_out[...] = m
    v_out[...] = v


@functools.partial(
    jax.jit, static_argnames=("interpret", "block_rows"))
def adahessian_update_flat(
    p, g, h, m, v, scalars, *, interpret: bool = True,
    block_rows: int = BLOCK_ROWS,
):
    """All arrays (rows, 128); scalars (7,) f32 = lr,b1,b2,bc1,bc2,κ/2,ε."""
    rows, lanes = p.shape
    assert lanes == LANES and rows % block_rows == 0
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    sspec = pl.BlockSpec((7,), lambda i: (0,))
    out = pl.pallas_call(
        _kernel,
        grid=(rows // block_rows,),
        in_specs=[sspec, spec, spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(p.shape, p.dtype),
            jax.ShapeDtypeStruct(m.shape, jnp.float32),
            jax.ShapeDtypeStruct(v.shape, jnp.float32),
        ],
        interpret=interpret,
    )(scalars, p, g, h, m, v)
    return out


# ---------------------------------------------------------------------------
# multi-worker fused local phase (ISSUE-7)
# ---------------------------------------------------------------------------

def _make_batched_kernel(k: int, lr: float, b1: float, b2: float,
                         denom_pow: float, eps: float, lrwd: float):
    def kernel(bc_ref, p_ref, g_ref, h_ref, m_ref, v_ref,
               p_out, m_out, v_out):
        # bc_ref: (2, k) scalar-prefetched into SMEM (per-worker bias
        # corrections — straggler-frozen workers carry diverging counts);
        # the data blocks are (k, bR, LANES). The ops below mirror
        # repro.optim.adahessian.moment_update one-for-one (constants are
        # the same Python floats), so interpret mode is bit-exact with it.
        for i in range(k):  # k is static → unrolled; scalar SMEM reads
            bc1 = bc_ref[0, i]
            bc2 = bc_ref[1, i]
            g = g_ref[i].astype(jnp.float32)
            h = h_ref[i].astype(jnp.float32)
            m = b1 * m_ref[i] + (1 - b1) * g
            v = b2 * v_ref[i] + (1 - b2) * jnp.square(h)
            denom = jnp.power(v / bc2 + 1e-30, denom_pow) + eps
            u = -lr * (m / bc1) / denom
            if lrwd:
                u = u - lrwd * p_ref[i].astype(jnp.float32)
            p_out[i] = (p_ref[i].astype(jnp.float32) + u).astype(p_out.dtype)
            m_out[i] = m
            v_out[i] = v

    return kernel


def batched_block_rows(k: int, block_rows: int = BLOCK_ROWS) -> int:
    """Shrink the row tile so the 8 resident (k, bR, 128) f32 blocks
    (5 inputs + 3 outputs) stay within ~8 MB of VMEM."""
    budget = 8 * 1024 * 1024
    fit = budget // (8 * max(1, k) * LANES * 4)
    return max(8, min(block_rows, fit // 8 * 8))


@functools.partial(
    jax.jit, static_argnames=("lr", "b1", "b2", "denom_pow", "eps", "lrwd",
                              "interpret", "block_rows"))
def adahessian_update_batched_flat(
    p, g, h, m, v, bc1, bc2, *, lr: float, b1: float, b2: float,
    denom_pow: float, eps: float, lrwd: float = 0.0,
    interpret: bool = True, block_rows: int | None = None,
):
    """All data arrays (k, rows, 128); bc1/bc2 (k,) f32 per-worker bias
    corrections (the only runtime scalars — everything else is a static
    Python float baked into the kernel). Returns (p', m', v')."""
    k, rows, lanes = p.shape
    if block_rows is None:
        block_rows = batched_block_rows(k)
    assert lanes == LANES and rows % block_rows == 0, (p.shape, block_rows)
    assert bc1.shape == bc2.shape == (k,)
    bc = jnp.stack([bc1.astype(jnp.float32), bc2.astype(jnp.float32)])
    spec = pl.BlockSpec((k, block_rows, LANES), lambda i, bv: (0, i, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # bc lands in SMEM before the body runs
        grid=(rows // block_rows,),
        in_specs=[spec] * 5,
        out_specs=[spec] * 3,
    )
    out = pl.pallas_call(
        _make_batched_kernel(k, lr, b1, b2, denom_pow, eps, lrwd),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(p.shape, p.dtype),
            jax.ShapeDtypeStruct(m.shape, jnp.float32),
            jax.ShapeDtypeStruct(v.shape, jnp.float32),
        ],
        interpret=interpret,
    )(bc, p, g, h, m, v)
    return out
