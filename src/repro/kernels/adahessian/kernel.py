"""Pallas TPU kernel: fused AdaHessian moment + parameter update.

Per element (f32 accumulation):

    m ← β1·m + (1−β1)·g
    v ← β2·v + (1−β2)·h²          (h = spatially averaged Hessian diagonal)
    p ← p − lr · (m/bc1) / ((v/bc2)^{κ/2} + ε)

Five HBM reads + three writes fused into one pass over (BLOCK_ROWS × 128)
VMEM tiles; the jnp path (repro.optim.adahessian) performs the same update
as ~6 separate elementwise HLO ops. Scalars (lr, β, bias corrections, κ, ε)
arrive in a small prefetch vector.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
LANES = 128


def _kernel(s_ref, p_ref, g_ref, h_ref, m_ref, v_ref,
            p_out, m_out, v_out):
    lr, b1, b2, bc1, bc2, half_k, eps = (s_ref[i] for i in range(7))
    g = g_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * h * h
    denom = jnp.exp(half_k * jnp.log(v / bc2 + 1e-30)) + eps
    p = p_ref[...].astype(jnp.float32) - lr * (m / bc1) / denom
    p_out[...] = p.astype(p_out.dtype)
    m_out[...] = m
    v_out[...] = v


@functools.partial(
    jax.jit, static_argnames=("interpret", "block_rows"))
def adahessian_update_flat(
    p, g, h, m, v, scalars, *, interpret: bool = True,
    block_rows: int = BLOCK_ROWS,
):
    """All arrays (rows, 128); scalars (7,) f32 = lr,b1,b2,bc1,bc2,κ/2,ε."""
    rows, lanes = p.shape
    assert lanes == LANES and rows % block_rows == 0
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    sspec = pl.BlockSpec((7,), lambda i: (0,))
    out = pl.pallas_call(
        _kernel,
        grid=(rows // block_rows,),
        in_specs=[sspec, spec, spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(p.shape, p.dtype),
            jax.ShapeDtypeStruct(m.shape, jnp.float32),
            jax.ShapeDtypeStruct(v.shape, jnp.float32),
        ],
        interpret=interpret,
    )(scalars, p, g, h, m, v)
    return out
