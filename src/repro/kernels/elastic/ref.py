"""Pure-jnp oracles for the elastic-update kernels = repro.core.elastic."""
from repro.core.elastic import (  # noqa: F401
    elastic_update as elastic_update_ref,
    elastic_update_batched as elastic_update_batched_ref,
)
