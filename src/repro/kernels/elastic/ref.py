"""Pure-jnp oracle for the elastic-update kernel = repro.core.elastic."""
from repro.core.elastic import elastic_update as elastic_update_ref  # noqa: F401
