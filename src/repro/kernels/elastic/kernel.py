"""Pallas TPU kernel: fused dynamic-weight elastic update (paper eqs. 12–13).

    θ^i ← θ^i − h1 · (θ^i − θ^m)
    θ^m ← θ^m + h2 · (θ^i − θ^m)

The update is memory-bound and elementwise over the *entire* parameter
pytree: the jnp path reads both trees twice (once per equation). The kernel
fuses both updates into a single HBM round-trip over VMEM tiles of
(BLOCK_ROWS × 128) — one read of (w, m), one write of (w', m'). h1/h2 are
prefetched scalars (SMEM) since they are per-*worker*, not per-element.

Weights flow in flattened to (rows, 128); the ops.py wrapper handles pytree
flattening/padding. Accumulation in f32 regardless of storage dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_ROWS = 256
LANES = 128


def _kernel(h_ref, w_ref, m_ref, w_out_ref, m_out_ref):
    h1 = h_ref[0]
    h2 = h_ref[1]
    w = w_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    diff = w - m
    w_out_ref[...] = (w - h1 * diff).astype(w_out_ref.dtype)
    m_out_ref[...] = (m + h2 * diff).astype(m_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def elastic_update_flat(
    w: jax.Array,
    m: jax.Array,
    h1: jax.Array,
    h2: jax.Array,
    *,
    interpret: bool = True,
    block_rows: int = BLOCK_ROWS,
) -> tuple:
    """w, m: (rows, 128) — rows must be a multiple of ``block_rows``."""
    rows, lanes = w.shape
    assert lanes == LANES and rows % block_rows == 0, (w.shape, block_rows)
    grid = (rows // block_rows,)
    h = jnp.stack([h1.astype(jnp.float32), h2.astype(jnp.float32)])
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),  # h1/h2 broadcast to all tiles
            spec, spec,
        ],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(w.shape, w.dtype),
            jax.ShapeDtypeStruct(m.shape, m.dtype),
        ],
        interpret=interpret,
    )(h, w, m)
    return out[0], out[1]


# ---------------------------------------------------------------------------
# multi-worker fused communication phase
# ---------------------------------------------------------------------------

def _make_batched_kernel(k: int, stale: bool = False):
    def kernel(h_ref, w_ref, m_ref, *rest):
        # h_ref: (2, k) scalar-prefetched into SMEM; w_ref: (k, bR, LANES).
        # With ``stale`` (delayed averaging) an extra ref block follows m:
        # diffs are measured against it, accumulation stays on m.
        if stale:
            r_ref, w_out_ref, m_out_ref = rest
            ref = r_ref[...].astype(jnp.float32)
        else:
            w_out_ref, m_out_ref = rest
        m = m_ref[...].astype(jnp.float32)
        if not stale:
            ref = m
        acc = jnp.zeros_like(m)
        for i in range(k):  # k is static → unrolled; scalar SMEM reads
            h1 = h_ref[0, i]
            h2 = h_ref[1, i]
            w = w_ref[i].astype(jnp.float32)
            diff = w - ref
            w_out_ref[i] = (w - h1 * diff).astype(w_out_ref.dtype)
            acc = acc + h2 * diff
        m_out_ref[...] = (m + acc).astype(m_out_ref.dtype)

    return kernel


def batched_block_rows(k: int, block_rows: int = BLOCK_ROWS) -> int:
    """Shrink the row tile so all k worker blocks fit in VMEM together."""
    return max(8, (block_rows // max(1, k)) // 8 * 8)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def elastic_update_batched_flat(
    w: jax.Array,
    m: jax.Array,
    h1: jax.Array,
    h2: jax.Array,
    ref: jax.Array | None = None,
    *,
    interpret: bool = True,
    block_rows: int | None = None,
) -> tuple:
    """w: (k, rows, 128) stacked workers; m: (rows, 128); h1/h2: (k,).

    One grid pass over row tiles performs every worker update *and* the
    h2-weighted master reduction θ^m ← θ^m + Σ_i h2_i (θ^i − θ^m) in a
    single HBM round-trip: each (w, m) element is read once and each
    (w', m') element written once, vs 2k reads of m in the sequential scan.

    ``ref`` (optional, (rows, 128)): delayed averaging — every diff is
    measured against this stale master snapshot instead of ``m``, while the
    master accumulation target stays ``m`` (one extra read per element).
    ``None`` compiles the exact pre-staleness kernel.
    """
    k, rows, lanes = w.shape
    if block_rows is None:
        block_rows = batched_block_rows(k)
    assert lanes == LANES and rows % block_rows == 0, (w.shape, block_rows)
    assert m.shape == (rows, lanes) and h1.shape == h2.shape == (k,)
    h = jnp.stack([h1.astype(jnp.float32), h2.astype(jnp.float32)])
    wspec = pl.BlockSpec((k, block_rows, LANES), lambda i, hv: (0, i, 0))
    mspec = pl.BlockSpec((block_rows, LANES), lambda i, hv: (i, 0))
    stale = ref is not None
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # h lands in SMEM before the body runs
        grid=(rows // block_rows,),
        in_specs=[wspec, mspec] + ([mspec] if stale else []),
        out_specs=[wspec, mspec],
    )
    operands = (h, w, m) + ((ref,) if stale else ())
    out = pl.pallas_call(
        _make_batched_kernel(k, stale=stale),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(w.shape, w.dtype),
            jax.ShapeDtypeStruct(m.shape, m.dtype),
        ],
        interpret=interpret,
    )(*operands)
    return out[0], out[1]
