"""jit'd public wrappers: fused elastic update over parameter pytrees."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.elastic.kernel import (BLOCK_ROWS, LANES,
                                          batched_block_rows,
                                          elastic_update_batched_flat,
                                          elastic_update_flat)
from repro.kernels.flatten import (flatten_stacked, flatten_tree, unflatten,
                                   unflatten_stacked)

# Shared with repro.kernels.adahessian via repro.kernels.flatten; the old
# private names stay importable.
_flatten_tree = flatten_tree
_unflatten = unflatten
_flatten_stacked = flatten_stacked
_unflatten_stacked = unflatten_stacked


def elastic_update_pallas(worker_params, master_params, h1, h2, *,
                          interpret: bool = True):
    """Fused eqs. (12)–(13) over whole pytrees. Returns (worker', master')."""
    wf, wl, wd, n = flatten_tree(worker_params, BLOCK_ROWS)
    mf, ml, md, _ = flatten_tree(master_params, BLOCK_ROWS)
    w2d, m2d = elastic_update_flat(
        wf, mf, jnp.asarray(h1), jnp.asarray(h2), interpret=interpret)
    return (unflatten(w2d, wl, wd, n), unflatten(m2d, ml, md, n))


def elastic_update_batched_pallas(worker_stacked, master_params, h1, h2, *,
                                  master_ref=None, interpret: bool = True):
    """All k worker exchanges + the h2-weighted master reduction in one
    kernel pass. ``worker_stacked`` leaves carry a leading (k,) axis; h1/h2
    are (k,) vectors (pass ``master_schedule_weights(h2)`` for event-order
    parity with the sequential scan). Returns (workers', master').

    ``master_ref`` (optional pytree like the master): delayed averaging —
    the elastic diffs θ^i − θ^ref are measured against this stale snapshot
    while the accumulation target stays the live master (see
    ``repro.core.elastic.elastic_update_batched``). ``None`` is the exact
    pre-staleness kernel."""
    h1 = jnp.asarray(h1, jnp.float32)
    h2 = jnp.asarray(h2, jnp.float32)
    k = h1.shape[0]
    tile_rows = batched_block_rows(k)
    wf, wl, wd, n = flatten_stacked(worker_stacked, tile_rows)
    mf, ml, md, _ = flatten_tree(master_params, tile_rows)
    rf = None
    if master_ref is not None:
        rf = flatten_tree(master_ref, tile_rows)[0]
    w3d, m2d = elastic_update_batched_flat(
        wf, mf, h1, h2, ref=rf, interpret=interpret, block_rows=tile_rows)
    return (unflatten_stacked(w3d, wl, wd, n), unflatten(m2d, ml, md, n))
