"""jit'd public wrappers: fused elastic update over parameter pytrees."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.elastic.kernel import (BLOCK_ROWS, LANES,
                                          batched_block_rows,
                                          elastic_update_batched_flat,
                                          elastic_update_flat)


def _flatten_tree(tree, tile_rows: int = BLOCK_ROWS):
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    n = flat.shape[0]
    tile = tile_rows * LANES
    pad = (-n) % tile
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES), leaves, treedef, n


def _unflatten(flat2d, leaves, treedef, n):
    flat = flat2d.reshape(-1)[:n]
    out, off = [], 0
    for l in leaves:
        out.append(flat[off:off + l.size].reshape(l.shape).astype(l.dtype))
        off += l.size
    return jax.tree.unflatten(treedef, out)


def elastic_update_pallas(worker_params, master_params, h1, h2, *,
                          interpret: bool = True):
    """Fused eqs. (12)–(13) over whole pytrees. Returns (worker', master')."""
    wf, wl, wd, n = _flatten_tree(worker_params)
    mf, ml, md, _ = _flatten_tree(master_params)
    w2d, m2d = elastic_update_flat(
        wf, mf, jnp.asarray(h1), jnp.asarray(h2), interpret=interpret)
    return (_unflatten(w2d, wl, wd, n), _unflatten(m2d, ml, md, n))


def _flatten_stacked(tree, tile_rows: int):
    """Stacked pytree (leading worker axis k) → (k, rows, LANES)."""
    leaves, treedef = jax.tree.flatten(tree)
    k = leaves[0].shape[0]
    flat = jnp.concatenate([l.reshape(k, -1).astype(jnp.float32)
                            for l in leaves], axis=1)
    n = flat.shape[1]
    tile = tile_rows * LANES
    pad = (-n) % tile
    flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat.reshape(k, -1, LANES), leaves, treedef, n


def _unflatten_stacked(flat3d, leaves, treedef, n):
    k = flat3d.shape[0]
    flat = flat3d.reshape(k, -1)[:, :n]
    out, off = [], 0
    for l in leaves:
        size = l.size // k
        out.append(flat[:, off:off + size].reshape(l.shape).astype(l.dtype))
        off += size
    return jax.tree.unflatten(treedef, out)


def elastic_update_batched_pallas(worker_stacked, master_params, h1, h2, *,
                                  interpret: bool = True):
    """All k worker exchanges + the h2-weighted master reduction in one
    kernel pass. ``worker_stacked`` leaves carry a leading (k,) axis; h1/h2
    are (k,) vectors (pass ``master_schedule_weights(h2)`` for event-order
    parity with the sequential scan). Returns (workers', master')."""
    h1 = jnp.asarray(h1, jnp.float32)
    h2 = jnp.asarray(h2, jnp.float32)
    k = h1.shape[0]
    tile_rows = batched_block_rows(k)
    wf, wl, wd, n = _flatten_stacked(worker_stacked, tile_rows)
    mf, ml, md, _ = _flatten_tree(master_params, tile_rows)
    w3d, m2d = elastic_update_batched_flat(
        wf, mf, h1, h2, interpret=interpret, block_rows=tile_rows)
    return (_unflatten_stacked(w3d, wl, wd, n), _unflatten(m2d, ml, md, n))
