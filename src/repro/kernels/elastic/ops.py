"""jit'd public wrapper: fused elastic update over parameter pytrees."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.elastic.kernel import BLOCK_ROWS, LANES, elastic_update_flat


def _flatten_tree(tree):
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    n = flat.shape[0]
    tile = BLOCK_ROWS * LANES
    pad = (-n) % tile
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES), leaves, treedef, n


def _unflatten(flat2d, leaves, treedef, n):
    flat = flat2d.reshape(-1)[:n]
    out, off = [], 0
    for l in leaves:
        out.append(flat[off:off + l.size].reshape(l.shape).astype(l.dtype))
        off += l.size
    return jax.tree.unflatten(treedef, out)


def elastic_update_pallas(worker_params, master_params, h1, h2, *,
                          interpret: bool = True):
    """Fused eqs. (12)–(13) over whole pytrees. Returns (worker', master')."""
    wf, wl, wd, n = _flatten_tree(worker_params)
    mf, ml, md, _ = _flatten_tree(master_params)
    w2d, m2d = elastic_update_flat(
        wf, mf, jnp.asarray(h1), jnp.asarray(h2), interpret=interpret)
    return (_unflatten(w2d, wl, wd, n), _unflatten(m2d, ml, md, n))
