"""Pytree ↔ flat (rows, 128) lane views shared by the Pallas kernels.

Both kernel families (``repro.kernels.elastic``, ``repro.kernels.adahessian``)
operate on whole parameter pytrees flattened into f32 lane-major 2-D/3-D
views: leaves are raveled, concatenated, zero-padded up to a whole number of
(tile_rows × 128) tiles and reshaped to (rows, 128) — stacked trees (leading
worker axis k) flatten per worker to (k, rows, 128). ``unflatten`` reverses
the trip, casting each leaf back to its original dtype.

The pad value is configurable (``pad_value``) because padding must be benign
for the kernel's math: elastic updates are linear (0 is fine), but the
AdaHessian second moment feeds a fractional power, so its ``v`` buffer pads
with 1s.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LANES = 128


def flatten_tree(tree, tile_rows: int, pad_value: float = 0.0):
    """Pytree → ((rows, LANES) f32, leaves, treedef, n)."""
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    n = flat.shape[0]
    tile = tile_rows * LANES
    pad = (-n) % tile
    flat = jnp.pad(flat, (0, pad), constant_values=pad_value)
    return flat.reshape(-1, LANES), leaves, treedef, n


def unflatten(flat2d, leaves, treedef, n):
    flat = flat2d.reshape(-1)[:n]
    out, off = [], 0
    for l in leaves:
        out.append(flat[off:off + l.size].reshape(l.shape).astype(l.dtype))
        off += l.size
    return jax.tree.unflatten(treedef, out)


def flatten_stacked(tree, tile_rows: int, pad_value: float = 0.0):
    """Stacked pytree (leading worker axis k) → (k, rows, LANES)."""
    leaves, treedef = jax.tree.flatten(tree)
    k = leaves[0].shape[0]
    flat = jnp.concatenate([l.reshape(k, -1).astype(jnp.float32)
                            for l in leaves], axis=1)
    n = flat.shape[1]
    tile = tile_rows * LANES
    pad = (-n) % tile
    flat = jnp.pad(flat, ((0, 0), (0, pad)), constant_values=pad_value)
    return flat.reshape(k, -1, LANES), leaves, treedef, n


def unflatten_stacked(flat3d, leaves, treedef, n):
    k = flat3d.shape[0]
    flat = flat3d.reshape(k, -1)[:, :n]
    out, off = [], 0
    for l in leaves:
        size = l.size // k
        out.append(flat[:, off:off + size].reshape(l.shape).astype(l.dtype))
        off += size
    return jax.tree.unflatten(treedef, out)
