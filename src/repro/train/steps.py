"""jit-able train / serve steps + sharding trees for any model.

``make_train_step`` builds the paper-faithful worker-local step: gradient +
(for AdaHessian) the Hutchinson HVP + fused optimizer update. The elastic
round step (local phase × τ + dynamic-weight sync) lives in
``repro.core.coordinator`` and is shared between the CPU simulation and the
multi-pod production path.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (ModelConfig, OptimizerConfig, ShapeConfig,
                                TrainConfig)
from repro.nn.param import ParamSpec, abstract_tree, tree_map_spec
from repro.nn.sharding import physical_spec, tree_pspecs
from repro.optim.base import apply_updates, make_optimizer
from repro.optim.hutchinson import hessian_diag


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def make_train_step(model, opt_cfg: OptimizerConfig,
                    train_cfg: Optional[TrainConfig] = None):
    opt = make_optimizer(opt_cfg)
    remat = bool(train_cfg and train_cfg.remat != "none")

    def train_step(state, batch, rng):
        params = state["params"]
        loss_fn = lambda p: model.loss(p, batch, remat=remat)[0]
        loss, grads = jax.value_and_grad(loss_fn)(params)
        extras = None
        if opt.needs_hessian:
            extras = {"hess_diag": hessian_diag(
                jax.grad(loss_fn), params, rng,
                opt_cfg.hutchinson_samples)}
        updates, opt_state = opt.update(grads, state["opt"], params, extras)
        params = apply_updates(params, updates)
        return {"params": params, "opt": opt_state,
                "step": state["step"] + 1}, {"loss": loss}

    return train_step


def make_train_step_stale_hessian(model, opt_cfg: OptimizerConfig,
                                  train_cfg: Optional[TrainConfig] = None):
    """Beyond-paper §Perf variant: the off-refresh step of the lazy-Hessian
    schedule (no Hutchinson HVP; v is reused, only m/params advance).

    Amortized cost with refresh period h:
        cost = (1/h)·cost(train_step) + (1−1/h)·cost(this step)
    Both steps are lowered separately in the dry-run; EXPERIMENTS.md §Perf
    combines them analytically.
    """
    opt = make_optimizer(opt_cfg)
    remat = bool(train_cfg and train_cfg.remat != "none")
    b1, _ = opt_cfg.betas

    def train_step(state, batch, rng):
        del rng
        params = state["params"]
        loss_fn = lambda p: model.loss(p, batch, remat=remat)[0]
        loss, grads = jax.value_and_grad(loss_fn)(params)
        st = state["opt"]
        t = st["count"] + 1
        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            st["m"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - opt_cfg.betas[1] ** t.astype(jnp.float32)
        k = opt_cfg.hessian_power / 2.0
        upd = jax.tree.map(
            lambda m_, v_: -opt_cfg.lr * (m_ / bc1)
            / (jnp.power(v_ / bc2 + 1e-30, k) + opt_cfg.eps),
            m, st["v"])
        params = apply_updates(params, upd)
        return {"params": params,
                "opt": {"count": t, "m": m, "v": st["v"]},
                "step": state["step"] + 1}, {"loss": loss}

    return train_step


def init_train_state(model, opt_cfg: OptimizerConfig, rng):
    from repro.nn.param import init_tree

    opt = make_optimizer(opt_cfg)
    params = init_tree(rng, model.spec)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(model, opt_cfg: OptimizerConfig):
    """ShapeDtypeStruct train state — dry-run only, no allocation."""
    params = abstract_tree(model.spec)
    f32 = lambda t: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t)
    opt: dict = {"count": jax.ShapeDtypeStruct((), jnp.int32)}
    if opt_cfg.name in ("momentum", "adam", "adahessian"):
        opt["m"] = f32(params)
    if opt_cfg.name in ("adam", "adahessian"):
        opt["v"] = f32(params)
    return {"params": params, "opt": opt,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def make_serve_step(model, kind: str = "decode"):
    if kind == "prefill":
        def prefill_step(params, batch, cache):
            logits, cache = model.prefill(params, batch, cache)
            return jnp.argmax(logits[:, -1], axis=-1), cache

        return prefill_step

    def serve_step(params, batch, cache, index):
        logits, cache = model.decode_step(params, batch, cache, index)
        return jnp.argmax(logits[:, -1], axis=-1), cache

    return serve_step


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------

def params_pspecs(model, mesh: Mesh, rules=None):
    return tree_pspecs(model.spec, mesh, rules)


def train_state_pspecs(model, opt_cfg: OptimizerConfig, mesh: Mesh,
                       rules=None):
    p = params_pspecs(model, mesh, rules)
    opt: dict = {"count": P()}
    if opt_cfg.name in ("momentum", "adam", "adahessian"):
        opt["m"] = p
    if opt_cfg.name in ("adam", "adahessian"):
        opt["v"] = p
    return {"params": p, "opt": opt, "step": P()}


def batch_pspecs(specs: dict, mesh: Mesh, rules=None):
    return {
        name: physical_spec(s.shape, s.axes, mesh, rules)
        for name, s in specs.items()
    }


def cache_pspecs(model, batch_size: int, cache_len: int, mesh: Mesh,
                 rules=None):
    return tree_pspecs(model.cache_spec(batch_size, cache_len), mesh, rules)
