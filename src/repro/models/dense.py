"""Decoder-only transformer LM (dense and MoE variants).

Covers: stablelm-3b, h2o-danube-1.8b, qwen3-4b (dense); mixtral-8x22b,
llama4-scout, moonshot/moonlight (MoE, incl. first-k-dense and shared
experts). Layers are *scanned* (stacked params + ``lax.scan``) so the HLO is
depth-independent — essential for 48-81 layer configs at dry-run compile.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import api
from repro.nn import layers, moe as moe_lib
from repro.nn.param import ParamSpec, init_tree, stack_specs, zeros_init
from repro.nn.sharding import logical_constraint


def _block_specs(cfg: ModelConfig, use_moe: bool):
    p = {
        "ln1": layers.norm_specs(cfg),
        "attn": layers.attention_specs(cfg),
        "ln2": layers.norm_specs(cfg),
    }
    if use_moe:
        p["moe"] = moe_lib.moe_specs(cfg)
    else:
        p["mlp"] = layers.mlp_specs(cfg)
    return p


def _apply_block(bp, x, cfg: ModelConfig, use_moe: bool, *, angles,
                 q_pos, cache=None, cache_index=None):
    h = layers.apply_norm(bp["ln1"], x, cfg)
    a, new_cache = layers.multihead_attention(
        bp["attn"], h, cfg, angles=angles, q_pos=q_pos,
        cache=cache, cache_index=cache_index,
    )
    x = x + a
    h = layers.apply_norm(bp["ln2"], x, cfg)
    if use_moe:
        m, aux = moe_lib.apply_moe(bp["moe"], h, cfg)
    else:
        m, aux = layers.apply_mlp(bp["mlp"], h, cfg), 0.0
    return x + m, aux, new_cache


@dataclasses.dataclass
class DecoderLM:
    cfg: ModelConfig

    def __post_init__(self):
        cfg = self.cfg
        n_dense = cfg.first_dense_layers if cfg.moe else cfg.num_layers
        n_moe = cfg.num_layers - n_dense if cfg.moe else 0
        self.n_dense, self.n_moe = n_dense, n_moe
        spec = {"embed": layers.embedding_specs(cfg),
                "final_norm": layers.norm_specs(cfg)}
        if n_dense:
            spec["dense_layers"] = stack_specs(
                _block_specs(cfg, False), n_dense)
        if n_moe:
            spec["moe_layers"] = stack_specs(_block_specs(cfg, True), n_moe)
        self.spec = spec

    # -- positions / rope ---------------------------------------------------
    def _angles(self, positions):
        return layers.rope_angles(positions, self.cfg)

    def positions(self, batch, B, S, offset=0):
        del batch
        return api.default_positions(B, S) + offset

    def input_embeds(self, params, batch):
        return layers.embed(params["embed"], batch["tokens"], self.cfg)

    # -- full-sequence forward (train / logits) ------------------------------
    def forward(self, params, batch, *, remat: bool = False):
        cfg = self.cfg
        x = self.input_embeds(params, batch)
        B, S, _ = x.shape
        pos = self.positions(batch, B, S)
        angles = self._angles(pos)
        q_pos = api.default_positions(B, S)  # mask positions are sequential

        x, aux = self._stacks(params, x, angles=angles, q_pos=q_pos,
                              remat=remat)
        x = layers.apply_norm(params["final_norm"], x, cfg)
        logits = layers.unembed(params["embed"], x, cfg)
        return logits, aux

    def _stacks(self, params, x, *, angles, q_pos, remat):
        cfg = self.cfg
        aux_total = 0.0
        for key, use_moe in (("dense_layers", False), ("moe_layers", True)):
            if key not in params:
                continue

            def body(carry, lp, _use_moe=use_moe):
                h, aux = carry
                h2, a, _ = _apply_block(lp, h, cfg, _use_moe,
                                        angles=angles, q_pos=q_pos)
                return (h2, aux + a), None

            fn = jax.checkpoint(body) if remat else body
            (x, aux_total), _ = jax.lax.scan(
                fn, (x, aux_total + 0.0), params[key])
        if isinstance(aux_total, float):
            aux_total = jnp.zeros((), jnp.float32)
        return x, aux_total

    # -- decode ---------------------------------------------------------------
    def cache_spec(self, batch_size: int, cache_len: int):
        cfg = self.cfg
        kv = lambda n: ParamSpec(
            (n, batch_size, cache_len, cfg.kv_heads, cfg.hd), cfg.adtype,
            zeros_init, ("layers", "cache_batch", "cache_seq", "cache_heads",
                         None),
        )
        spec = {}
        if self.n_dense:
            spec["dense"] = {"k": kv(self.n_dense), "v": kv(self.n_dense)}
        if self.n_moe:
            spec["moe"] = {"k": kv(self.n_moe), "v": kv(self.n_moe)}
        return spec

    def init_cache(self, batch_size: int, cache_len: int):
        return init_tree(jax.random.key(0),
                         self.cache_spec(batch_size, cache_len))

    def _with_cache(self, params, batch, cache, index, q_len=None):
        cfg = self.cfg
        x = self.input_embeds(params, batch)
        B = x.shape[0]
        q_len = x.shape[1]  # total (e.g. patches + text for VLM)
        pos = self.positions(batch, B, q_len, offset=index)
        angles = self._angles(pos)
        q_pos = api.default_positions(B, q_len) + index

        aux = jnp.zeros((), jnp.float32)
        new_cache = {}
        for key, ckey, use_moe in (("dense_layers", "dense", False),
                                   ("moe_layers", "moe", True)):
            if key not in params:
                continue

            def body(carry, xs, _use_moe=use_moe):
                h, aux = carry
                lp, ck, cv = xs
                h2, a, nc = _apply_block(
                    lp, h, cfg, _use_moe, angles=angles, q_pos=q_pos,
                    cache={"k": ck, "v": cv}, cache_index=index,
                )
                return (h2, aux + a), (nc["k"], nc["v"])

            (x, aux), (nk, nv) = jax.lax.scan(
                body, (x, aux), (params[key], cache[ckey]["k"],
                                 cache[ckey]["v"]))
            new_cache[ckey] = {"k": nk, "v": nv}
        x = layers.apply_norm(params["final_norm"], x, cfg)
        logits = layers.unembed(params["embed"], x, cfg)
        return logits, new_cache

    def prefill(self, params, batch, cache):
        S = batch["tokens"].shape[1]
        return self._with_cache(params, batch, cache, 0, S)

    def decode_step(self, params, batch, cache, index):
        return self._with_cache(params, batch, cache, index, 1)

    # -- launch plumbing ------------------------------------------------------
    def input_specs(self, shape: ShapeConfig):
        return api.token_input_specs(self.cfg, shape)

    def dummy_batch(self, rng, shape: ShapeConfig):
        return api.dummy_tokens(rng, self.cfg, shape)

    def loss(self, params, batch, *, remat: bool = False):
        logits, aux = self.forward(params, batch, remat=remat)
        ce = api.cross_entropy(logits, batch["targets"], self.cfg.vocab_size)
        return ce + self.cfg.router_aux_weight * aux, {"ce": ce, "aux": aux}
