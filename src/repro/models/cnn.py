"""The paper's own model: a simple 2-conv-layer CNN for 28×28 10-class
classification ("a simple 2-layer convolutional neural network from PyTorch",
paper §VI — i.e. the canonical PyTorch MNIST example: conv(1→32,3×3),
conv(32→64,3×3), maxpool 2×2, fc(9216→128), fc(128→10))."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.nn.param import ParamSpec, fan_in_init, zeros_init


def _conv(x, w, b):
    """3×3 VALID conv via im2col matmul.

    Pure slicing + matmul (no lax.conv): XLA-CPU's conv gradients fall into
    a very slow grouped-conv path under vmap-over-workers + jvp-of-grad
    (the Hutchinson HVP), while matmuls stay on the fast Eigen path.
    Numerically identical to lax.conv_general_dilated.
    """
    B, Hh, Ww, C = x.shape
    kh, kw, _, O = w.shape
    oh, ow = Hh - kh + 1, Ww - kw + 1
    cols = jnp.stack(
        [x[:, i:i + oh, j:j + ow, :] for i in range(kh) for j in range(kw)],
        axis=3)  # (B, oh, ow, kh*kw, C)
    cols = cols.reshape(B, oh, ow, kh * kw * C)
    return cols @ w.reshape(kh * kw * C, O) + b


def _maxpool2(x):
    """2×2 max pool via reshape (fast differentiable path on CPU)."""
    B, Hh, Ww, C = x.shape
    return x.reshape(B, Hh // 2, 2, Ww // 2, 2, C).max(axis=(2, 4))


@dataclasses.dataclass
class PaperCNN:
    cfg: ModelConfig

    def __post_init__(self):
        f32 = jnp.float32
        self.spec = {
            "conv1": {"w": ParamSpec((3, 3, 1, 32), f32, fan_in_init(2)),
                      "b": ParamSpec((32,), f32, zeros_init)},
            "conv2": {"w": ParamSpec((3, 3, 32, 64), f32, fan_in_init(2)),
                      "b": ParamSpec((64,), f32, zeros_init)},
            "fc1": {"w": ParamSpec((9216, 128), f32, fan_in_init(0)),
                    "b": ParamSpec((128,), f32, zeros_init)},
            "fc2": {"w": ParamSpec((128, 10), f32, fan_in_init(0)),
                    "b": ParamSpec((10,), f32, zeros_init)},
        }

    def forward(self, params, batch, *, remat: bool = False):
        x = batch["images"]  # (B, 28, 28, 1)
        x = jax.nn.relu(_conv(x, params["conv1"]["w"], params["conv1"]["b"]))
        x = jax.nn.relu(_conv(x, params["conv2"]["w"], params["conv2"]["b"]))
        x = _maxpool2(x)
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
        return x @ params["fc2"]["w"] + params["fc2"]["b"], jnp.zeros((), jnp.float32)

    def loss(self, params, batch, *, remat: bool = False):
        logits, _ = self.forward(params, batch)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, batch["labels"][:, None], -1)[:, 0]
        ce = jnp.mean(logz - gold)
        return ce, {"ce": ce,
                    "acc": jnp.mean(
                        (jnp.argmax(logits, -1) == batch["labels"]))}

    def accuracy(self, params, batch):
        logits, _ = self.forward(params, batch)
        return jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(
            jnp.float32))

    def input_specs(self, shape: ShapeConfig):
        B = shape.global_batch
        return {
            "images": ParamSpec((B, 28, 28, 1), jnp.float32, zeros_init,
                                ("batch", None, None, None)),
            "labels": ParamSpec((B,), jnp.int32, zeros_init, ("batch",)),
        }

    def dummy_batch(self, rng, shape: ShapeConfig):
        k1, k2 = jax.random.split(rng)
        B = shape.global_batch
        return {"images": jax.random.normal(k1, (B, 28, 28, 1)),
                "labels": jax.random.randint(k2, (B,), 0, 10, jnp.int32)}
