"""build_model(cfg) — family dispatch."""
from __future__ import annotations

from repro.configs.base import ModelConfig, get_config


def build_model(cfg_or_arch, smoke: bool = False):
    cfg = (cfg_or_arch if isinstance(cfg_or_arch, ModelConfig)
           else get_config(cfg_or_arch, smoke=smoke))
    fam = cfg.family
    if fam in ("dense", "moe"):
        from repro.models.dense import DecoderLM

        return DecoderLM(cfg)
    if fam == "hybrid":
        from repro.models.hybrid import HybridLM

        return HybridLM(cfg)
    if fam == "rwkv":
        from repro.models.rwkv6 import RWKV6LM

        return RWKV6LM(cfg)
    if fam == "encdec":
        from repro.models.encdec import EncDecLM

        return EncDecLM(cfg)
    if fam == "vlm":
        from repro.models.vlm import VLM

        return VLM(cfg)
    if fam == "cnn":
        from repro.models.cnn import PaperCNN

        return PaperCNN(cfg)
    raise ValueError(f"unknown family {fam!r}")
