"""Zamba2-style hybrid: Mamba2 (SSD) backbone + a *shared* attention+MLP
block invoked every ``attn_every`` layers (weight-tied across invocations).

arXiv:2411.15242. The SSD sequence mix runs through the shared chunked GLA
engine (scalar per-head decay, inclusive read). Layers are grouped
(``attn_every`` Mamba layers + one shared-block invocation) and scanned over
groups, so the decode cache holds exactly one KV slot per invocation (13 for
the 81-layer config), not per layer.

Simplification noted in DESIGN.md: Zamba2's per-invocation LoRA deltas on the
shared block and the concat-with-embedding input are omitted (pure weight
tying kept).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import api
from repro.nn import layers
from repro.nn.gla import causal_conv1d, gla_chunked, gla_decode_step
from repro.nn.param import (ParamSpec, fan_in_init, init_tree, normal_init,
                            ones_init, stack_specs, zeros_init)
from repro.nn.sharding import logical_constraint


def _d_inner(cfg):
    return cfg.ssm_expand * cfg.d_model


def _n_heads(cfg):
    return _d_inner(cfg) // cfg.ssm_head_dim


def mamba_specs(cfg: ModelConfig):
    d = cfg.d_model
    din = _d_inner(cfg)
    N, H = cfg.ssm_state, _n_heads(cfg)
    conv_c = din + 2 * N
    proj_out = 2 * din + 2 * N + H  # z, x, B, C, dt
    pd = cfg.pdtype
    return {
        "norm": layers.norm_specs(cfg),
        "in_proj": ParamSpec((d, proj_out), pd, fan_in_init(0),
                             ("embed", "mlp")),
        "conv_w": ParamSpec((cfg.ssm_conv_width, conv_c), jnp.float32,
                            normal_init(0.1), (None, "mlp")),
        "conv_b": ParamSpec((conv_c,), jnp.float32, zeros_init, ("mlp",)),
        "A_log": ParamSpec((H,), jnp.float32,
                           lambda k, s, dt: jnp.log(
                               jax.random.uniform(k, s, dt, 1.0, 16.0)),
                           ("heads",)),
        "D": ParamSpec((H,), jnp.float32, ones_init, ("heads",)),
        "dt_bias": ParamSpec((H,), jnp.float32,
                             lambda k, s, dt: jnp.log(
                                 jnp.expm1(jax.random.uniform(
                                     k, s, dt, 1e-3, 1e-1))),
                             ("heads",)),
        "gate_norm": {"scale": ParamSpec((din,), jnp.float32, ones_init,
                                         ("norm",))},
        "out_proj": ParamSpec((din, d), pd, fan_in_init(0), ("mlp", "embed")),
    }


def apply_mamba(mp, x, cfg: ModelConfig, *, conv_buf=None, state=None):
    """x: (B,T,d). Returns (out, new_conv_buf, new_state)."""
    B, T, d = x.shape
    din = _d_inner(cfg)
    N, H = cfg.ssm_state, _n_heads(cfg)
    P = cfg.ssm_head_dim
    dt_ = x.dtype

    u = layers.apply_norm(mp["norm"], x, cfg)
    zxbcdt = u @ mp["in_proj"].astype(dt_)
    z, xBC, dt_raw = jnp.split(zxbcdt, [din, 2 * din + 2 * N], axis=-1)
    xBC, new_conv = causal_conv1d(xBC, mp["conv_w"], buffer=conv_buf)
    xBC = jax.nn.silu(xBC + mp["conv_b"].astype(dt_))
    xs, Bc, Cc = jnp.split(xBC, [din, din + N], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + mp["dt_bias"])  # (B,T,H)
    logw = -jnp.exp(mp["A_log"])[None, None] * dt  # (B,T,H)

    v = xs.reshape(B, T, H, P) * dt[..., None].astype(dt_)
    q = jnp.broadcast_to(Cc[:, :, None], (B, T, H, N))
    k = jnp.broadcast_to(Bc[:, :, None], (B, T, H, N))

    if T == 1 and state is not None:
        y, new_state = gla_decode_step(
            state, q[:, 0], k[:, 0], v[:, 0], logw[:, 0], inclusive=True)
        y = y[:, None]
    else:
        # scalar per-head decay → exact pairwise-decay chunked path
        y, new_state = gla_chunked(
            q, k, v, logw, chunk=min(cfg.scan_chunk, T), inclusive=True,
            initial_state=state, scalar_decay=True)
    y = y + mp["D"].astype(dt_)[None, None, :, None] * xs.reshape(B, T, H, P)
    y = y.reshape(B, T, din) * jax.nn.silu(z)
    y = layers.rms_norm(y, mp["gate_norm"]["scale"], cfg.norm_eps)
    return x + y @ mp["out_proj"].astype(dt_), new_conv, new_state


def shared_block_specs(cfg: ModelConfig):
    return {
        "ln1": layers.norm_specs(cfg),
        "attn": layers.attention_specs(cfg),
        "ln2": layers.norm_specs(cfg),
        "mlp": layers.mlp_specs(cfg),
    }


def apply_shared_block(sp, x, cfg, *, angles, q_pos, cache=None,
                       cache_index=None):
    h = layers.apply_norm(sp["ln1"], x, cfg)
    a, new_cache = layers.multihead_attention(
        sp["attn"], h, cfg, angles=angles, q_pos=q_pos, cache=cache,
        cache_index=cache_index)
    x = x + a
    h = layers.apply_norm(sp["ln2"], x, cfg)
    return x + layers.apply_mlp(sp["mlp"], h, cfg), new_cache


@dataclasses.dataclass
class HybridLM:
    cfg: ModelConfig

    def __post_init__(self):
        cfg = self.cfg
        every = cfg.attn_every or cfg.num_layers
        self.n_groups = cfg.num_layers // every
        self.tail = cfg.num_layers - self.n_groups * every
        self.every = every
        spec = {
            "embed": layers.embedding_specs(cfg),
            "shared": shared_block_specs(cfg),
            "groups": stack_specs(
                stack_specs(mamba_specs(cfg), every), self.n_groups),
            "final_norm": layers.norm_specs(cfg),
        }
        if self.tail:
            spec["tail"] = stack_specs(mamba_specs(cfg), self.tail)
        self.spec = spec

    def _run(self, params, x, *, angles, q_pos, cache=None, cache_index=None,
             remat=False):
        cfg = self.cfg
        decode = cache is not None

        def mamba_scan(h, lps, bufs=None, states=None):
            def body(carry, xs):
                hh = carry
                if bufs is None:
                    out, nb, ns = apply_mamba(xs, hh, cfg)
                else:
                    out, nb, ns = apply_mamba(
                        xs[0], hh, cfg, conv_buf=xs[1], state=xs[2])
                return out, (nb, ns)

            fn = jax.checkpoint(body) if remat else body
            xs = lps if bufs is None else (lps, bufs, states)
            return jax.lax.scan(fn, h, xs)

        def group_body(carry, xs):
            h = carry
            if decode:
                gp, bufs, states, ck, cv = xs
                h, (nb, ns) = mamba_scan(h, gp, bufs, states)
                h, nc = apply_shared_block(
                    params["shared"], h, cfg, angles=angles, q_pos=q_pos,
                    cache={"k": ck, "v": cv}, cache_index=cache_index)
                return h, (nb, ns, nc["k"], nc["v"])
            gp = xs
            h, _ = mamba_scan(h, gp)
            h, _ = apply_shared_block(params["shared"], h, cfg,
                                      angles=angles, q_pos=q_pos)
            return h, None

        if decode:
            fn = group_body
            x, (nb, ns, nk, nv) = jax.lax.scan(
                fn, x, (params["groups"], cache["conv"], cache["state"],
                        cache["k"], cache["v"]))
            new_cache = {"conv": nb, "state": ns, "k": nk, "v": nv}
            if self.tail:
                x, (tb, ts) = mamba_scan(x, params["tail"],
                                         cache["tail_conv"],
                                         cache["tail_state"])
                new_cache["tail_conv"], new_cache["tail_state"] = tb, ts
            return x, new_cache
        fn = jax.checkpoint(group_body) if remat else group_body
        x, _ = jax.lax.scan(fn, x, params["groups"])
        if self.tail:
            x, _ = mamba_scan(x, params["tail"])
        return x, None

    def forward(self, params, batch, *, remat: bool = False):
        cfg = self.cfg
        x = layers.embed(params["embed"], batch["tokens"], cfg)
        B, S, _ = x.shape
        pos = api.default_positions(B, S)
        x, _ = self._run(params, x, angles=layers.rope_angles(pos, cfg),
                         q_pos=pos, remat=remat)
        x = layers.apply_norm(params["final_norm"], x, cfg)
        return layers.unembed(params["embed"], x, cfg), jnp.zeros((), jnp.float32)

    def cache_spec(self, batch_size: int, cache_len: int):
        cfg = self.cfg
        G, E, T = self.n_groups, self.every, self.tail
        din = _d_inner(cfg)
        N, H, P = cfg.ssm_state, _n_heads(cfg), cfg.ssm_head_dim
        conv_c = din + 2 * N
        K = cfg.ssm_conv_width
        spec = {
            "conv": ParamSpec((G, E, batch_size, K - 1, conv_c), cfg.adtype,
                              zeros_init,
                              ("layers", None, "cache_batch", None, "mlp")),
            "state": ParamSpec((G, E, batch_size, H, N, P), jnp.float32,
                               zeros_init,
                               ("layers", None, "cache_batch", "cache_heads",
                                None, None)),
            "k": ParamSpec((G, batch_size, cache_len, cfg.kv_heads, cfg.hd),
                           cfg.adtype, zeros_init,
                           ("layers", "cache_batch", "cache_seq",
                            "cache_heads", None)),
            "v": ParamSpec((G, batch_size, cache_len, cfg.kv_heads, cfg.hd),
                           cfg.adtype, zeros_init,
                           ("layers", "cache_batch", "cache_seq",
                            "cache_heads", None)),
        }
        if T:
            spec["tail_conv"] = ParamSpec(
                (T, batch_size, K - 1, conv_c), cfg.adtype, zeros_init,
                ("layers", "cache_batch", None, "mlp"))
            spec["tail_state"] = ParamSpec(
                (T, batch_size, H, N, P), jnp.float32, zeros_init,
                ("layers", "cache_batch", "cache_heads", None, None))
        return spec

    def init_cache(self, batch_size: int, cache_len: int):
        return init_tree(jax.random.key(0),
                         self.cache_spec(batch_size, cache_len))

    def _cached(self, params, batch, cache, index, q_len):
        cfg = self.cfg
        x = layers.embed(params["embed"], batch["tokens"], cfg)
        B = x.shape[0]
        pos = api.default_positions(B, q_len) + index
        x, new_cache = self._run(
            params, x, angles=layers.rope_angles(pos, cfg), q_pos=pos,
            cache=cache, cache_index=index)
        x = layers.apply_norm(params["final_norm"], x, cfg)
        return layers.unembed(params["embed"], x, cfg), new_cache

    def prefill(self, params, batch, cache):
        return self._cached(params, batch, cache, 0, batch["tokens"].shape[1])

    def decode_step(self, params, batch, cache, index):
        return self._cached(params, batch, cache, index, 1)

    def input_specs(self, shape: ShapeConfig):
        return api.token_input_specs(self.cfg, shape)

    def dummy_batch(self, rng, shape: ShapeConfig):
        return api.dummy_tokens(rng, self.cfg, shape)

    def loss(self, params, batch, *, remat: bool = False):
        logits, aux = self.forward(params, batch, remat=remat)
        ce = api.cross_entropy(logits, batch["targets"], self.cfg.vocab_size)
        return ce, {"ce": ce, "aux": aux}
