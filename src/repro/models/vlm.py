"""Qwen2-VL-style VLM backbone: dense GQA decoder + M-RoPE + patch inputs.

arXiv:2409.12191. The vision frontend (ViT + merger) is a STUB per the
assignment carve-out — the batch carries precomputed patch embeddings
(B, Np, d_model) which are prepended to the text embeddings. M-RoPE splits
each rotary half into (temporal, height, width) sections; vision tokens get
grid (h, w) coordinates at t=0, text tokens get equal (t,h,w) starting after
the vision grid extent (dynamic-resolution semantics, one image per sample).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import api
from repro.models.dense import DecoderLM
from repro.nn import layers
from repro.nn.param import ParamSpec, zeros_init


@dataclasses.dataclass
class VLM(DecoderLM):
    cfg: ModelConfig

    @property
    def grid(self) -> int:
        return max(1, int(math.sqrt(self.cfg.num_patch_tokens)))

    def _mrope_positions(self, B, n_patch, n_text, offset=0):
        g = self.grid
        idx = jnp.arange(n_patch, dtype=jnp.int32)
        vis = jnp.stack([jnp.zeros_like(idx), idx // g, idx % g])  # (3, Np)
        t0 = g  # text starts after the grid extent
        txt = jnp.broadcast_to(t0 + jnp.arange(n_text, dtype=jnp.int32),
                               (3, n_text))
        pos = jnp.concatenate([vis, txt], axis=1) if n_patch else txt
        return jnp.broadcast_to(pos[:, None], (3, B, n_patch + n_text)) + offset

    def positions(self, batch, B, S, offset=0):
        if "patches" in batch:
            n_patch = batch["patches"].shape[1]
            return self._mrope_positions(B, n_patch, S - n_patch, offset)
        # decode: global index `offset` counts patches + text, but M-RoPE
        # text positions advance from the grid extent by *text* index only
        return self._mrope_positions(
            B, 0, S, offset - self.cfg.num_patch_tokens)

    def input_embeds(self, params, batch):
        cfg = self.cfg
        txt = layers.embed(params["embed"], batch["tokens"], cfg)
        if "patches" in batch:
            return jnp.concatenate(
                [batch["patches"].astype(cfg.adtype), txt], axis=1)
        return txt

    def input_specs(self, shape: ShapeConfig):
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        Np = min(cfg.num_patch_tokens, S // 4)
        patches = ParamSpec((B, Np, cfg.d_model), cfg.adtype, zeros_init,
                            ("batch", "seq", None))
        tok = lambda s: ParamSpec(s, jnp.int32, zeros_init, ("batch", "seq"))
        if shape.kind == "train":
            return {"patches": patches, "tokens": tok((B, S - Np)),
                    "targets": tok((B, S - Np))}
        if shape.kind == "prefill":
            return {"patches": patches, "tokens": tok((B, S - Np))}
        return {"tokens": ParamSpec((B, 1), jnp.int32, zeros_init,
                                    ("batch", None))}

    def dummy_batch(self, rng, shape: ShapeConfig):
        cfg = self.cfg
        out = {}
        for name, s in self.input_specs(shape).items():
            rng, k = jax.random.split(rng)
            if s.dtype == jnp.int32:
                out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab_size,
                                               jnp.int32)
            else:
                out[name] = jax.random.normal(k, s.shape, s.dtype)
        return out

    def loss(self, params, batch, *, remat: bool = False):
        logits, aux = self.forward(params, batch, remat=remat)
        n_patch = batch["patches"].shape[1] if "patches" in batch else 0
        text_logits = logits[:, n_patch:]
        ce = api.cross_entropy(text_logits, batch["targets"],
                               self.cfg.vocab_size)
        return ce + self.cfg.router_aux_weight * aux, {"ce": ce, "aux": aux}
