"""Model protocol + shared helpers.

Every family builder returns an object with:

- ``cfg``            — the ModelConfig
- ``spec``           — ParamSpec tree (abstract; materialize via init_tree)
- ``forward(params, batch, remat=False)``  → (logits, aux_loss)   [train]
- ``cache_spec(batch_size, cache_len)``    → ParamSpec tree of decode state
- ``prefill(params, batch, cache)``        → (logits, cache)
- ``decode_step(params, batch, cache, index)`` → (logits, cache)  [1 token]
- ``input_specs(shape)``  → dict[str, ParamSpec] describing the batch
- ``dummy_batch(rng, shape)`` → concrete batch (smoke tests)

Batches are plain dicts; tokens are int32.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.nn.param import ParamSpec, zeros_init


def token_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, ParamSpec]:
    B, S = shape.global_batch, shape.seq_len
    tok = lambda s: ParamSpec(s, jnp.int32, zeros_init, ("batch", "seq"))
    if shape.kind == "train":
        return {"tokens": tok((B, S)), "targets": tok((B, S))}
    if shape.kind == "prefill":
        return {"tokens": tok((B, S))}
    # decode: one new token against a cache of length S
    return {"tokens": ParamSpec((B, 1), jnp.int32, zeros_init, ("batch", None))}


def dummy_tokens(rng, cfg: ModelConfig, shape: ShapeConfig):
    specs = token_input_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        rng, k = jax.random.split(rng)
        out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab_size, jnp.int32)
    return out


def cross_entropy(logits: jax.Array, targets: jax.Array, vocab: int):
    """Mean token-level cross entropy; logits (B,S,V) any dtype."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def default_positions(batch_size: int, seq_len: int):
    return jnp.broadcast_to(jnp.arange(seq_len, dtype=jnp.int32),
                            (batch_size, seq_len))
