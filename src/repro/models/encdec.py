"""Encoder-decoder backbone (seamless-m4t-large-v2 text/speech backbone).

arXiv:2308.11596. The modality frontend (mel-spectrogram + conv feature
extractor) is a STUB per the assignment carve-out: the batch carries
precomputed frame embeddings ``src`` of shape (B, S_enc, d_model). The
encoder is a non-causal pre-norm transformer; the decoder adds causal
self-attention + cross-attention. Decode caches: self-attn KV (per decoder
layer) + frozen cross-attn KV computed once at prefill from the memory.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import api
from repro.nn import layers
from repro.nn.param import ParamSpec, init_tree, stack_specs, zeros_init


def _enc_block_specs(cfg):
    return {
        "ln1": layers.norm_specs(cfg),
        "attn": layers.attention_specs(cfg),
        "ln2": layers.norm_specs(cfg),
        "mlp": layers.mlp_specs(cfg),
    }


def _dec_block_specs(cfg):
    return {
        "ln1": layers.norm_specs(cfg),
        "self_attn": layers.attention_specs(cfg),
        "ln_x": layers.norm_specs(cfg),
        "cross_attn": layers.attention_specs(cfg, cross=True),
        "ln2": layers.norm_specs(cfg),
        "mlp": layers.mlp_specs(cfg),
    }


@dataclasses.dataclass
class EncDecLM:
    cfg: ModelConfig

    def __post_init__(self):
        cfg = self.cfg
        self.spec = {
            "embed": layers.embedding_specs(cfg),
            "enc_layers": stack_specs(_enc_block_specs(cfg), cfg.enc_layers),
            "enc_norm": layers.norm_specs(cfg),
            "dec_layers": stack_specs(_dec_block_specs(cfg), cfg.dec_layers),
            "final_norm": layers.norm_specs(cfg),
        }

    def enc_len(self, dec_len: int) -> int:
        return max(128, dec_len // self.cfg.enc_seq_ratio)

    # -- encoder --------------------------------------------------------------
    def encode(self, params, src, *, remat=False):
        cfg = self.cfg
        B, Se, _ = src.shape
        pos = api.default_positions(B, Se)
        angles = layers.rope_angles(pos, cfg)

        def body(h, lp):
            u = layers.apply_norm(lp["ln1"], h, cfg)
            a, _ = layers.multihead_attention(
                lp["attn"], u, cfg, angles=angles, q_pos=pos, causal=False)
            h = h + a
            u = layers.apply_norm(lp["ln2"], h, cfg)
            return h + layers.apply_mlp(lp["mlp"], u, cfg), None

        fn = jax.checkpoint(body) if remat else body
        x = src.astype(cfg.adtype)
        x, _ = jax.lax.scan(fn, x, params["enc_layers"])
        return layers.apply_norm(params["enc_norm"], x, cfg)

    # -- decoder --------------------------------------------------------------
    def _decode_stack(self, params, x, memory, *, q_pos, angles, cache=None,
                      cache_index=None, remat=False):
        cfg = self.cfg

        def body(carry, xs):
            h = carry
            if cache is not None:
                lp, ck, cv, xk, xv = xs
            else:
                lp = xs
            u = layers.apply_norm(lp["ln1"], h, cfg)
            a, nc = layers.multihead_attention(
                lp["self_attn"], u, cfg, angles=angles, q_pos=q_pos,
                cache=None if cache is None else {"k": ck, "v": cv},
                cache_index=cache_index)
            h = h + a
            u = layers.apply_norm(lp["ln_x"], h, cfg)
            if cache is not None:
                # frozen cross KV from prefill
                c, _ = layers.multihead_attention(
                    lp["cross_attn"], u, cfg, q_pos=q_pos, causal=False,
                    kv_x=None, cache=None,
                    kv_precomputed=(xk, xv))
            else:
                c, _ = layers.multihead_attention(
                    lp["cross_attn"], u, cfg, kv_x=memory, q_pos=q_pos)
            h = h + c
            u = layers.apply_norm(lp["ln2"], h, cfg)
            h = h + layers.apply_mlp(lp["mlp"], u, cfg)
            if cache is not None:
                return h, (nc["k"], nc["v"])
            return h, None

        if cache is not None:
            x, (nk, nv) = jax.lax.scan(
                body, x, (params["dec_layers"], cache["k"], cache["v"],
                          cache["xk"], cache["xv"]))
            return x, {"k": nk, "v": nv, "xk": cache["xk"], "xv": cache["xv"]}
        fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(fn, x, params["dec_layers"])
        return x, None

    def cross_kv(self, params, memory):
        """Precompute per-layer cross-attention K/V from encoder memory."""
        cfg = self.cfg

        def body(_, lp):
            ap = lp["cross_attn"]
            k = jnp.einsum("bsd,dhk->bshk", memory, ap["wk"].astype(memory.dtype))
            v = jnp.einsum("bsd,dhk->bshk", memory, ap["wv"].astype(memory.dtype))
            return None, (k, v)

        _, (ks, vs) = jax.lax.scan(body, None, params["dec_layers"])
        return ks, vs  # (L,B,Se,KVH,D)

    def forward(self, params, batch, *, remat: bool = False):
        cfg = self.cfg
        memory = self.encode(params, batch["src"], remat=remat)
        x = layers.embed(params["embed"], batch["tokens"], cfg)
        B, S, _ = x.shape
        pos = api.default_positions(B, S)
        x, _ = self._decode_stack(
            params, x, memory, q_pos=pos,
            angles=layers.rope_angles(pos, cfg), remat=remat)
        x = layers.apply_norm(params["final_norm"], x, cfg)
        return layers.unembed(params["embed"], x, cfg), jnp.zeros((), jnp.float32)

    # -- decode ---------------------------------------------------------------
    def cache_spec(self, batch_size: int, cache_len: int):
        cfg = self.cfg
        L = cfg.dec_layers
        Se = self.enc_len(cache_len)
        kv = lambda s: ParamSpec(
            (L, batch_size, s, cfg.kv_heads, cfg.hd), cfg.adtype, zeros_init,
            ("layers", "cache_batch", "cache_seq", "cache_heads", None))
        return {"k": kv(cache_len), "v": kv(cache_len),
                "xk": kv(Se), "xv": kv(Se)}

    def init_cache(self, batch_size: int, cache_len: int):
        return init_tree(jax.random.key(0),
                         self.cache_spec(batch_size, cache_len))

    def prefill(self, params, batch, cache):
        """Encode src, fill cross KV, then run the target prefix."""
        memory = self.encode(params, batch["src"])
        xk, xv = self.cross_kv(params, memory)
        cache = dict(cache, xk=xk, xv=xv)
        return self._step(params, batch, cache, 0,
                          batch["tokens"].shape[1])

    def decode_step(self, params, batch, cache, index):
        return self._step(params, batch, cache, index, 1)

    def _step(self, params, batch, cache, index, q_len):
        cfg = self.cfg
        x = layers.embed(params["embed"], batch["tokens"], cfg)
        B = x.shape[0]
        pos = api.default_positions(B, q_len) + index
        x, new_cache = self._decode_stack(
            params, x, None, q_pos=pos,
            angles=layers.rope_angles(pos, cfg), cache=cache,
            cache_index=index)
        x = layers.apply_norm(params["final_norm"], x, cfg)
        return layers.unembed(params["embed"], x, cfg), new_cache

    # -- launch plumbing ------------------------------------------------------
    def input_specs(self, shape: ShapeConfig):
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        Se = self.enc_len(S)
        src = ParamSpec((B, Se, cfg.d_model), cfg.adtype, zeros_init,
                        ("batch", "seq", None))
        tok = lambda s: ParamSpec(s, jnp.int32, zeros_init, ("batch", "seq"))
        if shape.kind == "train":
            return {"src": src, "tokens": tok((B, S)), "targets": tok((B, S))}
        if shape.kind == "prefill":
            return {"src": src, "tokens": tok((B, S))}
        return {"tokens": ParamSpec((B, 1), jnp.int32, zeros_init,
                                    ("batch", None))}

    def dummy_batch(self, rng, shape: ShapeConfig):
        cfg = self.cfg
        specs = self.input_specs(shape)
        out = {}
        for name, s in specs.items():
            rng, k = jax.random.split(rng)
            if s.dtype == jnp.int32:
                out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab_size,
                                               jnp.int32)
            else:
                out[name] = jax.random.normal(k, s.shape, s.dtype)
        return out

    def loss(self, params, batch, *, remat: bool = False):
        logits, aux = self.forward(params, batch, remat=remat)
        ce = api.cross_entropy(logits, batch["targets"], self.cfg.vocab_size)
        return ce, {"ce": ce, "aux": aux}
