"""RWKV6 ("Finch") — attention-free RNN LM with data-dependent decay.

arXiv:2404.05892. Time-mix uses data-dependent token-shift (ddlerp via a
low-rank MLP over the shifted pair) and a per-channel data-dependent decay
w_t = exp(-exp(ω_t)); the wkv recurrence runs through the shared chunked GLA
engine (exclusive read + bonus u). Channel-mix is the squared-ReLU variant.

Train/prefill: chunked scan (MXU-friendly); decode: O(1) state update.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import api
from repro.nn import layers
from repro.nn.gla import gla_chunked, gla_decode_step
from repro.nn.param import (ParamSpec, fan_in_init, init_tree, normal_init,
                            ones_init, stack_specs, zeros_init)
from repro.nn.sharding import logical_constraint

MIX_RANK = 32
DECAY_RANK = 64


def _ln_specs(d):
    return {"scale": ParamSpec((d,), jnp.float32, ones_init, ("norm",)),
            "bias": ParamSpec((d,), jnp.float32, zeros_init, ("norm",))}


def _ln(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), -1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]).astype(dt)


def _group_norm(x, scale, bias, eps=1e-5):
    """x: (B,T,H,P) — LayerNorm per head."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), -1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(dt)


def _layer_specs(cfg: ModelConfig):
    d = cfg.d_model
    H = d // cfg.rwkv_head_dim
    P = cfg.rwkv_head_dim
    pd = cfg.pdtype
    lin = lambda dout: ParamSpec((d, dout), pd, fan_in_init(0),
                                 ("embed", "mlp"))
    return {
        "ln1": _ln_specs(d),
        "ln2": _ln_specs(d),
        "tm": {
            "mu_x": ParamSpec((d,), jnp.float32, zeros_init, (None,)),
            "mu": ParamSpec((5, d), jnp.float32, zeros_init, (None, None)),
            "mix_w1": ParamSpec((d, 5 * MIX_RANK), pd, normal_init(0.01),
                                ("embed", None)),
            "mix_w2": ParamSpec((5, MIX_RANK, d), pd, normal_init(0.01),
                                (None, None, "embed_tp")),
            "wr": lin(d), "wk": lin(d), "wv": lin(d), "wg": lin(d),
            "w0": ParamSpec((d,), jnp.float32,
                            lambda k, s, dt: jnp.full(s, -0.6, dt), (None,)),
            "w1": ParamSpec((d, DECAY_RANK), pd, normal_init(0.01),
                            ("embed", None)),
            "w2": ParamSpec((DECAY_RANK, d), pd, normal_init(0.01),
                            (None, "embed_tp")),
            "u": ParamSpec((H, P), jnp.float32, normal_init(0.3),
                           ("heads", None)),
            "gn_scale": ParamSpec((H, P), jnp.float32, ones_init,
                                  ("heads", None)),
            "gn_bias": ParamSpec((H, P), jnp.float32, zeros_init,
                                 ("heads", None)),
            "wo": ParamSpec((d, d), pd, fan_in_init(0), ("mlp", "embed")),
        },
        "cm": {
            "mu_k": ParamSpec((d,), jnp.float32, zeros_init, (None,)),
            "mu_r": ParamSpec((d,), jnp.float32, zeros_init, (None,)),
            "wk": ParamSpec((d, cfg.d_ff), pd, fan_in_init(0),
                            ("embed", "mlp")),
            "wv": ParamSpec((cfg.d_ff, d), pd, fan_in_init(0),
                            ("mlp", "embed")),
            "wr": ParamSpec((d, d), pd, fan_in_init(0), ("embed", "mlp")),
        },
    }


def _shift(x, prev):
    """prev token's x; full-seq: shift right. prev: (B,d) or None."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([prev[:, None].astype(x.dtype), x[:, :-1]], 1)


def _time_mix(tp, x, cfg, *, prev=None, state=None, chunk=256):
    """x: (B,T,d). Returns (out, last_x, new_state)."""
    B, T, d = x.shape
    H, P = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    dt = x.dtype
    sx = _shift(x, prev) - x
    xxx = x + sx * tp["mu_x"].astype(dt)
    dd = jnp.tanh(xxx @ tp["mix_w1"].astype(dt))  # (B,T,5r)
    dd = dd.reshape(B, T, 5, MIX_RANK)
    dmix = jnp.einsum("btcr,crd->cbtd", dd, tp["mix_w2"].astype(dt))
    mix = tp["mu"].astype(dt)[:, None, None] + dmix  # (5,B,T,d)
    xr, xk, xv, xw, xg = (x + sx * mix[i] for i in range(5))

    r = (xr @ tp["wr"].astype(dt)).reshape(B, T, H, P)
    k = (xk @ tp["wk"].astype(dt)).reshape(B, T, H, P)
    v = (xv @ tp["wv"].astype(dt)).reshape(B, T, H, P)
    g = jax.nn.silu(xg @ tp["wg"].astype(dt))
    omega = tp["w0"] + jnp.tanh(xw @ tp["w1"].astype(dt)) @ tp["w2"].astype(dt)
    logw = -jnp.exp(omega.astype(jnp.float32)).reshape(B, T, H, P)

    # decay floor tied to the training chunk (see gla_chunked docstring);
    # decode applies the same floor so train/decode semantics match.
    floor = -30.0 / chunk
    if T == 1 and state is not None:
        y, new_state = gla_decode_step(
            state, r[:, 0], k[:, 0], v[:, 0], logw[:, 0],
            inclusive=False, bonus=tp["u"], decay_floor=floor)
        y = y[:, None]
    else:
        y, new_state = gla_chunked(
            r, k, v, logw, chunk=min(chunk, T), inclusive=False,
            bonus=tp["u"], initial_state=state, decay_floor=floor)
    y = _group_norm(y, tp["gn_scale"], tp["gn_bias"])
    y = (y.reshape(B, T, d) * g) @ tp["wo"].astype(dt)
    return y, x[:, -1], new_state


def _channel_mix(cp, x, *, prev=None):
    dt = x.dtype
    sx = _shift(x, prev) - x
    xk = x + sx * cp["mu_k"].astype(dt)
    xr = x + sx * cp["mu_r"].astype(dt)
    kk = jnp.square(jax.nn.relu(xk @ cp["wk"].astype(dt)))
    kk = logical_constraint(kk, ("batch", "seq", "act_mlp"))
    out = jax.nn.sigmoid(xr @ cp["wr"].astype(dt)) * (kk @ cp["wv"].astype(dt))
    return out, x[:, -1]


@dataclasses.dataclass
class RWKV6LM:
    cfg: ModelConfig

    def __post_init__(self):
        cfg = self.cfg
        self.spec = {
            "embed": layers.embedding_specs(cfg),
            "ln_in": _ln_specs(cfg.d_model),
            "layers": stack_specs(_layer_specs(cfg), cfg.num_layers),
            "final_norm": _ln_specs(cfg.d_model),
        }

    def _blocks(self, params, x, caches=None, remat=False):
        cfg = self.cfg

        def body(carry, xs):
            h = carry[0]
            lp = xs[0]
            tm_prev = cm_prev = state = None
            if caches is not None:
                _, tm_prev, cm_prev, state = None, xs[1], xs[2], xs[3]
            a, tm_last, new_state = _time_mix(
                lp["tm"], _ln(lp["ln1"], h), cfg, prev=tm_prev, state=state,
                chunk=cfg.scan_chunk)
            h = h + a
            m, cm_last = _channel_mix(lp["cm"], _ln(lp["ln2"], h),
                                      prev=cm_prev)
            h = h + m
            return (h,), (tm_last, cm_last, new_state)

        fn = jax.checkpoint(body) if remat else body
        xs = (params["layers"],) if caches is None else (
            params["layers"], caches["tm_x"], caches["cm_x"], caches["state"])
        (x,), (tm_x, cm_x, state) = jax.lax.scan(fn, (x,), xs)
        return x, {"tm_x": tm_x, "cm_x": cm_x, "state": state}

    def forward(self, params, batch, *, remat: bool = False):
        cfg = self.cfg
        x = layers.embed(params["embed"], batch["tokens"], cfg)
        x = _ln(params["ln_in"], x)
        x, _ = self._blocks(params, x, remat=remat)
        x = _ln(params["final_norm"], x)
        return layers.unembed(params["embed"], x, cfg), jnp.zeros((), jnp.float32)

    def cache_spec(self, batch_size: int, cache_len: int):
        cfg = self.cfg
        L, d = cfg.num_layers, cfg.d_model
        H, P = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
        return {
            "tm_x": ParamSpec((L, batch_size, d), cfg.adtype, zeros_init,
                              ("layers", "cache_batch", None)),
            "cm_x": ParamSpec((L, batch_size, d), cfg.adtype, zeros_init,
                              ("layers", "cache_batch", None)),
            "state": ParamSpec((L, batch_size, H, P, P), jnp.float32,
                               zeros_init,
                               ("layers", "cache_batch", "cache_heads", None,
                                None)),
        }

    def init_cache(self, batch_size: int, cache_len: int):
        return init_tree(jax.random.key(0),
                         self.cache_spec(batch_size, cache_len))

    def _cached_forward(self, params, batch, cache):
        cfg = self.cfg
        x = layers.embed(params["embed"], batch["tokens"], cfg)
        x = _ln(params["ln_in"], x)
        x, new_cache = self._blocks(params, x, caches=cache)
        x = _ln(params["final_norm"], x)
        return layers.unembed(params["embed"], x, cfg), new_cache

    def prefill(self, params, batch, cache):
        return self._cached_forward(params, batch, cache)

    def decode_step(self, params, batch, cache, index):
        del index  # state is positionless
        return self._cached_forward(params, batch, cache)

    def input_specs(self, shape: ShapeConfig):
        return api.token_input_specs(self.cfg, shape)

    def dummy_batch(self, rng, shape: ShapeConfig):
        return api.dummy_tokens(rng, self.cfg, shape)

    def loss(self, params, batch, *, remat: bool = False):
        logits, aux = self.forward(params, batch, remat=remat)
        ce = api.cross_entropy(logits, batch["targets"], self.cfg.vocab_size)
        return ce, {"ce": ce, "aux": aux}
