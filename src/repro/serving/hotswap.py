"""Live checkpoint hot-swap: track a running ``ElasticSession``'s master.

The artifact being served is the EASGD master, which a live training
session keeps rewriting through failures and membership churn. The
watcher polls that checkpoint directory between decode steps, detects a
new save via :func:`checkpoint.read_fingerprint` (manifest mtime+size —
the manifest is written *after* the shards, so a fresh fingerprint means
the shards it indexes are complete), validates the arch against the
engine's config via :func:`checkpoint.read_metadata`, restores the
multi-shard params into a **standby buffer** off the hot path, and flips
them into the engine atomically with ``ContinuousEngine.swap_params`` —
in-flight requests keep decoding on their existing KV.

Serving a one-checkpoint-stale master while the restore runs is the same
tolerance that makes delayed averaging (DaSGD) work in training: the
master moves slowly relative to any single update, so brief staleness is
benign and the swap never blocks a decode tick.

Every poll that changes anything is journalled as a :class:`SwapEvent`,
mirroring how ``control.actuator.Actuator`` journals membership actions —
a serving run's whole swap story is replayable from ``watcher.log``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.checkpoint import checkpoint


@dataclasses.dataclass(frozen=True)
class SwapEvent:
    """Journal entry: one poll that found a new checkpoint (or rejected
    one)."""

    tick: int  # engine decode tick when the poll ran
    fingerprint: str
    applied: bool
    rounds: Optional[int] = None  # training rounds recorded in metadata
    arch: str = ""
    note: str = ""


class CheckpointWatcher:
    """Polls one checkpoint dir and hot-swaps an engine's params.

    ``poll()`` is designed to be called between decode steps (the
    scheduler does this every ``poll_every`` ticks); it is a no-op unless
    the fingerprint moved. The restore targets ``like=engine.params`` so
    the standby tree arrives in the live tree's dtypes/structure and the
    flip is guaranteed recompile-free.
    """

    def __init__(self, engine, path: str, *, expect_arch: Optional[str] = None):
        self.engine = engine
        self.path = path
        # None → swap regardless of recorded arch (metadata-less ckpts)
        self.expect_arch = (expect_arch if expect_arch is not None
                            else engine.model.cfg.name)
        self.log: List[SwapEvent] = []
        # adopt the current fingerprint as the baseline: the engine's
        # params are assumed to already reflect what's on disk at attach
        # time (launch/serve.py restores before building the watcher)
        self._seen = checkpoint.read_fingerprint(path)

    @property
    def swaps_applied(self) -> int:
        return sum(e.applied for e in self.log)

    def poll(self) -> bool:
        """One poll; returns True iff a swap was applied."""
        fp = checkpoint.read_fingerprint(self.path)
        if fp is None or fp == self._seen:
            return False
        # re-read until quiescent: a save could land between our stat and
        # the restore; retrying on a moved fingerprint keeps the restore
        # consistent with exactly one manifest generation
        meta = checkpoint.read_metadata(self.path)
        arch = str(meta.get("arch", ""))
        if self.expect_arch is not None and arch != self.expect_arch:
            self._seen = fp
            self.log.append(SwapEvent(
                tick=self.engine.ticks, fingerprint=fp, applied=False,
                rounds=meta.get("rounds"), arch=arch,
                note=f"arch mismatch: checkpoint {arch!r} != engine "
                     f"{self.expect_arch!r}"))
            return False
        standby, meta = checkpoint.restore(self.path, like=self.engine.params)
        fp_after = checkpoint.read_fingerprint(self.path)
        if fp_after != fp:
            # a new save raced our restore; skip — the next poll sees the
            # newer fingerprint and restores that generation instead
            self.log.append(SwapEvent(
                tick=self.engine.ticks, fingerprint=fp, applied=False,
                rounds=meta.get("rounds"), arch=arch,
                note="checkpoint changed during restore; deferred"))
            return False
        self.engine.swap_params(standby)
        self._seen = fp
        self.log.append(SwapEvent(
            tick=self.engine.ticks, fingerprint=fp, applied=True,
            rounds=meta.get("rounds"), arch=arch))
        return True
