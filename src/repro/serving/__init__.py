"""Serving: static reference engine + continuous-batching subsystem.

- :class:`~repro.serving.engine.ServeEngine` — static-batch reference.
- :class:`~repro.serving.continuous.ContinuousEngine` — in-flight
  batching over a fixed request-slot pool (zero-recompile join/finish).
- :class:`~repro.serving.scheduler.Scheduler` — wait-queue admission,
  deadlines, virtual-clock trace replay.
- :class:`~repro.serving.hotswap.CheckpointWatcher` — live param
  hot-swap from a running ``ElasticSession``'s checkpoint dir.
- :func:`~repro.serving.traffic.synthetic_traffic` — bursty MMPP traces.
"""
from repro.serving.continuous import ContinuousEngine, FinishedRequest
from repro.serving.engine import ServeEngine
from repro.serving.hotswap import CheckpointWatcher, SwapEvent
from repro.serving.scheduler import Request, RequestResult, Scheduler
from repro.serving.traffic import TrafficConfig, synthetic_traffic

__all__ = [
    "CheckpointWatcher",
    "ContinuousEngine",
    "FinishedRequest",
    "Request",
    "RequestResult",
    "Scheduler",
    "ServeEngine",
    "SwapEvent",
    "TrafficConfig",
    "synthetic_traffic",
]
