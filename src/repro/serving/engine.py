"""Static-batch serving engine: prefill + greedy decode over a KV cache.

One fixed batch in, one batch of generations out: jitted prefill/decode
steps, EOS pinning and short-circuiting on host. This is *not* continuous
batching — every request starts together and the batch runs to completion
(``repro.serving.continuous`` is the in-flight engine). ``ServeEngine``
is kept as the **bit-exactness reference**: the continuous engine must
reproduce its tokens exactly on the degenerate all-arrive-at-t0 batch
(``tests/test_serving_continuous.py``). Used by examples/serve_batch.py,
launch/serve.py's static mode, and tests/test_serving.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(eq=False)
class ServeEngine:
    model: object
    params: object
    max_len: int = 256

    def __post_init__(self):
        self._prefill = jax.jit(
            lambda p, b, c: self.model.prefill(p, b, c))
        self._decode = jax.jit(
            lambda p, b, c, i: self.model.decode_step(p, b, c, i))

    def generate(self, prompts: np.ndarray, *, steps: int = 32,
                 eos_id: Optional[int] = None, extra_batch=None):
        """prompts: (B, S0) int32 → (B, ≤steps) generated tokens (greedy;
        the width shrinks only when every row hits ``eos_id`` early).

        Rows that have emitted ``eos_id`` are pinned: their remaining
        output positions are ``eos_id`` and the pinned token is what gets
        fed back into the decode step, so a finished row can never
        resurface non-EOS tokens. The KV cache holds ``max_len`` positions
        including the prompt — a request that could decode past it is
        rejected up front (the old code only checked mid-loop, and only
        when ``eos_id`` was set).
        """
        B, S0 = prompts.shape
        if S0 + steps > self.max_len:
            raise ValueError(
                f"generate: prompt length {S0} + steps {steps} = "
                f"{S0 + steps} overruns the KV cache (max_len="
                f"{self.max_len}); raise max_len or request fewer steps")
        cache = self.model.init_cache(B, self.max_len)
        batch = {"tokens": jnp.asarray(prompts)}
        if extra_batch:
            batch.update(extra_batch)
        logits, cache = self._prefill(self.params, batch, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out = [np.asarray(tok)]
        finished = np.zeros(B, bool)
        if eos_id is not None:
            # the prefill-produced first token can itself be EOS
            finished |= out[0][:, 0] == eos_id
        index = S0
        for _ in range(steps - 1):
            if eos_id is not None and finished.all():
                break
            logits, cache = self._decode(
                self.params, {"tokens": tok}, cache, index)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            if eos_id is not None:
                tok = jnp.where(jnp.asarray(finished)[:, None],
                                jnp.asarray(eos_id, jnp.int32), tok)
            t_np = np.asarray(tok)
            out.append(t_np)
            index += 1
            if eos_id is not None:
                finished |= t_np[:, 0] == eos_id
        return np.concatenate(out, axis=1)
