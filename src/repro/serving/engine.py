"""Batched serving engine: prefill + greedy decode over a KV cache.

Small but real: continuous token-level loop with jitted prefill/decode
steps, per-request lengths, and EOS short-circuiting on host. Used by
examples/serve_batch.py and the decode smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(eq=False)
class ServeEngine:
    model: object
    params: object
    max_len: int = 256

    def __post_init__(self):
        self._prefill = jax.jit(
            lambda p, b, c: self.model.prefill(p, b, c))
        self._decode = jax.jit(
            lambda p, b, c, i: self.model.decode_step(p, b, c, i))

    def generate(self, prompts: np.ndarray, *, steps: int = 32,
                 eos_id: Optional[int] = None, extra_batch=None):
        """prompts: (B, S0) int32 → (B, steps) generated tokens (greedy)."""
        B, S0 = prompts.shape
        cache = self.model.init_cache(B, self.max_len)
        batch = {"tokens": jnp.asarray(prompts)}
        if extra_batch:
            batch.update(extra_batch)
        logits, cache = self._prefill(self.params, batch, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out = [np.asarray(tok)]
        finished = np.zeros(B, bool)
        index = S0
        for _ in range(steps - 1):
            logits, cache = self._decode(
                self.params, {"tokens": tok}, cache, index)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            t_np = np.asarray(tok)
            out.append(t_np)
            index += 1
            if eos_id is not None:
                finished |= (t_np[:, 0] == eos_id)
                if finished.all() or index >= self.max_len:
                    break
        return np.concatenate(out, axis=1)
