"""Continuous (in-flight) batching engine over a fixed request-slot pool.

The capacity-padded-axis trick from the elastic trainer (ISSUE-5), applied
to *requests* instead of workers: the KV cache and every jitted input is
shaped at ``capacity`` slots, an active mask marks the live ones, and
requests join / finish / are evicted between decode steps with **zero
recompiles** — both jitted functions (one prefill-and-adopt, one pooled
decode step) trace exactly once.

Mechanics, per slot lifecycle:

- **admit** — the prompt is right-padded to the fixed ``prefill_len``
  bucket and prefilled alone at batch 1 into a length-``prefill_len``
  scratch cache; the first generated token is the argmax at the *real*
  last prompt position (``L-1``, a traced scalar — padding positions are
  causally invisible to it) and the scratch KV is adopted into the slot's
  row of the pool cache with one ``dynamic_update_slice`` per cache leaf.
  Padding KV at positions ``L..prefill_len-1`` is garbage, but decode
  overwrites position ``pos`` before any query reaches it, so the causal
  mask keeps garbage forever ahead of — and invisible to — every real
  query.
- **decode** — one pooled step for all ``capacity`` rows with *per-slot*
  cache indices (each request sits at its own offset; see
  ``multihead_attention``'s vector ``cache_index`` path). Vacant rows
  compute garbage that is masked out of the returned tokens; there is no
  cross-row interaction, so their presence cannot perturb live rows.
- **finish** — on EOS / token budget the slot is freed on the host; the
  next admit simply overwrites its cache row.

``ServeEngine`` (``repro.serving.engine``) is the static-batch reference:
with every request arriving at t=0 at identical lengths, this engine's
tokens are bitwise identical to ``ServeEngine.generate``
(``tests/test_serving_continuous.py`` proves it across archs).

Parameters are hot-swappable between decode steps (``swap_params``): the
new tree has identical shapes, so the jit caches are untouched and
in-flight requests continue on their already-written KV — the same
one-checkpoint-stale tolerance that lets DaSGD-style delayed averaging
train against a stale master justifies serving across a mid-request swap.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.param import is_spec

SUPPORTED_FAMILIES = ("dense", "moe")


@dataclasses.dataclass(frozen=True)
class FinishedRequest:
    """One completed (or evicted) request, materialized on the host."""

    rid: int
    slot: int
    tokens: np.ndarray  # (n_generated,) int32, includes the EOS token
    reason: str  # "eos" | "length" | "evicted"
    prompt_len: int
    admitted_tick: int
    finished_tick: int

    @property
    def num_tokens(self) -> int:
        return int(self.tokens.size)


@dataclasses.dataclass
class _Slot:
    rid: int
    prompt_len: int
    budget: int  # remaining new tokens
    eos_id: Optional[int]
    tokens: List[int]
    admitted_tick: int


class ContinuousEngine:
    """Fixed-shape request-slot pool with in-flight batching.

    ``capacity`` is the max simultaneous requests, ``max_len`` the KV
    positions per slot (prompt + generated), ``prefill_len`` the fixed
    prompt bucket every admission pads to. All three are baked into the
    two jitted functions' shapes; everything else (which slots are live,
    where each request sits, the parameters being served) is runtime data.
    """

    def __init__(self, model, params, *, capacity: int = 8,
                 max_len: int = 256, prefill_len: int = 32,
                 eos_id: Optional[int] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if not 1 <= prefill_len <= max_len:
            raise ValueError(
                f"need 1 <= prefill_len ({prefill_len}) <= max_len "
                f"({max_len})")
        fam = model.cfg.family
        if fam not in SUPPORTED_FAMILIES:
            raise NotImplementedError(
                f"continuous batching serves decoder-LM families "
                f"{SUPPORTED_FAMILIES}; {model.cfg.name!r} is family "
                f"{fam!r} (recurrent/cross-attention caches have no "
                "per-slot positional rows to adopt into)")
        self.model = model
        self.params = params
        self.capacity = capacity
        self.max_len = max_len
        self.prefill_len = prefill_len
        self.eos_id = eos_id
        # per-cache-leaf batch axis, from the spec tree's logical axis
        # names — adoption must know where "this slot's row" lives
        spec_leaves = jax.tree.leaves(
            model.cache_spec(capacity, max_len), is_leaf=is_spec)
        self._cache_baxes = []
        for s in spec_leaves:
            if "cache_batch" not in s.axes or "cache_seq" not in s.axes:
                raise NotImplementedError(
                    "continuous batching needs cache leaves with "
                    f"cache_batch/cache_seq axes; got {s.axes}")
            self._cache_baxes.append(s.axes.index("cache_batch"))
        self.cache = model.init_cache(capacity, max_len)
        # host-side pool state
        self._tok = np.zeros((capacity, 1), np.int32)  # last token per slot
        self._pos = np.zeros((capacity,), np.int32)  # next KV write index
        self._active = np.zeros((capacity,), bool)
        self._slots: Dict[int, _Slot] = {}
        self._done: List[FinishedRequest] = []
        self.ticks = 0  # decode steps executed
        self.swaps = 0  # hot swaps applied
        self._admit_fn = jax.jit(self._admit_impl)
        self._decode_fn = jax.jit(self._decode_impl)

    # -- jitted bodies -------------------------------------------------------
    def _admit_impl(self, params, cache, toks, length, slot):
        """(1, prefill_len) padded prompt → first token + adopted pool
        cache. ``length``/``slot`` are traced scalars: any prompt length
        and any slot reuse the one trace."""
        scratch = self.model.init_cache(1, self.prefill_len)
        logits, scratch = self.model.prefill(
            params, {"tokens": toks}, scratch)
        row = jax.lax.dynamic_slice(
            logits, (0, length - 1, 0), (1, 1, logits.shape[-1]))
        tok0 = jnp.argmax(row[:, -1], axis=-1).astype(jnp.int32)
        pool, treedef = jax.tree.flatten(cache)
        single = jax.tree.leaves(scratch)
        out = []
        for pleaf, sleaf, b in zip(pool, single, self._cache_baxes):
            start = [0] * pleaf.ndim
            start[b] = slot
            out.append(jax.lax.dynamic_update_slice(
                pleaf, sleaf.astype(pleaf.dtype), tuple(start)))
        return tok0, jax.tree.unflatten(treedef, out)

    def _decode_impl(self, params, cache, tok, idx, active):
        """One token for every slot; per-slot cache indices ``idx``
        ((capacity, 1) int32). Vacant rows are masked to 0 so the returned
        tokens are independent of whatever garbage their rows hold."""
        logits, cache = self.model.decode_step(
            params, {"tokens": tok}, cache, idx)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return jnp.where(active, nxt, 0), cache

    # -- pool introspection --------------------------------------------------
    @property
    def num_active(self) -> int:
        return int(self._active.sum())

    def vacant_slots(self) -> List[int]:
        return np.flatnonzero(~self._active).tolist()

    def active_slots(self) -> List[int]:
        return np.flatnonzero(self._active).tolist()

    def jit_cache_sizes(self) -> Dict[str, int]:
        """Compiled-trace counts of the two jitted fns — the
        zero-recompile assertion reads these (1 each after warmup)."""
        return {"admit": self._admit_fn._cache_size(),
                "decode": self._decode_fn._cache_size()}

    # -- lifecycle -----------------------------------------------------------
    def admit(self, prompt, *, max_new: int, eos_id=None,
              rid: Optional[int] = None) -> int:
        """Seat one request in a vacant slot; returns the slot. The first
        generated token comes out of the prefill itself, so a request can
        finish here (EOS at token 1 / max_new == 1) without ever decoding.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        L = int(prompt.size)
        if not 1 <= L <= self.prefill_len:
            raise ValueError(
                f"prompt length {L} outside 1..prefill_len="
                f"{self.prefill_len}")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        if L + max_new > self.max_len:
            raise ValueError(
                f"prompt {L} + max_new {max_new} overruns the slot's KV "
                f"row (max_len={self.max_len})")
        vacant = self.vacant_slots()
        if not vacant:
            raise RuntimeError("pool full: no vacant slot to admit into")
        slot = vacant[0]
        eos = self.eos_id if eos_id is None else eos_id
        padded = np.zeros((1, self.prefill_len), np.int32)
        padded[0, :L] = prompt
        tok0, self.cache = self._admit_fn(
            self.params, self.cache, jnp.asarray(padded), L, slot)
        t0 = int(np.asarray(tok0)[0])
        self._tok[slot, 0] = t0
        self._pos[slot] = L
        self._active[slot] = True
        self._slots[slot] = _Slot(
            rid=rid if rid is not None else slot, prompt_len=L,
            budget=max_new - 1, eos_id=eos, tokens=[t0],
            admitted_tick=self.ticks)
        self._maybe_finish(slot)
        return slot

    def _maybe_finish(self, slot: int) -> None:
        s = self._slots[slot]
        if s.eos_id is not None and s.tokens[-1] == s.eos_id:
            self._finish(slot, "eos")
        elif s.budget <= 0:
            self._finish(slot, "length")

    def _finish(self, slot: int, reason: str) -> None:
        s = self._slots.pop(slot)
        self._active[slot] = False
        self._done.append(FinishedRequest(
            rid=s.rid, slot=slot, tokens=np.asarray(s.tokens, np.int32),
            reason=reason, prompt_len=s.prompt_len,
            admitted_tick=s.admitted_tick, finished_tick=self.ticks))

    def evict(self, slot: int) -> None:
        """Forcibly finish a live slot (deadline miss, shutdown); its
        partial output is returned through ``drain_finished`` with reason
        ``"evicted"``."""
        if not self._active[slot]:
            raise ValueError(f"slot {slot} is not live")
        self._finish(slot, "evicted")

    def drain_finished(self) -> List[FinishedRequest]:
        done, self._done = self._done, []
        return done

    def step(self) -> List[FinishedRequest]:
        """One pooled decode tick (no-op when nothing is live); returns
        every request that finished by the end of the tick — including
        ones that finished at admit/evict time since the last drain."""
        if self._active.any():
            nxt, self.cache = self._decode_fn(
                self.params, self.cache, jnp.asarray(self._tok),
                jnp.asarray(self._pos)[:, None],
                jnp.asarray(self._active))
            nxt = np.asarray(nxt)
            self.ticks += 1
            live = np.flatnonzero(self._active)
            self._pos[live] += 1
            for slot in live.tolist():
                t = int(nxt[slot])
                s = self._slots[slot]
                s.tokens.append(t)
                s.budget -= 1
                self._tok[slot, 0] = t
                self._maybe_finish(slot)
        return self.drain_finished()

    # -- hot swap ------------------------------------------------------------
    def swap_params(self, new_params) -> None:
        """Atomically flip the served parameters between decode steps.
        The standby tree must match the live one structurally (identical
        shapes ⇒ the jit caches are reused, zero recompiles); in-flight
        requests keep their KV from the old parameters and continue."""
        old = jax.tree.structure(self.params)
        new = jax.tree.structure(new_params)
        if old != new:
            raise ValueError(
                f"swap_params: tree structure mismatch ({new} != {old})")
        for a, b in zip(jax.tree.leaves(self.params),
                        jax.tree.leaves(new_params)):
            if a.shape != b.shape or a.dtype != b.dtype:
                raise ValueError(
                    f"swap_params: leaf {b.shape}/{b.dtype} != "
                    f"{a.shape}/{a.dtype}")
        self.params = new_params
        self.swaps += 1
