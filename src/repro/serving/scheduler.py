"""Scheduler: wait-queue admission and decode ticking over the slot pool.

The :class:`ContinuousEngine` owns the jitted math and the slot pool; the
scheduler owns *policy*: FIFO admission from a bounded wait queue,
prefill/decode interleaving (at most ``max_admissions_per_tick`` prefills
between decode steps, so a burst of arrivals cannot starve in-flight
requests of decode ticks), per-request deadlines (missed ⇒ the slot is
evicted and reclaimed), and periodic hot-swap polling through an attached
:class:`~repro.serving.hotswap.CheckpointWatcher`.

Time is **virtual**: the clock advances by the measured wall duration of
each engine call, and request arrivals are timestamps on that clock. A
trace replays identically (modulo machine speed) whether it was recorded
live or synthesized by ``repro.serving.traffic`` — benchmarks and CI
smokes drive the same ``run()`` loop with no sleeping.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request: prompt tokens plus its traffic-trace timing."""

    rid: int
    prompt: np.ndarray  # (L,) int32
    max_new: int
    arrival: float = 0.0  # virtual seconds
    deadline: Optional[float] = None  # seconds after arrival; None = none
    eos_id: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class RequestResult:
    """One finished request with its virtual-clock latency breakdown."""

    rid: int
    tokens: np.ndarray  # (n_generated,) int32
    reason: str  # "eos" | "length" | "evicted" | "rejected"
    arrival: float
    admitted_at: float  # first token exists once admission returns
    finished_at: float

    @property
    def num_tokens(self) -> int:
        return int(np.asarray(self.tokens).size)

    @property
    def ttft(self) -> float:
        """Time to first token: queue wait + prefill."""
        return self.admitted_at - self.arrival

    @property
    def latency(self) -> float:
        return self.finished_at - self.arrival


class Scheduler:
    """Drives one engine over a request stream on a virtual clock."""

    def __init__(self, engine, *, watcher=None, poll_every: int = 8,
                 max_admissions_per_tick: int = 2,
                 max_queue: Optional[int] = None):
        if max_admissions_per_tick < 1:
            raise ValueError("max_admissions_per_tick must be >= 1")
        self.engine = engine
        self.watcher = watcher
        self.poll_every = max(1, poll_every)
        self.max_admissions_per_tick = max_admissions_per_tick
        self.max_queue = max_queue
        self.vnow = 0.0
        self.queue: Deque[Request] = deque()
        self.results: List[RequestResult] = []
        self.rejected = 0
        self._meta: Dict[int, dict] = {}  # rid → {arrival, admitted_at, deadline}
        self._slot_rid: Dict[int, int] = {}

    @property
    def busy(self) -> bool:
        return bool(self.queue) or self.engine.num_active > 0

    def submit(self, req: Request) -> bool:
        """Enqueue a request; False (and a ``rejected`` result) when the
        wait queue is at ``max_queue`` — load shedding, not an error."""
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self.rejected += 1
            self.results.append(RequestResult(
                rid=req.rid, tokens=np.zeros((0,), np.int32),
                reason="rejected", arrival=req.arrival,
                admitted_at=self.vnow, finished_at=self.vnow))
            return False
        self.queue.append(req)
        return True

    def _admit_from_queue(self) -> int:
        """Seat queued requests into vacant slots, bounded per tick."""
        n = 0
        while (self.queue and self.engine.vacant_slots()
               and n < self.max_admissions_per_tick):
            req = self.queue.popleft()
            t0 = time.perf_counter()
            slot = self.engine.admit(
                req.prompt, max_new=req.max_new, eos_id=req.eos_id,
                rid=req.rid)
            self.vnow += time.perf_counter() - t0
            self._slot_rid[slot] = req.rid
            self._meta[req.rid] = {
                "arrival": req.arrival, "admitted_at": self.vnow,
                "deadline": (None if req.deadline is None
                             else req.arrival + req.deadline)}
            n += 1
        return n

    def _evict_deadline_misses(self) -> None:
        for slot in self.engine.active_slots():
            rid = self._slot_rid[slot]
            dl = self._meta[rid]["deadline"]
            if dl is not None and self.vnow > dl:
                self.engine.evict(slot)

    def _collect(self, finished) -> None:
        for f in finished:
            meta = self._meta.pop(f.rid)
            self._slot_rid.pop(f.slot, None)
            self.results.append(RequestResult(
                rid=f.rid, tokens=f.tokens, reason=f.reason,
                arrival=meta["arrival"], admitted_at=meta["admitted_at"],
                finished_at=self.vnow))

    def tick(self) -> List[RequestResult]:
        """One scheduling round: admit (bounded), evict deadline misses,
        one pooled decode step, optional hot-swap poll. Returns the
        results that completed this round."""
        before = len(self.results)
        self._admit_from_queue()
        self._collect(self.engine.drain_finished())  # finished-at-admit
        self._evict_deadline_misses()
        t0 = time.perf_counter()
        finished = self.engine.step()
        self.vnow += time.perf_counter() - t0
        self._collect(finished)
        if self.watcher is not None and self.engine.ticks and \
                self.engine.ticks % self.poll_every == 0:
            self.watcher.poll()
        return self.results[before:]

    def run(self, requests, *, max_ticks: int = 100_000) -> List[RequestResult]:
        """Replay a traffic trace to completion: requests are submitted
        when the virtual clock passes their ``arrival``, then the loop
        ticks until queue and pool drain. ``max_ticks`` bounds runaway
        loops (e.g. an EOS id the model never emits with huge budgets)."""
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        i = 0
        ticks = 0
        while i < len(pending) or self.busy:
            if ticks >= max_ticks:
                raise RuntimeError(
                    f"scheduler exceeded max_ticks={max_ticks} with "
                    f"{len(pending) - i} unsubmitted, "
                    f"{len(self.queue)} queued, "
                    f"{self.engine.num_active} in flight")
            while i < len(pending) and pending[i].arrival <= self.vnow:
                self.submit(pending[i])
                i += 1
            if not self.busy and i < len(pending):
                # idle gap in the trace: jump the clock to the next arrival
                self.vnow = max(self.vnow, pending[i].arrival)
                continue
            self.tick()
            ticks += 1
        return self.results
