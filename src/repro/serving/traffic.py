"""Synthetic serving traffic: bursty Poisson arrivals, mixed prompts.

Arrival times come from a two-state Markov-modulated Poisson process —
the classic bursty-traffic model: a background state at ``rate`` req/s
and a burst state at ``burst_factor``× that, with exponentially
distributed dwell times in each. Prompt lengths are drawn uniformly from
``prompt_lens`` and token ids uniformly from the vocab; everything is
derived from one ``numpy`` generator seeded by ``seed``, so a trace is
reproducible request-for-request (asserted in tests — benchmarks compare
continuous vs static on the *same* trace).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.serving.scheduler import Request


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """One synthetic trace's shape."""

    num_requests: int = 32
    rate: float = 4.0  # background arrivals per virtual second
    burst_factor: float = 8.0  # burst-state rate multiplier
    burst_dwell: float = 0.5  # mean seconds spent bursting
    calm_dwell: float = 2.0  # mean seconds between bursts
    prompt_lens: Sequence[int] = (4, 8, 12, 16)
    max_new: int = 16
    vocab_size: int = 1000
    deadline: Optional[float] = None  # per-request, seconds after arrival
    eos_id: Optional[int] = None
    seed: int = 0


def synthetic_traffic(cfg: TrafficConfig) -> List[Request]:
    """A reproducible bursty trace as a list of scheduler Requests."""
    if cfg.num_requests < 1:
        raise ValueError("num_requests must be >= 1")
    if not cfg.prompt_lens:
        raise ValueError("prompt_lens must be non-empty")
    rng = np.random.default_rng(cfg.seed)
    reqs: List[Request] = []
    t = 0.0
    bursting = False
    state_left = rng.exponential(cfg.calm_dwell)
    for rid in range(cfg.num_requests):
        rate = cfg.rate * (cfg.burst_factor if bursting else 1.0)
        gap = rng.exponential(1.0 / rate)
        # flip the MMPP state as many times as the gap walks through
        while gap >= state_left:
            gap -= state_left
            t += state_left
            bursting = not bursting
            state_left = rng.exponential(
                cfg.burst_dwell if bursting else cfg.calm_dwell)
            rate = cfg.rate * (cfg.burst_factor if bursting else 1.0)
            gap = rng.exponential(1.0 / rate)  # redraw at the new rate
        state_left -= gap
        t += gap
        L = int(rng.choice(np.asarray(cfg.prompt_lens)))
        prompt = rng.integers(
            0, cfg.vocab_size, (L,), dtype=np.int64).astype(np.int32)
        reqs.append(Request(
            rid=rid, prompt=prompt, max_new=cfg.max_new, arrival=t,
            deadline=cfg.deadline, eos_id=cfg.eos_id))
    return reqs
