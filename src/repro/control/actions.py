"""Typed live-control vocabulary for the session (ISSUE-6).

The session's live-control surface used to be two ad-hoc methods
(``resize()`` / ``set_membership()``). The closed-loop controller needs a
*value* it can produce, log, rate-limit and replay — so control is now a
datatype: :class:`ControlAction` describes one membership edit and
``ElasticSession.apply(action)`` is the single entrypoint that executes it.
The old methods survive as deprecated wrappers that build the equivalent
action.

:class:`SessionObserver` is the hook protocol both the rule controller
(``repro.control.actuator.RuleController``) and user callbacks attach
through: ``on_round(record)`` fires once per completed round with the
host-side :class:`repro.api.RoundRecord`; ``on_chunk_end(session)`` fires
between jit chunks — the only point where membership may change — and is
where a controller calls ``session.apply(...)``.

This module is deliberately leaf-level (numpy only): the session imports it
for ``apply``'s signature and every ``repro.control`` module builds on it,
with no import cycle through ``repro.api``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Protocol, Tuple, runtime_checkable

import numpy as np

ACTION_KINDS = ("evict", "readmit", "resize", "set_membership", "noop")


@dataclasses.dataclass(frozen=True)
class ControlAction:
    """One membership edit, as a value.

    ``kind`` selects the payload: ``evict``/``readmit`` name slot indices,
    ``resize`` carries the target live-worker count ``k``,
    ``set_membership`` a full (capacity,) bool mask, and ``noop`` nothing
    (it exists so a policy's "decided to do nothing" is loggable). Build
    instances through the classmethods — they validate the payload shape at
    construction; ``ElasticSession.apply`` validates against the live pool.
    ``reason`` is free-form provenance (which detector verdict produced
    this), carried into the actuator log.
    """

    kind: str
    slots: Tuple[int, ...] = ()
    k: int = 0
    mask: Optional[np.ndarray] = None
    reason: str = ""

    def __post_init__(self):
        if self.kind not in ACTION_KINDS:
            raise ValueError(f"ControlAction.kind must be one of "
                             f"{ACTION_KINDS}, got {self.kind!r}")
        if self.kind in ("evict", "readmit"):
            if not self.slots:
                raise ValueError(f"{self.kind} action needs >= 1 slot")
            if any(s < 0 for s in self.slots):
                raise ValueError(f"{self.kind} slots must be >= 0, "
                                 f"got {self.slots}")
        if self.kind == "resize" and self.k < 1:
            raise ValueError(f"resize target must be >= 1, got {self.k}")
        if self.kind == "set_membership" and self.mask is None:
            raise ValueError("set_membership action needs a mask")

    # -- constructors --------------------------------------------------------
    @classmethod
    def evict(cls, slots, reason: str = "") -> "ControlAction":
        """Retire the given live slots (their data shards are re-dealt to
        the survivors; the slots freeze until readmitted)."""
        return cls("evict", slots=tuple(int(s) for s in slots),
                   reason=reason)

    @classmethod
    def readmit(cls, slots, reason: str = "") -> "ControlAction":
        """Re-activate the given vacant slots; they rejoin at the next
        round cold-started from the master (EASGD admission)."""
        return cls("readmit", slots=tuple(int(s) for s in slots),
                   reason=reason)

    @classmethod
    def resize(cls, k: int, reason: str = "") -> "ControlAction":
        """Resize the live pool to ``k`` workers: growing activates the
        lowest-numbered vacant slots, shrinking retires the highest live
        ones."""
        return cls("resize", k=int(k), reason=reason)

    @classmethod
    def set_membership(cls, mask, reason: str = "") -> "ControlAction":
        """Replace the live mask wholesale with the given (capacity,)
        bools."""
        return cls("set_membership", mask=np.asarray(mask, bool),
                   reason=reason)

    @classmethod
    def noop(cls, reason: str = "") -> "ControlAction":
        return cls("noop", reason=reason)

    def describe(self) -> str:
        body = {"evict": f"evict slots {list(self.slots)}",
                "readmit": f"readmit slots {list(self.slots)}",
                "resize": f"resize pool to k={self.k}",
                "set_membership": (
                    "set membership "
                    f"{self.mask.astype(int).tolist()}"
                    if self.mask is not None else "set membership"),
                "noop": "no-op"}[self.kind]
        return f"{body} ({self.reason})" if self.reason else body


@runtime_checkable
class SessionObserver(Protocol):
    """Hook protocol for anything watching a running ``ElasticSession``.

    Both hooks are optional at runtime (the session feature-checks with
    ``getattr``), so a bare callback object implementing only ``on_round``
    is a valid observer. ``on_chunk_end`` runs between jit chunks — the only
    point where ``session.apply(action)`` is legal — and receives the live
    session, so a controller can both read (``active_mask``, ``round``) and
    act.
    """

    def on_round(self, record: Any) -> None:
        """Called once per completed round with its ``RoundRecord``."""

    def on_chunk_end(self, session: Any) -> None:
        """Called after each jit chunk, before the next one is built."""
