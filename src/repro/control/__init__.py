"""Closed-loop elastic control: detector → policy → actuator (ISSUE-6).

Infers worker failures/stragglers from observable telemetry only (no
ground-truth masks — enforced by ``tests/test_control.py``) and drives the
session's live membership through typed :class:`ControlAction` values.
"""
from repro.control.actions import ControlAction, SessionObserver
from repro.control.actuator import (Actuator, AppliedAction, RuleController,
                                    make_controller)
from repro.control.detector import (FAILED_SUSPECT, HEALTHY,
                                    STRAGGLER_SUSPECT, VERDICTS,
                                    DetectorConfig, FailureDetector)
from repro.control.policy import (MembershipPolicy, PolicyConfig, RulePolicy,
                                  make_policy)

__all__ = [
    "ControlAction", "SessionObserver",
    "DetectorConfig", "FailureDetector",
    "HEALTHY", "STRAGGLER_SUSPECT", "FAILED_SUSPECT", "VERDICTS",
    "MembershipPolicy", "PolicyConfig", "RulePolicy", "make_policy",
    "Actuator", "AppliedAction", "RuleController", "make_controller",
]
