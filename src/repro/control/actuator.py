"""Actuator: applies policy actions to the session at chunk boundaries.

Membership is only mutable between jit chunks (``ElasticSession`` bakes the
live mask into each chunk's schedule rows), so the control loop runs on the
session's observer hooks: ``on_round`` streams each completed round's
telemetry into the detector; ``on_chunk_end`` — the one legal mutation
point — asks the policy for actions and pushes them through
``session.apply``. :class:`RuleController` bundles detector + policy +
actuator into a single :class:`~repro.control.actions.SessionObserver` that
``RunSpec(controller="rules")`` attaches automatically.

Every application is journalled as an :class:`AppliedAction` (round,
action, whether it took effect, live count after), so a closed-loop run's
whole membership story is replayable from ``controller.actuator.log``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.control.actions import ControlAction
from repro.control.detector import DetectorConfig, FailureDetector
from repro.control.policy import (MembershipPolicy, PolicyConfig,
                                  RulePolicy)


@dataclasses.dataclass(frozen=True)
class AppliedAction:
    """Journal entry: one action as actually applied (or skipped)."""

    round: int
    action: ControlAction
    applied: bool
    live_after: int
    note: str = ""


class Actuator:
    """Pushes :class:`ControlAction` lists into a session, safely.

    Skips (and journals) actions that are no longer applicable when the
    chunk boundary arrives: evicting an already-vacant slot, readmitting a
    live one, or acting after the run's final round.
    """

    def __init__(self):
        self.log: List[AppliedAction] = []

    def apply(self, session, actions) -> int:
        """Apply actions in order; returns how many took effect."""
        applied = 0
        for action in actions:
            note = ""
            ok = False
            if action.kind == "noop":
                note = "noop"
            elif session.round >= session.spec.rounds:
                note = "run complete"
            else:
                act = session.active_mask
                if action.kind == "evict":
                    slots = tuple(s for s in action.slots if act[s])
                    note = "" if slots == action.slots else "some vacant"
                    if slots and len(slots) < int(act.sum()):
                        session.apply(dataclasses.replace(
                            action, slots=slots))
                        ok = True
                    elif slots:
                        note = "would empty pool"
                elif action.kind == "readmit":
                    slots = tuple(s for s in action.slots if not act[s])
                    note = "" if slots == action.slots else "some live"
                    if slots:
                        session.apply(dataclasses.replace(
                            action, slots=slots))
                        ok = True
                else:  # resize / set_membership pass straight through
                    session.apply(action)
                    ok = True
            applied += ok
            self.log.append(AppliedAction(
                round=session.round, action=action, applied=ok,
                live_after=int(session.active_mask.sum()), note=note))
        return applied


class RuleController:
    """Detector + policy + actuator as one session observer.

    Attach with ``RunSpec(controller="rules")`` (the session builds one via
    :func:`make_controller`) or manually with ``session.add_observer``.
    """

    def __init__(self, capacity: int,
                 detector: Optional[DetectorConfig] = None,
                 policy: Optional[PolicyConfig] = None):
        self.detector = FailureDetector(capacity, detector)
        self.policy: MembershipPolicy = RulePolicy(policy)
        self.actuator = Actuator()

    # -- SessionObserver ------------------------------------------------------
    def on_round(self, record) -> None:
        self.detector.observe(record)

    def on_chunk_end(self, session) -> None:
        if session.round >= session.spec.rounds:
            return
        actions = self.policy.decide(self.detector.verdicts(),
                                     session.active_mask, session.round)
        self.actuator.apply(session, actions)


def make_controller(name: str, capacity: int,
                    detector: Optional[DetectorConfig] = None,
                    policy: Optional[PolicyConfig] = None) -> RuleController:
    """Controller factory behind ``RunSpec.controller`` / ``--controller``."""
    if name != "rules":
        raise ValueError(f"unknown controller {name!r}; available: 'rules'")
    return RuleController(capacity, detector=detector, policy=policy)
