"""Membership policy: detector verdicts → typed control actions (ISSUE-6).

The detector says *what it believes* about each slot; the policy decides
*what to do about it*, under operational guardrails the detector shouldn't
know about: a minimum pool size (evicting below it would stall training
more than a bad worker does), a per-decision action budget (rate limiting —
one noisy chunk must not churn the whole pool), and a per-slot cooldown so
an evict→readmit→evict cycle can't flap faster than the detector's own
hysteresis resolves.

:class:`MembershipPolicy` is the plug-in base: ``decide(verdicts, active,
round)`` returns a list of :class:`ControlAction` for the actuator to apply
at the next chunk boundary. :class:`RulePolicy` is the rule-based instance
the ``--controller rules`` flag wires in: evict FAILED/STRAGGLER suspects
(down to the floor, worst-first), readmit slots the policy itself evicted
once their verdict returns to healthy (the detector's probe-readmission
signal — see ``detector.py``: a dark slot's recovery is unobservable, so
"healthy again" means "cooldown elapsed, probe it").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.control.actions import ControlAction
from repro.control.detector import (FAILED_SUSPECT, HEALTHY,
                                    STRAGGLER_SUSPECT)


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    """Guardrails for :class:`RulePolicy`.

    ``min_pool`` — never evict below this many live slots;
    ``max_actions`` — at most this many evict/readmit actions per decision;
    ``slot_cooldown`` — rounds a slot must wait between membership flips;
    ``evict_stragglers`` — whether straggler suspects are evicted too (off
    leaves them in the pool for the paper's dynamic weighting to down-weight,
    which is the right call when spare capacity is scarce).
    """

    min_pool: int = 2
    max_actions: int = 2
    slot_cooldown: int = 2
    evict_stragglers: bool = True


class MembershipPolicy:
    """Base protocol: override :meth:`decide`."""

    def decide(self, verdicts: Sequence[str], active: np.ndarray,
               round: int) -> List[ControlAction]:
        raise NotImplementedError

    def reset(self) -> None:
        """Forget slot history (new run)."""


class RulePolicy(MembershipPolicy):
    """Evict suspects, probe-readmit healed slots, respect guardrails."""

    def __init__(self, config: Optional[PolicyConfig] = None):
        self.cfg = config or PolicyConfig()
        self._evicted: Dict[int, int] = {}   # slot -> round we evicted it
        self._last_flip: Dict[int, int] = {}  # slot -> round of last action
        self.decisions: List[ControlAction] = []  # full action log

    def reset(self) -> None:
        self._evicted.clear()
        self._last_flip.clear()
        self.decisions.clear()

    def _cooled(self, slot: int, round: int) -> bool:
        last = self._last_flip.get(slot)
        return last is None or round - last >= self.cfg.slot_cooldown

    def decide(self, verdicts: Sequence[str], active: np.ndarray,
               round: int) -> List[ControlAction]:
        cfg = self.cfg
        active = np.asarray(active, bool)
        actions: List[ControlAction] = []
        budget = cfg.max_actions

        # 1) readmit: slots *we* evicted whose verdict is healthy again
        #    (detector cooldown elapsed -> probe). Never readmit slots that
        #    are vacant for other reasons (planned schedules own those).
        probe = sorted(s for s, _ in self._evicted.items()
                       if not active[s] and verdicts[s] == HEALTHY
                       and self._cooled(s, round))
        if probe and budget > 0:
            take = probe[:budget]
            budget -= 1
            actions.append(ControlAction.readmit(
                take, reason="probe-readmit after cooldown"))
            for s in take:
                del self._evicted[s]
                self._last_flip[s] = round

        # 2) evict: failed suspects first, then stragglers, worst-first,
        #    never below the floor
        live = int(active.sum()) + sum(
            1 for a in actions if a.kind == "readmit"
            for _ in a.slots)
        headroom = live - cfg.min_pool
        suspects = [s for s in range(len(verdicts))
                    if active[s] and verdicts[s] == FAILED_SUSPECT
                    and self._cooled(s, round)]
        if cfg.evict_stragglers:
            suspects += [s for s in range(len(verdicts))
                         if active[s] and verdicts[s] == STRAGGLER_SUSPECT
                         and self._cooled(s, round)]
        take = suspects[:max(0, min(headroom, budget))]
        if take:
            kinds = {s: verdicts[s] for s in take}
            actions.append(ControlAction.evict(
                sorted(take),
                reason="; ".join(f"slot {s}: {kinds[s]}"
                                 for s in sorted(take))))
            for s in take:
                self._evicted[s] = round
                self._last_flip[s] = round

        if not actions:
            actions.append(ControlAction.noop(reason="all healthy"))
        self.decisions.extend(actions)
        return actions


def make_policy(name: str, config: Optional[PolicyConfig] = None
                ) -> MembershipPolicy:
    if name != "rules":
        raise ValueError(f"unknown policy {name!r}; available: 'rules'")
    return RulePolicy(config)
