"""Failure/straggler detection from observable telemetry only (ISSUE-6).

The paper's §VI machinery — and every scenario run before this PR — hands
the coordinator ground-truth masks. Production has no such oracle: a
parameter server sees only what the training loop itself emits. This
detector closes that gap. It consumes exactly the host-observable fields of
each ``RoundRecord``:

- ``u`` — per-slot log-distance to the master (§V-B); its per-round
  increment ``du`` carries the failure signature (below).
- ``loss_w`` — per-slot mean local-phase loss; a persistently lagging slot
  sits above the pool's EWMA level.
- ``round_ms`` — host wall time of the round (round-level, not per-slot:
  one jit call executes all slots, so a slow round corroborates a slot-level
  suspicion but cannot name the slot by itself).
- ``active`` — the session's *own* membership decisions (not an oracle
  signal: the controller made them).

It never reads the schedule's ground-truth masks — ``tests/test_control.py``
enforces this both statically (source scan) and at runtime (records whose
mask fields raise on access).

Failure signature (calibrated empirically on detector-blind telemetry —
the thresholds below come from sweeping crash/straggler/burst scenario runs
across seeds, see tests/test_control.py):

A live worker is *pulled back* toward the master every round it syncs (the
h1·α elastic term), so its ``du`` sequence keeps flipping sign — drift up,
yank down. A worker whose communication is cut keeps drifting but is never
yanked, which shows up in one of two ways depending on where it died:

- **adrift** (near the master): ``du`` stays solidly positive round after
  round — ``du > pull_eps`` *and* not below the live pool's median
  (``du - median > -rel_margin``; the cross-sectional term is what
  separates a cut worker from rounds where the whole pool drifts up
  because the master moved). ``drift_rounds`` consecutive such rounds →
  failed-suspect. The strict positivity floor matters: healthy slots
  hovering at their elastic equilibrium emit runs of *weak* positives,
  and only the floor separates those from genuine cut-drift.
- **silent** (far from the master): the distance is so large that local
  drift barely moves ``log‖θ−master‖`` — ``|du|`` collapses below a
  pool-relative floor (``max(freeze_eps, silent_ratio·median|du|)``)
  while the pool is mobile (median live |du| > ``mobile_du``; the gate
  keeps a uniformly-quiet converged pool from mass-flagging).
  ``suspect_rounds`` consecutive → failed-suspect. The relative floor is
  what catches early-run cuts: a slot ticking along at |du| ≈ 0.04 is
  unremarkable in a calm pool but glaringly frozen while everyone else
  moves by ≈ 1.0.

Byzantine slots (ISSUE-9) trip **adrift** too, for the same mechanical
reason a cut worker does: once ``ElasticConfig.score_clip`` makes the
master refuse a gradient-corrupted worker's pulls, that worker drifts
without the yank-back, and ``du`` goes solidly positive. Measured on the
acceptance regime (noise-mode corruption, byzantine_frac=0.5,
score_clip=0.5, seeds 1–3, 20 rounds): 5/5 corrupt slots flagged
failed-suspect, ≤ 2 false flags per run — the FPs cluster in rounds 9–11
where the clip's warm-up freeze (every slot starts refused while the
score history fills) leaves honest slots with unusually jumpy telemetry.
Without the clip the detector largely misses noise-mode corruption: the
full-α elastic pull holds the noisy worker at a fixed elevated distance,
``du`` keeps flipping sign, and no drift accumulates — the clip is what
converts "polluting the master" into the observable cut-drift signature
(``tests/test_control.py::TestDetectorSweep`` encodes both floors).

Scope: both rules lean on cross-sectional statistics of the live pool
(median du, pool mobility), which assumes a strict *minority* of the pool
is faulty at once. When half or more of the live slots fail concurrently,
the median itself drifts and the adrift margin can stall for a few rounds
— the slot is still caught once the pool re-anchors, just later (observed
on crash seeds with two overlapping episodes in a k=4 pool). Correlated
whole-rack bursts need rack-level detectors (see the hierarchical-master
roadmap item); ``tests/test_control.py`` encodes exactly this contract.

**Straggler-suspect** is the conservative companion rule: the slot's
EWMA(u) sits ``slow_z`` robust-z below the live pool (it completes fewer
local steps per round, so it hugs the master), or its EWMA(loss_w) sits
``slow_loss_z`` above (slower progress); a wall-time-outlier round halves
the bar. Transient per-round straggles are *not* reliably observable in
this telemetry — the rule is tuned to fire on persistent laggards and stay
quiet otherwise (the paper's dynamic weighting already down-weights mild
stragglers without eviction).

Hysteresis. A slot must look suspect K consecutive rounds before its
verdict flips — one noisy round never flaps the pool — and a flag on a
live slot clears only after ``clear_rounds`` consecutive calm rounds. Once
the policy evicts a flagged slot its telemetry goes dark (vacant slots
report frozen values), so recovery cannot be *observed*; instead the flag
ages out after ``readmit_cooldown`` dark rounds and the verdict returns to
healthy, which the policy reads as "probe-ready": it readmits the slot,
the join re-seats it from the master, and if it is still broken the
renewed drift re-flags it K rounds later. Slots that (re)join have their
rolling state reset — a cold-started slot's first round is not evidence.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

# Per-slot health verdicts.
HEALTHY = "healthy"
STRAGGLER_SUSPECT = "straggler_suspect"
FAILED_SUSPECT = "failed_suspect"
VERDICTS = (HEALTHY, STRAGGLER_SUSPECT, FAILED_SUSPECT)


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    """Thresholds and hysteresis constants (documented in
    ``docs/architecture.md`` §control loop; calibration described in the
    module docstring).

    ``suspect_rounds`` is K for the silent rule, ``drift_rounds`` K for the
    adrift rule, ``clear_rounds`` the calm streak that clears a live flag,
    and ``readmit_cooldown`` how many dark rounds an evicted slot stays
    flagged before its verdict returns to healthy (probe-ready).
    ``pull_eps``/``rel_margin`` define adrift evidence (positive drift, not
    below the pool median); ``freeze_eps``/``silent_ratio``/``mobile_du``
    define silent evidence (pool-relatively frozen u while the pool
    moves). ``slow_z``/``slow_loss_z``
    are robust-z thresholds on the EWMA(u)/EWMA(loss_w) level vs the live
    pool (straggler rule) with ``ewma_beta`` the history weight;
    ``round_ms_z`` marks a wall-time-outlier round, which halves the
    straggler bar. ``min_stat_slots`` is the smallest live pool the
    cross-sectional statistics are trusted on; ``mad_floor`` keeps the z
    denominators sane when the pool is tightly clustered (it is relative:
    floor = mad_floor·|median|, with an absolute backstop).
    """

    suspect_rounds: int = 2
    drift_rounds: int = 3
    clear_rounds: int = 2
    readmit_cooldown: int = 3
    pull_eps: float = 0.02
    rel_margin: float = 0.02
    freeze_eps: float = 0.02
    silent_ratio: float = 0.1
    mobile_du: float = 0.04
    slow_z: float = 3.0
    slow_loss_z: float = 3.0
    ewma_beta: float = 0.5
    round_ms_z: float = 3.0
    min_stat_slots: int = 3
    mad_floor: float = 0.10
    time_window: int = 8  # rolling round_ms window for the wall-time gate


class FailureDetector:
    """Rolling per-slot health state machine over observed round records.

    Feed rounds in order with :meth:`observe`; read :meth:`verdicts` (one
    of :data:`VERDICTS` per slot) between chunks. ``capacity`` fixes the
    slot count up front so the detector works on a padded pool too.
    """

    def __init__(self, capacity: int,
                 config: Optional[DetectorConfig] = None):
        self.cfg = config or DetectorConfig()
        self.capacity = capacity
        self.round = -1  # last observed round
        self._u_prev = np.full(capacity, np.nan)
        self._ewma_u = np.full(capacity, np.nan)
        self._ewma_loss = np.full(capacity, np.nan)
        self._silent_streak = np.zeros(capacity, np.int64)
        self._adrift_streak = np.zeros(capacity, np.int64)
        self._slow_streak = np.zeros(capacity, np.int64)
        self._calm_streak = np.zeros(capacity, np.int64)
        # committed flag per slot: None | STRAGGLER_SUSPECT | FAILED_SUSPECT
        self._flag: List[Optional[str]] = [None] * capacity
        self._dark_since = np.full(capacity, -1, np.int64)  # evict round
        self._prev_active = np.ones(capacity, bool)
        self._round_ms_hist: List[float] = []
        # (round, slot, verdict) transitions, for logging/inspection
        self.events: List[tuple] = []

    # -- helpers -------------------------------------------------------------
    def _robust_z(self, x: np.ndarray, sel: np.ndarray) -> np.ndarray:
        """z-scores of x against the median/MAD of x[sel]; zeros when too
        few finite samples are selected for the statistics to mean
        anything."""
        sel = sel & np.isfinite(x)
        z = np.zeros_like(x, dtype=float)
        if sel.sum() < 2:
            return z
        med = np.median(x[sel])
        mad = np.median(np.abs(x[sel] - med))
        scale = max(1.4826 * mad, self.cfg.mad_floor * abs(med), 1e-3)
        out = (x - med) / scale
        z[np.isfinite(out)] = out[np.isfinite(out)]
        return z

    def _set_flag(self, i: int, flag: Optional[str], r: int):
        if self._flag[i] != flag:
            self._flag[i] = flag
            self.events.append((r, i, flag or HEALTHY))

    def _reset_slot(self, i: int):
        self._u_prev[i] = np.nan
        self._ewma_u[i] = np.nan
        self._ewma_loss[i] = np.nan
        self._silent_streak[i] = 0
        self._adrift_streak[i] = 0
        self._slow_streak[i] = 0
        self._calm_streak[i] = 0
        self._dark_since[i] = -1

    # -- main entry ----------------------------------------------------------
    def observe(self, record) -> None:
        """Consume one round's observable telemetry (in round order)."""
        cfg = self.cfg
        r = int(record.round)
        self.round = r
        act = (np.asarray(record.active, bool)
               if record.active is not None
               else np.ones(self.capacity, bool))
        u = np.asarray(record.u, float)
        loss_w = (np.asarray(record.loss_w, float)
                  if getattr(record, "loss_w", None) is not None
                  else np.full(self.capacity, np.nan))

        # a slot that just (re)joined cold-starts its rolling state: its
        # first round back is a master-re-seated step, not evidence
        for i in np.flatnonzero(act & ~self._prev_active):
            self._reset_slot(i)

        # round-level wall-time outlier (corroboration, not attribution)
        slow_round = False
        ms = float(getattr(record, "round_ms", 0.0) or 0.0)
        if ms > 0.0:
            hist = self._round_ms_hist
            if len(hist) >= 4:
                med = float(np.median(hist))
                mad = max(1.4826 * float(np.median(np.abs(
                    np.asarray(hist) - med))), 1e-3 * max(med, 1e-9))
                slow_round = (ms - med) / mad > cfg.round_ms_z
            hist.append(ms)
            if len(hist) > cfg.time_window:
                del hist[0]

        du = u - self._u_prev
        known = act & np.isfinite(du)
        enough = int(act.sum()) >= cfg.min_stat_slots
        if known.sum() >= 2:
            du_med = float(np.median(du[known]))
            du_meda = float(np.median(np.abs(du[known])))
            pool_mobile = du_meda > cfg.mobile_du
        else:
            du_med = 0.0
            du_meda = 0.0
            pool_mobile = False
        silent_floor = max(cfg.freeze_eps, cfg.silent_ratio * du_meda)

        b = cfg.ewma_beta
        ew_u = np.where(np.isfinite(self._ewma_u),
                        b * self._ewma_u + (1 - b) * u, u)
        ew_l = np.where(np.isfinite(self._ewma_loss) & np.isfinite(loss_w),
                        b * self._ewma_loss + (1 - b) * loss_w, loss_w)
        z_u = self._robust_z(ew_u, act)
        z_l = self._robust_z(ew_l, act)

        slow_bar = cfg.slow_z * (0.5 if slow_round else 1.0)
        loss_bar = cfg.slow_loss_z * (0.5 if slow_round else 1.0)
        for i in range(self.capacity):
            if not act[i]:
                # dark slot: if we flagged it and it left the pool, age the
                # flag out so the policy can probe-readmit it
                if self._flag[i] is not None:
                    if self._dark_since[i] < 0:
                        self._dark_since[i] = r
                    elif r - self._dark_since[i] >= cfg.readmit_cooldown:
                        self._set_flag(i, None, r)
                        self._dark_since[i] = -1
                continue
            if not np.isfinite(du[i]):
                continue  # first observed round for this slot: no drift yet
            silent = pool_mobile and abs(du[i]) < silent_floor
            adrift = (not silent and enough and du[i] > cfg.pull_eps
                      and du[i] - du_med > -cfg.rel_margin)
            lagging = (not (silent or adrift) and enough
                       and (z_u[i] < -slow_bar or z_l[i] > loss_bar))
            self._silent_streak[i] = (self._silent_streak[i] + 1
                                      if silent else 0)
            self._adrift_streak[i] = (self._adrift_streak[i] + 1
                                      if adrift else 0)
            self._slow_streak[i] = self._slow_streak[i] + 1 if lagging else 0
            calm = not (silent or adrift or lagging)
            self._calm_streak[i] = self._calm_streak[i] + 1 if calm else 0

            failed_now = (self._silent_streak[i] >= cfg.suspect_rounds
                          or self._adrift_streak[i] >= cfg.drift_rounds)
            if self._flag[i] is None:
                if failed_now:
                    self._set_flag(i, FAILED_SUSPECT, r)
                elif self._slow_streak[i] >= cfg.suspect_rounds:
                    self._set_flag(i, STRAGGLER_SUSPECT, r)
            else:
                # escalate a straggler flag if the slot stops syncing
                if self._flag[i] == STRAGGLER_SUSPECT and failed_now:
                    self._set_flag(i, FAILED_SUSPECT, r)
                elif self._calm_streak[i] >= cfg.clear_rounds:
                    self._set_flag(i, None, r)

        self._u_prev = np.where(act, u, np.nan)
        self._ewma_u = np.where(act, ew_u, np.nan)
        self._ewma_loss = np.where(act & np.isfinite(ew_l), ew_l, np.nan)
        self._prev_active = act

    # -- outputs -------------------------------------------------------------
    def verdicts(self) -> List[str]:
        """(capacity,) current per-slot verdicts."""
        return [f or HEALTHY for f in self._flag]

    def verdict(self, slot: int) -> str:
        return self._flag[slot] or HEALTHY

    @property
    def flagged(self) -> np.ndarray:
        """(capacity,) bool — slots currently carrying any flag."""
        return np.asarray([f is not None for f in self._flag])
