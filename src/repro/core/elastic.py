"""Elastic-averaging parameter updates (EASGD eqs. 8–9; dynamic eqs. 12–13).

    θ^i ← θ^i − h1 · (θ^i − θ^m)          (worker pulled toward master)
    θ^m ← θ^m + h2 · (θ^i − θ^m)          (master pulled toward worker)

With h1 = h2 = α this is exactly EASGD's symmetric elastic force. The fused
form (one pass over both pytrees) also exists as a Pallas TPU kernel
(``repro.kernels.elastic``); this is the jnp path / oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _unzip_pairs(pairs):
    """Split a pytree of (worker, master) leaf tuples into two pytrees."""
    is_pair = lambda x: isinstance(x, tuple)
    return (jax.tree.map(lambda p: p[0], pairs, is_leaf=is_pair),
            jax.tree.map(lambda p: p[1], pairs, is_leaf=is_pair))


def elastic_update(worker_params, master_params, w1, w2):
    """Apply eqs. (12)–(13). w1/w2 are scalars (possibly traced)."""

    def upd(w, m):
        wf = w.astype(jnp.float32)
        mf = m.astype(jnp.float32)
        diff = wf - mf
        return ((wf - w1 * diff).astype(w.dtype),
                (mf + w2 * diff).astype(m.dtype))

    return _unzip_pairs(jax.tree.map(upd, worker_params, master_params))


def elastic_update_batched(worker_stacked, master_params, w1, w2,
                           axis_name=None, master_ref=None):
    """All k worker exchanges plus the master reduction in one batched pass.

    ``worker_stacked`` leaves have a leading worker axis (k, ...); w1/w2 are
    (k,) vectors. Every worker syncs against the *same* master snapshot and

        θ^i ← θ^i − w1_i · (θ^i − θ^m)
        θ^m ← θ^m + Σ_i w2_i · (θ^i − θ^m)

    Pass ``dynamic_weight.master_schedule_weights(h2)`` as ``w2`` to make the
    master reduction exactly match the sequential event-ordered scan.

    With ``axis_name`` (sharded placement, inside ``shard_map``): the leading
    axis holds only this shard's k/n_pods workers and the master reduction
    becomes a cross-pod collective. The worker pull stays shard-local; the
    weighted diffs are all-gathered along the worker axis and reduced with
    the *same* (k, ...)-shaped sum as the single-device path — an all-reduce
    decomposed as all-gather + local reduction — so the sharded master is
    bit-exact with the single-device fused master (a ``psum`` of per-shard
    partial sums would differ in the last ulp from re-associating the sum).

    ``master_ref`` (optional pytree like the master): delayed averaging
    (DaSGD / ``ElasticConfig.staleness``) — every diff θ^i − θ^ref is
    measured against this stale snapshot while the accumulation target stays
    the live master:

        θ^i ← θ^i − w1_i · (θ^i − θ^ref)
        θ^m ← θ^m + Σ_i w2_i · (θ^i − θ^ref)

    so round r's exchange depends only on the snapshot, not on round r−1's
    master reduction. ``None`` (the default) is the exact pre-staleness
    code path — ``staleness=0`` trajectories are bit-identical.
    """
    w1 = jnp.asarray(w1, jnp.float32)
    w2 = jnp.asarray(w2, jnp.float32)

    def upd(ws, m, ref=None):
        h1 = w1.reshape((-1,) + (1,) * (ws.ndim - 1))
        h2 = w2.reshape((-1,) + (1,) * (ws.ndim - 1))
        wf = ws.astype(jnp.float32)
        mf = m.astype(jnp.float32)
        diff = wf - (mf[None] if ref is None
                     else ref.astype(jnp.float32)[None])
        pull = h2 * diff
        if axis_name is not None:
            pull = jax.lax.all_gather(pull, axis_name, axis=0, tiled=True)
        return ((wf - h1 * diff).astype(ws.dtype),
                (mf + jnp.sum(pull, axis=0)).astype(m.dtype))

    if master_ref is None:
        pairs = jax.tree.map(upd, worker_stacked, master_params)
    else:
        pairs = jax.tree.map(upd, worker_stacked, master_params, master_ref)
    return _unzip_pairs(pairs)
