"""Elastic-averaging parameter updates (EASGD eqs. 8–9; dynamic eqs. 12–13).

    θ^i ← θ^i − h1 · (θ^i − θ^m)          (worker pulled toward master)
    θ^m ← θ^m + h2 · (θ^i − θ^m)          (master pulled toward worker)

With h1 = h2 = α this is exactly EASGD's symmetric elastic force. The fused
form (one pass over both pytrees) also exists as a Pallas TPU kernel
(``repro.kernels.elastic``); this is the jnp path / oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def elastic_update(worker_params, master_params, w1, w2):
    """Apply eqs. (12)–(13). w1/w2 are scalars (possibly traced)."""

    def upd(w, m):
        wf = w.astype(jnp.float32)
        mf = m.astype(jnp.float32)
        diff = wf - mf
        return ((wf - w1 * diff).astype(w.dtype),
                (mf + w2 * diff).astype(m.dtype))

    pairs = jax.tree.map(upd, worker_params, master_params)
    new_worker = jax.tree.map(lambda p: p[0], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_master = jax.tree.map(lambda p: p[1], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))
    return new_worker, new_master
