"""Elastic-averaging parameter updates (EASGD eqs. 8–9; dynamic eqs. 12–13).

    θ^i ← θ^i − h1 · (θ^i − θ^m)          (worker pulled toward master)
    θ^m ← θ^m + h2 · (θ^i − θ^m)          (master pulled toward worker)

With h1 = h2 = α this is exactly EASGD's symmetric elastic force. The fused
form (one pass over both pytrees) also exists as a Pallas TPU kernel
(``repro.kernels.elastic``); this is the jnp path / oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _unzip_pairs(pairs):
    """Split a pytree of (worker, master) leaf tuples into two pytrees."""
    is_pair = lambda x: isinstance(x, tuple)
    return (jax.tree.map(lambda p: p[0], pairs, is_leaf=is_pair),
            jax.tree.map(lambda p: p[1], pairs, is_leaf=is_pair))


def elastic_update(worker_params, master_params, w1, w2):
    """Apply eqs. (12)–(13). w1/w2 are scalars (possibly traced)."""

    def upd(w, m):
        wf = w.astype(jnp.float32)
        mf = m.astype(jnp.float32)
        diff = wf - mf
        return ((wf - w1 * diff).astype(w.dtype),
                (mf + w2 * diff).astype(m.dtype))

    return _unzip_pairs(jax.tree.map(upd, worker_params, master_params))


def elastic_update_batched(worker_stacked, master_params, w1, w2,
                           axis_name=None, master_ref=None):
    """All k worker exchanges plus the master reduction in one batched pass.

    ``worker_stacked`` leaves have a leading worker axis (k, ...); w1/w2 are
    (k,) vectors. Every worker syncs against the *same* master snapshot and

        θ^i ← θ^i − w1_i · (θ^i − θ^m)
        θ^m ← θ^m + Σ_i w2_i · (θ^i − θ^m)

    Pass ``dynamic_weight.master_schedule_weights(h2)`` as ``w2`` to make the
    master reduction exactly match the sequential event-ordered scan.

    With ``axis_name`` (sharded placement, inside ``shard_map``): the leading
    axis holds only this shard's k/n_pods workers and the master reduction
    becomes a cross-pod collective. The worker pull stays shard-local; the
    weighted diffs are all-gathered along the worker axis and reduced with
    the *same* (k, ...)-shaped sum as the single-device path — an all-reduce
    decomposed as all-gather + local reduction — so the sharded master is
    bit-exact with the single-device fused master (a ``psum`` of per-shard
    partial sums would differ in the last ulp from re-associating the sum).

    ``master_ref`` (optional pytree like the master): delayed averaging
    (DaSGD / ``ElasticConfig.staleness``) — every diff θ^i − θ^ref is
    measured against this stale snapshot while the accumulation target stays
    the live master:

        θ^i ← θ^i − w1_i · (θ^i − θ^ref)
        θ^m ← θ^m + Σ_i w2_i · (θ^i − θ^ref)

    so round r's exchange depends only on the snapshot, not on round r−1's
    master reduction. ``None`` (the default) is the exact pre-staleness
    code path — ``staleness=0`` trajectories are bit-identical.
    """
    w1 = jnp.asarray(w1, jnp.float32)
    w2 = jnp.asarray(w2, jnp.float32)

    def upd(ws, m, ref=None):
        h1 = w1.reshape((-1,) + (1,) * (ws.ndim - 1))
        h2 = w2.reshape((-1,) + (1,) * (ws.ndim - 1))
        wf = ws.astype(jnp.float32)
        mf = m.astype(jnp.float32)
        diff = wf - (mf[None] if ref is None
                     else ref.astype(jnp.float32)[None])
        pull = h2 * diff
        if axis_name is not None:
            pull = jax.lax.all_gather(pull, axis_name, axis=0, tiled=True)
        return ((wf - h1 * diff).astype(ws.dtype),
                (mf + jnp.sum(pull, axis=0)).astype(m.dtype))

    if master_ref is None:
        pairs = jax.tree.map(upd, worker_stacked, master_params)
    else:
        pairs = jax.tree.map(upd, worker_stacked, master_params, master_ref)
    return _unzip_pairs(pairs)


def elastic_update_grouped(worker_stacked, submasters, w1, w2, grp,
                           axis_name=None):
    """Rack-level exchange: every worker syncs against its group's sub-master.

    ``submasters`` leaves carry a leading group axis (G, ...); ``grp`` is the
    static (capacity,) slot→group assignment. Each worker i is pulled toward
    its own sub-master and each sub-master accumulates its members' pushes:

        θ^i   ← θ^i   − w1_i · (θ^i − θ^s_{g(i)})
        θ^s_g ← θ^s_g + Σ_{i : g(i)=g} w2_i · (θ^i − θ^s_{g(i)})

    Pass ``dynamic_weight.master_schedule_weights_grouped(h2, grp)`` as
    ``w2`` so each group's reduction matches a sequential event-ordered scan
    of its own members (groups are independent: worker j in another group
    never discounts worker i's push).

    With ``axis_name`` (sharded placement, inside ``shard_map``): the worker
    leaves/weights hold only this shard's slots; sub-masters are replicated.
    The weighted pushes are all-gathered to the full (capacity, ...) shape
    and every shard performs the *identical* full segment reduction into
    (G, ...) — same shape, same summation tree as the single-device path —
    so sharded sub-masters are bit-exact with single-device ones (the same
    trick ``elastic_update_batched`` uses for the flat master).

    Two segment-reduction paths, picked statically from the topology:

    - **Balanced racks** (capacity divisible by G and ``grp`` is the
      contiguous balanced assignment ``group_assignment`` produces — the
      common case): reshape to (G, k/G, ...), broadcast-subtract the
      sub-master row, reduce over the rack axis. No gather, no scatter —
      this path costs within ~10% of the flat master reduction.
    - **General** (uneven racks): gather each worker's sub-master row and
      segment-sum via a one-hot (G, capacity) matmul. The matmul rather
      than ``.at[grp].add``: XLA's CPU scatter serializes per index and
      measures >2x slower than the equivalent matmul at rack sizes.

    The two paths differ in summation order (last-ulp on sub-masters), but
    the choice is a static function of the topology, so any given config
    is internally consistent — and bit-exact across placements, which is
    the invariant tests/test_hierarchy.py pins.
    """
    w1 = jnp.asarray(w1, jnp.float32)
    w2 = jnp.asarray(w2, jnp.float32)
    grp_np = np.asarray(grp)                 # static topology, never traced
    cap = grp_np.shape[0]
    n_groups = jax.tree.leaves(submasters)[0].shape[0]
    balanced = (cap % n_groups == 0 and np.array_equal(
        grp_np, (np.arange(cap) * n_groups) // cap))
    grp = jnp.asarray(grp_np)
    if axis_name is not None:
        k_local = jax.tree.leaves(worker_stacked)[0].shape[0]
        i0 = jax.lax.axis_index(axis_name) * k_local
        grp_local = jax.lax.dynamic_slice_in_dim(grp, i0, k_local)
    else:
        grp_local = grp

    if balanced and axis_name is None:
        s = cap // n_groups

        def upd(ws, sm):
            h1 = w1.reshape((n_groups, s) + (1,) * (ws.ndim - 1))
            h2 = w2.reshape((n_groups, s) + (1,) * (ws.ndim - 1))
            wf = ws.astype(jnp.float32).reshape(
                (n_groups, s) + ws.shape[1:])
            smf = sm.astype(jnp.float32)
            diff = wf - smf[:, None]
            acc = jnp.sum(h2 * diff, axis=1)
            return ((wf - h1 * diff).reshape(ws.shape).astype(ws.dtype),
                    (smf + acc).astype(sm.dtype))

        return _unzip_pairs(jax.tree.map(upd, worker_stacked, submasters))

    if balanced:
        s = cap // n_groups

        def upd(ws, sm):
            h1 = w1.reshape((-1,) + (1,) * (ws.ndim - 1))
            h2 = w2.reshape((-1,) + (1,) * (ws.ndim - 1))
            wf = ws.astype(jnp.float32)
            smf = sm.astype(jnp.float32)
            diff = wf - jnp.take(smf, grp_local, axis=0)
            push = jax.lax.all_gather(h2 * diff, axis_name, axis=0,
                                      tiled=True)
            # identical values and reduction tree as the single-device
            # branch: reshape the full push to (G, k/G, ...) and reduce
            acc = jnp.sum(push.reshape((n_groups, s) + push.shape[1:]),
                          axis=1)
            return ((wf - h1 * diff).astype(ws.dtype),
                    (smf + acc).astype(sm.dtype))

        return _unzip_pairs(jax.tree.map(upd, worker_stacked, submasters))

    seg = (grp[:, None] == jnp.arange(n_groups)[None, :]).astype(jnp.float32)

    def upd(ws, sm):
        h1 = w1.reshape((-1,) + (1,) * (ws.ndim - 1))
        h2 = w2.reshape((-1,) + (1,) * (ws.ndim - 1))
        wf = ws.astype(jnp.float32)
        smf = sm.astype(jnp.float32)
        diff = wf - jnp.take(smf, grp_local, axis=0)
        push = h2 * diff
        if axis_name is not None:
            push = jax.lax.all_gather(push, axis_name, axis=0, tiled=True)
        acc = (seg.T @ push.reshape(push.shape[0], -1)).reshape(smf.shape)
        return ((wf - h1 * diff).astype(ws.dtype),
                (smf + acc).astype(sm.dtype))

    return _unzip_pairs(jax.tree.map(upd, worker_stacked, submasters))
