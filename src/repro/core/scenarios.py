"""Failure scenario engine: pluggable fault / straggler models (ISSUE-2).

The paper models exactly one failure mode — i.i.d. Bernoulli suppression of
the worker↔master communication (``repro.core.failure``). Real clusters fail
in richer ways: NICs flap (failures correlated in time), racks lose power
(failures correlated across workers), nodes run slow without dying
(stragglers — DaSGD, Zhou et al. 2020), and crashed workers rejoin from the
master checkpoint. Each regime stresses a different part of DEAHES-O's
dynamic weighting, so each gets its own generator here.

A :class:`FailureScenario` emits a :class:`ScenarioSchedule` — three
``(rounds, k)`` bool masks precomputed host-side with numpy (deterministic
given the seed). ``ElasticSession`` slices rows (or whole ``(R, k)``
blocks for jit-chunked execution) into the coordinator's ``RoundInputs``,
so every scenario is jit-compatible by construction:

``fail``
    communication with the master suppressed this round (the worker keeps
    training locally — network partition semantics, as in the paper).
``straggle``
    the worker is slow, not dead: it completes only a reduced effective τ in
    the local phase and scores itself against a stale master estimate
    (``ElasticConfig.straggler_tau_scale``).
``restart``
    the worker rejoins this round: its params are reset to the master
    before the local phase. Optimizer accumulators are restored, not
    re-initialized, and the u-history is deliberately *kept* — see
    ``ElasticTrainer.apply_restarts`` for both rationales (the score's
    recovery path, and the AdaHessian cold-start blow-up a fresh init
    causes).
``active``
    optional live-membership mask (ISSUE-5): which of the
    ``ElasticConfig.cap`` worker *slots* hold a live worker this round.
    ``None`` means every slot is live for the whole run (the fixed-k fast
    path). Unlike the three failure masks this stream is *planned*, not
    random — pools are resized by schedulers, not by coin flips — so the
    membership generators below are deterministic and seed-free. A slot
    that flips inactive→active is a **join**: the coordinator re-seats its
    params from the master (EASGD cold start). A slot that flips
    active→inactive is a **leave**: it simply freezes. The paper's §VI
    crash/restart experiments only ever suppress communication; live
    resize is a deliberate extension beyond §VI (see docs/paper_map.md).
``corrupt``
    optional byzantine mask (ISSUE-9): the worker's *gradients* are
    adversarially corrupted this round (sign-flip / scale / noise,
    ``ElasticConfig.byzantine_mode``), applied by the coordinator inside
    the jitted local phase. The worker still syncs — a poisoned node does
    not announce itself — which is exactly what stresses the h1/h2
    log-distance score. Disjoint from ``fail`` by construction (a corrupt
    round that also dropped comm would be invisible to the master and
    prove nothing). ``None`` = no corruption anywhere (the masking-free
    fast path; the jitted round specializes the branch away).
``speed``
    optional (rounds, k) float32 per-slot speed in (0, 1]: slot i completes
    ``max(1, round(speed * tau))`` local steps per round. Persistent
    heterogeneity (the ``hetero`` scenario repeats one row) as opposed to
    the transient ``straggle`` mask — a permanently slow node is a
    capacity fact, not a fault, so it does *not* stale the worker's score
    the way straggling does. ``None`` = homogeneous full-τ pool.

Scenario catalogue (names in ``repro.configs.base.FAILURE_SCENARIOS``):

=============== ============================================================
``iid``         paper baseline: Bernoulli(``failure_prob``) per (round, worker)
``burst``       two-state Markov chain per worker (flapping NIC): failures
                arrive in bursts; stationary failure rate = ``failure_prob``
``correlated``  rack-level faults: workers are split into ``fault_groups``
                groups and a whole group fails together
``straggler``   no drops; Markov-persistent slow periods per worker at
                stationary rate ``failure_prob``
``crash_restart`` renewal process: a crash takes the worker down for
                ``crash_downtime`` rounds, then it rejoins reset to the
                master; stationary down-fraction = ``failure_prob``
``hetero``      no faults; persistent per-slot speeds drawn once from a
                lognormal or bimodal distribution (``hetero_*`` knobs)
``byzantine``   persistent corrupt-gradient slots (Bernoulli
                ``byzantine_frac`` per slot, ≥ 1 honest slot guaranteed);
                honest slots still fail iid at ``failure_prob``
=============== ============================================================

Trace replay (:class:`TraceScenario`, ``read_trace`` / ``write_trace``)
deliberately sits outside the catalogue: a recorded JSON-lines trace
carries its own rounds/capacity/channels and replays bit-identically,
ignoring the generator knobs. ``launch/train.py --dump-trace`` records any
live run (including controller-driven membership edits) and ``--trace``
replays it.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import (FAILURE_SCENARIOS, MEMBERSHIP_SCENARIOS,
                                ElasticConfig)
from repro.core.failure import failure_schedule_np


@dataclasses.dataclass(frozen=True)
class ScenarioSchedule:
    """Precomputed (rounds, k) bool masks (k = slot capacity);
    ``ElasticSession`` feeds rows (per-round) or contiguous blocks
    (``round_chunk``) into ``RoundInputs``. ``active`` is the optional
    live-membership stream — ``None`` keeps every slot live."""

    fail: np.ndarray
    straggle: np.ndarray
    restart: np.ndarray
    active: Optional[np.ndarray] = None
    corrupt: Optional[np.ndarray] = None
    speed: Optional[np.ndarray] = None

    def __post_init__(self):
        assert self.fail.shape == self.straggle.shape == self.restart.shape
        assert self.fail.dtype == bool
        if self.active is not None:
            assert self.active.shape == self.fail.shape
            assert self.active.dtype == bool
            assert self.active.any(axis=1).all(), \
                "every round needs at least one live worker"
        if self.corrupt is not None:
            assert self.corrupt.shape == self.fail.shape
            assert self.corrupt.dtype == bool
            assert not (self.corrupt & self.fail).any(), \
                "corrupt and fail masks must be disjoint: a corrupt round " \
                "that also drops comm never reaches the master"
        if self.speed is not None:
            assert self.speed.shape == self.fail.shape
            assert self.speed.dtype == np.float32, \
                f"speed must be float32, got {self.speed.dtype}"
            assert (self.speed > 0).all() and (self.speed <= 1).all(), \
                "speeds must be in (0, 1]"

    @property
    def rounds(self) -> int:
        return self.fail.shape[0]

    @property
    def num_workers(self) -> int:
        return self.fail.shape[1]

    @property
    def has_stragglers(self) -> bool:
        return bool(self.straggle.any())

    @property
    def has_restarts(self) -> bool:
        return bool(self.restart.any())

    @property
    def has_corruption(self) -> bool:
        """True when any (round, slot) cell is corrupt. An all-False
        ``corrupt`` array gates exactly like ``None``: the session never
        materializes the mask into ``RoundInputs``, so the jitted round
        keeps its corruption-free trace (no recompile, bitwise-identical
        masters — see tests/test_adversarial.py)."""
        return self.corrupt is not None and bool(self.corrupt.any())

    @property
    def has_hetero(self) -> bool:
        """True when any slot runs below full speed (a speed array of all
        ones gates like ``None``, same reasoning as ``has_corruption``)."""
        return self.speed is not None and bool((self.speed < 1.0).any())

    @property
    def has_membership(self) -> bool:
        return self.active is not None

    def with_membership(self, active: Optional[np.ndarray]
                        ) -> "ScenarioSchedule":
        """Attach a live-membership stream to this schedule (failure masks
        are kept verbatim; a failure drawn for a vacant slot is simply
        masked out by the coordinator)."""
        return dataclasses.replace(self, active=active)

    def joins(self) -> np.ndarray:
        """(rounds, k) bool — slot flips inactive→active at round r, i.e.
        the rounds where the coordinator must re-seat a joining slot from
        the master. Row 0 is all-False: the initial membership is seated by
        ``init_state``, not by a join event. All-False when ``active`` is
        ``None``."""
        if self.active is None:
            return np.zeros_like(self.fail)
        out = np.zeros_like(self.active)
        out[1:] = self.active[1:] & ~self.active[:-1]
        return out

    def leaves(self) -> np.ndarray:
        """(rounds, k) bool — slot flips active→inactive at round r (the
        worker left the pool before this round ran)."""
        if self.active is None:
            return np.zeros_like(self.fail)
        out = np.zeros_like(self.active)
        out[1:] = ~self.active[1:] & self.active[:-1]
        return out

    def blind(self) -> "ScenarioSchedule":
        """Detector-blind view: same shape/membership, all ground-truth
        event masks zeroed (ISSUE-6).

        ``RunSpec(detector_blind=True)`` echoes this view — not the real
        schedule — into every ``RoundRecord``, so nothing downstream of the
        session can read which slots truly failed, straggled, restarted or
        were corrupted; the truth still drives the run itself. ``active``
        is kept: live membership is the session's *own* output (the
        controller decided it), not an oracle input. ``speed`` is dropped
        entirely (replaced by ``None``) — a zeroed speed row would be an
        invalid schedule, and the ground-truth step rates are exactly what
        a blind detector must infer from ``round_ms``/``u`` telemetry.
        """
        z = np.zeros_like(self.fail)
        return dataclasses.replace(
            self, fail=z, straggle=z, restart=z,
            corrupt=None if self.corrupt is None else z, speed=None)

    def failed_recent(self, r: int) -> np.ndarray:
        """(k,) bool — the worker's sync was suppressed in the *previous*
        round (r−1; all-False at r=0).

        This is the canonical definition of "failed recently", the feed for
        the oracle baseline EAHES-OM which is allowed to read the schedule
        directly. Paper §VI frames the oracle as acting "as if we know when
        a node will fail": it snaps a worker back (h1=1) and shields the
        master (h2=0) on exactly the first successful sync after a missed
        one, then immediately restores normal α. Before ISSUE-3 two
        readings coexisted — launch/train.py used failed-within-
        ``score_window`` while paper_repro.py used previous-round-only; the
        window reading keeps suppressing up to ``score_window−1`` healthy
        syncs after a worker has already re-synced, which over-protects the
        master and is not what §VI describes. Previous-round-only is now
        the single definition, and every entrypoint receives it through
        ``ElasticSession``.
        """
        if r == 0:
            return np.zeros(self.num_workers, bool)
        return self.fail[r - 1]

    def failed_recent_all(self) -> np.ndarray:
        """(rounds, k) bool — ``failed_recent`` for every round (row r is
        ``fail[r−1]``, row 0 all-False). Precomputed form consumed by
        ``ElasticSession`` so chunked execution can slice (R, k) blocks
        straight into ``round_chunk``."""
        out = np.zeros_like(self.fail)
        out[1:] = self.fail[:-1]
        return out


def _zeros(rounds: int, k: int) -> np.ndarray:
    return np.zeros((rounds, k), bool)


def _check_rate(rate: float, name: str, lt_one: bool = False):
    hi_ok = rate < 1.0 if lt_one else rate <= 1.0
    if not (0.0 <= rate and hi_ok):
        bound = "[0, 1)" if lt_one else "[0, 1]"
        raise ValueError(f"{name}: rate must be in {bound}, got {rate}")


def _chain_enter_prob(rate: float, recover_prob: float, name: str) -> float:
    """Entry probability giving a two-state chain the stationary bad-rate
    ``rate``; validates that such a chain exists."""
    _check_rate(rate, name, lt_one=True)
    if not 0.0 < recover_prob <= 1.0:
        raise ValueError(f"{name}: recover_prob must be in (0, 1], "
                         f"got {recover_prob}")
    enter = recover_prob * rate / (1.0 - rate)
    if enter > 1.0:
        raise ValueError(
            f"{name}: no two-state chain has stationary rate {rate} with "
            f"recover_prob {recover_prob} (derived entry prob "
            f"{enter:.3f} > 1); lower one of them")
    return enter


def _markov_chain(rng: np.random.Generator, rounds: int, k: int,
                  p_enter: float, p_exit: float) -> np.ndarray:
    """(rounds, k) bool two-state chain per worker, True = 'bad' state.

    The chain starts from its stationary distribution
    π = p_enter / (p_enter + p_exit), so the marginal bad-rate is π at
    *every* round, not only asymptotically.
    """
    pi = p_enter / max(p_enter + p_exit, 1e-12)
    state = rng.random(k) < pi
    out = np.empty((rounds, k), bool)
    for t in range(rounds):
        out[t] = state
        u = rng.random(k)
        state = np.where(state, u < 1.0 - p_exit, u < p_enter)
    return out


@dataclasses.dataclass(frozen=True)
class FailureScenario:
    """Base class: emits (rounds, k) schedules, deterministic given seed."""

    name = "base"

    def schedule(self, seed: int, rounds: int, k: int) -> ScenarioSchedule:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class IIDScenario(FailureScenario):
    """Paper §VI baseline: i.i.d. Bernoulli(rate) comm suppression."""

    rate: float = 1.0 / 3.0
    name = "iid"

    def __post_init__(self):
        _check_rate(self.rate, self.name)

    def schedule(self, seed, rounds, k):
        fail = failure_schedule_np(seed, rounds, k, self.rate)
        return ScenarioSchedule(fail, _zeros(rounds, k), _zeros(rounds, k))


@dataclasses.dataclass(frozen=True)
class _MarkovScenario(FailureScenario):
    """Shared two-state-chain machinery for ``burst`` and ``straggler``:
    ``recover_prob`` is P(bad→good) per round (mean bad period
    1/recover_prob rounds); the entry probability is derived so the
    stationary bad-rate equals ``rate``. Subclasses pick which schedule
    mask the chain fills."""

    rate: float = 1.0 / 3.0
    recover_prob: float = 0.25

    def __post_init__(self):
        self.enter_prob  # validates at construction

    @property
    def enter_prob(self) -> float:
        # stationarity: rate = enter / (enter + recover)
        return _chain_enter_prob(self.rate, self.recover_prob, self.name)

    def _chain(self, seed: int, rounds: int, k: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        return _markov_chain(rng, rounds, k, self.enter_prob,
                             self.recover_prob)


@dataclasses.dataclass(frozen=True)
class BurstScenario(_MarkovScenario):
    """Time-correlated failures (flapping NIC): failures arrive in
    multi-round bursts."""

    name = "burst"

    def schedule(self, seed, rounds, k):
        return ScenarioSchedule(self._chain(seed, rounds, k),
                                _zeros(rounds, k), _zeros(rounds, k))


@dataclasses.dataclass(frozen=True)
class CorrelatedScenario(FailureScenario):
    """Rack-level faults: workers are split into ``groups`` contiguous
    groups; each group draws one Bernoulli(rate) per round and all its
    workers fail together."""

    rate: float = 1.0 / 3.0
    groups: int = 2
    name = "correlated"

    def __post_init__(self):
        _check_rate(self.rate, self.name)
        if self.groups < 1:
            raise ValueError(f"{self.name}: need ≥ 1 group, "
                             f"got {self.groups}")

    def group_of(self, k: int) -> np.ndarray:
        g = min(self.groups, k)
        return (np.arange(k) * g) // k

    def schedule(self, seed, rounds, k):
        rng = np.random.default_rng(seed)
        g = min(self.groups, k)
        group_fail = rng.random((rounds, g)) < self.rate
        fail = group_fail[:, self.group_of(k)]
        return ScenarioSchedule(fail, _zeros(rounds, k), _zeros(rounds, k))


@dataclasses.dataclass(frozen=True)
class StragglerScenario(_MarkovScenario):
    """Slow-not-dead workers (DaSGD regime): Markov-persistent slow periods
    at stationary rate ``rate``. No communication is dropped; a straggling
    worker runs a reduced effective τ and scores against a stale master."""

    name = "straggler"

    def schedule(self, seed, rounds, k):
        return ScenarioSchedule(_zeros(rounds, k),
                                self._chain(seed, rounds, k),
                                _zeros(rounds, k))


@dataclasses.dataclass(frozen=True)
class CrashRestartScenario(FailureScenario):
    """Crash + rejoin renewal process: an up worker crashes with a derived
    per-round probability, stays down (comm suppressed) for ``downtime``
    rounds, then rejoins with its state reset to the master (restart mask).
    The crash probability is chosen so the stationary fraction of down
    rounds equals ``rate``."""

    rate: float = 1.0 / 3.0
    downtime: int = 3
    name = "crash_restart"

    def __post_init__(self):
        if self.downtime < 1:
            raise ValueError(f"{self.name}: downtime must be ≥ 1 round, "
                             f"got {self.downtime}")
        _check_rate(self.rate, self.name, lt_one=True)
        if self.crash_prob > 1.0:
            d = self.downtime
            raise ValueError(
                f"{self.name}: rate {self.rate} unreachable with downtime "
                f"{d} — every cycle has ≥ 1 up round, capping the "
                f"down-fraction at {d / (d + 1):.3f}")

    @property
    def crash_prob(self) -> float:
        # renewal cycle: up-time of 1 + Geometric(c) rounds (the rejoin
        # round is crash-free, mean up-time 1/c) + `downtime` down rounds;
        # solve downtime / (downtime + 1/c) = rate for c.
        return self.rate / (self.downtime * (1.0 - self.rate))

    def schedule(self, seed, rounds, k):
        rng = np.random.default_rng(seed)
        d, c = self.downtime, self.crash_prob
        # near-stationary init: down with prob `rate`, residual downtime
        # uniform over 1..d
        remaining = np.where(rng.random(k) < self.rate,
                             rng.integers(1, d + 1, size=k), 0)
        down = np.empty((rounds, k), bool)
        just_up = np.zeros(k, bool)
        for t in range(rounds):
            # a worker never re-crashes on its rejoin round, so every outage
            # is followed by at least one up round where `restart` fires
            crash = (remaining == 0) & ~just_up & (rng.random(k) < c)
            remaining = np.where(crash, d, remaining)
            down[t] = remaining > 0
            just_up = remaining == 1
            remaining = np.maximum(remaining - 1, 0)
        restart = _zeros(rounds, k)
        restart[1:] = down[:-1] & ~down[1:]
        return ScenarioSchedule(down, _zeros(rounds, k), restart)


@dataclasses.dataclass(frozen=True)
class HeteroScenario(FailureScenario):
    """Persistent heterogeneous worker speeds (ISSUE-9): each slot draws
    one speed in (0, 1] at schedule time and keeps it for every round —
    the EASGD-analysis regime break where dynamic weighting should beat
    fixed-α hardest. No faults: a permanently slow node is a capacity
    fact, not a failure, so the ``fail``/``straggle`` channels stay empty
    and the score is *not* staled (unlike transient stragglers).

    ``lognormal``: speed = min(1, exp(sigma·z)), z ~ N(0,1) — about half
    the pool at full speed, the rest lognormally slower (heavier tail for
    larger sigma). ``bimodal``: a ``slow_frac`` fraction of slots runs at
    ``slow_scale``, the rest at full speed (two hardware generations).
    """

    dist: str = "lognormal"
    sigma: float = 0.6
    slow_frac: float = 0.25
    slow_scale: float = 0.25
    name = "hetero"

    def __post_init__(self):
        if self.dist not in ("lognormal", "bimodal"):
            raise ValueError(f"{self.name}: dist must be 'lognormal' or "
                             f"'bimodal', got {self.dist!r}")
        if self.sigma <= 0:
            raise ValueError(f"{self.name}: sigma must be > 0, "
                             f"got {self.sigma}")
        _check_rate(self.slow_frac, self.name)
        if not 0.0 < self.slow_scale <= 1.0:
            raise ValueError(f"{self.name}: slow_scale must be in (0, 1], "
                             f"got {self.slow_scale}")

    def slot_speeds(self, seed: int, k: int) -> np.ndarray:
        """(k,) float32 persistent speeds — the single row every round
        repeats."""
        rng = np.random.default_rng(seed)
        if self.dist == "lognormal":
            s = np.minimum(1.0, np.exp(self.sigma * rng.standard_normal(k)))
        else:
            s = np.where(rng.random(k) < self.slow_frac,
                         self.slow_scale, 1.0)
        return s.astype(np.float32)

    def schedule(self, seed, rounds, k):
        speed = np.tile(self.slot_speeds(seed, k), (rounds, 1))
        return ScenarioSchedule(_zeros(rounds, k), _zeros(rounds, k),
                                _zeros(rounds, k), speed=speed)


@dataclasses.dataclass(frozen=True)
class ByzantineScenario(FailureScenario):
    """Persistent corrupt-gradient slots (ISSUE-9): each slot is byzantine
    with probability ``frac`` for the whole run (compromised nodes do not
    heal), with at least one honest slot guaranteed. Honest slots still
    suffer iid comm failures at ``fail_rate`` — the paper's §VI noise
    floor — drawn on honest slots only, so ``corrupt`` and ``fail`` are
    disjoint by construction (a corrupt round that also dropped comm never
    reaches the master and would prove nothing about the weighting)."""

    frac: float = 0.25
    fail_rate: float = 1.0 / 3.0
    name = "byzantine"

    def __post_init__(self):
        _check_rate(self.frac, f"{self.name}.frac", lt_one=True)
        _check_rate(self.fail_rate, f"{self.name}.fail_rate")

    def corrupt_slots(self, seed: int, k: int) -> np.ndarray:
        """(k,) bool persistent byzantine assignment (the row every round
        repeats). Deterministic given seed; slot 0 is force-cleared in the
        measure-zero draw where every slot came up corrupt."""
        rng = np.random.default_rng(seed)
        bad = rng.random(k) < self.frac
        if bad.all():
            bad[0] = False
        return bad

    def schedule(self, seed, rounds, k):
        rng = np.random.default_rng(seed)
        bad = rng.random(k) < self.frac      # same draw as corrupt_slots
        if bad.all():
            bad[0] = False
        corrupt = np.tile(bad, (rounds, 1))
        fail = (rng.random((rounds, k)) < self.fail_rate) & ~corrupt
        return ScenarioSchedule(fail, _zeros(rounds, k), _zeros(rounds, k),
                                corrupt=corrupt)


# ---------------------------------------------------------------------------
# trace replay (ISSUE-9): record / replay ScenarioSchedules as JSON lines
# ---------------------------------------------------------------------------

TRACE_KIND = "scenario-trace"
TRACE_VERSION = 1


def trace_membership_steps(sched: ScenarioSchedule
                           ) -> Tuple[Tuple[int, int], ...]:
    """The (round, k) resize steps equivalent to ``sched.active``, in the
    exact vocabulary ``parse_membership_plan`` accepts — so
    ``",".join(f"{r}:{k}" for r, k in steps)`` round-trips through the CLI
    plan parser and ``PlanMembership``. Only defined when every active row
    is a prefix mask (the lowest-n slots live, which is what every
    membership generator and ``ElasticSession.apply`` emit); raises
    ``ValueError`` for non-prefix masks, which a trace records as explicit
    ``active`` slot lists instead."""
    if sched.active is None:
        return ()
    counts = sched.active.sum(axis=1)
    if not (sched.active == _active_rows(sched.rounds, sched.num_workers,
                                         counts)).all():
        raise ValueError(
            "membership stream has non-prefix active rows; no "
            "parse_membership_plan-compatible step list exists")
    steps = [(0, int(counts[0]))]
    for r in range(1, sched.rounds):
        if counts[r] != counts[r - 1]:
            steps.append((int(r), int(counts[r])))
    return tuple(steps)


def trace_lines(sched: ScenarioSchedule) -> List[str]:
    """Serialize a schedule as JSON lines: one header line (kind, version,
    rounds, capacity, optional channels present), then one event line per
    True mask cell / value change. Replays bit-identically through
    ``parse_trace`` — including the exact ``None``-ness of the optional
    channels, which gates jit specialization downstream.

    Membership events use the same ``(round, k)`` vocabulary as
    ``parse_membership_plan`` (``{"ch": "k", "k": n}`` = resize to the
    lowest n slots) whenever the active rows are prefix masks, falling
    back to explicit ``{"ch": "active", "slots": [...]}`` rows otherwise.
    """
    header = {"kind": TRACE_KIND, "version": TRACE_VERSION,
              "rounds": sched.rounds, "capacity": sched.num_workers}
    channels = [ch for ch in ("active", "corrupt", "speed")
                if getattr(sched, ch) is not None]
    if channels:
        header["channels"] = channels
    lines = [json.dumps(header)]
    for ch in ("fail", "straggle", "restart", "corrupt"):
        arr = getattr(sched, ch)
        if arr is None:
            continue
        for r, i in zip(*np.nonzero(arr)):
            lines.append(json.dumps(
                {"round": int(r), "slot": int(i), "ch": ch}))
    if sched.speed is not None:
        for i in range(sched.num_workers):
            col = sched.speed[:, i]
            for r in range(sched.rounds):
                if r == 0 or col[r] != col[r - 1]:
                    # float32 -> python float (f64) -> float32 is exact
                    lines.append(json.dumps(
                        {"round": r, "slot": i, "ch": "speed",
                         "v": float(col[r])}))
    if sched.active is not None:
        try:
            steps = trace_membership_steps(sched)
            for r, k in steps:
                lines.append(json.dumps({"round": r, "ch": "k", "k": k}))
        except ValueError:
            prev = None
            for r in range(sched.rounds):
                row = sched.active[r]
                if prev is None or (row != prev).any():
                    lines.append(json.dumps(
                        {"round": r, "ch": "active",
                         "slots": [int(s) for s in np.nonzero(row)[0]]}))
                prev = row
    return lines


def parse_trace(lines: Sequence[str]) -> ScenarioSchedule:
    """Inverse of ``trace_lines``: rebuild the exact ScenarioSchedule
    (bit-identical masks, same optional-channel ``None``-ness). Events are
    applied in round order; ``speed``/``k``/``active`` events fill forward
    from their round until the next event for that slot/stream."""
    body = [ln for ln in lines if ln.strip()]
    if not body:
        raise ValueError("empty trace")
    header = json.loads(body[0])
    if header.get("kind") != TRACE_KIND:
        raise ValueError(f"not a scenario trace: kind={header.get('kind')!r}")
    if header.get("version") != TRACE_VERSION:
        raise ValueError(f"unsupported trace version {header.get('version')!r}"
                         f" (this reader is v{TRACE_VERSION})")
    rounds, k = int(header["rounds"]), int(header["capacity"])
    channels = set(header.get("channels", ()))
    unknown = channels - {"active", "corrupt", "speed"}
    if unknown:
        raise ValueError(f"unknown trace channels {sorted(unknown)}")
    masks = {ch: _zeros(rounds, k) for ch in ("fail", "straggle", "restart")}
    corrupt = _zeros(rounds, k) if "corrupt" in channels else None
    speed = np.ones((rounds, k), np.float32) if "speed" in channels else None
    active = np.ones((rounds, k), bool) if "active" in channels else None
    events = [json.loads(ln) for ln in body[1:]]
    events.sort(key=lambda e: e["round"])  # stable: file order within a round
    for ev in events:
        r, ch = int(ev["round"]), ev["ch"]
        if not 0 <= r < rounds:
            raise ValueError(f"trace event round {r} outside 0..{rounds-1}")
        if ch in masks or ch == "corrupt":
            i = int(ev["slot"])
            if not 0 <= i < k:
                raise ValueError(f"trace event slot {i} outside 0..{k-1}")
            if ch == "corrupt":
                if corrupt is None:
                    raise ValueError(
                        "corrupt event but 'corrupt' not in header channels")
                corrupt[r, i] = True
            else:
                masks[ch][r, i] = True
        elif ch == "speed":
            if speed is None:
                raise ValueError(
                    "speed event but 'speed' not in header channels")
            speed[r:, int(ev["slot"])] = np.float32(ev["v"])
        elif ch == "k":
            if active is None:
                raise ValueError(
                    "membership event but 'active' not in header channels")
            active[r:] = np.arange(k) < int(ev["k"])
        elif ch == "active":
            if active is None:
                raise ValueError(
                    "membership event but 'active' not in header channels")
            row = np.zeros(k, bool)
            row[[int(s) for s in ev["slots"]]] = True
            active[r:] = row
        else:
            raise ValueError(f"unknown trace event channel {ch!r}")
    return ScenarioSchedule(masks["fail"], masks["straggle"],
                            masks["restart"], active=active,
                            corrupt=corrupt, speed=speed)


def write_trace(path, sched: ScenarioSchedule) -> None:
    Path(path).write_text("\n".join(trace_lines(sched)) + "\n")


def read_trace(path) -> ScenarioSchedule:
    return parse_trace(Path(path).read_text().splitlines())


@dataclasses.dataclass(frozen=True)
class TraceScenario(FailureScenario):
    """Replay a recorded trace (``launch/train.py --dump-trace`` writes
    one from any live run, controller-driven membership edits included).
    The trace carries its own rounds/capacity, so ``schedule`` validates
    the requested shape against it and ignores the seed — replay is
    deterministic by construction. Deliberately not in
    ``FAILURE_SCENARIOS`` (it has no generator knobs); sessions attach it
    via ``RunSpec.schedule`` (CLI: ``--trace``)."""

    path: str = ""
    name = "trace"

    def schedule(self, seed, rounds, k):
        sched = read_trace(self.path)
        if rounds != sched.rounds or k != sched.num_workers:
            raise ValueError(
                f"trace {self.path!r} was recorded for "
                f"(rounds={sched.rounds}, capacity={sched.num_workers}); "
                f"requested (rounds={rounds}, capacity={k}) — replay runs "
                f"must match the recorded shape")
        return sched


# ---------------------------------------------------------------------------
# membership scenarios (ISSUE-5): planned worker-pool resize streams
# ---------------------------------------------------------------------------

def _active_rows(rounds: int, capacity: int, counts: np.ndarray
                 ) -> np.ndarray:
    """(rounds, capacity) mask with ``counts[r]`` live slots at round r,
    always the lowest-numbered slots (resize keeps surviving workers in
    place: growing activates the lowest vacant slots, shrinking retires
    the highest live ones)."""
    return np.arange(capacity)[None, :] < np.asarray(counts)[:, None]


@dataclasses.dataclass(frozen=True)
class MembershipScenario:
    """Base class: emits a (rounds, capacity) live-slot mask, deterministic
    and seed-free (membership events are planned by a scheduler, unlike
    the random failure streams)."""

    name = "static"

    def active_schedule(self, rounds: int, capacity: int, k0: int
                        ) -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class StaticMembership(MembershipScenario):
    """No membership events: the initial ``k0`` slots stay live. With
    ``capacity > k0`` this is the capacity-padded steady state the
    ``--what membership`` benchmark measures."""

    name = "static"

    def active_schedule(self, rounds, capacity, k0):
        return _active_rows(rounds, capacity,
                            np.full(rounds, k0, np.int64))


def _resolve_round(at: int, rounds: int) -> int:
    r = at or rounds // 2
    if not 0 < r < rounds:
        raise ValueError(
            f"membership_round={r} must fall inside the run (1..{rounds-1})")
    return r


@dataclasses.dataclass(frozen=True)
class ScaleUpMembership(MembershipScenario):
    """The pool grows once: k0 → ``k_to`` live workers at round ``at``
    (defaults: every slot, mid-run). Joining slots cold-start from the
    master — the EASGD round-robin loop's natural admission."""

    k_to: int = 0
    at: int = 0
    name = "scale_up"

    def active_schedule(self, rounds, capacity, k0):
        k_to, at = self.k_to or capacity, _resolve_round(self.at, rounds)
        if not k0 < k_to <= capacity:
            raise ValueError(
                f"scale_up: need k0 < k_to <= capacity, got "
                f"{k0} -> {k_to} at capacity {capacity}")
        counts = np.where(np.arange(rounds) < at, k0, k_to)
        return _active_rows(rounds, capacity, counts)


@dataclasses.dataclass(frozen=True)
class ScaleDownMembership(MembershipScenario):
    """The pool shrinks once: k0 → ``k_to`` at round ``at`` (defaults:
    half the pool, mid-run). Retired slots freeze; their data shards are
    re-partitioned over the survivors."""

    k_to: int = 0
    at: int = 0
    name = "scale_down"

    def active_schedule(self, rounds, capacity, k0):
        k_to, at = self.k_to or max(1, k0 // 2), _resolve_round(self.at,
                                                                rounds)
        if not 1 <= k_to < k0:
            raise ValueError(
                f"scale_down: need 1 <= k_to < k0, got {k0} -> {k_to}")
        counts = np.where(np.arange(rounds) < at, k0, k_to)
        return _active_rows(rounds, capacity, counts)


@dataclasses.dataclass(frozen=True)
class PreemptRejoinMembership(MembershipScenario):
    """Spot-instance preemption: the highest ``n`` live slots leave the
    pool at round ``at`` and rejoin ``downtime`` rounds later (cold-started
    from the master on rejoin). Unlike ``crash_restart`` the slots are
    *vacant* while gone — no local training, no scoring — which is what
    actually happens when the instance is reclaimed."""

    n: int = 1
    at: int = 0
    downtime: int = 3
    name = "preempt_rejoin"

    def active_schedule(self, rounds, capacity, k0):
        at = _resolve_round(self.at, rounds)
        if not 1 <= self.n < k0:
            raise ValueError(
                f"preempt_rejoin: need 1 <= n < k0, got n={self.n}, "
                f"k0={k0}")
        if self.downtime < 1:
            raise ValueError("preempt_rejoin: downtime must be >= 1")
        down = (np.arange(rounds) >= at) & (np.arange(rounds)
                                            < at + self.downtime)
        counts = np.where(down, k0 - self.n, k0)
        return _active_rows(rounds, capacity, counts)


@dataclasses.dataclass(frozen=True)
class PlanMembership(MembershipScenario):
    """Explicit resize plan: ``steps`` is a sorted tuple of (round, k)
    events; the pool runs at k0 until the first step, then at each step's
    k until the next. The CI membership smoke drives 4→2→6 through this."""

    steps: Tuple[Tuple[int, int], ...] = ()
    name = "plan"

    def active_schedule(self, rounds, capacity, k0):
        counts = np.full(rounds, k0, np.int64)
        for r, k in sorted(self.steps):
            if not 1 <= k <= capacity:
                raise ValueError(
                    f"membership plan step ({r}, {k}): k outside "
                    f"1..{capacity}")
            if r < rounds:
                counts[r:] = k
        return _active_rows(rounds, capacity, counts)


def parse_membership_plan(text: str) -> Tuple[Tuple[int, int], ...]:
    """CLI form of a resize plan: ``"round:k,round:k,..."`` (e.g.
    ``"2:2,4:6"`` = shrink to 2 workers at round 2, grow to 6 at 4)."""
    steps = []
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        try:
            r, k = part.split(":")
            steps.append((int(r), int(k)))
        except ValueError:
            raise ValueError(
                f"membership plan step {part!r}: expected 'round:k'")
    return tuple(steps)


def make_membership(ecfg: ElasticConfig) -> MembershipScenario:
    """Build the membership scenario named by ``ecfg.membership_scenario``
    from the ElasticConfig knobs (``membership_k``, ``membership_round``,
    ``membership_plan``; preempt downtime reuses ``crash_downtime``)."""
    name = ecfg.membership_scenario
    if name == "static":
        return StaticMembership()
    if name == "scale_up":
        return ScaleUpMembership(ecfg.membership_k, ecfg.membership_round)
    if name == "scale_down":
        return ScaleDownMembership(ecfg.membership_k, ecfg.membership_round)
    if name == "preempt_rejoin":
        return PreemptRejoinMembership(ecfg.membership_k or 1,
                                       ecfg.membership_round,
                                       ecfg.crash_downtime)
    if name == "plan":
        return PlanMembership(ecfg.membership_plan)
    raise ValueError(f"unknown membership scenario {name!r}; "
                     f"known: {MEMBERSHIP_SCENARIOS}")


def make_scenario(ecfg: ElasticConfig) -> FailureScenario:
    """Build the scenario named by ``ecfg.failure_scenario`` from the
    ElasticConfig knobs (rate = ``failure_prob`` for every scenario)."""
    name, p = ecfg.failure_scenario, ecfg.failure_prob
    if name == "iid":
        return IIDScenario(p)
    if name == "burst":
        return BurstScenario(p, ecfg.burst_recover_prob)
    if name == "correlated":
        return CorrelatedScenario(p, ecfg.fault_groups)
    if name == "straggler":
        return StragglerScenario(p, ecfg.burst_recover_prob)
    if name == "crash_restart":
        return CrashRestartScenario(p, ecfg.crash_downtime)
    if name == "hetero":
        return HeteroScenario(ecfg.hetero_dist, ecfg.hetero_sigma,
                              ecfg.hetero_slow_frac, ecfg.hetero_slow_scale)
    if name == "byzantine":
        return ByzantineScenario(ecfg.byzantine_frac, p)
    raise ValueError(f"unknown failure scenario {name!r}; "
                     f"known: {FAILURE_SCENARIOS}")


def scenario_names() -> tuple:
    return FAILURE_SCENARIOS
