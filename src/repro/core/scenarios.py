"""Failure scenario engine: pluggable fault / straggler models (ISSUE-2).

The paper models exactly one failure mode — i.i.d. Bernoulli suppression of
the worker↔master communication (``repro.core.failure``). Real clusters fail
in richer ways: NICs flap (failures correlated in time), racks lose power
(failures correlated across workers), nodes run slow without dying
(stragglers — DaSGD, Zhou et al. 2020), and crashed workers rejoin from the
master checkpoint. Each regime stresses a different part of DEAHES-O's
dynamic weighting, so each gets its own generator here.

A :class:`FailureScenario` emits a :class:`ScenarioSchedule` — three
``(rounds, k)`` bool masks precomputed host-side with numpy (deterministic
given the seed). ``ElasticSession`` slices rows (or whole ``(R, k)``
blocks for jit-chunked execution) into the coordinator's ``RoundInputs``,
so every scenario is jit-compatible by construction:

``fail``
    communication with the master suppressed this round (the worker keeps
    training locally — network partition semantics, as in the paper).
``straggle``
    the worker is slow, not dead: it completes only a reduced effective τ in
    the local phase and scores itself against a stale master estimate
    (``ElasticConfig.straggler_tau_scale``).
``restart``
    the worker rejoins this round: its params are reset to the master
    before the local phase. Optimizer accumulators are restored, not
    re-initialized, and the u-history is deliberately *kept* — see
    ``ElasticTrainer.apply_restarts`` for both rationales (the score's
    recovery path, and the AdaHessian cold-start blow-up a fresh init
    causes).
``active``
    optional live-membership mask (ISSUE-5): which of the
    ``ElasticConfig.cap`` worker *slots* hold a live worker this round.
    ``None`` means every slot is live for the whole run (the fixed-k fast
    path). Unlike the three failure masks this stream is *planned*, not
    random — pools are resized by schedulers, not by coin flips — so the
    membership generators below are deterministic and seed-free. A slot
    that flips inactive→active is a **join**: the coordinator re-seats its
    params from the master (EASGD cold start). A slot that flips
    active→inactive is a **leave**: it simply freezes. The paper's §VI
    crash/restart experiments only ever suppress communication; live
    resize is a deliberate extension beyond §VI (see docs/paper_map.md).

Scenario catalogue (names in ``repro.configs.base.FAILURE_SCENARIOS``):

=============== ============================================================
``iid``         paper baseline: Bernoulli(``failure_prob``) per (round, worker)
``burst``       two-state Markov chain per worker (flapping NIC): failures
                arrive in bursts; stationary failure rate = ``failure_prob``
``correlated``  rack-level faults: workers are split into ``fault_groups``
                groups and a whole group fails together
``straggler``   no drops; Markov-persistent slow periods per worker at
                stationary rate ``failure_prob``
``crash_restart`` renewal process: a crash takes the worker down for
                ``crash_downtime`` rounds, then it rejoins reset to the
                master; stationary down-fraction = ``failure_prob``
=============== ============================================================
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.configs.base import (FAILURE_SCENARIOS, MEMBERSHIP_SCENARIOS,
                                ElasticConfig)
from repro.core.failure import failure_schedule_np


@dataclasses.dataclass(frozen=True)
class ScenarioSchedule:
    """Precomputed (rounds, k) bool masks (k = slot capacity);
    ``ElasticSession`` feeds rows (per-round) or contiguous blocks
    (``round_chunk``) into ``RoundInputs``. ``active`` is the optional
    live-membership stream — ``None`` keeps every slot live."""

    fail: np.ndarray
    straggle: np.ndarray
    restart: np.ndarray
    active: Optional[np.ndarray] = None

    def __post_init__(self):
        assert self.fail.shape == self.straggle.shape == self.restart.shape
        assert self.fail.dtype == bool
        if self.active is not None:
            assert self.active.shape == self.fail.shape
            assert self.active.dtype == bool
            assert self.active.any(axis=1).all(), \
                "every round needs at least one live worker"

    @property
    def rounds(self) -> int:
        return self.fail.shape[0]

    @property
    def num_workers(self) -> int:
        return self.fail.shape[1]

    @property
    def has_stragglers(self) -> bool:
        return bool(self.straggle.any())

    @property
    def has_restarts(self) -> bool:
        return bool(self.restart.any())

    @property
    def has_membership(self) -> bool:
        return self.active is not None

    def with_membership(self, active: Optional[np.ndarray]
                        ) -> "ScenarioSchedule":
        """Attach a live-membership stream to this schedule (failure masks
        are kept verbatim; a failure drawn for a vacant slot is simply
        masked out by the coordinator)."""
        return dataclasses.replace(self, active=active)

    def joins(self) -> np.ndarray:
        """(rounds, k) bool — slot flips inactive→active at round r, i.e.
        the rounds where the coordinator must re-seat a joining slot from
        the master. Row 0 is all-False: the initial membership is seated by
        ``init_state``, not by a join event. All-False when ``active`` is
        ``None``."""
        if self.active is None:
            return np.zeros_like(self.fail)
        out = np.zeros_like(self.active)
        out[1:] = self.active[1:] & ~self.active[:-1]
        return out

    def leaves(self) -> np.ndarray:
        """(rounds, k) bool — slot flips active→inactive at round r (the
        worker left the pool before this round ran)."""
        if self.active is None:
            return np.zeros_like(self.fail)
        out = np.zeros_like(self.active)
        out[1:] = ~self.active[1:] & self.active[:-1]
        return out

    def blind(self) -> "ScenarioSchedule":
        """Detector-blind view: same shape/membership, all ground-truth
        event masks zeroed (ISSUE-6).

        ``RunSpec(detector_blind=True)`` echoes this view — not the real
        schedule — into every ``RoundRecord``, so nothing downstream of the
        session can read which slots truly failed, straggled or restarted;
        the truth still drives the run itself. ``active`` is kept: live
        membership is the session's *own* output (the controller decided
        it), not an oracle input.
        """
        z = np.zeros_like(self.fail)
        return dataclasses.replace(self, fail=z, straggle=z, restart=z)

    def failed_recent(self, r: int) -> np.ndarray:
        """(k,) bool — the worker's sync was suppressed in the *previous*
        round (r−1; all-False at r=0).

        This is the canonical definition of "failed recently", the feed for
        the oracle baseline EAHES-OM which is allowed to read the schedule
        directly. Paper §VI frames the oracle as acting "as if we know when
        a node will fail": it snaps a worker back (h1=1) and shields the
        master (h2=0) on exactly the first successful sync after a missed
        one, then immediately restores normal α. Before ISSUE-3 two
        readings coexisted — launch/train.py used failed-within-
        ``score_window`` while paper_repro.py used previous-round-only; the
        window reading keeps suppressing up to ``score_window−1`` healthy
        syncs after a worker has already re-synced, which over-protects the
        master and is not what §VI describes. Previous-round-only is now
        the single definition, and every entrypoint receives it through
        ``ElasticSession``.
        """
        if r == 0:
            return np.zeros(self.num_workers, bool)
        return self.fail[r - 1]

    def failed_recent_all(self) -> np.ndarray:
        """(rounds, k) bool — ``failed_recent`` for every round (row r is
        ``fail[r−1]``, row 0 all-False). Precomputed form consumed by
        ``ElasticSession`` so chunked execution can slice (R, k) blocks
        straight into ``round_chunk``."""
        out = np.zeros_like(self.fail)
        out[1:] = self.fail[:-1]
        return out


def _zeros(rounds: int, k: int) -> np.ndarray:
    return np.zeros((rounds, k), bool)


def _check_rate(rate: float, name: str, lt_one: bool = False):
    hi_ok = rate < 1.0 if lt_one else rate <= 1.0
    if not (0.0 <= rate and hi_ok):
        bound = "[0, 1)" if lt_one else "[0, 1]"
        raise ValueError(f"{name}: rate must be in {bound}, got {rate}")


def _chain_enter_prob(rate: float, recover_prob: float, name: str) -> float:
    """Entry probability giving a two-state chain the stationary bad-rate
    ``rate``; validates that such a chain exists."""
    _check_rate(rate, name, lt_one=True)
    if not 0.0 < recover_prob <= 1.0:
        raise ValueError(f"{name}: recover_prob must be in (0, 1], "
                         f"got {recover_prob}")
    enter = recover_prob * rate / (1.0 - rate)
    if enter > 1.0:
        raise ValueError(
            f"{name}: no two-state chain has stationary rate {rate} with "
            f"recover_prob {recover_prob} (derived entry prob "
            f"{enter:.3f} > 1); lower one of them")
    return enter


def _markov_chain(rng: np.random.Generator, rounds: int, k: int,
                  p_enter: float, p_exit: float) -> np.ndarray:
    """(rounds, k) bool two-state chain per worker, True = 'bad' state.

    The chain starts from its stationary distribution
    π = p_enter / (p_enter + p_exit), so the marginal bad-rate is π at
    *every* round, not only asymptotically.
    """
    pi = p_enter / max(p_enter + p_exit, 1e-12)
    state = rng.random(k) < pi
    out = np.empty((rounds, k), bool)
    for t in range(rounds):
        out[t] = state
        u = rng.random(k)
        state = np.where(state, u < 1.0 - p_exit, u < p_enter)
    return out


@dataclasses.dataclass(frozen=True)
class FailureScenario:
    """Base class: emits (rounds, k) schedules, deterministic given seed."""

    name = "base"

    def schedule(self, seed: int, rounds: int, k: int) -> ScenarioSchedule:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class IIDScenario(FailureScenario):
    """Paper §VI baseline: i.i.d. Bernoulli(rate) comm suppression."""

    rate: float = 1.0 / 3.0
    name = "iid"

    def __post_init__(self):
        _check_rate(self.rate, self.name)

    def schedule(self, seed, rounds, k):
        fail = failure_schedule_np(seed, rounds, k, self.rate)
        return ScenarioSchedule(fail, _zeros(rounds, k), _zeros(rounds, k))


@dataclasses.dataclass(frozen=True)
class _MarkovScenario(FailureScenario):
    """Shared two-state-chain machinery for ``burst`` and ``straggler``:
    ``recover_prob`` is P(bad→good) per round (mean bad period
    1/recover_prob rounds); the entry probability is derived so the
    stationary bad-rate equals ``rate``. Subclasses pick which schedule
    mask the chain fills."""

    rate: float = 1.0 / 3.0
    recover_prob: float = 0.25

    def __post_init__(self):
        self.enter_prob  # validates at construction

    @property
    def enter_prob(self) -> float:
        # stationarity: rate = enter / (enter + recover)
        return _chain_enter_prob(self.rate, self.recover_prob, self.name)

    def _chain(self, seed: int, rounds: int, k: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        return _markov_chain(rng, rounds, k, self.enter_prob,
                             self.recover_prob)


@dataclasses.dataclass(frozen=True)
class BurstScenario(_MarkovScenario):
    """Time-correlated failures (flapping NIC): failures arrive in
    multi-round bursts."""

    name = "burst"

    def schedule(self, seed, rounds, k):
        return ScenarioSchedule(self._chain(seed, rounds, k),
                                _zeros(rounds, k), _zeros(rounds, k))


@dataclasses.dataclass(frozen=True)
class CorrelatedScenario(FailureScenario):
    """Rack-level faults: workers are split into ``groups`` contiguous
    groups; each group draws one Bernoulli(rate) per round and all its
    workers fail together."""

    rate: float = 1.0 / 3.0
    groups: int = 2
    name = "correlated"

    def __post_init__(self):
        _check_rate(self.rate, self.name)
        if self.groups < 1:
            raise ValueError(f"{self.name}: need ≥ 1 group, "
                             f"got {self.groups}")

    def group_of(self, k: int) -> np.ndarray:
        g = min(self.groups, k)
        return (np.arange(k) * g) // k

    def schedule(self, seed, rounds, k):
        rng = np.random.default_rng(seed)
        g = min(self.groups, k)
        group_fail = rng.random((rounds, g)) < self.rate
        fail = group_fail[:, self.group_of(k)]
        return ScenarioSchedule(fail, _zeros(rounds, k), _zeros(rounds, k))


@dataclasses.dataclass(frozen=True)
class StragglerScenario(_MarkovScenario):
    """Slow-not-dead workers (DaSGD regime): Markov-persistent slow periods
    at stationary rate ``rate``. No communication is dropped; a straggling
    worker runs a reduced effective τ and scores against a stale master."""

    name = "straggler"

    def schedule(self, seed, rounds, k):
        return ScenarioSchedule(_zeros(rounds, k),
                                self._chain(seed, rounds, k),
                                _zeros(rounds, k))


@dataclasses.dataclass(frozen=True)
class CrashRestartScenario(FailureScenario):
    """Crash + rejoin renewal process: an up worker crashes with a derived
    per-round probability, stays down (comm suppressed) for ``downtime``
    rounds, then rejoins with its state reset to the master (restart mask).
    The crash probability is chosen so the stationary fraction of down
    rounds equals ``rate``."""

    rate: float = 1.0 / 3.0
    downtime: int = 3
    name = "crash_restart"

    def __post_init__(self):
        if self.downtime < 1:
            raise ValueError(f"{self.name}: downtime must be ≥ 1 round, "
                             f"got {self.downtime}")
        _check_rate(self.rate, self.name, lt_one=True)
        if self.crash_prob > 1.0:
            d = self.downtime
            raise ValueError(
                f"{self.name}: rate {self.rate} unreachable with downtime "
                f"{d} — every cycle has ≥ 1 up round, capping the "
                f"down-fraction at {d / (d + 1):.3f}")

    @property
    def crash_prob(self) -> float:
        # renewal cycle: up-time of 1 + Geometric(c) rounds (the rejoin
        # round is crash-free, mean up-time 1/c) + `downtime` down rounds;
        # solve downtime / (downtime + 1/c) = rate for c.
        return self.rate / (self.downtime * (1.0 - self.rate))

    def schedule(self, seed, rounds, k):
        rng = np.random.default_rng(seed)
        d, c = self.downtime, self.crash_prob
        # near-stationary init: down with prob `rate`, residual downtime
        # uniform over 1..d
        remaining = np.where(rng.random(k) < self.rate,
                             rng.integers(1, d + 1, size=k), 0)
        down = np.empty((rounds, k), bool)
        just_up = np.zeros(k, bool)
        for t in range(rounds):
            # a worker never re-crashes on its rejoin round, so every outage
            # is followed by at least one up round where `restart` fires
            crash = (remaining == 0) & ~just_up & (rng.random(k) < c)
            remaining = np.where(crash, d, remaining)
            down[t] = remaining > 0
            just_up = remaining == 1
            remaining = np.maximum(remaining - 1, 0)
        restart = _zeros(rounds, k)
        restart[1:] = down[:-1] & ~down[1:]
        return ScenarioSchedule(down, _zeros(rounds, k), restart)


# ---------------------------------------------------------------------------
# membership scenarios (ISSUE-5): planned worker-pool resize streams
# ---------------------------------------------------------------------------

def _active_rows(rounds: int, capacity: int, counts: np.ndarray
                 ) -> np.ndarray:
    """(rounds, capacity) mask with ``counts[r]`` live slots at round r,
    always the lowest-numbered slots (resize keeps surviving workers in
    place: growing activates the lowest vacant slots, shrinking retires
    the highest live ones)."""
    return np.arange(capacity)[None, :] < np.asarray(counts)[:, None]


@dataclasses.dataclass(frozen=True)
class MembershipScenario:
    """Base class: emits a (rounds, capacity) live-slot mask, deterministic
    and seed-free (membership events are planned by a scheduler, unlike
    the random failure streams)."""

    name = "static"

    def active_schedule(self, rounds: int, capacity: int, k0: int
                        ) -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class StaticMembership(MembershipScenario):
    """No membership events: the initial ``k0`` slots stay live. With
    ``capacity > k0`` this is the capacity-padded steady state the
    ``--what membership`` benchmark measures."""

    name = "static"

    def active_schedule(self, rounds, capacity, k0):
        return _active_rows(rounds, capacity,
                            np.full(rounds, k0, np.int64))


def _resolve_round(at: int, rounds: int) -> int:
    r = at or rounds // 2
    if not 0 < r < rounds:
        raise ValueError(
            f"membership_round={r} must fall inside the run (1..{rounds-1})")
    return r


@dataclasses.dataclass(frozen=True)
class ScaleUpMembership(MembershipScenario):
    """The pool grows once: k0 → ``k_to`` live workers at round ``at``
    (defaults: every slot, mid-run). Joining slots cold-start from the
    master — the EASGD round-robin loop's natural admission."""

    k_to: int = 0
    at: int = 0
    name = "scale_up"

    def active_schedule(self, rounds, capacity, k0):
        k_to, at = self.k_to or capacity, _resolve_round(self.at, rounds)
        if not k0 < k_to <= capacity:
            raise ValueError(
                f"scale_up: need k0 < k_to <= capacity, got "
                f"{k0} -> {k_to} at capacity {capacity}")
        counts = np.where(np.arange(rounds) < at, k0, k_to)
        return _active_rows(rounds, capacity, counts)


@dataclasses.dataclass(frozen=True)
class ScaleDownMembership(MembershipScenario):
    """The pool shrinks once: k0 → ``k_to`` at round ``at`` (defaults:
    half the pool, mid-run). Retired slots freeze; their data shards are
    re-partitioned over the survivors."""

    k_to: int = 0
    at: int = 0
    name = "scale_down"

    def active_schedule(self, rounds, capacity, k0):
        k_to, at = self.k_to or max(1, k0 // 2), _resolve_round(self.at,
                                                                rounds)
        if not 1 <= k_to < k0:
            raise ValueError(
                f"scale_down: need 1 <= k_to < k0, got {k0} -> {k_to}")
        counts = np.where(np.arange(rounds) < at, k0, k_to)
        return _active_rows(rounds, capacity, counts)


@dataclasses.dataclass(frozen=True)
class PreemptRejoinMembership(MembershipScenario):
    """Spot-instance preemption: the highest ``n`` live slots leave the
    pool at round ``at`` and rejoin ``downtime`` rounds later (cold-started
    from the master on rejoin). Unlike ``crash_restart`` the slots are
    *vacant* while gone — no local training, no scoring — which is what
    actually happens when the instance is reclaimed."""

    n: int = 1
    at: int = 0
    downtime: int = 3
    name = "preempt_rejoin"

    def active_schedule(self, rounds, capacity, k0):
        at = _resolve_round(self.at, rounds)
        if not 1 <= self.n < k0:
            raise ValueError(
                f"preempt_rejoin: need 1 <= n < k0, got n={self.n}, "
                f"k0={k0}")
        if self.downtime < 1:
            raise ValueError("preempt_rejoin: downtime must be >= 1")
        down = (np.arange(rounds) >= at) & (np.arange(rounds)
                                            < at + self.downtime)
        counts = np.where(down, k0 - self.n, k0)
        return _active_rows(rounds, capacity, counts)


@dataclasses.dataclass(frozen=True)
class PlanMembership(MembershipScenario):
    """Explicit resize plan: ``steps`` is a sorted tuple of (round, k)
    events; the pool runs at k0 until the first step, then at each step's
    k until the next. The CI membership smoke drives 4→2→6 through this."""

    steps: Tuple[Tuple[int, int], ...] = ()
    name = "plan"

    def active_schedule(self, rounds, capacity, k0):
        counts = np.full(rounds, k0, np.int64)
        for r, k in sorted(self.steps):
            if not 1 <= k <= capacity:
                raise ValueError(
                    f"membership plan step ({r}, {k}): k outside "
                    f"1..{capacity}")
            if r < rounds:
                counts[r:] = k
        return _active_rows(rounds, capacity, counts)


def parse_membership_plan(text: str) -> Tuple[Tuple[int, int], ...]:
    """CLI form of a resize plan: ``"round:k,round:k,..."`` (e.g.
    ``"2:2,4:6"`` = shrink to 2 workers at round 2, grow to 6 at 4)."""
    steps = []
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        try:
            r, k = part.split(":")
            steps.append((int(r), int(k)))
        except ValueError:
            raise ValueError(
                f"membership plan step {part!r}: expected 'round:k'")
    return tuple(steps)


def make_membership(ecfg: ElasticConfig) -> MembershipScenario:
    """Build the membership scenario named by ``ecfg.membership_scenario``
    from the ElasticConfig knobs (``membership_k``, ``membership_round``,
    ``membership_plan``; preempt downtime reuses ``crash_downtime``)."""
    name = ecfg.membership_scenario
    if name == "static":
        return StaticMembership()
    if name == "scale_up":
        return ScaleUpMembership(ecfg.membership_k, ecfg.membership_round)
    if name == "scale_down":
        return ScaleDownMembership(ecfg.membership_k, ecfg.membership_round)
    if name == "preempt_rejoin":
        return PreemptRejoinMembership(ecfg.membership_k or 1,
                                       ecfg.membership_round,
                                       ecfg.crash_downtime)
    if name == "plan":
        return PlanMembership(ecfg.membership_plan)
    raise ValueError(f"unknown membership scenario {name!r}; "
                     f"known: {MEMBERSHIP_SCENARIOS}")


def make_scenario(ecfg: ElasticConfig) -> FailureScenario:
    """Build the scenario named by ``ecfg.failure_scenario`` from the
    ElasticConfig knobs (rate = ``failure_prob`` for every scenario)."""
    name, p = ecfg.failure_scenario, ecfg.failure_prob
    if name == "iid":
        return IIDScenario(p)
    if name == "burst":
        return BurstScenario(p, ecfg.burst_recover_prob)
    if name == "correlated":
        return CorrelatedScenario(p, ecfg.fault_groups)
    if name == "straggler":
        return StragglerScenario(p, ecfg.burst_recover_prob)
    if name == "crash_restart":
        return CrashRestartScenario(p, ecfg.crash_downtime)
    raise ValueError(f"unknown failure scenario {name!r}; "
                     f"known: {FAILURE_SCENARIOS}")


def scenario_names() -> tuple:
    return FAILURE_SCENARIOS
