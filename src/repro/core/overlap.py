"""Data overlap partition (paper §V-A, "data distribution method").

All k workers share a random subset O with |O| = round(r·n); the remainder
D − O is split disjointly:  D_j = O ∪ S_j,  |S_j| = ⌊(n−o)/k⌋. The overlap
ratio r = o/n is the paper's hedge against losing a worker's unique shard
for good: when worker j dies, only S_j's information is at risk, and the
shared O keeps the survivors' gradients correlated enough for the master
to keep improving (§VI uses r = 0.25 at k = 4, 0.125 at k = 8 —
``ElasticConfig.overlap_ratio``).

Host-side (numpy) — this feeds the data pipeline
(``repro.data.pipeline.WorkerBatcher``), not the jitted graph; both
placements consume the same host-built batches, so the partition is
placement-independent by construction.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def overlap_partition(
    n: int, k: int, ratio: float, seed: int = 0
) -> Tuple[np.ndarray, List[np.ndarray]]:
    """The §V-A split itself: returns (overlap indices O, [per-worker
    unique index sets S_j]); deterministic in ``seed``.

    O depends only on (n, ratio, seed) — not on k — so re-partitioning
    after a membership change keeps the shared overlap stable and only
    redeals the unique shards S_j among the new pool.

    The ``len(rest) % k`` remainder is dealt round-robin (one extra sample
    to each of the first ``rest % k`` workers) instead of being dropped, so
    every index in D is assigned to at least one worker; when k divides
    evenly the split is unchanged.
    """
    if not 0.0 <= ratio < 1.0:
        raise ValueError(f"overlap ratio must be in [0,1), got {ratio}")
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    o = int(round(ratio * n))
    overlap = perm[:o]
    rest = perm[o:]
    per, rem = divmod(len(rest), k)
    bounds = np.cumsum([0] + [per + (1 if j < rem else 0)
                              for j in range(k)])
    uniques = [rest[bounds[j]:bounds[j + 1]] for j in range(k)]
    return overlap, uniques


def worker_datasets(n: int, k: int, ratio: float, seed: int = 0
                    ) -> List[np.ndarray]:
    """Each worker's dataset D_j = O ∪ S_j as index arrays (shuffled per
    worker, deterministic) — what the batcher samples worker j's τ local
    steps from each round (§V-A)."""
    overlap, uniques = overlap_partition(n, k, ratio, seed)
    rng = np.random.default_rng(seed + 1)
    out = []
    for j in range(k):
        dj = np.concatenate([overlap, uniques[j]])
        rng.shuffle(dj)
        out.append(dj)
    return out
