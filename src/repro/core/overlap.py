"""Data overlap partition (paper §V-A).

All k workers share a random subset O with |O| = round(r·n); the remainder
D − O is split disjointly:  D_j = O ∪ S_j,  |S_j| = ⌊(n−o)/k⌋.

Host-side (numpy) — this feeds the data pipeline, not the jitted graph.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def overlap_partition(
    n: int, k: int, ratio: float, seed: int = 0
) -> Tuple[np.ndarray, List[np.ndarray]]:
    """Returns (overlap_indices, [per-worker unique indices])."""
    if not 0.0 <= ratio < 1.0:
        raise ValueError(f"overlap ratio must be in [0,1), got {ratio}")
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    o = int(round(ratio * n))
    overlap = perm[:o]
    rest = perm[o:]
    per = len(rest) // k
    uniques = [rest[j * per:(j + 1) * per] for j in range(k)]
    return overlap, uniques


def worker_datasets(n: int, k: int, ratio: float, seed: int = 0
                    ) -> List[np.ndarray]:
    """D_j = O ∪ S_j index arrays (shuffled per worker, deterministic)."""
    overlap, uniques = overlap_partition(n, k, ratio, seed)
    rng = np.random.default_rng(seed + 1)
    out = []
    for j in range(k):
        dj = np.concatenate([overlap, uniques[j]])
        rng.shuffle(dj)
        out.append(dj)
    return out
