"""Worker-failure model (paper §VI): a worker's communication with the master
is suppressed with probability ``failure_prob`` (1/3 in the paper) at each
communication round. The failure is *algorithmically invisible* — no detector
exists; only DEAHES-O's score sees its footprint. The oracle baseline
(EAHES-OM) is allowed to read this schedule directly.

This module keeps the paper's i.i.d. Bernoulli generator only. Richer
regimes — bursty (Markov) failures, rack-correlated faults, stragglers, and
crash/restart cycles — live in the pluggable scenario engine,
``repro.core.scenarios`` (the ``iid`` scenario there wraps these functions).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def failure_schedule(rng: jax.Array, rounds: int, k: int, prob: float
                     ) -> jax.Array:
    """(rounds, k) bool — True = communication suppressed that round."""
    return jax.random.bernoulli(rng, prob, (rounds, k))


def failure_schedule_np(seed: int, rounds: int, k: int, prob: float
                        ) -> np.ndarray:
    """Host-side mirror of :func:`failure_schedule`: materializes the *same*
    bits for the same integer seed (it is the jax generator, evaluated), so
    the two variants are seed-parity by construction."""
    return np.asarray(
        failure_schedule(jax.random.key(seed), rounds, k, prob))


def failed_recently(schedule: jax.Array, t: int | jax.Array, window: int
                    ) -> jax.Array:
    """(k,) bool — worker failed in any of the last `window` rounds ≤ t.

    Used only by the oracle baseline EAHES-OM.
    """
    rounds = schedule.shape[0]
    idx = jnp.arange(rounds)
    in_win = (idx <= t) & (idx > t - window)
    return jnp.any(schedule & in_win[:, None], axis=0)
