"""Worker-failure model (paper §VI): a worker's communication with the master
is suppressed with probability ``failure_prob`` (1/3 in the paper) at each
communication round. The failure is *algorithmically invisible* — no detector
exists; only DEAHES-O's score sees its footprint. The oracle baseline
(EAHES-OM) is allowed to read this schedule directly."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def failure_schedule(rng: jax.Array, rounds: int, k: int, prob: float
                     ) -> jax.Array:
    """(rounds, k) bool — True = communication suppressed that round."""
    return jax.random.bernoulli(rng, prob, (rounds, k))


def failure_schedule_np(seed: int, rounds: int, k: int, prob: float
                        ) -> np.ndarray:
    return np.random.default_rng(seed).random((rounds, k)) < prob


def failed_recently(schedule: jax.Array, t: int | jax.Array, window: int
                    ) -> jax.Array:
    """(k,) bool — worker failed in any of the last `window` rounds ≤ t.

    Used only by the oracle baseline EAHES-OM.
    """
    rounds = schedule.shape[0]
    idx = jnp.arange(rounds)
    in_win = (idx <= t) & (idx > t - window)
    return jnp.any(schedule & in_win[:, None], axis=0)
