"""Dynamic weighting (paper §V-B).

Raw score from the trend of log-distances between a worker and the estimated
master model, then piece-wise-linear maps h1/h2 replacing EASGD's fixed α:

    u_t^i = log ||θ_t^i − θ̃_t^m||
    a_t^i = Σ_j c_j (u_{t−j} − u_{t−j−1}),  Σ c_j = 1, c_0 weights the newest

    h1(a) = 1                     a < k        (snap worker to master)
          = 1 + (1−α)/k · (a−k)   k ≤ a ≤ 0    (linear 1 → α)
          = α                     a > 0        (EASGD behaviour)

    h2(a) = 0                     a < k        (master ignores worker)
          = −α/k · a + α          k ≤ a ≤ 0    (linear 0 → α)
          = α                     a > 0

with threshold k < 0. Worker update uses h1, master update uses h2
(eqs. 12–13). Healthy workers (small positive scores) recover exact EASGD.

Robustness clamp (beyond-paper, ISSUE-9): note h2 gives the *full* α to any
worker with a positive score — including a byzantine worker whose distance
grows without bound, which therefore pollutes the master at the same rate
as a healthy one. ``ElasticConfig.score_clip > 0`` zeroes h2 for scores
above +score_clip (the master refuses pulls from workers diverging too
fast); 0 keeps the paper's maps bit-identically. Applied in
:func:`weights_for`, so it covers both comm backends. Honest raw scores
hover within a few multiples of |score_k| even under failures, so a clip
around 10·|score_k| separates cleanly (measured in
tests/test_adversarial.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ElasticConfig


def h1(a, alpha: float, k: float):
    a = jnp.asarray(a, jnp.float32)
    mid = 1.0 + (1.0 - alpha) / k * (a - k)
    return jnp.where(a < k, 1.0, jnp.where(a <= 0.0, mid, alpha))


def h2(a, alpha: float, k: float):
    a = jnp.asarray(a, jnp.float32)
    mid = -alpha / k * a + alpha
    return jnp.where(a < k, 0.0, jnp.where(a <= 0.0, mid, alpha))


def log_distance(worker_params, master_params) -> jax.Array:
    """u = log ||θ_i − θ̃_m|| (global 2-norm over the whole pytree)."""
    sq = sum(
        jnp.sum(jnp.square(w.astype(jnp.float32) - m.astype(jnp.float32)))
        for w, m in zip(jax.tree.leaves(worker_params),
                        jax.tree.leaves(master_params))
    )
    return jnp.log(jnp.sqrt(sq) + 1e-30)


def push_history(hist: jax.Array, u: jax.Array) -> jax.Array:
    """hist: (..., p) oldest→newest rolling window."""
    return jnp.concatenate([hist[..., 1:], u[..., None]], axis=-1)


def raw_score(hist: jax.Array, weights) -> jax.Array:
    """hist: (..., p); weights c_0.. over the p−1 diffs, newest first."""
    diffs = hist[..., 1:] - hist[..., :-1]  # oldest→newest, (p−1,)
    c = jnp.asarray(weights, jnp.float32)
    n = min(c.shape[0], diffs.shape[-1])
    c = c[:n] / jnp.sum(c[:n])
    # c_0 applies to the newest diff
    return jnp.einsum("...d,d->...", diffs[..., ::-1][..., :n], c)


def log_distance_batched(worker_stacked, master_params) -> jax.Array:
    """u for all k workers in one vmapped pass.

    ``worker_stacked`` is a pytree whose leaves carry a leading worker axis
    (k, ...); returns (k,) log-distances against the shared master.
    """
    return jax.vmap(lambda w: log_distance(w, master_params))(worker_stacked)


def log_distance_batched_ref(worker_stacked, ref_stacked) -> jax.Array:
    """u for all k workers, each against its *own* reference tree.

    Both pytrees carry a leading (k,) axis; worker i is measured against
    ``ref_stacked[i]``. The hierarchical coordinator uses this with the
    per-worker gathered sub-master rows (each worker scores against its
    rack's sub-master, not the global master)."""
    return jax.vmap(log_distance)(worker_stacked, ref_stacked)


def robust_zscore(u: jax.Array, live=None) -> jax.Array:
    """Robust z-score of each u against the live pool's u distribution:
    (u − median) / (1.4826·MAD + eps), median/MAD over live entries only.

    Non-live entries still get a z (measured against the live pool) but do
    not contaminate the statistics. Degenerate pools are safe: a pool
    whose live u are all equal has MAD 0 and the eps keeps z finite (and
    huge for any outlier, which is the point); a single live worker is its
    own median, z = 0. NaN/inf u produce NaN z — callers refuse those via
    ``comparison-fails-closed`` like the score_clip path."""
    u = jnp.asarray(u, jnp.float32)
    masked = u if live is None else jnp.where(live, u, jnp.nan)
    med = jnp.nanmedian(masked)
    mad = jnp.nanmedian(jnp.abs(masked - med))
    return (u - med) / (1.4826 * mad + 1e-6)


def group_assignment(capacity: int, groups: int):
    """Static slot→group map of the hierarchical coordinator: ``capacity``
    slots split into ``groups`` contiguous near-equal blocks,
    ``grp[i] = i·G // C`` — the same balanced split the rack-correlated
    failure scenario uses (``CorrelatedScenario.group_of``), so a
    correlated outage takes out whole hierarchy racks. Handles capacity
    not divisible by groups (block sizes differ by at most one; no group
    is ever empty for groups <= capacity). Returns a numpy int32 array —
    a trace-time constant, never a traced value."""
    import numpy as np

    g = min(groups, capacity)
    return ((np.arange(capacity) * g) // capacity).astype(np.int32)


def master_schedule_weights_grouped(w2: jax.Array, grp) -> jax.Array:
    """Per-group event-order-equivalent weights (hierarchical coordinator).

    Within each group the sequential-scan discount applies among that
    group's members only — worker i's pull on its *sub-master* is
    discounted by every later worker of the same group:

        g_i = h2_i · Π_{j>i, grp[j]=grp[i]} (1 − h2_j)

    so each sub-master reduction matches an event-ordered per-rack scan.
    ``grp`` is the static (k,) slot→group map (``group_assignment``).
    Implemented as a masked O(k²) product over scalars — k is at most a
    few hundred slots and this is weights-only, no parameter traffic.
    With one group this equals :func:`master_schedule_weights` up to
    product re-association (the flat path stays on the cumprod form)."""
    w2 = jnp.asarray(w2, jnp.float32)
    grp = jnp.asarray(grp)
    k = w2.shape[0]
    om = 1.0 - w2
    later_same_group = (jnp.arange(k)[None, :] > jnp.arange(k)[:, None]) \
        & (grp[None, :] == grp[:, None])
    excl = jnp.prod(jnp.where(later_same_group, om[None, :], 1.0), axis=1)
    return w2 * excl


def comm_scores_batched(cfg: ElasticConfig, worker_stacked, master_params,
                        u_hist: jax.Array, *, failed_recently=None,
                        stale_master=None, straggle=None, active=None,
                        axis_name=None):
    """Fused-mode scoring: all k log-distances, history pushes, raw scores
    and h1/h2 weights computed in one batched pass against the round-start
    master (no per-worker sequencing).

    ``straggle`` (k,) bool + ``stale_master``: straggling workers measure
    their distance against the stale master snapshot instead (their estimate
    of the master lags — scenario engine, repro/core/scenarios.py).

    Every quantity here is per-worker-independent (the master is a shared
    read-only input), so under sharded placement each mesh shard calls this
    on its local (k/n_pods,) worker slice unchanged — no collectives. The
    one cross-worker quantity in the fused comm phase is the master
    schedule weighting; see :func:`master_schedule_weights`'s ``axis_name``.

    ``active`` (optional (k,) bool) + ``cfg.u_zclip > 0``: the
    absolute-distance containment — w2 is additionally refused for any
    worker whose u sits beyond a robust z-score of the *live pool's* u
    distribution (``axis_name`` all-gathers the k u scalars so the
    statistics cover the whole pool under sharded placement).

    Returns ``(u, hist_new, a, w1, w2)`` with leading (k,) axes.
    """
    u = log_distance_batched(worker_stacked, master_params)
    if straggle is not None and stale_master is not None:
        u_stale = log_distance_batched(worker_stacked, stale_master)
        u = jnp.where(straggle, u_stale, u)
    hist_new = push_history(u_hist, u)
    a = raw_score(hist_new, cfg.score_weights)
    w1, w2 = weights_for(cfg, a, failed_recently=failed_recently,
                         u=u, live=active, axis_name=axis_name)
    return u, hist_new, a, w1, w2


def master_schedule_weights(w2: jax.Array, *, axis_name=None) -> jax.Array:
    """Event-order-equivalent master weights for the batched reduction.

    The sequential scan applies θ^m ← θ^m + h2_i (θ^i − θ^m) worker by
    worker, so worker i's pull is discounted by every later worker:

        θ^m_final = θ^m + Σ_i g_i (θ^i − θ^m),
        g_i = h2_i · Π_{j>i} (1 − h2_j)

    Feeding g into the single batched reduction reproduces the sequential
    master bit-for-bit (up to float associativity). A suppressed worker
    (h2_i = 0) contributes g_i = 0 and leaves the other factors untouched,
    exactly like the sequential skip.

    With ``axis_name`` (sharded placement, inside ``shard_map``): ``w2`` is
    this shard's local slice in worker order, g_i couples every worker
    (Π over j > i crosses shard boundaries), so the full (k,) h2 vector is
    all-gathered — k scalars, negligible traffic — the weights are computed
    identically on every shard, and the local slice is returned.
    """
    if axis_name is not None:
        k_loc = w2.shape[0]
        w2_all = jax.lax.all_gather(w2, axis_name, axis=0, tiled=True)
        g_all = master_schedule_weights(w2_all)
        i0 = jax.lax.axis_index(axis_name) * k_loc
        return jax.lax.dynamic_slice_in_dim(g_all, i0, k_loc)
    om = 1.0 - jnp.asarray(w2, jnp.float32)
    rev = om[::-1]
    excl = jnp.concatenate(
        [jnp.ones((1,), rev.dtype), jnp.cumprod(rev[:-1])])[::-1]
    return w2 * excl


def weights_for(cfg: ElasticConfig, a, *, failed_recently=None,
                u=None, live=None, axis_name=None):
    """(h1, h2) for a raw score; supports fixed-α and oracle modes.

    Dynamic mode applies the ``score_clip`` robustness clamp (module
    docstring): runaway scores above +score_clip get w2 = 0 — the worker
    may still pull itself toward the master (h1 untouched; that only helps
    re-anchor it), but the master refuses the exchange. Fixed-α and oracle
    modes are deliberately exempt: they are the paper's baselines.

    Absolute-distance containment (``cfg.u_zclip > 0``, ROADMAP item 5):
    when the (k,) log-distances ``u`` are supplied, w2 is also refused for
    any worker whose u exceeds a robust z-score of ``u_zclip`` over the
    live pool's u distribution (``live`` masks the pool; ``None`` = all
    live). This is the cross-sectional complement to score_clip's trend
    clamp — a worker *parked* at a huge but static distance (the measured
    noise-mode + AdaHessian attack, deviation #10) has score ≈ 0 yet
    stands z-scores away from every honest worker. Scalar/sequential
    callers pass no ``u`` and are untouched: the containment needs a pool
    snapshot, which only the batched scoring paths have. ``axis_name``
    (sharded placement) all-gathers the k u/live scalars so the pool
    statistics span every shard. Like score_clip, the refusal comparison
    fails closed on NaN z.
    """
    if cfg.oracle:
        assert failed_recently is not None
        w1 = jnp.where(failed_recently, 1.0, cfg.alpha)
        w2 = jnp.where(failed_recently, 0.0, cfg.alpha)
        return w1, w2
    if not cfg.dynamic:
        one = jnp.ones_like(jnp.asarray(a, jnp.float32))
        return cfg.alpha * one, cfg.alpha * one
    w1 = h1(a, cfg.alpha, cfg.score_k)
    w2 = h2(a, cfg.alpha, cfg.score_k)
    if cfg.score_clip > 0:
        # written as `a <= clip keeps w2` so a non-finite score (a worker
        # already diverged past float32 range) is also refused — NaN/inf
        # fail the comparison
        w2 = jnp.where(jnp.asarray(a, jnp.float32) <= cfg.score_clip,
                       w2, 0.0)
    if cfg.u_zclip > 0 and u is not None:
        u_all = jnp.asarray(u, jnp.float32)
        live_all = live
        if axis_name is not None:
            u_all = jax.lax.all_gather(u_all, axis_name, axis=0, tiled=True)
            if live is not None:
                live_all = jax.lax.all_gather(live, axis_name, axis=0,
                                              tiled=True)
        z_all = robust_zscore(u_all, live_all)
        if axis_name is not None:
            i0 = jax.lax.axis_index(axis_name) * jnp.shape(u)[0]
            z = jax.lax.dynamic_slice_in_dim(z_all, i0, jnp.shape(u)[0])
        else:
            z = z_all
        w2 = jnp.where(z <= cfg.u_zclip, w2, 0.0)
    return w1, w2
