"""Asynchronous elastic-averaging coordinator (the paper's system, §V–§VI).

Round inputs travel as one :class:`RoundInputs` pytree (batches, rng, fail,
failed_recent, straggle, restart) instead of a growing positional signature;
``round_step`` runs one round per jit call and ``round_chunk`` runs R rounds
inside a single jit via ``lax.scan`` (inputs carry a leading (R,) axis), so
per-round Python/dispatch overhead is paid once per chunk. The driver that
builds the inputs — batcher, schedule, eval cadence — is
``repro.api.session.ElasticSession``.

One round =

  1. **local phase** — every worker runs τ local optimizer steps on its own
     (overlap-sharded) data: ``vmap`` over the worker axis, ``scan`` over τ.
     With AdaHessian the Hutchinson HVP rides along (EAHES); with
     SGD/Momentum this is EASGD/EAMSGD. Under ``use_pallas`` the AdaHessian
     τ-step is *fused* (ISSUE-7): the gradient and the HVP share one
     linearization and all k workers' moment + parameter updates run as a
     single batched Pallas kernel over flat (k, rows, 128) views
     (``repro.kernels.adahessian``) — one HBM round-trip per τ-step,
     bit-exact with the plain path.
  2. **communication phase** — workers sync with the master: update the
     u-history from the estimated master distance, compute the raw score,
     map through h1/h2 (or fixed α / oracle), and apply the elastic
     exchange — unless this worker's communication is suppressed by the
     failure schedule this round. ``ecfg.comm_mode`` picks the backend:
     ``"sequential"`` scans workers one by one (event-ordered asynchrony,
     matching the paper's single-device simulation); ``"fused"`` batches
     all k syncs into one vmapped scoring pass plus one multi-worker
     elastic update (Pallas kernel on TPU), with event-order-equivalent
     master weights so the two masters agree whenever per-worker h2 do.

Placement (``ecfg.placement``) picks where the k workers live:

- ``"single"`` — all k workers simulated on one device (``vmap`` over the
  worker axis); both comm modes available. This is the paper's setting.
- ``"sharded"`` — the worker axis is partitioned over the mesh's ``'pod'``
  axis via ``shard_map`` (``round_step_sharded`` / ``round_chunk_sharded``):
  each shard runs its k/n_pods workers' local phase fully in parallel and
  scores them locally; cross-shard traffic per round is the fused master
  reduction (an all-gather of k scalars for the event-order schedule
  weights plus one worker-axis all-gather of the weighted pulls, reduced
  with the same (k, ...)-shaped sum as the single-device path — so the
  sharded master is **bit-exact** with single-device fused mode) plus one
  scalar psum for the mean-loss metric. Requires
  ``comm_mode="fused"``: the sequential backend is an event-ordered scan
  where each worker reads the master the previous one wrote, a serial
  dependency that cannot be placed on disjoint shards. Any extra mesh axes
  ('data', 'model') are currently *replicated* inside the sharded round —
  fully-manual shard_map; leaving them in the ``auto`` set so GSPMD shards
  each worker's model within its pod is the intended endgame, but this
  XLA version's partitioner aborts on partial-auto transformer bodies
  (see ``_round_sharded``). The production multi-pod lowering in
  repro/launch/dryrun.py reuses exactly these entry points.

Both placements run the same ``_round`` body; the sharded path threads the
mesh axis name through the local/comm phases, which switch their few
cross-worker reductions (mean loss, master reduction) to collectives.

Elastic membership (ISSUE-5): the worker axis is sized at
``ecfg.cap >= num_workers`` *slots* and an optional per-round ``active``
mask in :class:`RoundInputs` selects the live ones. Inactive slots are
frozen end to end — no local steps, no history push, no elastic exchange,
no loss contribution — so membership (join / leave / resize) can change
between rounds with zero recompiles: every shape is fixed at capacity.
Slots joining this round arrive in the ``join`` mask and are re-seated
from the master (EASGD cold start) exactly like a crash-restart rejoin.
When ``active``/``join`` are ``None`` (a fixed-k run), the traced round is
literally the pre-capacity graph — masking costs nothing and the
all-active path is bit-exact with it by construction (``jnp.where`` /
logical masking with an all-True mask is an elementwise identity).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ElasticConfig, OptimizerConfig
from repro.core import dynamic_weight as dw
from repro.core.elastic import (elastic_update, elastic_update_batched,
                                elastic_update_grouped)
from repro.optim.adahessian import spatial_average
from repro.optim.base import apply_updates, make_optimizer
from repro.optim.hutchinson import hessian_diag, hessian_diag_with_grad


def tree_stack_copies(tree, k: int):
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (k,) + x.shape).copy(),
                        tree)


# Mesh axis hosting the worker shards under sharded placement (the
# production meshes in repro/launch/mesh.py name it the same).
POD_AXIS = "pod"


def padded_capacity(capacity: int, n_pod: int) -> int:
    """Smallest multiple of ``n_pod`` >= ``capacity`` — sharded placement
    partitions the slot axis evenly over the pod axis, so a capacity that
    does not divide is padded up and the extra slots stay permanently
    inactive (uneven-shard masking: shards may hold unequal numbers of
    *live* workers, but equal numbers of slots)."""
    return -(-capacity // n_pod) * n_pod


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RoundInputs:
    """Everything one simulated round consumes, as a single pytree.

    Leaves are per-round (``round_step``) or carry a leading (R,) rounds
    axis (``round_chunk``, which scans over that axis). ``straggle`` and
    ``restart`` stay ``None`` when a scenario never fires them — ``None``
    is an empty subtree, so the jitted round specializes those branches
    away entirely (single trace, no mask traffic). Keep the None-ness
    consistent across calls to avoid retraces.

    All per-worker leaves are sized at the *slot capacity*
    ``ElasticConfig.cap`` (written k below; k == num_workers unless the
    pool is capacity-padded):

    - ``batches``: pytree with (τ, k, ...) leaves (or (R, τ, k, ...))
    - ``rng``: per-round PRNG key (or a stacked (R,) key array)
    - ``fail``: (k,) bool — communication suppressed this round
    - ``failed_recent``: (k,) bool — oracle feed, see
      ``ScenarioSchedule.failed_recent``
    - ``straggle``: optional (k,) bool — reduced-τ slow workers
    - ``restart``: optional (k,) bool — crash-rejoin resets
    - ``active``: optional (k,) bool — live-membership mask; ``None``
      means every slot is live (the fixed-k fast path). Inactive slots
      freeze entirely: no local steps, no sync, no history, no loss.
    - ``join``: optional (k,) bool — slots (re)joining the pool this
      round; their params are re-seated from the master before the local
      phase (same cold-start op as a crash-restart rejoin).
    - ``corrupt``: optional (k,) bool — byzantine slots (ISSUE-9): their
      gradients are adversarially corrupted every local τ-step
      (``ElasticConfig.byzantine_mode``). They still sync — a poisoned
      node does not announce itself.
    - ``speed``: optional (k,) float32 in (0, 1] — persistent per-slot
      speeds (ISSUE-9): slot i completes ``max(1, round(speed·τ))`` local
      steps this round. Unlike ``straggle`` this does not stale the
      worker's score against ``master_prev``.
    """

    batches: Any
    rng: jax.Array
    fail: jax.Array
    failed_recent: jax.Array
    straggle: Optional[jax.Array] = None
    restart: Optional[jax.Array] = None
    active: Optional[jax.Array] = None
    join: Optional[jax.Array] = None
    corrupt: Optional[jax.Array] = None
    speed: Optional[jax.Array] = None


@dataclasses.dataclass(eq=False)  # hash by id → usable as a static jit arg
class ElasticTrainer:
    model: Any
    opt_cfg: OptimizerConfig
    ecfg: ElasticConfig
    use_pallas: bool = False
    # sharded placement only: mesh whose 'pod' axis hosts the worker shards
    mesh: Any = None
    # Fused local phase (ISSUE-7): one batched multi-worker AdaHessian
    # update per τ-step instead of vmapping the per-worker optimizer, with
    # the gradient and the Hutchinson HVP sharing one linearization. None
    # (default) follows ``use_pallas``; an explicit bool decouples the
    # fused *structure* from the Pallas kernel (the local-phase benchmark
    # measures the jnp-fused variant this way). AdaHessian-only — other
    # optimizers fall back to the plain path.
    fused_local: Any = None
    # Hierarchical averaging (ISSUE-10): None (default) follows
    # ``ecfg.hierarchical`` (groups > 1 or global_period > 1); an explicit
    # True forces the hierarchical state/comm structure even at the trivial
    # groups=1, global_period=1 topology, where it collapses to the flat
    # fused phase bit-for-bit (the degenerate-equivalence proof in
    # tests/test_hierarchy.py runs exactly this).
    hierarchical: Any = None

    def __post_init__(self):
        self.opt = make_optimizer(self.opt_cfg)
        self._fused_local = (
            (self.use_pallas if self.fused_local is None
             else bool(self.fused_local))
            and self.opt_cfg.name == "adahessian")
        self._hier = (self.ecfg.hierarchical if self.hierarchical is None
                      else bool(self.hierarchical))
        if self._hier:
            if self.ecfg.comm_mode != "fused":
                raise ValueError(
                    "hierarchical averaging needs comm_mode='fused' (the "
                    "sequential scan has no grouped equivalent)")
            if self.ecfg.staleness:
                raise ValueError(
                    "hierarchical averaging is incompatible with "
                    "staleness=1 (workers sync against sub-masters; there "
                    "is no stale sub-master snapshot)")
            # static slot→group map; group count after clamping to capacity
            self._grp = dw.group_assignment(self.ecfg.cap, self.ecfg.groups)
            self._n_groups = int(self._grp.max()) + 1
        if self.ecfg.placement == "sharded":
            if self.mesh is None:
                raise ValueError(
                    "placement='sharded' needs a mesh with a 'pod' axis "
                    "(see repro.launch.mesh.make_host_mesh)")
            if POD_AXIS not in self.mesh.shape:
                raise ValueError(
                    f"sharded placement needs a {POD_AXIS!r} mesh axis, "
                    f"mesh has {tuple(self.mesh.shape)}")
            n_pod = self.mesh.shape[POD_AXIS]
            if self.ecfg.cap % n_pod:
                raise ValueError(
                    f"worker capacity={self.ecfg.cap} must divide evenly "
                    f"over the {n_pod}-way {POD_AXIS!r} mesh axis (pad it "
                    f"with coordinator.padded_capacity and leave the extra "
                    f"slots inactive)")

    # -- state ----------------------------------------------------------------
    def init_state(self, rng: jax.Array, params=None):
        """All worker-axis entries are sized at ``ecfg.cap`` slots; slots
        beyond the initial membership hold master copies until a join
        re-seats them (they are frozen by the active mask regardless)."""
        from repro.nn.param import init_tree

        k = self.ecfg.cap
        if params is None:
            params = init_tree(rng, self.model.spec)
        master = jax.tree.map(lambda x: x.astype(jnp.float32), params)
        worker_params = tree_stack_copies(params, k)
        worker_opt = jax.vmap(self.opt.init)(worker_params)
        state = {
            "workers": worker_params,
            "opt": worker_opt,
            "master": master,
            # previous-round master snapshot: the stale estimate straggling
            # workers score against (scenario engine, repro/core/scenarios.py).
            # A distinct buffer, not an alias of "master": round_step donates
            # the state, and donation rejects the same buffer appearing twice.
            "master_prev": jax.tree.map(jnp.copy, master),
            "u_hist": jnp.full((k, self.ecfg.score_window), -30.0,
                               jnp.float32),
            "round": jnp.zeros((), jnp.int32),
        }
        if self._hier:
            # one sub-master per rack, seeded from the master like workers;
            # rack-level distance history mirrors the worker u_hist shape
            state["submasters"] = tree_stack_copies(master, self._n_groups)
            state["g_u_hist"] = jnp.full(
                (self._n_groups, self.ecfg.score_window), -30.0, jnp.float32)
        return state

    # -- failure-scenario state transitions --------------------------------------
    def apply_restarts(self, state, restart):
        """Crash-restart rejoin (scenario ``crash_restart``): workers with
        ``restart[i]`` True have their params reset to the master. The
        u-history is deliberately kept — the recorded pre-crash drift makes
        the next score see the distance collapse, driving the recovery path
        h1→1 / h2→0 (§V-B).

        Optimizer accumulators are restored rather than re-initialized
        (restore-from-checkpoint semantics): a cold AdaHessian state takes
        violently large first steps from the master position, and the h2 map
        gives runaway workers the full α for any positive score, so a fresh
        init lets a single rejoin corrupt the master.
        """

        def sel(new, old):
            r = restart.reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(r, new, old)

        workers = jax.tree.map(
            lambda w, m: sel(jnp.broadcast_to(m.astype(w.dtype), w.shape), w),
            state["workers"], state["master"])
        return dict(state, workers=workers)

    # -- byzantine gradient corruption (ISSUE-9) ---------------------------------
    def _poison(self, grads, rng):
        """The adversarial gradient a byzantine worker reports, per
        ``ecfg.byzantine_mode`` (static — the trace only ever contains one
        mode's ops): ``sign_flip`` ascends the loss, ``scale`` overshoots
        by ``byzantine_scale``×, ``noise`` adds N(0, byzantine_scale²) per
        element. Noise keys are folded from the worker's step key, so the
        honest PRNG stream is untouched."""
        mode, c = self.ecfg.byzantine_mode, self.ecfg.byzantine_scale
        if mode == "sign_flip":
            return jax.tree.map(jnp.negative, grads)
        if mode == "scale":
            return jax.tree.map(lambda g: (c * g).astype(g.dtype), grads)
        leaves, treedef = jax.tree.flatten(grads)
        keys = jax.random.split(jax.random.fold_in(rng, 0x6B7A), len(leaves))
        return jax.tree.unflatten(treedef, [
            g + c * jax.random.normal(kk, g.shape, g.dtype)
            for g, kk in zip(leaves, keys)])

    def _corrupt_grads(self, grads, corrupt_i, rng):
        """One worker's gradients with the byzantine corruption selected in
        where ``corrupt_i`` (scalar bool) is True. Only the gradient
        channel is attacked; the Hutchinson curvature estimate rides
        through untouched (AdaHessian preconditions by |diag|, which
        sign_flip would not change anyway — the gradient is the attack
        surface that reaches the master)."""
        bad = self._poison(grads, rng)
        return jax.tree.map(lambda b, g: jnp.where(corrupt_i, b, g),
                            bad, grads)

    # -- local phase ------------------------------------------------------------
    def _one_step(self, params, opt_state, batch, rng, corrupt_i=None):
        loss_fn = lambda p: self.model.loss(p, batch)[0]
        loss, grads = jax.value_and_grad(loss_fn)(params)
        if corrupt_i is not None:
            grads = self._corrupt_grads(grads, corrupt_i, rng)
        extras = None
        if self.opt.needs_hessian:
            extras = {
                "hess_diag": hessian_diag(
                    jax.grad(loss_fn), params, rng,
                    self.opt_cfg.hutchinson_samples)
            }
        updates, opt_state = self.opt.update(grads, opt_state, params, extras)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    def _grads_one(self, params, batch, rng):
        """Front half of ``_one_step`` for the fused local phase: loss,
        gradient and *spatially averaged* Hutchinson diagonal for one
        worker. The gradient and the HVP probes share one linearization
        (``hessian_diag_with_grad``) instead of ``value_and_grad`` plus a
        fresh ``jvp`` — same bits, one less backward derivation. Spatial
        averaging happens here, per worker, because a stacked scalar leaf
        would otherwise average across the worker axis."""
        loss_fn = lambda p: self.model.loss(p, batch)[0]
        loss = loss_fn(params)
        grads, diag = hessian_diag_with_grad(
            jax.grad(loss_fn), params, rng, self.opt_cfg.hutchinson_samples)
        hs = jax.tree.map(
            lambda h: spatial_average(h, self.opt_cfg.spatial_block), diag)
        return loss, grads, hs

    def _fused_local_step(self, params, opt_state, batch, rngs, k_loc, axis,
                          corrupt=None):
        """One τ-step for all k workers with the update batched (ISSUE-7):
        per-worker gradients + averaged Hessian diagonals, then a single
        multi-worker AdaHessian step over the stacked trees — the Pallas
        kernel on the single-device path (interpret mode on CPU), the
        bitwise-identical vmapped jnp expression per shard under sharded
        placement (mirroring the elastic comm kernel's gating)."""
        from repro.kernels.adahessian.ops import adahessian_update_batched

        if axis is not None and k_loc == 1:
            # one worker per shard: unbatched gradients, for the same
            # singleton-vmap conv-lowering reason as the plain path below
            sq = lambda t: jax.tree.map(lambda x: x[0], t)
            loss, grads, hs = self._grads_one(sq(params), sq(batch), rngs[0])
            loss = loss[None]
            grads = jax.tree.map(lambda x: x[None], grads)
            hs = jax.tree.map(lambda x: x[None], hs)
        else:
            loss, grads, hs = jax.vmap(self._grads_one)(params, batch, rngs)
        if corrupt is not None:
            # per-worker corruption on the stacked gradient trees, same
            # semantics as the plain path's in-step corruption
            grads = jax.vmap(self._corrupt_grads)(grads, corrupt, rngs)
        new_p, new_o = adahessian_update_batched(
            params, grads, hs, opt_state, self.opt_cfg,
            use_kernel=self.use_pallas and axis is None,
            interpret=jax.default_backend() != "tpu")
        return new_p, new_o, loss

    def local_phase(self, state, batches, rng, straggle=None, active=None,
                    axis=None, corrupt=None, speed=None):
        """batches: pytree with leading (τ, k, ...) axes (k = slot capacity).

        ``straggle``: optional (k,) bool — straggling workers are slow, not
        dead: they complete only the first
        ``max(1, round(straggler_tau_scale·τ))`` local steps; params and
        optimizer state freeze for the rest of the phase.

        ``corrupt``: optional (k,) bool — byzantine slots: every local
        τ-step their gradients are replaced by the adversarial variant
        (``_corrupt_grads``). Applied on both the plain and fused local
        paths; ``None`` keeps the corruption-free trace bit-identical
        (the branch is specialized away, tests/test_adversarial.py).

        ``speed``: optional (k,) float32 in (0, 1] — persistent per-slot
        speeds: slot i runs ``max(1, round(speed·τ))`` steps and freezes
        for the rest of the phase, composing with (not replacing) the
        transient straggler mask. Distinct semantics: a straggler also
        scores against a stale master, a slow-but-healthy node does not.

        ``active``: optional (k,) bool — live-membership mask. Inactive
        slots freeze for the whole phase (params/optimizer unchanged) and
        contribute neither loss nor active-count to the mean-loss metric,
        so the metric averages over the live pool only.

        ``axis``: mesh axis name when running inside ``shard_map`` (sharded
        placement). The worker axis of every input then holds only this
        shard's k/n_pods workers; each worker's τ steps are computed exactly
        as in single placement (the per-worker PRNG keys are split from the
        global key and sliced by shard, so worker i sees identical keys
        under either placement) and the only collective is one scalar psum
        of the loss/active-count totals *after* the τ-step scan — the τ
        local steps themselves run with zero cross-shard traffic. (This
        re-associates the mean-loss reduction, which is why that metric —
        and only that metric — is last-ulp-tolerant across placements.)
        """
        k = self.ecfg.cap
        tau = jax.tree.leaves(batches)[0].shape[0]
        k_loc = jax.tree.leaves(batches)[0].shape[1]
        tau_eff = max(1, round(self.ecfg.straggler_tau_scale * tau))
        # persistent heterogeneity: per-slot step budget for this round
        # (computed once — speed is constant across the τ scan)
        speed_steps = (None if speed is None else
                       jnp.maximum(1, jnp.round(speed * tau))
                       .astype(jnp.int32))

        def tau_step(carry, inp):
            params, opt_state = carry
            batch_t, rng_t, t = inp
            rngs = jax.random.split(rng_t, k)
            if axis is not None:
                i0 = jax.lax.axis_index(axis) * k_loc
                rngs = jax.lax.dynamic_slice_in_dim(rngs, i0, k_loc)
            if self._fused_local:
                new_p, new_o, loss = self._fused_local_step(
                    params, opt_state, batch_t, rngs, k_loc, axis,
                    corrupt=corrupt)
            elif axis is not None and k_loc == 1:
                # one worker per shard: run it unbatched. A vmap over a
                # singleton worker axis lowers the conv weight-gradient
                # differently from wider vmaps and breaks master bit-
                # exactness with single placement; the unbatched gradient
                # matches any width >= 2 bit-for-bit
                # (tests/test_placement.py holds the line).
                sq = lambda t: jax.tree.map(lambda x: x[0], t)
                p1, o1, loss = self._one_step(
                    sq(params), sq(opt_state), sq(batch_t), rngs[0],
                    None if corrupt is None else corrupt[0])
                new_p = jax.tree.map(lambda x: x[None], p1)
                new_o = jax.tree.map(lambda x: x[None], o1)
                loss = loss[None]
            elif corrupt is not None:
                new_p, new_o, loss = jax.vmap(self._one_step)(
                    params, opt_state, batch_t, rngs, corrupt)
            else:
                new_p, new_o, loss = jax.vmap(self._one_step)(
                    params, opt_state, batch_t, rngs)
            # frozen steps (slow stragglers past their reduced τ, slots past
            # their heterogeneous speed budget, inactive slots) contribute
            # neither updates nor loss metrics
            live = None
            if straggle is not None:
                live = jnp.logical_or(~straggle, t < tau_eff)
            if speed_steps is not None:
                live_sp = t < speed_steps
                live = live_sp if live is None else jnp.logical_and(live,
                                                                    live_sp)
            if active is not None:
                live = active if live is None else jnp.logical_and(live,
                                                                   active)
            if live is not None:
                sel = lambda n, o: jnp.where(
                    live.reshape((-1,) + (1,) * (n.ndim - 1)), n, o)
                new_p = jax.tree.map(sel, new_p, params)
                new_o = jax.tree.map(sel, new_o, opt_state)
                loss = jnp.where(live, loss, 0.0)
                active_f = live
            else:
                active_f = jnp.ones_like(loss, bool)
            return ((new_p, new_o),
                    (jnp.sum(loss), jnp.sum(active_f), loss, active_f))

        rngs = jax.random.split(rng, tau)
        (workers, opt_state), (losses, counts, loss_steps, live_steps) = (
            jax.lax.scan(tau_step, (state["workers"], state["opt"]),
                         (batches, rngs, jnp.arange(tau))))
        sum_loss, n_active = jnp.sum(losses), jnp.sum(counts)
        if axis is not None:
            # one collective for the whole phase: metric totals only
            sum_loss, n_active = jax.lax.psum((sum_loss, n_active), axis)
        mean_loss = sum_loss / jnp.maximum(n_active, 1)
        # per-slot mean loss over each slot's *live* steps (frozen straggler
        # tails and vacancies excluded) — the controller's progress signal.
        # Slot-local, so it needs no collective under sharded placement.
        # The scalar mean-loss reduction above is kept verbatim: loss_w is
        # an additional scan output, not a re-association of that metric.
        loss_w = (jnp.sum(loss_steps, axis=0)
                  / jnp.maximum(jnp.sum(live_steps, axis=0), 1))
        return dict(state, workers=workers, opt=opt_state), mean_loss, loss_w

    # -- communication phase -----------------------------------------------------
    def comm_phase(self, state, fail_mask, failed_recent=None, straggle=None,
                   active=None, axis=None):
        """fail_mask: (k,) bool — True suppresses this worker's sync.

        ``straggle``: optional (k,) bool — straggling workers score against
        the *previous* round's master snapshot (their estimate of the master
        is stale; the elastic exchange itself still uses the live master,
        which the parameter server holds).

        ``active``: optional (k,) bool — live-membership mask. An inactive
        slot is a vacancy, not a failure: it performs no elastic exchange
        *and* its u-history stays frozen (a failed worker keeps training
        locally and keeps scoring; a vacant slot has no worker at all). In
        the sequential scan it is a no-op on the master, so the event order
        of the live workers is identical to a pool that never had the slot.

        Dispatches on ``ecfg.comm_mode``: "sequential" is the paper's
        event-ordered scan; "fused" batches all k syncs into one scoring
        pass plus one multi-worker elastic update. ``axis`` (sharded
        placement) is fused-only — the sequential scan's serial master
        dependency cannot shard.
        """
        ecfg = self.ecfg
        if failed_recent is None:
            failed_recent = jnp.zeros_like(fail_mask)
        if ecfg.comm_mode == "fused":
            if self._hier:
                return self._comm_phase_hier(state, fail_mask, failed_recent,
                                             straggle, active, axis)
            return self._comm_phase_fused(state, fail_mask, failed_recent,
                                          straggle, active, axis)
        if axis is not None:  # unreachable: ElasticConfig validates this
            raise ValueError("sequential comm cannot run sharded")
        stale_master = state.get("master_prev", state["master"])
        straggle_in = (jnp.zeros_like(fail_mask) if straggle is None
                       else straggle)
        active_in = (jnp.ones_like(fail_mask) if active is None
                     else active)

        def sync_one(master, xs):
            w_i, hist_i, fail_i, fr_i, st_i, act_i = xs
            # u from the estimated master (other-worker estimate ≈ current
            # master in the event-ordered simulation)
            u_t = dw.log_distance(w_i, master)
            if straggle is not None:
                u_t = jnp.where(st_i, dw.log_distance(w_i, stale_master),
                                u_t)
            if ecfg.score_clip > 0:
                # quarantine (ISSUE-9): a worker whose distance left
                # float32 range (diverged byzantine slot) is re-seated to
                # the master here, so the refused exchange below never
                # computes 0·inf and the u-history stays finite. The
                # pushed u is exactly log_distance(master, master); the
                # resulting huge positive score keeps the slot refused
                # while it stays suspicious.
                quar = ~jnp.isfinite(u_t)
                w_i = jax.tree.map(
                    lambda w, m: jnp.where(quar, m.astype(w.dtype), w),
                    w_i, master)
                u_t = jnp.where(quar, jnp.log(jnp.float32(1e-30)), u_t)
            hist_new = dw.push_history(hist_i, u_t)
            if active is not None:
                hist_new = jnp.where(act_i, hist_new, hist_i)
            a = dw.raw_score(hist_new, ecfg.score_weights)
            w1, w2 = dw.weights_for(ecfg, a, failed_recently=fr_i)
            # suppressed communication (failure or vacancy): no exchange
            dead_i = (fail_i if active is None
                      else jnp.logical_or(fail_i, ~act_i))
            w1 = jnp.where(dead_i, 0.0, w1)
            w2 = jnp.where(dead_i, 0.0, w2)
            if self.use_pallas:
                from repro.kernels.elastic.ops import elastic_update_pallas

                new_w, new_master = elastic_update_pallas(
                    w_i, master, w1, w2,
                    interpret=jax.default_backend() != "tpu")
            else:
                new_w, new_master = elastic_update(w_i, master, w1, w2)
            if active is not None:  # vacant slots report zeroed diagnostics
                u_t = jnp.where(act_i, u_t, 0.0)
                a = jnp.where(act_i, a, 0.0)
            return new_master, (new_w, hist_new, (u_t, a, w1, w2))

        master, (workers, hist, diag) = jax.lax.scan(
            sync_one, state["master"],
            (state["workers"], state["u_hist"], fail_mask, failed_recent,
             straggle_in, active_in))
        u, a, w1, w2 = diag
        metrics = {"u": u, "score": a, "h1": w1, "h2": w2}
        return dict(state, workers=workers, master=master,
                    master_prev=state["master"], u_hist=hist,
                    round=state["round"] + 1), metrics

    def _comm_phase_fused(self, state, fail_mask, failed_recent,
                          straggle=None, active=None, axis=None):
        """Batched communication: one vmapped scoring pass over all k
        workers, then a single multi-worker elastic update.

        Workers sync against the round-start master (delayed averaging);
        the master reduction uses the event-order-equivalent weights
        g_i = h2_i·Π_{j>i}(1−h2_j), so the resulting master matches the
        sequential scan exactly whenever the per-worker h2 agree (e.g. the
        fixed-α and oracle modes). Scores are computed against the same
        round-start master, which drops the scan's serial dependency.

        ``ecfg.staleness = 1`` deepens the delay by one round (DaSGD):
        scoring *and* the elastic diffs use the previous round's master
        snapshot (``master_prev``), with the weighted pulls still
        accumulated onto the live master. Straggler stale scoring
        coincides with the ordinary scoring in that mode (both read
        ``master_prev``).

        ``axis`` (sharded placement): scoring runs on this shard's local
        workers against the replicated master; the schedule weighting
        all-gathers the k h2 scalars and the elastic update all-gathers the
        weighted pulls for a reduction bit-exact with the single-device
        path. The Pallas kernel covers the single-device fused path only —
        per-shard the update is the plain jnp expression, which XLA fuses
        fine at k/n_pods workers per device.
        """
        ecfg = self.ecfg
        master = state["master"]
        # Delayed averaging (ElasticConfig.staleness, DaSGD): score and
        # pull toward the previous round's master snapshot instead of the
        # round-start master, so this round's exchange depends only on
        # state known before the previous reduction landed (comm of round
        # r can overlap local of round r+1). With staleness=0 ``ref`` is
        # the master itself and every expression below is unchanged.
        ref = (state.get("master_prev", master) if ecfg.staleness
               else master)
        workers_in = state["workers"]
        if ecfg.score_clip > 0:
            # quarantine (ISSUE-9), mirroring the sequential scan: a
            # worker whose log-distance left float32 range is re-seated to
            # the scoring reference before anything else reads it, so the
            # refused master reduction never multiplies 0·inf and the
            # history push (inside comm_scores_batched, which re-measures
            # the sanitized workers) records the exact re-seat distance.
            u0 = dw.log_distance_batched(workers_in, ref)
            quar = ~jnp.isfinite(u0)
            workers_in = jax.tree.map(
                lambda w, m: jnp.where(
                    quar.reshape((-1,) + (1,) * (w.ndim - 1)),
                    m.astype(w.dtype)[None], w),
                workers_in, ref)
        u, hist, a, w1, w2 = dw.comm_scores_batched(
            ecfg, workers_in, ref, state["u_hist"],
            failed_recently=failed_recent,
            stale_master=(None if straggle is None
                          else state.get("master_prev", master)),
            straggle=straggle, active=active, axis_name=axis)
        # suppressed communication: no elastic exchange at all. A vacant
        # (inactive) slot additionally freezes its u-history and zeroes its
        # diagnostics — it contributes g_i = 0 to the master reduction,
        # exactly like the sequential scan skipping it.
        dead = (fail_mask if active is None
                else jnp.logical_or(fail_mask, ~active))
        w1 = jnp.where(dead, 0.0, w1)
        w2 = jnp.where(dead, 0.0, w2)
        if active is not None:
            hist = jnp.where(active[:, None], hist, state["u_hist"])
            u = jnp.where(active, u, 0.0)
            a = jnp.where(active, a, 0.0)
        g2 = dw.master_schedule_weights(w2, axis_name=axis)
        master_ref = ref if ecfg.staleness else None
        # workers_in == state["workers"] unless the score_clip quarantine
        # re-seated a diverged slot above
        if self.use_pallas and axis is None:
            from repro.kernels.elastic.ops import elastic_update_batched_pallas

            workers, master = elastic_update_batched_pallas(
                workers_in, master, w1, g2, master_ref=master_ref,
                interpret=jax.default_backend() != "tpu")
        else:
            workers, master = elastic_update_batched(
                workers_in, master, w1, g2, axis_name=axis,
                master_ref=master_ref)
        metrics = {"u": u, "score": a, "h1": w1, "h2": w2}
        return dict(state, workers=workers, master=master,
                    master_prev=state["master"], u_hist=hist,
                    round=state["round"] + 1), metrics

    def _comm_phase_hier(self, state, fail_mask, failed_recent,
                         straggle=None, active=None, axis=None):
        """Two-level hierarchical communication (ISSUE-10, tree-EASGD).

        **Rack level, every round**: each worker scores and elastic-averages
        against its group's *sub-master* — the same batched scoring +
        event-order-equivalent reduction as the flat fused phase, with the
        schedule weights grouped (``master_schedule_weights_grouped``) so
        every sub-master matches a per-rack sequential scan. The (G, ...)
        sub-master trees are replicated under sharded placement; the
        grouped reduction all-gathers the weighted pushes and performs the
        identical full scatter-add on every shard, so sub-masters stay
        bit-exact across placements (see ``elastic_update_grouped``).

        **Global level, every** ``global_period`` **rounds**: sub-masters
        play the worker role against the global master — their own
        u-history (``g_u_hist``), raw scores and dynamic h1/h2, the same
        event-order weights, one ``elastic_update_batched``. A rack with no
        syncing member this round (all failed/vacant — e.g. a correlated
        rack outage) is down-weighted exactly like a dead worker at rack
        level: gw1 = gw2 = 0, no exchange, while a merely *dark* history
        still records the drift. A fully vacant rack freezes its history
        and zeroes its diagnostics, like a vacant slot. Off-cycle rounds
        skip the global phase entirely under ``lax.cond`` — no comparison,
        no distance computation, no master traffic — which is the
        per-round comm saving the hierarchy buys (benchmarks/run.py
        ``--what hierarchy``). Everything the global phase reads is
        replicated or all-gathered, so it runs identically on every shard
        with zero collectives of parameter size.

        **Degenerate topology** (groups=1 and global_period=1): statically
        collapses to the flat fused phase — the master trajectory is
        bit-exact with ``_comm_phase_fused`` by construction — and the
        single sub-master mirrors the new master (a global sync through a
        lone all-member rack is the flat exchange twice over; mirroring
        keeps the checkpointable hierarchical state consistent without
        perturbing the proof trajectory).

        Stragglers score against their live sub-master (no stale-snapshot
        variant at rack granularity — there is no ``submaster_prev``);
        ``staleness=1`` is rejected at construction.
        """
        ecfg = self.ecfg
        G = self._n_groups
        if G == 1 and ecfg.global_period == 1:
            new_state, metrics = self._comm_phase_fused(
                state, fail_mask, failed_recent, straggle, active, axis)
            new_state["submasters"] = jax.tree.map(
                lambda m: m[None], new_state["master"])
            z = jnp.zeros((1,), jnp.float32)
            metrics.update(g_u=z, g_score=z, g_h1=z, g_h2=z)
            return new_state, metrics

        master = state["master"]
        submasters = state["submasters"]
        grp = jnp.asarray(self._grp)
        if axis is not None:
            k_loc = fail_mask.shape[0]
            i0 = jax.lax.axis_index(axis) * k_loc
            grp_local = jax.lax.dynamic_slice_in_dim(grp, i0, k_loc)
        else:
            grp_local = grp
        # each worker's reference: its rack's sub-master row
        sub_ref = jax.tree.map(lambda sm: jnp.take(sm, grp_local, axis=0),
                               submasters)

        workers_in = state["workers"]
        u = dw.log_distance_batched_ref(workers_in, sub_ref)
        if ecfg.score_clip > 0:
            # quarantine (ISSUE-9), as in the flat fused phase, but the
            # re-seat target is the worker's sub-master; the recorded u is
            # exactly log_distance(sub_ref, sub_ref)
            quar = ~jnp.isfinite(u)
            workers_in = jax.tree.map(
                lambda w, r: jnp.where(
                    quar.reshape((-1,) + (1,) * (w.ndim - 1)),
                    r.astype(w.dtype), w),
                workers_in, sub_ref)
            u = jnp.where(quar, jnp.log(jnp.float32(1e-30)), u)
        hist = dw.push_history(state["u_hist"], u)
        a = dw.raw_score(hist, ecfg.score_weights)
        w1, w2 = dw.weights_for(ecfg, a, failed_recently=failed_recent,
                                u=u, live=active, axis_name=axis)
        dead = (fail_mask if active is None
                else jnp.logical_or(fail_mask, ~active))
        w1 = jnp.where(dead, 0.0, w1)
        w2 = jnp.where(dead, 0.0, w2)
        if active is not None:
            hist = jnp.where(active[:, None], hist, state["u_hist"])
            u = jnp.where(active, u, 0.0)
            a = jnp.where(active, a, 0.0)

        # grouped event-order weights couple workers within a rack only,
        # but a shard may hold a rack fragment — compute on the full (k,)
        # h2 vector, identically on every shard, and slice back
        if axis is not None:
            w2_full = jax.lax.all_gather(w2, axis, axis=0, tiled=True)
            g2 = jax.lax.dynamic_slice_in_dim(
                dw.master_schedule_weights_grouped(w2_full, grp), i0, k_loc)
        else:
            g2 = dw.master_schedule_weights_grouped(w2, grp)
        workers, submasters = elastic_update_grouped(
            workers_in, submasters, w1, g2, self._grp, axis_name=axis)

        # rack liveness, from the full masks (replicated across shards)
        gather = (lambda x: x) if axis is None else (
            lambda x: jax.lax.all_gather(x, axis, axis=0, tiled=True))
        as_i32 = lambda b: b.astype(jnp.int32)
        seg_any = lambda b: (jnp.zeros((G,), jnp.int32)
                             .at[grp].max(as_i32(b))) > 0
        g_synced = seg_any(~gather(dead))   # some member exchanged
        g_live = (jnp.ones((G,), bool) if active is None
                  else seg_any(gather(active)))
        g_fr = seg_any(gather(failed_recent))

        round_new = state["round"] + 1

        def global_sync(args):
            subs, mast, g_hist = args
            g_u = dw.log_distance_batched(subs, mast)
            g_hist_new = dw.push_history(g_hist, g_u)
            g_hist_new = jnp.where(g_live[:, None], g_hist_new, g_hist)
            g_a = dw.raw_score(g_hist_new, ecfg.score_weights)
            gw1, gw2 = dw.weights_for(ecfg, g_a, failed_recently=g_fr,
                                      u=g_u, live=g_live)
            g_dead = ~g_synced
            gw1 = jnp.where(g_dead, 0.0, gw1)
            gw2 = jnp.where(g_dead, 0.0, gw2)
            gg2 = dw.master_schedule_weights(gw2)
            subs2, mast2 = elastic_update_batched(subs, mast, gw1, gg2)
            g_u = jnp.where(g_live, g_u, 0.0)
            g_a = jnp.where(g_live, g_a, 0.0)
            return subs2, mast2, g_hist_new, (g_u, g_a, gw1, gw2)

        def global_skip(args):
            subs, mast, g_hist = args
            z = jnp.zeros((G,), jnp.float32)
            return subs, mast, g_hist, (z, z, z, z)

        submasters, master, g_hist, (g_u, g_a, gw1, gw2) = jax.lax.cond(
            (round_new % ecfg.global_period) == 0, global_sync, global_skip,
            (submasters, master, state["g_u_hist"]))

        metrics = {"u": u, "score": a, "h1": w1, "h2": w2,
                   "g_u": g_u, "g_score": g_a, "g_h1": gw1, "g_h2": gw2}
        return dict(state, workers=workers, master=master,
                    master_prev=state["master"], u_hist=hist,
                    submasters=submasters, g_u_hist=g_hist,
                    round=round_new), metrics

    # -- full round ---------------------------------------------------------------
    def _round(self, state, inputs: RoundInputs, axis=None):
        """One simulated round under a failure scenario: optional crash
        rejoins and membership joins (both re-seat params from the master),
        the local phase (with per-worker straggler slowdown and the
        live-membership mask), then the communication phase under the fail
        mask. ``axis`` names the worker-hosting mesh axis inside
        ``shard_map`` (sharded placement); ``apply_restarts`` is per-worker
        against the replicated master, so it needs no axis awareness."""
        reseat = inputs.restart
        if inputs.join is not None:
            # a joining slot cold-starts from the master, EASGD-style —
            # the same re-seat op as a crash-restart rejoin
            reseat = (inputs.join if reseat is None
                      else jnp.logical_or(reseat, inputs.join))
        if reseat is not None:
            state = self.apply_restarts(state, reseat)
        state, loss, loss_w = self.local_phase(state, inputs.batches,
                                               inputs.rng, inputs.straggle,
                                               inputs.active, axis=axis,
                                               corrupt=inputs.corrupt,
                                               speed=inputs.speed)
        state, metrics = self.comm_phase(state, inputs.fail,
                                         inputs.failed_recent,
                                         inputs.straggle, inputs.active,
                                         axis=axis)
        metrics["loss"] = loss
        metrics["loss_w"] = loss_w
        return state, metrics

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def round_step(self, state, inputs: RoundInputs):
        """One round per jit call; ``inputs`` leaves are per-round.

        ``state`` is donated: the output state reuses the input buffers, so
        a run holds one copy of the (k × params)-sized worker state instead
        of double-buffering it across calls. Don't reuse a state object
        after passing it in — keep the returned one.
        """
        return self._round(state, inputs)

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def round_chunk(self, state, inputs: RoundInputs):
        """R rounds in one jit call: every ``inputs`` leaf carries a leading
        (R,) axis and ``lax.scan`` threads the state through the rounds, so
        the Python/dispatch cost of a round is paid once per chunk. The
        scanned body is exactly ``round_step``'s, so a chunked run is
        bit-identical to R separate ``round_step`` calls; metrics come back
        stacked with a leading (R,) axis. ``state`` is donated, as in
        ``round_step``."""
        return jax.lax.scan(self._round, state, inputs)

    # -- sharded placement entry points -------------------------------------------
    def state_shard_specs(self):
        """Per-entry partition specs of the trainer state under sharded
        placement: worker-axis entries split over 'pod', master and
        counters replicated. The single source of truth for both the
        shard_map in/out specs (``_shard_specs``) and the session's
        device-resident state layout (``ElasticSession._place_state``) —
        a new state entry added here is placed consistently everywhere.
        """
        from jax.sharding import PartitionSpec as P

        wrk, rep = P(POD_AXIS), P()
        specs = {"workers": wrk, "opt": wrk, "master": rep,
                 "master_prev": rep, "u_hist": wrk, "round": rep}
        if self._hier:
            # sub-masters and their history replicate like the master: the
            # grouped reduction rebuilds them identically on every shard
            specs["submasters"] = rep
            specs["g_u_hist"] = rep
        return specs

    def _shard_specs(self, inputs: RoundInputs, chunk: bool):
        """``shard_map`` partition specs for (state, inputs, metrics).

        Worker-axis leaves split over the 'pod' axis; the master, the PRNG
        keys and the round counter replicate. Specs are pytree prefixes, so
        ``None`` scenario fields (straggle/restart) mirror the input's
        Noneness and keep the specialized trace. ``chunk`` prepends the
        (R,) rounds axis, which is never sharded.
        """
        from jax.sharding import PartitionSpec as P

        lead = (None,) if chunk else ()
        wrk = P(*lead, POD_AXIS)
        rep = P()
        state_spec = self.state_shard_specs()
        mask = lambda x: None if x is None else wrk
        in_spec = RoundInputs(
            batches=P(*lead, None, POD_AXIS),  # (R?, τ, k, ...)
            rng=rep,
            fail=wrk, failed_recent=mask(inputs.failed_recent),
            straggle=mask(inputs.straggle), restart=mask(inputs.restart),
            active=mask(inputs.active), join=mask(inputs.join),
            corrupt=mask(inputs.corrupt), speed=mask(inputs.speed))
        met_spec = {"u": wrk, "score": wrk, "h1": wrk, "h2": wrk,
                    "loss": rep, "loss_w": wrk}
        if self._hier:
            # rack-level diagnostics are (G,)-replicated, like the master
            met_spec.update(g_u=rep, g_score=rep, g_h1=rep, g_h2=rep)
        return state_spec, in_spec, met_spec

    def _round_sharded(self, state, inputs: RoundInputs, chunk: bool):
        """Shared body of the sharded jits: ``shard_map`` the round (or the
        R-round scan) over the mesh, fully manual. Specs mention only the
        'pod' axis, so any 'data'/'model' axes replicate the per-worker
        computation — exactly equivalent on the size-1 host-mesh axes.
        (Leaving those axes in ``shard_map``'s ``auto`` set so GSPMD shards
        each worker's model *within* its pod is the intended production
        endgame, but this jax/XLA version's SPMD partitioner hard-aborts on
        partial-auto transformer bodies — hlo_sharding_util
        ``IsManualSubgroup`` check — so within-pod model sharding waits on
        an XLA upgrade.)"""
        from jax.experimental.shard_map import shard_map

        state_spec, in_spec, met_spec = self._shard_specs(inputs, chunk)
        step = functools.partial(self._round, axis=POD_AXIS)
        body = (lambda s, i: jax.lax.scan(step, s, i)) if chunk else step
        fn = shard_map(
            body, self.mesh,
            in_specs=(state_spec, in_spec),
            out_specs=(state_spec, met_spec),
            check_rep=False)
        return fn(state, inputs)

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def round_step_sharded(self, state, inputs: RoundInputs):
        """``round_step`` with the worker axis placed over the mesh's 'pod'
        axis. Master params are bit-exact with single-device fused mode
        (tests/test_placement.py); ``state`` is donated and stays resident
        in its sharded layout across calls."""
        return self._round_sharded(state, inputs, chunk=False)

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def round_chunk_sharded(self, state, inputs: RoundInputs):
        """``round_chunk`` under sharded placement: the R-round ``lax.scan``
        runs *inside* ``shard_map``, so one jit call executes R rounds with
        the worker axis on hardware and per-round collectives only."""
        return self._round_sharded(state, inputs, chunk=True)

    # -- eval ----------------------------------------------------------------------
    @functools.partial(jax.jit, static_argnums=0)
    def master_accuracy(self, state, batch):
        params = state["master"]
        return self.model.accuracy(params, batch)

    @functools.partial(jax.jit, static_argnums=0)
    def master_loss(self, state, batch):
        params = state["master"]
        loss, _ = self.model.loss(params, batch)
        return loss
