"""Asynchronous elastic-averaging coordinator (the paper's system, §V–§VI).

Round inputs travel as one :class:`RoundInputs` pytree (batches, rng, fail,
failed_recent, straggle, restart) instead of a growing positional signature;
``round_step`` runs one round per jit call and ``round_chunk`` runs R rounds
inside a single jit via ``lax.scan`` (inputs carry a leading (R,) axis), so
per-round Python/dispatch overhead is paid once per chunk. The driver that
builds the inputs — batcher, schedule, eval cadence — is
``repro.api.session.ElasticSession``.

One round =

  1. **local phase** — every worker runs τ local optimizer steps on its own
     (overlap-sharded) data: ``vmap`` over the worker axis, ``scan`` over τ.
     With AdaHessian the Hutchinson HVP rides along (EAHES); with
     SGD/Momentum this is EASGD/EAMSGD.
  2. **communication phase** — workers sync with the master: update the
     u-history from the estimated master distance, compute the raw score,
     map through h1/h2 (or fixed α / oracle), and apply the elastic
     exchange — unless this worker's communication is suppressed by the
     failure schedule this round. ``ecfg.comm_mode`` picks the backend:
     ``"sequential"`` scans workers one by one (event-ordered asynchrony,
     matching the paper's single-device simulation); ``"fused"`` batches
     all k syncs into one vmapped scoring pass plus one multi-worker
     elastic update (Pallas kernel on TPU), with event-order-equivalent
     master weights so the two masters agree whenever per-worker h2 do.

The same object serves the paper-scale CPU simulation (k∈{4,8}, CNN) and the
production multi-pod path (worker axis sharded over the 'pod' mesh axis; see
repro/launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ElasticConfig, OptimizerConfig
from repro.core import dynamic_weight as dw
from repro.core.elastic import elastic_update, elastic_update_batched
from repro.optim.base import apply_updates, make_optimizer
from repro.optim.hutchinson import hessian_diag


def tree_stack_copies(tree, k: int):
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (k,) + x.shape).copy(),
                        tree)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RoundInputs:
    """Everything one simulated round consumes, as a single pytree.

    Leaves are per-round (``round_step``) or carry a leading (R,) rounds
    axis (``round_chunk``, which scans over that axis). ``straggle`` and
    ``restart`` stay ``None`` when a scenario never fires them — ``None``
    is an empty subtree, so the jitted round specializes those branches
    away entirely (single trace, no mask traffic). Keep the None-ness
    consistent across calls to avoid retraces.

    - ``batches``: pytree with (τ, k, ...) leaves (or (R, τ, k, ...))
    - ``rng``: per-round PRNG key (or a stacked (R,) key array)
    - ``fail``: (k,) bool — communication suppressed this round
    - ``failed_recent``: (k,) bool — oracle feed, see
      ``ScenarioSchedule.failed_recent``
    - ``straggle``: optional (k,) bool — reduced-τ slow workers
    - ``restart``: optional (k,) bool — crash-rejoin resets
    """

    batches: Any
    rng: jax.Array
    fail: jax.Array
    failed_recent: jax.Array
    straggle: Optional[jax.Array] = None
    restart: Optional[jax.Array] = None


@dataclasses.dataclass(eq=False)  # hash by id → usable as a static jit arg
class ElasticTrainer:
    model: Any
    opt_cfg: OptimizerConfig
    ecfg: ElasticConfig
    use_pallas: bool = False

    def __post_init__(self):
        self.opt = make_optimizer(self.opt_cfg)

    # -- state ----------------------------------------------------------------
    def init_state(self, rng: jax.Array, params=None):
        from repro.nn.param import init_tree

        k = self.ecfg.num_workers
        if params is None:
            params = init_tree(rng, self.model.spec)
        master = jax.tree.map(lambda x: x.astype(jnp.float32), params)
        worker_params = tree_stack_copies(params, k)
        worker_opt = jax.vmap(self.opt.init)(worker_params)
        return {
            "workers": worker_params,
            "opt": worker_opt,
            "master": master,
            # previous-round master snapshot: the stale estimate straggling
            # workers score against (scenario engine, repro/core/scenarios.py)
            "master_prev": master,
            "u_hist": jnp.full((k, self.ecfg.score_window), -30.0,
                               jnp.float32),
            "round": jnp.zeros((), jnp.int32),
        }

    # -- failure-scenario state transitions --------------------------------------
    def apply_restarts(self, state, restart):
        """Crash-restart rejoin (scenario ``crash_restart``): workers with
        ``restart[i]`` True have their params reset to the master. The
        u-history is deliberately kept — the recorded pre-crash drift makes
        the next score see the distance collapse, driving the recovery path
        h1→1 / h2→0 (§V-B).

        Optimizer accumulators are restored rather than re-initialized
        (restore-from-checkpoint semantics): a cold AdaHessian state takes
        violently large first steps from the master position, and the h2 map
        gives runaway workers the full α for any positive score, so a fresh
        init lets a single rejoin corrupt the master.
        """

        def sel(new, old):
            r = restart.reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(r, new, old)

        workers = jax.tree.map(
            lambda w, m: sel(jnp.broadcast_to(m.astype(w.dtype), w.shape), w),
            state["workers"], state["master"])
        return dict(state, workers=workers)

    # -- local phase ------------------------------------------------------------
    def _one_step(self, params, opt_state, batch, rng):
        loss_fn = lambda p: self.model.loss(p, batch)[0]
        loss, grads = jax.value_and_grad(loss_fn)(params)
        extras = None
        if self.opt.needs_hessian:
            extras = {
                "hess_diag": hessian_diag(
                    jax.grad(loss_fn), params, rng,
                    self.opt_cfg.hutchinson_samples)
            }
        updates, opt_state = self.opt.update(grads, opt_state, params, extras)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    def local_phase(self, state, batches, rng, straggle=None):
        """batches: pytree with leading (τ, k, ...) axes.

        ``straggle``: optional (k,) bool — straggling workers are slow, not
        dead: they complete only the first
        ``max(1, round(straggler_tau_scale·τ))`` local steps; params and
        optimizer state freeze for the rest of the phase.
        """
        k = self.ecfg.num_workers
        tau = jax.tree.leaves(batches)[0].shape[0]
        tau_eff = max(1, round(self.ecfg.straggler_tau_scale * tau))

        def tau_step(carry, inp):
            params, opt_state = carry
            batch_t, rng_t, t = inp
            rngs = jax.random.split(rng_t, k)
            new_p, new_o, loss = jax.vmap(self._one_step)(
                params, opt_state, batch_t, rngs)
            if straggle is not None:
                # frozen steps contribute neither updates nor loss metrics
                active = jnp.logical_or(~straggle, t < tau_eff)
                sel = lambda n, o: jnp.where(
                    active.reshape((-1,) + (1,) * (n.ndim - 1)), n, o)
                new_p = jax.tree.map(sel, new_p, params)
                new_o = jax.tree.map(sel, new_o, opt_state)
                loss = jnp.where(active, loss, 0.0)
                n_active = jnp.sum(active)
            else:
                n_active = jnp.asarray(k)
            return (new_p, new_o), (jnp.sum(loss), n_active)

        rngs = jax.random.split(rng, tau)
        (workers, opt_state), (losses, counts) = jax.lax.scan(
            tau_step, (state["workers"], state["opt"]),
            (batches, rngs, jnp.arange(tau)))
        mean_loss = jnp.sum(losses) / jnp.maximum(jnp.sum(counts), 1)
        return dict(state, workers=workers, opt=opt_state), mean_loss

    # -- communication phase -----------------------------------------------------
    def comm_phase(self, state, fail_mask, failed_recent=None, straggle=None):
        """fail_mask: (k,) bool — True suppresses this worker's sync.

        ``straggle``: optional (k,) bool — straggling workers score against
        the *previous* round's master snapshot (their estimate of the master
        is stale; the elastic exchange itself still uses the live master,
        which the parameter server holds).

        Dispatches on ``ecfg.comm_mode``: "sequential" is the paper's
        event-ordered scan; "fused" batches all k syncs into one scoring
        pass plus one multi-worker elastic update.
        """
        ecfg = self.ecfg
        if failed_recent is None:
            failed_recent = jnp.zeros_like(fail_mask)
        if ecfg.comm_mode == "fused":
            return self._comm_phase_fused(state, fail_mask, failed_recent,
                                          straggle)
        stale_master = state.get("master_prev", state["master"])
        straggle_in = (jnp.zeros_like(fail_mask) if straggle is None
                       else straggle)

        def sync_one(master, xs):
            w_i, hist_i, fail_i, fr_i, st_i = xs
            # u from the estimated master (other-worker estimate ≈ current
            # master in the event-ordered simulation)
            u_t = dw.log_distance(w_i, master)
            if straggle is not None:
                u_t = jnp.where(st_i, dw.log_distance(w_i, stale_master),
                                u_t)
            hist_new = dw.push_history(hist_i, u_t)
            a = dw.raw_score(hist_new, ecfg.score_weights)
            w1, w2 = dw.weights_for(ecfg, a, failed_recently=fr_i)
            # suppressed communication: no elastic exchange at all
            w1 = jnp.where(fail_i, 0.0, w1)
            w2 = jnp.where(fail_i, 0.0, w2)
            if self.use_pallas:
                from repro.kernels.elastic.ops import elastic_update_pallas

                new_w, new_master = elastic_update_pallas(
                    w_i, master, w1, w2,
                    interpret=jax.default_backend() != "tpu")
            else:
                new_w, new_master = elastic_update(w_i, master, w1, w2)
            return new_master, (new_w, hist_new, (u_t, a, w1, w2))

        master, (workers, hist, diag) = jax.lax.scan(
            sync_one, state["master"],
            (state["workers"], state["u_hist"], fail_mask, failed_recent,
             straggle_in))
        u, a, w1, w2 = diag
        metrics = {"u": u, "score": a, "h1": w1, "h2": w2}
        return dict(state, workers=workers, master=master,
                    master_prev=state["master"], u_hist=hist,
                    round=state["round"] + 1), metrics

    def _comm_phase_fused(self, state, fail_mask, failed_recent,
                          straggle=None):
        """Batched communication: one vmapped scoring pass over all k
        workers, then a single multi-worker elastic update.

        Workers sync against the round-start master (delayed averaging);
        the master reduction uses the event-order-equivalent weights
        g_i = h2_i·Π_{j>i}(1−h2_j), so the resulting master matches the
        sequential scan exactly whenever the per-worker h2 agree (e.g. the
        fixed-α and oracle modes). Scores are computed against the same
        round-start master, which drops the scan's serial dependency.
        """
        ecfg = self.ecfg
        master = state["master"]
        u, hist, a, w1, w2 = dw.comm_scores_batched(
            ecfg, state["workers"], master, state["u_hist"],
            failed_recently=failed_recent,
            stale_master=(None if straggle is None
                          else state.get("master_prev", master)),
            straggle=straggle)
        # suppressed communication: no elastic exchange at all
        w1 = jnp.where(fail_mask, 0.0, w1)
        w2 = jnp.where(fail_mask, 0.0, w2)
        g2 = dw.master_schedule_weights(w2)
        if self.use_pallas:
            from repro.kernels.elastic.ops import elastic_update_batched_pallas

            workers, master = elastic_update_batched_pallas(
                state["workers"], master, w1, g2,
                interpret=jax.default_backend() != "tpu")
        else:
            workers, master = elastic_update_batched(
                state["workers"], master, w1, g2)
        metrics = {"u": u, "score": a, "h1": w1, "h2": w2}
        return dict(state, workers=workers, master=master,
                    master_prev=state["master"], u_hist=hist,
                    round=state["round"] + 1), metrics

    # -- full round ---------------------------------------------------------------
    def _round(self, state, inputs: RoundInputs):
        """One simulated round under a failure scenario: optional crash
        rejoins, the local phase (with per-worker straggler slowdown), then
        the communication phase under the fail mask."""
        if inputs.restart is not None:
            state = self.apply_restarts(state, inputs.restart)
        state, loss = self.local_phase(state, inputs.batches, inputs.rng,
                                       inputs.straggle)
        state, metrics = self.comm_phase(state, inputs.fail,
                                         inputs.failed_recent,
                                         inputs.straggle)
        metrics["loss"] = loss
        return state, metrics

    @functools.partial(jax.jit, static_argnums=0)
    def round_step(self, state, inputs: RoundInputs):
        """One round per jit call; ``inputs`` leaves are per-round."""
        return self._round(state, inputs)

    @functools.partial(jax.jit, static_argnums=0)
    def round_chunk(self, state, inputs: RoundInputs):
        """R rounds in one jit call: every ``inputs`` leaf carries a leading
        (R,) axis and ``lax.scan`` threads the state through the rounds, so
        the Python/dispatch cost of a round is paid once per chunk. The
        scanned body is exactly ``round_step``'s, so a chunked run is
        bit-identical to R separate ``round_step`` calls; metrics come back
        stacked with a leading (R,) axis."""
        return jax.lax.scan(self._round, state, inputs)

    # -- eval ----------------------------------------------------------------------
    @functools.partial(jax.jit, static_argnums=0)
    def master_accuracy(self, state, batch):
        params = state["master"]
        return self.model.accuracy(params, batch)

    @functools.partial(jax.jit, static_argnums=0)
    def master_loss(self, state, batch):
        params = state["master"]
        loss, _ = self.model.loss(params, batch)
        return loss
