"""Unified run API for the paper's system (ISSUE-3; sharded placement
ISSUE-4).

One import surface for every driver — CLI, experiments, examples, tests,
benchmarks:

    from repro.api import ElasticSession, RoundRecord, RunSpec

    spec = RunSpec(arch="paper-cnn", rounds=20, rounds_per_call=4)
    for rec in ElasticSession(spec).run_iter():
        print(rec.round, rec.loss, rec.h2)

Exports (see docs/paper_map.md for the full paper→code table):

- :class:`RunSpec` — frozen, validated description of a run: architecture,
  optimizer, elastic/failure config, data source, scenario, seed, eval
  cadence, checkpoint path. Infrastructure, no paper analogue — it *names*
  the paper's experimental knobs (§VI: k, τ, α, overlap ratio r, failure
  probability) but the dataclass itself is driver plumbing.
- :class:`ElasticSession` — the paper's training loop (§V algorithm 1's
  outer rounds): owns trainer state, failure schedule, worker batcher and
  eval, yields structured records. ``rounds_per_call > 1`` executes whole
  chunks of rounds inside one jit (``ElasticTrainer.round_chunk``)
  bit-identically to per-round execution;
  ``ElasticConfig.placement="sharded"`` places the worker axis over the
  mesh's 'pod' axis (beyond-paper scale path, master bit-exact with the
  single-device simulation).
- :class:`RoundRecord` — one communication round materialized on the host:
  the §V-B diagnostics (u = log-distance, raw score a, h1/h2 weights) plus
  the schedule row, host-measured round/dispatch wall time, and optional
  held-out master metrics (the §VI curves).
- :class:`ControlAction` / :class:`MembershipPolicy` /
  :class:`SessionObserver` — the closed-loop control surface (ISSUE-6,
  beyond-paper): typed membership edits executed by
  ``ElasticSession.apply``, the policy plug-in base mapping detector
  verdicts to actions, and the observer protocol controllers and user
  callbacks attach through (``RunSpec.controller`` / ``add_observer``).
"""
from repro.api.session import ElasticSession, RoundRecord, RunSpec
from repro.control.actions import ControlAction, SessionObserver
from repro.control.policy import MembershipPolicy

__all__ = ["ElasticSession", "RoundRecord", "RunSpec",
           "ControlAction", "MembershipPolicy", "SessionObserver"]
