"""Unified run API for the paper's system (ISSUE-3).

One import surface for every driver — CLI, experiments, examples, tests,
benchmarks:

    from repro.api import ElasticSession, RoundRecord, RunSpec

    spec = RunSpec(arch="paper-cnn", rounds=20, rounds_per_call=4)
    for rec in ElasticSession(spec).run_iter():
        print(rec.round, rec.loss, rec.h2)

:class:`RunSpec` captures everything a run needs (architecture, optimizer,
elastic/failure config, data source, scenario, seed, eval cadence,
checkpoint path); :class:`ElasticSession` owns the trainer state, failure
schedule, batcher and eval, and yields structured :class:`RoundRecord`\\ s.
``rounds_per_call > 1`` executes whole chunks of rounds inside one jit
(``ElasticTrainer.round_chunk``) bit-identically to per-round execution.
"""
from repro.api.session import ElasticSession, RoundRecord, RunSpec

__all__ = ["ElasticSession", "RoundRecord", "RunSpec"]
