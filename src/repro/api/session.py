"""`RunSpec` → `ElasticSession`: the one driver for the paper's system.

Before ISSUE-3 the repo carried five hand-rolled copies of the same loop
(train CLI, paper_repro, grid, both examples), each re-deriving batchers,
failure schedules, mask conversion and the 7-positional-argument round call,
with semantics drifting between copies. This module replaces all of them:

- :class:`RunSpec` is a frozen, validated description of a run —
  architecture (or explicit :class:`ModelConfig`), optimizer, elastic /
  failure configuration, synthetic-data sizes, seeds, eval cadence,
  checkpoint path, and ``rounds_per_call``.
- :class:`ElasticSession` owns the mutable half: trainer state, the
  precomputed :class:`ScenarioSchedule`, the worker batcher, and the eval
  batch. ``run()`` / ``run_iter()`` yield one :class:`RoundRecord` per
  simulated round.

Chunked execution (the speed headline): with ``rounds_per_call = R`` the
session stacks R rounds of batches, masks and PRNG keys into one
:class:`RoundInputs` whose leaves carry a leading (R,) axis and calls
``ElasticTrainer.round_chunk`` — a ``lax.scan`` over the identical round
body inside a single jit — so per-round Python/dispatch overhead (the
DaSGD-style driver tax) is paid once per chunk. Chunked and per-round
execution are bit-identical (``tests/test_session.py`` asserts master-param
equality); chunk boundaries are snapped to eval rounds so the eval cadence
never changes results. Scenarios that never straggle/restart keep those
inputs ``None``, preserving the specialized single-trace fast path.

Sharded placement (``ElasticConfig.placement = "sharded"``): the session
builds (or accepts) a mesh whose ``'pod'`` axis hosts the worker shards,
device_puts the trainer state into its sharded-resident layout once at
init, and drives ``round_step_sharded`` / ``round_chunk_sharded`` instead —
the k workers' local+comm phases run on disjoint mesh shards with one
master reduction per round, bit-exact with single-device fused mode
(``tests/test_placement.py``). Records, eval and checkpointing are
placement-agnostic: the master is replicated, so everything host-side reads
identically.

Elastic membership (ISSUE-5): with ``ElasticConfig.capacity > num_workers``
(or any non-static ``membership_scenario``) the worker axis is
capacity-padded and a per-round active mask rides through ``RoundInputs``.
The session owns the membership lifecycle: it snaps chunk boundaries to
membership-transition rounds (so the host can re-partition the data over
the new pool — the shared overlap O is k-independent and stays put), feeds
join masks so the coordinator re-seats joining slots from the master, and
echoes the live mask in every :class:`RoundRecord`. ``resize()`` /
``set_membership()`` change the pool live between ``run`` calls, and
``restore()`` warm-starts a session — possibly at a *different* capacity —
from a checkpoint's master, re-seating the saved live slots' u-histories
and cold-starting any extra joiners from the master, EASGD-style.

Closed-loop control (ISSUE-6): live control is now a typed, single-entry
surface — ``apply(ControlAction)`` executes one membership edit (the old
``resize()``/``set_membership()`` delegate to it and emit
``DeprecationWarning``). Observers (:class:`SessionObserver`) attach via
``add_observer`` or ``RunSpec.controller``; they see every
:class:`RoundRecord` (``on_round``) and get a mutation window between jit
chunks (``on_chunk_end``), which is where the rule controller
(``repro.control``) closes the detect→decide→act loop.
``RunSpec(detector_blind=True)`` echoes a mask-zeroed schedule view into
the records so a controller provably runs on observable telemetry only;
each record also carries host-measured ``round_ms``/``dispatch_ms``, the
step-time outlier signal.
"""
from __future__ import annotations

import dataclasses
import os
import time
import warnings
from typing import Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint
from repro.control.actions import ControlAction, SessionObserver
from repro.configs.base import (ElasticConfig, ModelConfig, OptimizerConfig,
                                get_config)
from repro.core.coordinator import ElasticTrainer, RoundInputs
from repro.core.scenarios import (ScenarioSchedule, make_membership,
                                  make_scenario)
from repro.data.pipeline import TokenWorkerBatcher, WorkerBatcher
from repro.data.synthetic import SyntheticImages, SyntheticTokens
from repro.models.registry import build_model
from repro.train.steps import init_train_state, make_train_step


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """Everything a run needs, validated at construction.

    ``arch``/``smoke`` name a registered config; ``model_cfg`` (when given)
    overrides both. ``plain=True`` is the single-worker control (the k=1
    limit with no elastic sync, no failures): one "round" is one optimizer
    step. The synthetic data source follows the model family — images +
    :class:`WorkerBatcher` for ``cnn``, token stream +
    :class:`TokenWorkerBatcher` otherwise. ``data_seed`` seeds dataset
    *generation* (keep it fixed across methods to compare on identical
    data, as paper §VI does); ``seed`` seeds init, batching and the
    per-round PRNG; the failure schedule draws from ``scenario_seed``
    (default ``seed + 7``, the historical convention). ``schedule``
    injects a hand-crafted :class:`ScenarioSchedule` instead of the
    scenario engine (e.g. the failure demo's deterministic outage).
    """

    arch: str = "paper-cnn"
    smoke: bool = False
    model_cfg: Optional[ModelConfig] = None
    optimizer: OptimizerConfig = dataclasses.field(
        default_factory=OptimizerConfig)
    elastic: ElasticConfig = dataclasses.field(default_factory=ElasticConfig)
    rounds: int = 20
    rounds_per_call: int = 1
    seed: int = 0
    scenario_seed: Optional[int] = None
    schedule: Optional[ScenarioSchedule] = None
    plain: bool = False
    # synthetic data source (family-dependent)
    batch_size: int = 32
    seq_len: int = 128
    n_data: int = 8000
    n_test: int = 1000
    n_tokens: int = 100_000
    data_seed: int = 0
    # eval / io
    eval_every: int = 0  # 0 = never; >0 = every e rounds + the final round
    save_path: Optional[str] = None
    use_pallas: bool = False
    # closed-loop control (ISSUE-6)
    controller: Optional[str] = None  # None = open loop; "rules" = RuleController
    detector_blind: bool = False  # echo mask-zeroed schedule into records

    def __post_init__(self):
        for name in ("rounds", "rounds_per_call", "batch_size", "seq_len",
                     "n_data", "n_test", "n_tokens"):
            v = getattr(self, name)
            if v < 1:
                raise ValueError(f"RunSpec.{name} must be >= 1, got {v}")
        if self.eval_every < 0:
            raise ValueError(
                f"RunSpec.eval_every must be >= 0, got {self.eval_every}")
        if self.schedule is not None:
            if self.plain:
                raise ValueError(
                    "RunSpec: plain mode has no failure schedule")
            want = (self.rounds, self.elastic.cap)
            if self.schedule.fail.shape != want:
                raise ValueError(
                    f"RunSpec.schedule shape {self.schedule.fail.shape} != "
                    f"(rounds, capacity) = {want}")
        if self.controller is not None:
            if self.controller != "rules":
                raise ValueError(
                    f"RunSpec.controller must be None or 'rules', got "
                    f"{self.controller!r}")
            if self.plain:
                raise ValueError(
                    "RunSpec: plain mode has no worker pool to control")
        if self.detector_blind and self.elastic.oracle:
            raise ValueError(
                "RunSpec: detector_blind contradicts ElasticConfig.oracle — "
                "the oracle weighting itself reads the ground-truth masks")

    def replace(self, **kw) -> "RunSpec":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class RoundRecord:
    """One communication round, materialized on the host.

    ``u``/``score``/``h1``/``h2`` are the (cap,) dynamic-weighting
    diagnostics (zeros in plain mode and for vacant slots);
    ``fail``/``straggle``/``restart`` echo the schedule row that drove the
    round and ``active`` the live-membership mask (all-True for fixed-k
    runs); under ``RunSpec.detector_blind`` the echoed event masks are
    all-False (the truth still drives the run — see
    ``ScenarioSchedule.blind``). ``eval_loss``/``eval_acc`` are the
    master's held-out metrics, populated only on eval rounds (``eval_acc``
    only for model families that define ``accuracy``). ``loss_w`` is the
    (cap,) per-slot mean local-phase loss (``None`` in plain mode);
    ``round_ms`` is host wall time attributed to this round (its chunk's
    wall time / rounds in the chunk) and ``dispatch_ms`` the chunk's
    dispatch latency (jit-call return before materialization) — both are
    chunk-grained, repeated on each record of the chunk.
    """

    round: int
    loss: float
    u: np.ndarray
    score: np.ndarray
    h1: np.ndarray
    h2: np.ndarray
    fail: np.ndarray
    straggle: np.ndarray
    restart: np.ndarray
    eval_loss: Optional[float] = None
    eval_acc: Optional[float] = None
    active: Optional[np.ndarray] = None
    loss_w: Optional[np.ndarray] = None
    round_ms: float = 0.0
    dispatch_ms: float = 0.0
    # (cap,) bool — byzantine slots this round (ISSUE-9), echoed from the
    # schedule like fail/straggle/restart (all-False under detector_blind
    # or when the scenario has no corruption channel). Trails the field
    # list with a default so older positional constructions keep working.
    corrupt: Optional[np.ndarray] = None
    # (groups,) rack-level diagnostics (ISSUE-10): sub-master distance /
    # score / h1 / h2 against the global master. ``None`` on flat runs;
    # all-zero on hierarchical rounds that skip the global sync (the
    # two-period cadence — a round's g_h2 is nonzero only every
    # ``global_period`` rounds).
    g_u: Optional[np.ndarray] = None
    g_score: Optional[np.ndarray] = None
    g_h1: Optional[np.ndarray] = None
    g_h2: Optional[np.ndarray] = None

    @property
    def num_active(self) -> int:
        return int(self.active.sum()) if self.active is not None else 0


class ElasticSession:
    """Stateful driver for one run: trainer state + schedule + batcher + eval.

    ``run_iter()`` yields :class:`RoundRecord` s as rounds complete;
    ``run()`` collects them. Execution advances in chunks of up to
    ``spec.rounds_per_call`` rounds per jit call (``round_chunk``); chunk
    boundaries are shortened to land exactly on eval rounds, so the eval
    cadence is independent of the chunking. When the full ``spec.rounds``
    have run and ``spec.save_path`` is set, the master checkpoint is saved
    automatically with ``{"rounds", "arch", "scenario"}`` metadata.

    Under ``spec.elastic.placement == "sharded"`` the session drives the
    shard_mapped round fns; ``mesh`` overrides the default
    ``make_host_mesh(pod=jax.device_count())`` (it needs a 'pod' axis whose
    size divides ``num_workers``). The trainer state lives device-resident
    in its sharded layout from init: worker-axis entries split over 'pod',
    master replicated, with the donated round fns updating it in place.
    """

    def __init__(self, spec: RunSpec, mesh=None):
        self.spec = spec
        cfg = spec.model_cfg or get_config(spec.arch, smoke=spec.smoke)
        if cfg.use_pallas != spec.use_pallas:
            # RunSpec.use_pallas is the single source of truth (ISSUE-7):
            # the flag also exists on ModelConfig (it gates model-internal
            # kernels like flash attention), and a preset/model_cfg that
            # disagrees with the spec would silently split the run into
            # half-kernel/half-jnp execution. Coerce the model config so
            # one flag drives every kernel path.
            cfg = dataclasses.replace(cfg, use_pallas=spec.use_pallas)
        self.model_cfg = cfg
        self.model = build_model(cfg)
        ecfg = spec.elastic
        if spec.plain:
            # the k=1 limit has no worker axis to place (and no pool)
            ecfg = dataclasses.replace(ecfg, num_workers=1, capacity=0,
                                       tau=1, overlap_ratio=0.0,
                                       failure_prob=0.0, placement="single",
                                       membership_scenario="static",
                                       groups=1, global_period=1)
        self.ecfg = ecfg
        self.capacity = ecfg.cap
        self._sharded = ecfg.placement == "sharded"
        if not self._sharded and mesh is not None:
            raise ValueError(
                "ElasticSession: a mesh was passed but "
                f"placement={ecfg.placement!r} would ignore it — set "
                "ElasticConfig(placement='sharded', comm_mode='fused') to "
                "place the worker axis on it")
        if self._sharded and mesh is None:
            # default mesh: every visible device becomes one worker shard
            from repro.launch.mesh import make_host_mesh

            mesh = make_host_mesh(pod=jax.device_count())
        self.mesh = mesh
        self.trainer = ElasticTrainer(self.model, spec.optimizer, ecfg,
                                      use_pallas=spec.use_pallas,
                                      mesh=self.mesh)
        # -- data -----------------------------------------------------------
        if cfg.family == "cnn":
            ds = SyntheticImages(n=spec.n_data, n_test=spec.n_test,
                                 seed=spec.data_seed)
            self.batcher = WorkerBatcher(ds.images, ds.labels, ecfg,
                                         batch_size=spec.batch_size,
                                         seed=spec.seed)
            self._test = {k: jnp.asarray(v) for k, v in
                          ds.test_batch().items()}
        else:
            toks = SyntheticTokens(vocab=cfg.vocab_size,
                                   n_tokens=spec.n_tokens,
                                   seed=spec.data_seed)
            self.batcher = TokenWorkerBatcher(toks.tokens, ecfg,
                                              batch_size=spec.batch_size,
                                              seq_len=spec.seq_len,
                                              seed=spec.seed)
            # held-out eval batch from the same stream, disjoint rng
            self._test = {k: jnp.asarray(v) for k, v in toks.batch(
                np.random.default_rng(spec.seed + 31), spec.batch_size,
                spec.seq_len).items()}
        # -- schedule -------------------------------------------------------
        self._active = np.arange(self.capacity) < ecfg.num_workers
        if spec.plain:
            self.schedule = None
            self._failed_recent = None
            self._membership = None
            self._join_rows = None
        else:
            if spec.schedule is not None:
                self.schedule = spec.schedule
            else:
                sseed = (spec.scenario_seed if spec.scenario_seed is not None
                         else spec.seed + 7)
                self.schedule = make_scenario(ecfg).schedule(
                    sseed, spec.rounds, self.capacity)
            if self.schedule.active is None and (
                    self.capacity > ecfg.num_workers
                    or ecfg.membership_scenario != "static"):
                # membership stream: planned resize events at capacity
                self.schedule = self.schedule.with_membership(
                    make_membership(ecfg).active_schedule(
                        spec.rounds, self.capacity, ecfg.num_workers))
            self._failed_recent = self.schedule.failed_recent_all()
            self._refresh_membership()
        # -- observers / controller (ISSUE-6) -------------------------------
        # detector-blind runs echo a mask-zeroed schedule view into records;
        # the real schedule still drives RoundInputs
        self._echo = (self.schedule.blind()
                      if (not spec.plain and spec.detector_blind)
                      else self.schedule)
        self._observers: List[SessionObserver] = []
        self.controller = None
        if spec.controller is not None:
            from repro.control.actuator import make_controller

            self.controller = make_controller(spec.controller, self.capacity)
            self.add_observer(self.controller)
        # -- state ----------------------------------------------------------
        if spec.plain:
            self.state = init_train_state(self.model, spec.optimizer,
                                          jax.random.key(spec.seed))
            step = make_train_step(self.model, spec.optimizer)
            self._plain_chunk = jax.jit(
                lambda st, xs: jax.lax.scan(
                    lambda s, x: step(s, x[0], x[1]), st, xs))
        else:
            self.state = self.trainer.init_state(jax.random.key(spec.seed))
            if self._sharded:
                self.state = self._place_state(self.state)
        if not spec.plain and self.schedule.has_membership:
            # seat round 0's membership (a custom schedule or a plan step
            # at round 0 may start with a different pool than num_workers)
            self._apply_membership(self.schedule.active[0])
        self._rng_base = jax.random.key(spec.seed)
        self._eval_loss = jax.jit(lambda p, b: self.model.loss(p, b)[0])
        self._eval_acc = (jax.jit(self.model.accuracy)
                          if hasattr(self.model, "accuracy") else None)
        self.round = 0  # rounds completed so far

    # -- sharded placement ---------------------------------------------------
    def _place_state(self, state):
        """Device_put the trainer state into its sharded-resident layout,
        per entry as declared by ``ElasticTrainer.state_shard_specs`` (the
        same specs shard_map runs under, so there is no per-call
        resharding). Done once at init; the donated sharded round fns then
        keep the state resident in this layout for the whole run."""
        from jax.sharding import NamedSharding

        specs = self.trainer.state_shard_specs()
        return {key: jax.tree.map(
                    lambda x, s=specs[key]: jax.device_put(
                        x, NamedSharding(self.mesh, s)), sub)
                for key, sub in state.items()}

    # -- membership ----------------------------------------------------------
    def _refresh_membership(self):
        """Re-derive the per-round membership/join input rows from the
        schedule. Join rows stay ``None`` when no slot ever flips
        inactive→active, preserving the specialized no-join trace."""
        self._membership = self.schedule.active
        joins = self.schedule.joins()
        self._join_rows = joins if joins.any() else None

    def _apply_membership(self, row: np.ndarray):
        """Host-side membership transition: remember the live mask and
        re-partition the data over the new pool (O stays put; only the
        unique shards are redealt)."""
        if np.array_equal(row, self._active):
            return
        self._active = row.copy()
        self.batcher.set_active_mask(row)

    @property
    def active_mask(self) -> np.ndarray:
        """(cap,) bool — the live-membership mask as of the next round."""
        return self._active.copy()

    @property
    def num_active(self) -> int:
        return int(self._active.sum())

    def _set_membership(self, mask: np.ndarray) -> None:
        """Live membership change between chunks: the given (cap,) bool
        mask becomes the pool for every remaining round (overriding the
        scheduled stream from here on). Newly activated slots join at the
        next round, cold-started from the master. With a fixed-k spec (no
        membership stream) the first call materializes one, which retraces
        the jitted round once — capacity-padded specs
        (``capacity > num_workers`` or a membership scenario) pay nothing.
        """
        if self.spec.plain:
            raise ValueError("plain mode has no worker pool to resize")
        mask = np.asarray(mask, bool)
        if mask.shape != (self.capacity,):
            raise ValueError(
                f"membership mask shape {mask.shape} != ({self.capacity},)")
        if not mask.any():
            raise ValueError("at least one worker must stay active")
        if self.round >= self.spec.rounds:
            raise ValueError("run already complete; nothing left to resize")
        rows = self.schedule.active
        if rows is None:
            rows = np.arange(self.capacity)[None] < self.ecfg.num_workers
            rows = np.repeat(rows, self.spec.rounds, axis=0)
            rows[:self.round] = self._active  # frozen history
        rows = rows.copy()
        rows[self.round:] = mask
        self.schedule = self.schedule.with_membership(rows)
        self._refresh_membership()
        self._apply_membership(mask)

    def _resize(self, k: int) -> None:
        """Pool resize to ``k``: growing activates the lowest-numbered
        vacant slots (joiners, cold-started from the master); shrinking
        retires the highest-numbered live slots."""
        if self.spec.plain:
            raise ValueError("plain mode has no worker pool to resize")
        if not 1 <= k <= self.capacity:
            raise ValueError(
                f"resize target {k} outside 1..capacity={self.capacity}")
        mask = self._active.copy()
        live = np.flatnonzero(mask)
        if k > len(live):
            vacant = np.flatnonzero(~mask)
            mask[vacant[:k - len(live)]] = True
        elif k < len(live):
            mask[live[k:]] = False
        self._set_membership(mask)

    def apply(self, action: ControlAction) -> None:
        """The single live-control entrypoint (ISSUE-6): execute one
        :class:`ControlAction` against the pool. Legal between ``run``
        calls and inside ``on_chunk_end`` observer hooks (membership is
        baked into each jit chunk, so mid-chunk edits are impossible by
        construction). ``evict`` requires its slots live, ``readmit``
        requires them vacant — slot state is part of the action's meaning,
        so a stale action errors instead of silently half-applying (the
        controller's :class:`~repro.control.actuator.Actuator` journals and
        re-scopes stale actions before calling this).
        """
        if not isinstance(action, ControlAction):
            raise TypeError(
                f"ElasticSession.apply expects a ControlAction, got "
                f"{type(action).__name__}")
        if action.kind == "noop":
            return
        if action.kind == "resize":
            self._resize(action.k)
            return
        if action.kind == "set_membership":
            self._set_membership(action.mask)
            return
        if self.spec.plain:
            raise ValueError("plain mode has no worker pool to resize")
        bad = [s for s in action.slots if not 0 <= s < self.capacity]
        if bad:
            raise ValueError(
                f"{action.kind} slots {bad} outside 0..{self.capacity - 1}")
        mask = self._active.copy()
        if action.kind == "evict":
            dead = [s for s in action.slots if not mask[s]]
            if dead:
                raise ValueError(f"cannot evict vacant slots {dead}")
            mask[list(action.slots)] = False
        else:  # readmit
            live = [s for s in action.slots if mask[s]]
            if live:
                raise ValueError(f"cannot readmit live slots {live}")
            mask[list(action.slots)] = True
        self._set_membership(mask)

    def set_membership(self, mask) -> None:
        """Deprecated: use ``apply(ControlAction.set_membership(mask))``."""
        warnings.warn(
            "ElasticSession.set_membership() is deprecated; use "
            "apply(ControlAction.set_membership(mask))",
            DeprecationWarning, stacklevel=2)
        self._set_membership(mask)

    def resize(self, k: int) -> None:
        """Deprecated: use ``apply(ControlAction.resize(k))``."""
        warnings.warn(
            "ElasticSession.resize() is deprecated; use "
            "apply(ControlAction.resize(k))",
            DeprecationWarning, stacklevel=2)
        self._resize(k)

    # -- observers -----------------------------------------------------------
    def add_observer(self, observer: SessionObserver) -> None:
        """Attach an observer: ``on_round(record)`` fires for every
        completed round, ``on_chunk_end(session)`` between jit chunks (the
        mutation window — the only place ``apply`` is called by a
        controller). Both hooks are optional; missing ones are skipped."""
        self._observers.append(observer)

    # -- eval ---------------------------------------------------------------
    @property
    def master_params(self):
        """The authoritative parameters: the elastic master, or the single
        worker's params in plain mode."""
        return (self.state["params"] if self.spec.plain
                else self.state["master"])

    def evaluate(self):
        """(held-out loss, accuracy-or-None) of the master params."""
        loss = float(self._eval_loss(self.master_params, self._test))
        acc = (float(self._eval_acc(self.master_params, self._test))
               if self._eval_acc is not None else None)
        return loss, acc

    def _is_eval_round(self, r: int) -> bool:
        e = self.spec.eval_every
        return e > 0 and (r % e == 0 or r == self.spec.rounds - 1)

    # -- checkpoint ---------------------------------------------------------
    def save(self, path: Optional[str] = None,
             extra_metadata: Optional[dict] = None) -> str:
        """Save the master params with unified metadata. Every session
        checkpoint — plain or elastic, any entrypoint — records at least
        ``{"rounds", "arch", "scenario"}``; elastic checkpoints add the
        per-slot membership manifest (capacity, active mask, u-history)
        that ``restore`` re-seats — possibly into a different capacity."""
        path = path or self.spec.save_path
        if not path:
            raise ValueError("no save path: pass one or set RunSpec.save_path")
        meta = {"rounds": self.round, "arch": self.model_cfg.name,
                "scenario": ("none" if self.spec.plain
                             else self.ecfg.failure_scenario)}
        hier = not self.spec.plain and getattr(self.trainer, "_hier", False)
        if not self.spec.plain:
            meta["elastic"] = checkpoint.elastic_manifest(
                self._active, np.asarray(self.state["u_hist"], np.float32),
                **({"groups": self.trainer._n_groups,
                    "global_period": self.ecfg.global_period,
                    "g_u_hist": np.asarray(self.state["g_u_hist"],
                                           np.float32)} if hier else {}))
        meta.update(extra_metadata or {})
        if hier:
            # sub-master params ride in a sibling sub-checkpoint, written
            # *before* the main manifest — the manifest-last completeness
            # ordering (read_fingerprint) then covers them too. The main
            # tree stays a bare master-params tree, so flat consumers
            # (serving hot-swap ``restore(like=master)``) read
            # hierarchical checkpoints unchanged.
            checkpoint.save(os.path.join(path, "submasters"),
                            self.state["submasters"])
        checkpoint.save(path, self.master_params, metadata=meta)
        return path

    def restore(self, path: str) -> dict:
        """Warm-start this session from a saved checkpoint; returns its
        metadata. The master is restored exactly; every worker slot is
        cold-started *from the master* (EASGD-style — per-worker params are
        not checkpointed, and a restore is a pool-wide rejoin) with fresh
        optimizer accumulators. The checkpoint's live slots are re-seated
        into this session's active slots in order, carrying their
        u-histories across even when the two capacities differ; any extra
        active slots here are joiners with blank histories. Raises on an
        architecture mismatch between the manifest and this session's spec.
        """
        from repro.nn.param import abstract_tree

        arch = checkpoint.read_metadata(path).get("arch")
        if arch is not None and arch != self.model_cfg.name:
            raise ValueError(
                f"checkpoint {path!r} was saved from arch {arch!r}, this "
                f"session runs {self.model_cfg.name!r}")
        if self.spec.plain:
            tree, meta = checkpoint.restore(path, like=self.state["params"])
            self.state = dict(self.state, params=tree)
            return meta
        # the master lives (and was saved) in float32 — restore it at f32 so
        # it comes back bit-exact even when the model's param dtype is
        # narrower (bf16 transformers); workers re-seat at param dtype, as
        # a fresh run's workers would be
        spec_tree = abstract_tree(self.model.spec)
        like32 = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), spec_tree)
        master, meta = checkpoint.restore(path, like=like32)
        params = jax.tree.map(lambda m, s: m.astype(s.dtype), master,
                              spec_tree)
        u_hist = checkpoint.reseat_u_hist(
            meta.get("elastic"), self.capacity, self._active,
            self.ecfg.score_window)
        state = self.trainer.init_state(jax.random.key(self.spec.seed),
                                        params=params)
        state["master"] = master
        state["master_prev"] = jax.tree.map(jnp.copy, master)
        state["u_hist"] = jnp.asarray(u_hist)
        if getattr(self.trainer, "_hier", False):
            # hierarchical warm start (ISSUE-10), possibly at a different
            # group count: saved racks carry their sub-masters/histories
            # across in order, extra racks cold-start from the master; a
            # flat checkpoint seats every rack from the master
            sub_path = os.path.join(path, "submasters")
            saved = None
            if os.path.exists(os.path.join(sub_path, "manifest.json")):
                saved, _ = checkpoint.restore(sub_path)
            n_groups = self.trainer._n_groups
            state["submasters"] = checkpoint.reseat_submasters(
                saved, master, n_groups)
            state["g_u_hist"] = jnp.asarray(checkpoint.reseat_group_hist(
                (meta.get("elastic") or {}).get("g_u_hist"), n_groups,
                self.ecfg.score_window))
        self.state = self._place_state(state) if self._sharded else state
        return meta

    # -- execution ----------------------------------------------------------
    def _round_rng(self, r: int) -> jax.Array:
        return jax.random.fold_in(self._rng_base, r)

    def _next_chunk(self, end: int) -> int:
        """Rounds to run in the next jit call: at most ``rounds_per_call``,
        never past ``end``, never past the next eval round (evals read the
        master between chunks, so eval rounds must close a chunk), and
        never across a membership transition (the host re-partitions the
        data over the new pool between chunks, so a transition round must
        open a fresh chunk — this re-snap composes with the eval snapping,
        and the eval cadence itself never moves)."""
        n = min(self.spec.rounds_per_call, end - self.round)
        if self.spec.eval_every > 0:
            for r in range(self.round, self.round + n):
                if self._is_eval_round(r):
                    n = r - self.round + 1
                    break
        if self._membership is not None:
            row = self._membership[self.round]
            for r in range(self.round + 1, self.round + n):
                if not np.array_equal(self._membership[r], row):
                    n = r - self.round
                    break
        return n

    def _stack_batches(self, n: int):
        rounds = [self.batcher.round_batches() for _ in range(n)]
        return {key: np.stack([b[key] for b in rounds])
                for key in rounds[0]}

    def _run_chunk_elastic(self, n: int) -> List[RoundRecord]:
        lo, hi = self.round, self.round + n
        sched = self.schedule
        if self._membership is not None:
            # membership is chunk-constant (_next_chunk snaps transitions);
            # re-partition the data before building this chunk's batches
            self._apply_membership(self._membership[lo])
        stacked = self._stack_batches(n)
        rngs = [self._round_rng(r) for r in range(lo, hi)]
        # specialization on whole-schedule has_* keeps one trace per run
        # even when an individual chunk happens to be event-free
        straggle = sched.straggle[lo:hi] if sched.has_stragglers else None
        restart = sched.restart[lo:hi] if sched.has_restarts else None
        # adversarial channels (ISSUE-9) gate on has_* like the masks
        # above, so an all-False corrupt array / all-ones speed array never
        # reaches RoundInputs and the corruption-free trace is untouched
        corrupt = sched.corrupt[lo:hi] if sched.has_corruption else None
        speed = sched.speed[lo:hi] if sched.has_hetero else None
        active = (self._membership[lo:hi] if self._membership is not None
                  else None)
        join = self._join_rows[lo:hi] if self._join_rows is not None else None
        t0 = time.perf_counter()
        if n == 1:
            inputs = RoundInputs(
                batches={k: jnp.asarray(v[0]) for k, v in stacked.items()},
                rng=rngs[0],
                fail=jnp.asarray(sched.fail[lo]),
                failed_recent=jnp.asarray(self._failed_recent[lo]),
                straggle=None if straggle is None
                else jnp.asarray(straggle[0]),
                restart=None if restart is None else jnp.asarray(restart[0]),
                active=None if active is None else jnp.asarray(active[0]),
                join=None if join is None else jnp.asarray(join[0]),
                corrupt=None if corrupt is None else jnp.asarray(corrupt[0]),
                speed=None if speed is None else jnp.asarray(speed[0]))
            step = (self.trainer.round_step_sharded if self._sharded
                    else self.trainer.round_step)
            self.state, m = step(self.state, inputs)
            t1 = time.perf_counter()
            m = jax.tree.map(lambda x: np.asarray(x)[None], m)
        else:
            inputs = RoundInputs(
                batches={k: jnp.asarray(v) for k, v in stacked.items()},
                rng=jnp.stack(rngs),
                fail=jnp.asarray(sched.fail[lo:hi]),
                failed_recent=jnp.asarray(self._failed_recent[lo:hi]),
                straggle=None if straggle is None else jnp.asarray(straggle),
                restart=None if restart is None else jnp.asarray(restart),
                active=None if active is None else jnp.asarray(active),
                join=None if join is None else jnp.asarray(join),
                corrupt=None if corrupt is None else jnp.asarray(corrupt),
                speed=None if speed is None else jnp.asarray(speed))
            chunk = (self.trainer.round_chunk_sharded if self._sharded
                     else self.trainer.round_chunk)
            self.state, m = chunk(self.state, inputs)
            t1 = time.perf_counter()
            m = jax.tree.map(np.asarray, m)
        # materializing m above synced the chunk, so t2 - t0 is its wall
        # time; t1 - t0 is the async-dispatch latency (jit-call return)
        t2 = time.perf_counter()
        round_ms = (t2 - t0) * 1e3 / n
        dispatch_ms = (t1 - t0) * 1e3
        self.round = hi
        echo = self._echo
        no_corrupt = np.zeros(self.capacity, bool)
        records = []
        for i, r in enumerate(range(lo, hi)):
            ev_loss = ev_acc = None
            if r == hi - 1 and self._is_eval_round(r):
                ev_loss, ev_acc = self.evaluate()
            records.append(RoundRecord(
                round=r, loss=float(m["loss"][i]),
                u=m["u"][i], score=m["score"][i],
                h1=m["h1"][i], h2=m["h2"][i],
                fail=echo.fail[r], straggle=echo.straggle[r],
                restart=echo.restart[r],
                corrupt=(echo.corrupt[r] if echo.corrupt is not None
                         else no_corrupt),
                eval_loss=ev_loss, eval_acc=ev_acc,
                active=(self._membership[r] if self._membership is not None
                        else np.ones(self.capacity, bool)),
                loss_w=m["loss_w"][i],
                round_ms=round_ms, dispatch_ms=dispatch_ms,
                **({"g_u": m["g_u"][i], "g_score": m["g_score"][i],
                    "g_h1": m["g_h1"][i], "g_h2": m["g_h2"][i]}
                   if "g_u" in m else {})))
        return records

    def _run_chunk_plain(self, n: int) -> List[RoundRecord]:
        lo, hi = self.round, self.round + n
        stacked = self._stack_batches(n)
        # WorkerBatcher emits (τ=1, k=1, B, ...); drop the unit axes
        xs = ({k: jnp.asarray(v[:, 0, 0]) for k, v in stacked.items()},
              jnp.stack([self._round_rng(r) for r in range(lo, hi)]))
        t0 = time.perf_counter()
        self.state, m = self._plain_chunk(self.state, xs)
        t1 = time.perf_counter()
        loss = np.asarray(m["loss"])
        t2 = time.perf_counter()
        round_ms = (t2 - t0) * 1e3 / n
        dispatch_ms = (t1 - t0) * 1e3
        self.round = hi
        z = np.zeros(1, np.float32)
        zb = np.zeros(1, bool)
        records = []
        for i, r in enumerate(range(lo, hi)):
            ev_loss = ev_acc = None
            if r == hi - 1 and self._is_eval_round(r):
                ev_loss, ev_acc = self.evaluate()
            records.append(RoundRecord(
                round=r, loss=float(loss[i]), u=z, score=z, h1=z, h2=z,
                fail=zb, straggle=zb, restart=zb, corrupt=zb,
                eval_loss=ev_loss, eval_acc=ev_acc, active=~zb,
                round_ms=round_ms, dispatch_ms=dispatch_ms))
        return records

    def run_iter(self, rounds: Optional[int] = None
                 ) -> Iterator[RoundRecord]:
        """Advance up to ``rounds`` rounds (default: the rest of the run),
        yielding a :class:`RoundRecord` per round as each chunk lands."""
        remaining = (self.spec.rounds - self.round if rounds is None
                     else rounds)
        end = self.round + remaining
        if end > self.spec.rounds:
            raise ValueError(
                f"run would exceed RunSpec.rounds = {self.spec.rounds} "
                f"(at round {self.round}, asked for {rounds} more)")
        run_chunk = (self._run_chunk_plain if self.spec.plain
                     else self._run_chunk_elastic)
        while self.round < end:
            records = run_chunk(self._next_chunk(end))
            # observers run before the next chunk is built: on_chunk_end is
            # the mutation window where a controller may apply() membership
            # edits that the following chunk then executes under
            for obs in self._observers:
                on_round = getattr(obs, "on_round", None)
                if on_round is not None:
                    for rec in records:
                        on_round(rec)
            for obs in self._observers:
                on_chunk_end = getattr(obs, "on_chunk_end", None)
                if on_chunk_end is not None:
                    on_chunk_end(self)
            yield from records
        if self.round >= self.spec.rounds and self.spec.save_path:
            self.save()

    def run(self, rounds: Optional[int] = None) -> List[RoundRecord]:
        return list(self.run_iter(rounds))
