"""Property + unit tests for the paper's dynamic weighting (§V-B)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _property_shim import given, strategies as st

from repro.configs.base import ElasticConfig
from repro.core import dynamic_weight as dw

ALPHAS = st.floats(0.01, 0.9)
KS = st.floats(-5.0, -1e-3)
SCORES = st.floats(-10.0, 10.0)


@given(a=SCORES, alpha=ALPHAS, k=KS)
def test_h1_bounds_and_regions(a, alpha, k):
    v = float(dw.h1(a, alpha, k))
    assert alpha - 1e-6 <= v <= 1.0 + 1e-6
    if a < k:
        assert v == pytest.approx(1.0)
    if a > 0:
        assert v == pytest.approx(alpha)


@given(a=SCORES, alpha=ALPHAS, k=KS)
def test_h2_bounds_and_regions(a, alpha, k):
    v = float(dw.h2(a, alpha, k))
    assert -1e-6 <= v <= alpha + 1e-6
    if a < k:
        assert v == pytest.approx(0.0)
    if a > 0:
        assert v == pytest.approx(alpha)


@given(alpha=ALPHAS, k=KS)
def test_h_continuity_at_knots(alpha, k):
    eps = 1e-6 * max(1.0, abs(k))
    for h in (dw.h1, dw.h2):
        assert float(h(k - eps, alpha, k)) == pytest.approx(
            float(h(k + eps, alpha, k)), abs=1e-3)
        assert float(h(-eps, alpha, k)) == pytest.approx(
            float(h(eps, alpha, k)), abs=1e-3)


@given(alpha=ALPHAS, k=KS, a1=st.floats(-4, 0), a2=st.floats(-4, 0))
def test_h1_decreasing_h2_increasing_on_mid(alpha, k, a1, a2):
    lo, hi = min(a1, a2), max(a1, a2)
    assert float(dw.h1(lo, alpha, k)) >= float(dw.h1(hi, alpha, k)) - 1e-6
    assert float(dw.h2(lo, alpha, k)) <= float(dw.h2(hi, alpha, k)) + 1e-6


def test_healthy_worker_recovers_easgd():
    """a > 0 (paper: healthy) → exactly fixed-α EASGD."""
    cfg = ElasticConfig(alpha=0.1)
    w1, w2 = dw.weights_for(cfg, jnp.asarray(0.02))
    assert float(w1) == pytest.approx(0.1)
    assert float(w2) == pytest.approx(0.1)


def test_failed_worker_limits():
    cfg = ElasticConfig(alpha=0.1, score_k=-0.05)
    w1, w2 = dw.weights_for(cfg, jnp.asarray(-1.0))
    assert float(w1) == pytest.approx(1.0)   # snap to master
    assert float(w2) == pytest.approx(0.0)   # master ignores


def test_raw_score_weights_newest_most():
    hist_new_drop = jnp.asarray([0.0, 0.0, 0.0, 0.0, -1.0])
    hist_old_drop = jnp.asarray([1.0, 0.0, 0.0, 0.0, 0.0])
    c = (0.5, 0.25, 0.15, 0.10)
    a_new = float(dw.raw_score(hist_new_drop, c))
    a_old = float(dw.raw_score(hist_old_drop, c))
    assert a_new < a_old < 0
    assert abs(a_new) > abs(a_old)


@given(st.lists(st.floats(-5, 5), min_size=5, max_size=5))
def test_raw_score_zero_for_constant_history(h):
    hist = jnp.full((5,), h[0])
    assert float(dw.raw_score(hist, (0.5, 0.25, 0.15, 0.1))) == pytest.approx(
        0.0, abs=1e-5)


def test_push_history_rolls():
    hist = jnp.asarray([1.0, 2.0, 3.0])
    out = dw.push_history(hist, jnp.asarray(4.0))
    np.testing.assert_allclose(out, [2.0, 3.0, 4.0])


def test_log_distance_matches_manual():
    w = {"a": jnp.asarray([3.0, 0.0]), "b": jnp.asarray(4.0)}
    m = {"a": jnp.asarray([0.0, 0.0]), "b": jnp.asarray(0.0)}
    assert float(dw.log_distance(w, m)) == pytest.approx(np.log(5.0), abs=1e-5)


def test_oracle_mode():
    cfg = ElasticConfig(alpha=0.1, oracle=True)
    w1, w2 = dw.weights_for(cfg, jnp.asarray(0.0),
                            failed_recently=jnp.asarray(True))
    assert float(w1) == 1.0 and float(w2) == 0.0
    w1, w2 = dw.weights_for(cfg, jnp.asarray(0.0),
                            failed_recently=jnp.asarray(False))
    assert float(w1) == pytest.approx(0.1)
    assert float(w2) == pytest.approx(0.1)
