"""Elastic worker-pool membership (ISSUE-5): capacity-padded worker axis,
live join/leave/resize, and the refactor's safety rail — an all-active
membership mask is bit-exact with the unmasked fixed-k coordinator across
{sequential, fused} × {per-round, chunked} × {single, sharded}.

The multi-device sharded checks run in a subprocess (device count locks at
jax init); the in-process sharded check runs the full shard_map path on a
pod=1 mesh.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ElasticSession, RunSpec
from repro.configs.base import ElasticConfig, OptimizerConfig, get_config
from repro.core.coordinator import (ElasticTrainer, RoundInputs,
                                    padded_capacity)
from repro.core.scenarios import (PlanMembership, PreemptRejoinMembership,
                                  ScaleDownMembership, ScaleUpMembership,
                                  StaticMembership, make_membership,
                                  make_scenario, parse_membership_plan)
from repro.models.registry import build_model

ROOT = os.path.join(os.path.dirname(__file__), "..")
ROUNDS, K = 4, 2


def _spec(comm_mode="sequential", scenario="iid", rpc=1, **kw):
    ecfg = kw.pop("elastic", None) or ElasticConfig(
        num_workers=K, tau=2, alpha=0.1, dynamic=True, failure_prob=0.4,
        comm_mode=comm_mode, failure_scenario=scenario)
    defaults = dict(arch="paper-cnn",
                    optimizer=OptimizerConfig(name="sgd", lr=0.01),
                    elastic=ecfg, rounds=ROUNDS, rounds_per_call=rpc,
                    seed=1, batch_size=4, n_data=96, n_test=32)
    defaults.update(kw)
    return RunSpec(**defaults)


def _assert_trees_bit_exact(a, b, msg=""):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


# ---------------------------------------------------------------------------
# config validation + capacity helpers
# ---------------------------------------------------------------------------

def test_capacity_validated():
    with pytest.raises(ValueError, match="capacity"):
        ElasticConfig(num_workers=4, capacity=2)
    assert ElasticConfig(num_workers=4, capacity=8).cap == 8
    assert ElasticConfig(num_workers=4).cap == 4


def test_membership_scenario_validated():
    with pytest.raises(ValueError, match="membership_scenario"):
        ElasticConfig(membership_scenario="nope")
    with pytest.raises(ValueError, match="membership_plan"):
        ElasticConfig(membership_scenario="plan")
    with pytest.raises(ValueError, match="plan step"):
        ElasticConfig(num_workers=2, capacity=4, membership_scenario="plan",
                      membership_plan=((1, 9),))  # k > capacity


def test_padded_capacity():
    assert padded_capacity(4, 4) == 4
    assert padded_capacity(5, 4) == 8
    assert padded_capacity(3, 1) == 3
    assert padded_capacity(1, 4) == 4


def test_sharded_trainer_validates_capacity_not_workers():
    """Uneven live pools are fine under sharding as long as the *slot*
    capacity divides the pod axis."""

    class FakeMesh:
        shape = {"pod": 4}
        axis_names = ("pod",)

    model = build_model(get_config("paper_cnn"))
    ElasticTrainer(model, OptimizerConfig(name="sgd", lr=0.01),
                   ElasticConfig(num_workers=3, capacity=4,
                                 comm_mode="fused", placement="sharded"),
                   mesh=FakeMesh())  # ok: cap 4 divides, 3 live workers
    with pytest.raises(ValueError, match="capacity"):
        ElasticTrainer(model, OptimizerConfig(name="sgd", lr=0.01),
                       ElasticConfig(num_workers=3, comm_mode="fused",
                                     placement="sharded"), mesh=FakeMesh())


# ---------------------------------------------------------------------------
# membership scenario generators
# ---------------------------------------------------------------------------

def test_static_membership_rows():
    rows = StaticMembership().active_schedule(5, 4, 2)
    assert rows.shape == (5, 4) and rows.dtype == bool
    np.testing.assert_array_equal(rows, [[True, True, False, False]] * 5)


def test_scale_up_and_down_rows():
    up = ScaleUpMembership(k_to=4, at=2).active_schedule(5, 4, 2)
    assert up.sum(axis=1).tolist() == [2, 2, 4, 4, 4]
    down = ScaleDownMembership(k_to=1, at=3).active_schedule(5, 4, 3)
    assert down.sum(axis=1).tolist() == [3, 3, 3, 1, 1]
    with pytest.raises(ValueError, match="scale_up"):
        ScaleUpMembership(k_to=2, at=1).active_schedule(5, 4, 2)
    with pytest.raises(ValueError, match="membership_round"):
        ScaleUpMembership(k_to=4, at=7).active_schedule(5, 4, 2)


def test_preempt_rejoin_rows():
    rows = PreemptRejoinMembership(n=2, at=2, downtime=2
                                   ).active_schedule(7, 4, 4)
    assert rows.sum(axis=1).tolist() == [4, 4, 2, 2, 4, 4, 4]
    # the preempted slots are the highest-numbered live ones
    np.testing.assert_array_equal(rows[2], [True, True, False, False])


def test_plan_membership_and_parse():
    assert parse_membership_plan("2:2, 4:6") == ((2, 2), (4, 6))
    with pytest.raises(ValueError, match="round:k"):
        parse_membership_plan("2-2")
    rows = PlanMembership(((2, 2), (4, 6))).active_schedule(6, 8, 4)
    assert rows.sum(axis=1).tolist() == [4, 4, 2, 2, 6, 6]


def test_schedule_joins_and_leaves():
    ecfg = ElasticConfig(num_workers=4, capacity=8,
                         membership_scenario="plan",
                         membership_plan=((2, 2), (4, 6)))
    rows = make_membership(ecfg).active_schedule(6, 8, 4)
    sched = make_scenario(ecfg).schedule(0, 6, 8).with_membership(rows)
    joins, leaves = sched.joins(), sched.leaves()
    assert joins[0].sum() == 0  # round 0 seats via init, not join
    assert joins[4].sum() == 4 and joins.sum() == 4  # 2 -> 6: slots 2..5
    assert leaves[2].sum() == 2 and leaves.sum() == 2  # 4 -> 2
    with pytest.raises(AssertionError, match="live"):
        sched.with_membership(np.zeros((6, 8), bool))


def test_every_membership_scenario_buildable():
    for name in ("static", "scale_up", "scale_down", "preempt_rejoin"):
        ecfg = ElasticConfig(num_workers=4, capacity=8,
                             membership_scenario=name)
        rows = make_membership(ecfg).active_schedule(6, 8, 4)
        assert rows.shape == (6, 8) and rows.any(axis=1).all()


# ---------------------------------------------------------------------------
# the safety rail: all-active mask == unmasked fixed-k, bit-exact
# ---------------------------------------------------------------------------

def _run_master(comm_mode, rpc, force_mask, scenario="crash_restart"):
    spec = _spec(comm_mode, scenario, rpc)
    sched = make_scenario(spec.elastic).schedule(spec.seed + 7, ROUNDS, K)
    if force_mask:
        sched = sched.with_membership(np.ones((ROUNDS, K), bool))
    sess = ElasticSession(spec.replace(schedule=sched))
    recs = sess.run()
    return sess.master_params, recs


@pytest.mark.parametrize("comm_mode", ["sequential", "fused"])
@pytest.mark.parametrize("rpc", [1, 3])
def test_all_active_mask_bit_exact_vs_fixed_k(comm_mode, rpc):
    """The acceptance bar: forcing an all-True active mask through the
    masked round produces the identical master params (and diagnostics) as
    the unmasked fixed-k path, per-round and chunked, both comm modes."""
    want, wrecs = _run_master(comm_mode, rpc, force_mask=False)
    got, grecs = _run_master(comm_mode, rpc, force_mask=True)
    _assert_trees_bit_exact(want, got, f"{comm_mode} rpc={rpc}")
    for a, b in zip(wrecs, grecs):
        np.testing.assert_array_equal(a.h2, b.h2)
        np.testing.assert_array_equal(a.u, b.u)
        np.testing.assert_array_equal(np.float32(a.loss), np.float32(b.loss))


def test_all_active_mask_bit_exact_sharded_pod1():
    """Same property through the full shard_map machinery (pod=1 mesh)."""
    ecfg = ElasticConfig(num_workers=K, tau=1, dynamic=True,
                         failure_prob=0.4, comm_mode="fused",
                         placement="sharded")
    spec = _spec(elastic=ecfg, rounds=2)
    sched = make_scenario(ecfg).schedule(spec.seed + 7, 2, K)
    a = ElasticSession(spec.replace(schedule=sched))
    a.run()
    b = ElasticSession(spec.replace(
        schedule=sched.with_membership(np.ones((2, K), bool))))
    b.run()
    _assert_trees_bit_exact(a.master_params, b.master_params)


# ---------------------------------------------------------------------------
# masked-round semantics
# ---------------------------------------------------------------------------

def _tiny_trainer(comm_mode="fused", cap=3, k=2):
    model = build_model(get_config("paper_cnn"))
    return ElasticTrainer(model, OptimizerConfig(name="sgd", lr=0.01),
                          ElasticConfig(num_workers=k, capacity=cap, tau=1,
                                        dynamic=True, comm_mode=comm_mode))


def _round_inputs(cap, active=None, join=None, rng=0):
    batches = {"images": jnp.ones((1, cap, 2, 28, 28, 1), jnp.float32),
               "labels": jnp.zeros((1, cap, 2), jnp.int32)}
    return RoundInputs(
        batches=batches, rng=jax.random.key(rng),
        fail=jnp.zeros(cap, bool), failed_recent=jnp.zeros(cap, bool),
        active=None if active is None else jnp.asarray(active),
        join=None if join is None else jnp.asarray(join))


@pytest.mark.parametrize("comm_mode", ["sequential", "fused"])
def test_inactive_slot_fully_frozen(comm_mode):
    """A vacant slot neither trains, nor syncs, nor pushes u-history, nor
    leaks into the mean loss; the live workers' sync is untouched by its
    presence (sequential event order preserved)."""
    tr = _tiny_trainer(comm_mode)
    state = tr.init_state(jax.random.key(0))
    # give the vacant slot recognizable params/history
    poison = jax.tree.map(lambda x: x.at[2].set(7.0), state["workers"])
    state = dict(state, workers=poison,
                 u_hist=state["u_hist"].at[2].set(5.0))
    before = jax.tree.map(lambda x: np.asarray(x[2]).copy(),
                          state["workers"])
    active = np.array([True, True, False])
    new, m = tr.round_step(state, _round_inputs(3, active=active))
    after = jax.tree.map(lambda x: np.asarray(x[2]), new["workers"])
    _assert_trees_bit_exact(before, after, "vacant slot params moved")
    np.testing.assert_array_equal(np.asarray(new["u_hist"][2]),
                                  np.full(tr.ecfg.score_window, 5.0))
    assert m["h1"][2] == 0.0 and m["h2"][2] == 0.0 and m["u"][2] == 0.0
    assert np.isfinite(m["loss"])


def test_join_reseats_slot_from_master():
    """A joining slot's params are re-seated from the master before its
    first local phase — poisoned pre-join params never survive a join."""
    tr = _tiny_trainer("sequential")
    state = tr.init_state(jax.random.key(0))
    state = dict(state, workers=jax.tree.map(
        lambda x: x.at[2].set(1e6), state["workers"]))
    active = np.array([True, True, True])
    join = np.array([False, False, True])
    new, m = tr.round_step(state, _round_inputs(3, active=active, join=join))
    for leaf in jax.tree.leaves(new["workers"]):
        assert np.abs(np.asarray(leaf[2], np.float32)).max() < 1e3, \
            "join did not re-seat from master"
    assert np.isfinite(m["loss"])


def test_mean_loss_counts_live_workers_only():
    tr = _tiny_trainer("fused")
    state = tr.init_state(jax.random.key(0))
    s2 = jax.tree.map(jnp.copy, state)
    _, m_all = tr.round_step(state, _round_inputs(3))
    _, m_live = tr.round_step(s2, _round_inputs(
        3, active=np.array([True, True, False])))
    # identical per-worker data (all-ones batches) → identical mean loss
    np.testing.assert_allclose(float(m_all["loss"]),
                               float(m_live["loss"]), rtol=1e-6)


# ---------------------------------------------------------------------------
# session lifecycle: scheduled + live membership
# ---------------------------------------------------------------------------

def _plan_ecfg(comm_mode="sequential", **kw):
    defaults = dict(num_workers=4, capacity=8, tau=1, dynamic=True,
                    failure_prob=0.3, comm_mode=comm_mode,
                    membership_scenario="plan",
                    membership_plan=((2, 2), (4, 6)))
    defaults.update(kw)
    return ElasticConfig(**defaults)


def test_membership_chunking_invariant():
    """Chunk boundaries snap to membership transitions, so chunked and
    per-round execution agree bit-exactly through a 4→2→6 resize."""
    spec = _spec(elastic=_plan_ecfg(), rounds=6)
    a = ElasticSession(spec)
    ra = a.run()
    b = ElasticSession(spec.replace(rounds_per_call=4))
    rb = b.run()
    _assert_trees_bit_exact(a.master_params, b.master_params)
    assert [r.num_active for r in ra] == [4, 4, 2, 2, 6, 6]
    for x, y in zip(ra, rb):
        np.testing.assert_array_equal(x.active, y.active)
        np.testing.assert_array_equal(x.h2, y.h2)


def test_membership_repartitions_data():
    spec = _spec(elastic=_plan_ecfg(), rounds=6)
    sess = ElasticSession(spec)
    sess.run(2)
    assert sess.batcher.active == (0, 1, 2, 3)
    sess.run(2)
    assert sess.batcher.active == (0, 1)
    sess.run()
    assert sess.batcher.active == (0, 1, 2, 3, 4, 5)
    assert sess.num_active == 6


def test_vacant_slot_records_are_zeroed():
    spec = _spec(elastic=_plan_ecfg("fused"), rounds=6)
    recs = ElasticSession(spec).run()
    for r in recs:
        assert r.active.shape == (8,)
        np.testing.assert_array_equal(r.h2[~r.active], 0.0)
        np.testing.assert_array_equal(r.u[~r.active], 0.0)


def test_live_resize_between_runs():
    ecfg = ElasticConfig(num_workers=2, capacity=4, tau=1, dynamic=True)
    sess = ElasticSession(_spec(elastic=ecfg, rounds=6))
    sess.run(2)
    sess.resize(4)
    assert sess.num_active == 4
    recs = sess.run(2)
    assert [r.num_active for r in recs] == [4, 4]
    sess.resize(1)
    recs = sess.run()
    assert [r.num_active for r in recs] == [1, 1]
    with pytest.raises(ValueError, match="resize"):
        sess.resize(9)
    with pytest.raises(ValueError, match="complete"):
        sess.set_membership(np.ones(4, bool))


def test_set_membership_validation():
    ecfg = ElasticConfig(num_workers=2, capacity=4, tau=1, dynamic=True)
    sess = ElasticSession(_spec(elastic=ecfg, rounds=2))
    with pytest.raises(ValueError, match="shape"):
        sess.set_membership(np.ones(3, bool))
    with pytest.raises(ValueError, match="active"):
        sess.set_membership(np.zeros(4, bool))
    plain = ElasticSession(_spec(plain=True, rounds=2))
    with pytest.raises(ValueError, match="plain"):
        plain.set_membership(np.ones(1, bool))


def test_runspec_schedule_validated_at_capacity():
    from repro.core.scenarios import ScenarioSchedule

    z = np.zeros((ROUNDS, K), bool)
    ecfg = ElasticConfig(num_workers=K, capacity=K + 2)
    with pytest.raises(ValueError, match="capacity"):
        _spec(elastic=ecfg, schedule=ScenarioSchedule(z, z, z))


# ---------------------------------------------------------------------------
# checkpoint: scale-down → save → restore at larger capacity → scale-up
# ---------------------------------------------------------------------------

def test_scale_down_checkpoint_restore_scale_up(tmp_path):
    """The ISSUE-5 end-to-end acceptance: a scaled-down run checkpoints its
    membership manifest; a session at a *larger* capacity restores it —
    master exact, live slots' u-histories re-seated, every worker slot
    cold-started from the master — then scales up with joiners initialized
    from the master."""
    ck = str(tmp_path / "ck")
    ecfg1 = ElasticConfig(num_workers=4, tau=1, dynamic=True,
                          membership_scenario="scale_down", membership_k=2,
                          membership_round=2)
    s1 = ElasticSession(_spec(elastic=ecfg1, rounds=4, save_path=ck))
    s1.run()
    assert s1.active_mask.tolist() == [True, True, False, False]

    ecfg2 = ElasticConfig(num_workers=2, capacity=8, tau=1, dynamic=True)
    s2 = ElasticSession(_spec(elastic=ecfg2, rounds=6, rounds_per_call=2,
                              seed=2))
    meta = s2.restore(ck)
    assert meta["elastic"]["capacity"] == 4
    # master restored exactly
    _assert_trees_bit_exact(
        jax.tree.map(np.asarray, s1.master_params),
        jax.tree.map(np.asarray, s2.master_params))
    # every slot (including future joiners) re-seated from the master
    for i in range(8):
        for w, m in zip(jax.tree.leaves(s2.state["workers"]),
                        jax.tree.leaves(s2.state["master"])):
            np.testing.assert_array_equal(np.asarray(w[i], np.float32),
                                          np.asarray(m, np.float32))
    # the two surviving slots carried their u-histories across capacities
    uh1 = np.asarray(s1.state["u_hist"])
    uh2 = np.asarray(s2.state["u_hist"])
    np.testing.assert_array_equal(uh2[:2], uh1[:2])
    assert (uh2[2:] == -30.0).all()

    s2.run(2)
    s2.resize(6)
    recs = s2.run()
    assert [r.num_active for r in recs] == [6, 6, 6, 6]
    assert all(np.isfinite(r.loss) for r in recs)


def test_restore_master_bit_exact_for_narrow_param_dtypes(tmp_path):
    """The master is float32 state; restoring it must not round-trip
    through the model's (possibly bf16) param dtype — the restored master
    is bit-exact with the saved one, while the workers re-seat at the
    param dtype as a fresh run's would."""
    ck = str(tmp_path / "ck")
    lm = dict(arch="stablelm-3b", smoke=True, rounds=2, n_tokens=4000,
              seq_len=16, batch_size=2)
    s1 = ElasticSession(_spec(save_path=ck, **lm))
    s1.run()
    s2 = ElasticSession(_spec(seed=9, **lm))
    s2.restore(ck)
    _assert_trees_bit_exact(
        jax.tree.map(np.asarray, s1.master_params),
        jax.tree.map(np.asarray, s2.master_params))
    w_dt = {x.dtype for x in jax.tree.leaves(s2.state["workers"])}
    assert jnp.dtype(jnp.bfloat16) in w_dt  # workers stayed at param dtype
    recs = s2.run()
    assert all(np.isfinite(r.loss) for r in recs)


def test_restore_rejects_arch_mismatch(tmp_path):
    ck = str(tmp_path / "ck")
    s1 = ElasticSession(_spec(save_path=ck))
    s1.run()
    s2 = ElasticSession(_spec(arch="stablelm-3b", smoke=True, rounds=2,
                              n_tokens=4000, seq_len=16, batch_size=2))
    with pytest.raises(ValueError, match="arch"):
        s2.restore(ck)


# ---------------------------------------------------------------------------
# sharded placement under membership, real 4-device mesh (subprocess)
# ---------------------------------------------------------------------------

_SUBPROCESS_MEMBERSHIP = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import jax
import numpy as np
from repro.api import ElasticSession, RunSpec
from repro.configs.base import ElasticConfig, OptimizerConfig

assert jax.device_count() == 4

def run(placement):
    ecfg = ElasticConfig(num_workers=4, capacity=8, tau=1, dynamic=True,
                         failure_prob=0.3, comm_mode="fused",
                         placement=placement, membership_scenario="plan",
                         membership_plan=((2, 2), (4, 6)))
    spec = RunSpec(arch="paper-cnn",
                   optimizer=OptimizerConfig(name="sgd", lr=0.01),
                   elastic=ecfg, rounds=6, rounds_per_call=2, seed=1,
                   batch_size=4, n_data=96, n_test=32)
    sess = ElasticSession(spec)
    return sess, sess.run()

s1, r1 = run("single")
s2, r2 = run("sharded")
assert s2.mesh.shape["pod"] == 4
for a, b in zip(jax.tree.leaves(s1.master_params),
                jax.tree.leaves(s2.master_params)):
    assert np.array_equal(np.asarray(a), np.asarray(b)), "master not exact"
for a, b in zip(r1, r2):
    np.testing.assert_array_equal(a.h2, b.h2)
    np.testing.assert_allclose(a.loss, b.loss, rtol=1e-6)
assert [r.num_active for r in r2] == [4, 4, 2, 2, 6, 6]

# uneven-shard masking: 3 live workers in a 4-slot pool over 4 pods
ecfg = ElasticConfig(num_workers=3, capacity=4, tau=1, dynamic=True,
                     comm_mode="fused", placement="sharded")
spec = RunSpec(arch="paper-cnn",
               optimizer=OptimizerConfig(name="sgd", lr=0.01),
               elastic=ecfg, rounds=2, seed=0, batch_size=4,
               n_data=96, n_test=32)
sess = ElasticSession(spec)
recs = sess.run()
assert all(np.isfinite(r.loss) for r in recs)
assert all(r.num_active == 3 for r in recs)
print("MEMBERSHIP_OK")
"""


@pytest.mark.slow
def test_sharded_membership_bit_exact_vs_single_4dev():
    """On a forced 4-device host mesh, a capacity-8 pool resizing 4→2→6
    produces sharded masters bit-exact with single placement, and an
    uneven pool (3 live workers on 4 pods) runs end to end."""
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_MEMBERSHIP],
                         cwd=ROOT, capture_output=True, text=True,
                         timeout=540)
    assert "MEMBERSHIP_OK" in out.stdout, out.stdout + out.stderr[-3000:]
