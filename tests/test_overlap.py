"""Property tests for the data-overlap partition (paper §V-A)."""
import numpy as np
import pytest
from _property_shim import given, strategies as st

from repro.core.overlap import overlap_partition, worker_datasets


@given(n=st.integers(50, 2000), k=st.integers(1, 8),
       r=st.floats(0.0, 0.6), seed=st.integers(0, 100))
def test_partition_invariants(n, k, r, seed):
    overlap, uniques = overlap_partition(n, k, r, seed)
    o = int(round(r * n))
    assert len(overlap) == o
    per, rem = divmod(n - o, k)
    # unique shards are disjoint, near-equal (remainder dealt round-robin
    # to the first `rem` workers), and disjoint from overlap
    all_u = np.concatenate(uniques) if k else np.array([])
    assert len(set(all_u.tolist())) == len(all_u)
    assert set(all_u.tolist()).isdisjoint(set(overlap.tolist()))
    for j, s in enumerate(uniques):
        assert len(s) == per + (1 if j < rem else 0)
    # everything is a valid index, and D is fully covered: O ∪ ∪S_j = D
    assert all_u.max(initial=-1) < n and overlap.max(initial=-1) < n
    assert len(all_u) + o == n


@given(n=st.integers(100, 1000), k=st.integers(2, 8),
       r=st.floats(0.05, 0.5), seed=st.integers(0, 20))
def test_worker_datasets_shared_fraction(n, k, r, seed):
    ds = worker_datasets(n, k, r, seed)
    o = int(round(r * n))
    sets = [set(d.tolist()) for d in ds]
    shared = set.intersection(*sets)
    # the shared subset is exactly the overlap O
    assert len(shared) == o
    per, rem = divmod(n - o, k)
    sizes = sorted(len(d) for d in ds)
    assert sizes == sorted(o + per + (1 if j < rem else 0)
                           for j in range(k))


@pytest.mark.parametrize("n,k,seed", [
    (100, 3, 0),   # 100 % 3 = 1 — the old split dropped it
    (101, 4, 1),
    (257, 7, 2),
    (96, 4, 3),    # exact fit stays exact
])
def test_no_samples_dropped_without_overlap(n, k, seed):
    """Regression (ISSUE-5 satellite): the old split dropped the
    ``(n - o) % k`` remainder; with ratio=0 every index in D must be
    assigned to exactly one worker."""
    ds = worker_datasets(n, k, 0.0, seed)
    union = np.concatenate(ds)
    assert len(union) == n
    np.testing.assert_array_equal(np.sort(union), np.arange(n))
    assert max(len(d) for d in ds) - min(len(d) for d in ds) <= 1


def test_overlap_stable_across_worker_counts():
    """O depends only on (n, ratio, seed) — membership changes redeal the
    unique shards but never move the shared overlap."""
    o4, _ = overlap_partition(400, 4, 0.25, seed=5)
    o7, _ = overlap_partition(400, 7, 0.25, seed=5)
    np.testing.assert_array_equal(o4, o7)


def test_partition_deterministic():
    a = worker_datasets(500, 4, 0.25, seed=3)
    b = worker_datasets(500, 4, 0.25, seed=3)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_invalid_ratio_raises():
    with pytest.raises(ValueError):
        overlap_partition(100, 4, 1.0)
