"""Property tests for the data-overlap partition (paper §V-A)."""
import numpy as np
import pytest
from _property_shim import given, strategies as st

from repro.core.overlap import overlap_partition, worker_datasets


@given(n=st.integers(50, 2000), k=st.integers(1, 8),
       r=st.floats(0.0, 0.6), seed=st.integers(0, 100))
def test_partition_invariants(n, k, r, seed):
    overlap, uniques = overlap_partition(n, k, r, seed)
    o = int(round(r * n))
    assert len(overlap) == o
    per = (n - o) // k
    # unique shards are disjoint, correctly sized, and disjoint from overlap
    all_u = np.concatenate(uniques) if k else np.array([])
    assert len(set(all_u.tolist())) == len(all_u)
    assert set(all_u.tolist()).isdisjoint(set(overlap.tolist()))
    for s in uniques:
        assert len(s) == per
    # everything is a valid index
    assert all_u.max(initial=-1) < n and overlap.max(initial=-1) < n


@given(n=st.integers(100, 1000), k=st.integers(2, 8),
       r=st.floats(0.05, 0.5), seed=st.integers(0, 20))
def test_worker_datasets_shared_fraction(n, k, r, seed):
    ds = worker_datasets(n, k, r, seed)
    o = int(round(r * n))
    sets = [set(d.tolist()) for d in ds]
    shared = set.intersection(*sets)
    # the shared subset is exactly the overlap O
    assert len(shared) == o
    for d in ds:
        assert len(d) == o + (n - o) // k


def test_partition_deterministic():
    a = worker_datasets(500, 4, 0.25, seed=3)
    b = worker_datasets(500, 4, 0.25, seed=3)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_invalid_ratio_raises():
    with pytest.raises(ValueError):
        overlap_partition(100, 4, 1.0)
