"""Chunked GLA engine vs sequential oracle (Mamba2/RWKV6 substrate)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _property_shim import given, settings, strategies as st

from repro.nn.gla import causal_conv1d, gla_chunked, gla_decode_step, gla_ref


def _mk(seed, B, T, H, N, P, decay_scale=0.2):
    ks = jax.random.split(jax.random.key(seed), 5)
    q = jax.random.normal(ks[0], (B, T, H, N))
    k = jax.random.normal(ks[1], (B, T, H, N))
    v = jax.random.normal(ks[2], (B, T, H, P))
    logw = -jnp.abs(jax.random.normal(ks[3], (B, T, H, N))) * decay_scale
    u = jax.random.normal(ks[4], (H, N))
    return q, k, v, logw, u


@pytest.mark.parametrize("inclusive", [True, False])
@pytest.mark.parametrize("chunk", [4, 8, 32])
@pytest.mark.parametrize("T", [32, 48])  # includes non-multiple of chunk
def test_chunked_matches_ref(inclusive, chunk, T):
    q, k, v, logw, u = _mk(0, 2, T, 3, 8, 16)
    bonus = None if inclusive else u
    yc, Sc = gla_chunked(q, k, v, logw, chunk=chunk, inclusive=inclusive,
                         bonus=bonus)
    yr, Sr = gla_ref(q, k, v, logw, inclusive=inclusive, bonus=bonus)
    np.testing.assert_allclose(yc, yr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(Sc, Sr, rtol=2e-4, atol=2e-4)


@pytest.mark.slow
@given(seed=st.integers(0, 50), decay=st.floats(0.01, 1.5))
@settings(max_examples=15)
def test_chunked_matches_ref_property(seed, decay):
    q, k, v, logw, u = _mk(seed, 1, 24, 2, 4, 8, decay)
    # the vector path applies a decay floor (−CLAMP/chunk); the sequential
    # oracle must see the same floor for strong decays to be comparable
    floor = -30.0 / 8
    yc, Sc = gla_chunked(q, k, v, logw, chunk=8, inclusive=True)
    yr, Sr = gla_ref(q, k, v, logw, inclusive=True, decay_floor=floor)
    np.testing.assert_allclose(yc, yr, rtol=5e-4, atol=5e-4)


def test_initial_state_continuation():
    """Splitting a sequence across two chunked calls == one call."""
    q, k, v, logw, _ = _mk(3, 2, 32, 2, 4, 8)
    y_full, S_full = gla_chunked(q, k, v, logw, chunk=8)
    y1, S1 = gla_chunked(q[:, :16], k[:, :16], v[:, :16], logw[:, :16],
                         chunk=8)
    y2, S2 = gla_chunked(q[:, 16:], k[:, 16:], v[:, 16:], logw[:, 16:],
                         chunk=8, initial_state=S1)
    np.testing.assert_allclose(
        jnp.concatenate([y1, y2], 1), y_full, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(S2, S_full, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("inclusive", [True, False])
def test_decode_step_matches_prefix(inclusive):
    q, k, v, logw, u = _mk(4, 2, 9, 2, 4, 8)
    bonus = None if inclusive else u
    y_ref, _ = gla_ref(q, k, v, logw, inclusive=inclusive, bonus=bonus)
    _, S8 = gla_ref(q[:, :8], k[:, :8], v[:, :8], logw[:, :8],
                    inclusive=inclusive, bonus=bonus)
    y9, S9 = gla_decode_step(S8, q[:, 8], k[:, 8], v[:, 8], logw[:, 8],
                             inclusive=inclusive, bonus=bonus)
    np.testing.assert_allclose(y9, y_ref[:, 8], rtol=2e-4, atol=2e-4)


def test_strong_decay_scalar_path_exact():
    """Scalar decay (Mamba2) uses pairwise decays → exact for any strength."""
    q, k, v, logw, _ = _mk(5, 1, 64, 2, 4, 4)
    lw = logw[..., 0] * 100.0  # extreme per-head decay
    yc, Sc = gla_chunked(q, k, v, lw, chunk=16, scalar_decay=True)
    yr, Sr = gla_ref(q, k, v, lw)
    assert bool(jnp.isfinite(yc).all())
    np.testing.assert_allclose(yc, yr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(Sc, Sr, rtol=2e-4, atol=2e-4)


def test_strong_decay_vector_path_floored_consistent():
    """Vector decay (RWKV6): the decay floor keeps the factored path finite
    and consistent with a floored sequential reference."""
    q, k, v, logw, u = _mk(6, 1, 64, 1, 4, 4)
    logw = logw * 100.0
    floor = -30.0 / 16
    yc, Sc = gla_chunked(q, k, v, logw, chunk=16, inclusive=False, bonus=u,
                         decay_floor=floor)
    yr, Sr = gla_ref(q, k, v, logw, inclusive=False, bonus=u,
                     decay_floor=floor)
    assert bool(jnp.isfinite(yc).all()) and bool(jnp.isfinite(Sc).all())
    np.testing.assert_allclose(yc, yr, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_scalar_decay_matches_ref(chunk):
    q, k, v, logw, _ = _mk(7, 2, 64, 3, 4, 8)
    lw = logw[..., 0]  # (B,T,H)
    yc, Sc = gla_chunked(q, k, v, lw, chunk=chunk, scalar_decay=True)
    yr, Sr = gla_ref(q, k, v, lw)
    np.testing.assert_allclose(yc, yr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(Sc, Sr, rtol=2e-4, atol=2e-4)


def test_grad_flows():
    q, k, v, logw, _ = _mk(6, 1, 16, 1, 4, 4)
    f = lambda k: gla_chunked(q, k, v, logw, chunk=8)[0].sum()
    g = jax.grad(f)(k)
    assert bool(jnp.isfinite(g).all()) and float(jnp.abs(g).max()) > 0


def test_causal_conv1d_matches_manual():
    x = jax.random.normal(jax.random.key(0), (2, 10, 4))
    w = jax.random.normal(jax.random.key(1), (3, 4))
    y, buf = causal_conv1d(x, w)
    # manual: y_t = sum_k w[k] x_{t-(K-1)+k}
    xp = jnp.pad(x, ((0, 0), (2, 0), (0, 0)))
    want = sum(xp[:, i:i + 10] * w[i] for i in range(3))
    np.testing.assert_allclose(y, want, rtol=1e-5)
    np.testing.assert_allclose(buf, x[:, -2:], rtol=1e-6)


def test_causal_conv1d_decode_streaming():
    x = jax.random.normal(jax.random.key(2), (1, 8, 4))
    w = jax.random.normal(jax.random.key(3), (4, 4))
    y_full, _ = causal_conv1d(x, w)
    buf = jnp.zeros((1, 3, 4))
    outs = []
    for t in range(8):
        yt, buf = causal_conv1d(x[:, t:t + 1], w, buffer=buf)
        outs.append(yt)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), y_full, rtol=1e-5,
                               atol=1e-5)
