"""Failure scenario engine (ISSUE-2): generator properties, coordinator
integration (stragglers, crash restarts), and the per-scenario regression
check that dynamic weighting holds up under every regime.

Property-based tests ride the optional-hypothesis shim; plain tests cover
the same invariants deterministically so the suite stays meaningful without
hypothesis installed.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _property_shim import given, settings, st

from repro.configs.base import (FAILURE_SCENARIOS, ElasticConfig,
                                OptimizerConfig, get_config)
from repro.core import dynamic_weight as dw
from repro.core import scenarios as sc
from repro.core.coordinator import ElasticTrainer, RoundInputs
from repro.core.failure import (failed_recently, failure_schedule,
                                failure_schedule_np)
from repro.models.registry import build_model

ALL = FAILURE_SCENARIOS


def _scenario(name, rate=1.0 / 3.0):
    return sc.make_scenario(
        ElasticConfig(failure_scenario=name, failure_prob=rate))


# ---------------------------------------------------------------------------
# catalogue / config plumbing
# ---------------------------------------------------------------------------

def test_config_rejects_unknown_scenario():
    with pytest.raises(ValueError):
        ElasticConfig(failure_scenario="cosmic_rays")


def test_make_scenario_covers_catalogue():
    assert sc.scenario_names() == FAILURE_SCENARIOS
    for name in ALL:
        scen = _scenario(name)
        assert scen.name == name
        sched = scen.schedule(seed=0, rounds=7, k=3)
        for mask in (sched.fail, sched.straggle, sched.restart):
            assert mask.shape == (7, 3) and mask.dtype == bool
        assert sched.rounds == 7 and sched.num_workers == 3


def test_scenario_parameter_validation():
    with pytest.raises(ValueError):
        sc.IIDScenario(rate=1.2)
    with pytest.raises(ValueError):
        sc.BurstScenario(rate=0.9, recover_prob=0.25)  # entry prob 2.25 > 1
    with pytest.raises(ValueError):
        sc.BurstScenario(rate=1.0)
    with pytest.raises(ValueError):
        sc.StragglerScenario(recover_prob=0.0)
    with pytest.raises(ValueError):
        sc.CorrelatedScenario(groups=0)
    with pytest.raises(ValueError):
        sc.CrashRestartScenario(rate=0.9, downtime=3)  # cap is 3/4
    with pytest.raises(ValueError):
        sc.CrashRestartScenario(downtime=0)
    with pytest.raises(ValueError):
        sc.HeteroScenario(dist="trimodal")
    with pytest.raises(ValueError):
        sc.HeteroScenario(sigma=0.0)
    with pytest.raises(ValueError):
        sc.HeteroScenario(slow_scale=0.0)
    with pytest.raises(ValueError):
        sc.ByzantineScenario(frac=1.0)  # must leave an honest slot
    with pytest.raises(ValueError):
        sc.ByzantineScenario(fail_rate=1.5)


def test_make_scenario_rejects_unknown_name():
    cfg = ElasticConfig()
    bad = type(cfg).__new__(type(cfg))  # bypass __post_init__ validation
    object.__setattr__(bad, "failure_scenario", "nope")
    object.__setattr__(bad, "failure_prob", 0.3)
    with pytest.raises(ValueError):
        sc.make_scenario(bad)


# ---------------------------------------------------------------------------
# generator properties (plain)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL)
def test_schedule_deterministic_given_seed(name):
    scen = _scenario(name)
    a = scen.schedule(11, rounds=60, k=4)
    b = scen.schedule(11, rounds=60, k=4)
    for m in ("fail", "straggle", "restart", "corrupt", "speed"):
        av, bv = getattr(a, m), getattr(b, m)
        assert (av is None) == (bv is None)
        if av is not None:
            np.testing.assert_array_equal(av, bv)


# the channel each scenario's seed actually moves (everything else may be
# empty by design — hetero has no faults at all, byzantine's corrupt set
# is the persistent signature)
_MOVING = {"straggler": "straggle", "hetero": "speed",
           "byzantine": "corrupt"}


@pytest.mark.parametrize("name", ALL)
def test_schedule_varies_with_seed(name):
    scen = _scenario(name)
    a = scen.schedule(0, rounds=200, k=4)
    b = scen.schedule(1, rounds=200, k=4)
    moving = _MOVING.get(name, "fail")
    assert (getattr(a, moving) != getattr(b, moving)).any()


def test_iid_scenario_is_the_paper_generator():
    sched = _scenario("iid").schedule(5, rounds=40, k=4)
    np.testing.assert_array_equal(
        sched.fail, failure_schedule_np(5, 40, 4, 1.0 / 3.0))
    assert not sched.straggle.any() and not sched.restart.any()


def test_failure_schedule_seed_parity():
    """jax and numpy variants yield identical bits for the same seed."""
    want = np.asarray(failure_schedule(jax.random.key(123), 50, 6, 0.3))
    np.testing.assert_array_equal(
        failure_schedule_np(123, 50, 6, 0.3), want)


@pytest.mark.parametrize("name", ["iid", "burst", "correlated", "straggler"])
def test_marginal_rate_matches_config(name):
    rate = 1.0 / 3.0
    sched = _scenario(name, rate).schedule(3, rounds=3000, k=8)
    mask = sched.straggle if name == "straggler" else sched.fail
    assert abs(mask.mean() - rate) < 0.03


def test_crash_restart_marginal_rate():
    # renewal process: stationary down-fraction ≈ rate (looser tolerance —
    # the near-stationary init is approximate)
    sched = _scenario("crash_restart").schedule(3, rounds=4000, k=8)
    assert abs(sched.fail.mean() - 1.0 / 3.0) < 0.05


def test_burst_failures_are_time_correlated():
    sched = _scenario("burst").schedule(0, rounds=4000, k=8)
    f = sched.fail
    prev, cur = f[:-1], f[1:]
    p_stay = (prev & cur).sum() / prev.sum()
    # P(fail_t | fail_{t-1}) = 1 − recover_prob, far above the marginal 1/3
    assert abs(p_stay - 0.75) < 0.05
    assert p_stay > f.mean() + 0.2


def test_burst_stationary_distribution_matches_markov_params():
    scen = sc.BurstScenario(rate=0.2, recover_prob=0.4)
    pi = scen.enter_prob / (scen.enter_prob + scen.recover_prob)
    assert pi == pytest.approx(0.2)
    sched = scen.schedule(1, rounds=5000, k=8)
    assert abs(sched.fail.mean() - pi) < 0.03
    # every round is stationary (chain starts from π, no burn-in drift)
    assert abs(sched.fail[:100].mean() - pi) < 0.06


def test_correlated_groups_fail_together():
    scen = sc.CorrelatedScenario(rate=1.0 / 3.0, groups=2)
    sched = scen.schedule(2, rounds=500, k=8)
    group = scen.group_of(8)
    for g in range(2):
        cols = sched.fail[:, group == g]
        np.testing.assert_array_equal(cols, cols[:, :1].repeat(
            cols.shape[1], axis=1))
    # distinct groups draw independently — they must disagree somewhere
    assert (sched.fail[:, 0] != sched.fail[:, -1]).any()


def test_correlated_single_worker_groups_is_iid_shaped():
    scen = sc.CorrelatedScenario(rate=0.5, groups=8)
    sched = scen.schedule(0, rounds=300, k=8)
    cols = sched.fail.mean(axis=0)
    assert ((cols > 0.3) & (cols < 0.7)).all()


def test_straggler_never_drops_communication():
    sched = _scenario("straggler").schedule(9, rounds=800, k=4)
    assert not sched.fail.any() and not sched.restart.any()
    assert sched.straggle.any()


def test_crash_restart_downtime_and_rejoin_invariants():
    scen = sc.CrashRestartScenario(rate=1.0 / 3.0, downtime=3)
    sched = scen.schedule(4, rounds=600, k=6)
    down, restart = sched.fail, sched.restart
    # restart fires exactly on down→up transitions
    np.testing.assert_array_equal(restart[1:], down[:-1] & ~down[1:])
    assert not restart[0].any()
    # every internal down-streak lasts exactly `downtime` rounds
    for w in range(6):
        col = down[:, w].astype(int)
        edges = np.flatnonzero(np.diff(col))
        starts = edges[col[edges] == 0] + 1
        ends = edges[col[edges] == 1] + 1
        for s in starts:
            later = ends[ends > s]
            if later.size:  # streak completes inside the horizon
                assert later[0] - s == 3


def test_failed_recent_previous_round_semantics():
    """Canonical oracle feed (ISSUE-3): failed_recent(r) is previous-round
    fail only — the oracle snaps back on exactly the first successful sync
    after a missed one (§VI), not for a whole score_window."""
    fail = np.zeros((6, 2), bool)
    fail[1, 0] = True
    sched = sc.ScenarioSchedule(fail, np.zeros_like(fail),
                                np.zeros_like(fail))
    assert sched.failed_recent(0).tolist() == [False, False]
    assert sched.failed_recent(1).tolist() == [False, False]
    assert sched.failed_recent(2).tolist() == [True, False]
    assert sched.failed_recent(3).tolist() == [False, False]
    assert sched.has_stragglers is False and sched.has_restarts is False
    # the stacked (rounds, k) feed rows equal the per-round rows, and match
    # the window helper at window=1 (the previous-round special case)
    all_rows = sched.failed_recent_all()
    for r in range(6):
        np.testing.assert_array_equal(all_rows[r], sched.failed_recent(r))
        if r > 0:
            np.testing.assert_array_equal(
                sched.failed_recent(r),
                np.asarray(failed_recently(jnp.asarray(fail), r - 1, 1)))


# ---------------------------------------------------------------------------
# property-based (hypothesis shim: these skip without hypothesis)
# ---------------------------------------------------------------------------

@given(st.integers(min_value=0, max_value=2**31), st.floats(0.05, 0.9))
@settings(max_examples=20, deadline=None)
def test_prop_iid_marginal_rate(seed, rate):
    sched = sc.IIDScenario(rate).schedule(seed, rounds=1500, k=8)
    assert abs(sched.fail.mean() - rate) < 0.06


@given(st.integers(min_value=0, max_value=2**31),
       st.sampled_from(list(FAILURE_SCENARIOS)))
@settings(max_examples=20, deadline=None)
def test_prop_schedules_deterministic(seed, name):
    scen = _scenario(name)
    a, b = scen.schedule(seed, 50, 3), scen.schedule(seed, 50, 3)
    assert (a.fail == b.fail).all() and (a.straggle == b.straggle).all() \
        and (a.restart == b.restart).all()


@given(st.integers(min_value=0, max_value=2**31),
       st.floats(0.05, 0.6), st.floats(0.1, 0.9))
@settings(max_examples=15, deadline=None)
def test_prop_burst_stationary_rate(seed, rate, recover):
    scen = sc.BurstScenario(rate=rate, recover_prob=recover)
    sched = scen.schedule(seed, rounds=3000, k=4)
    pi = scen.enter_prob / (scen.enter_prob + scen.recover_prob)
    assert abs(sched.fail.mean() - pi) < 0.08


@given(st.integers(min_value=0, max_value=2**31))
@settings(max_examples=20, deadline=None)
def test_prop_failure_schedule_seed_parity(seed):
    want = np.asarray(failure_schedule(jax.random.key(seed), 20, 4, 0.4))
    np.testing.assert_array_equal(
        failure_schedule_np(seed, 20, 4, 0.4), want)


# ---------------------------------------------------------------------------
# coordinator integration: stragglers + crash restarts
# ---------------------------------------------------------------------------

def _trainer(k=2, opt="sgd", **kw):
    model = build_model(get_config("paper_cnn"))
    defaults = dict(num_workers=k, tau=1, alpha=0.1, dynamic=False)
    defaults.update(kw)
    return ElasticTrainer(model, OptimizerConfig(name=opt, lr=0.01),
                          ElasticConfig(**defaults))


def _img_batches(tau, k, n=4, seed=0):
    return {"images": jax.random.normal(jax.random.key(seed),
                                        (tau, k, n, 28, 28, 1)),
            "labels": jnp.zeros((tau, k, n), jnp.int32)}


def test_straggler_runs_reduced_effective_tau():
    """A straggling worker freezes after τ_eff = τ·straggler_tau_scale local
    steps: its end-of-phase params equal a clean run over the truncated
    batch stream."""
    tr = _trainer(k=2, tau=4)
    state = tr.init_state(jax.random.key(0))
    b = _img_batches(4, 2)
    full, _, _ = tr.local_phase(state, b, jax.random.key(1))
    half, _, _ = tr.local_phase(state, b, jax.random.key(1),
                                straggle=jnp.asarray([True, False]))
    trunc = {key: v[:2] for key, v in b.items()}  # τ_eff = 4·0.5 = 2
    want, _, _ = tr.local_phase(state, trunc, jax.random.key(1))
    for got, w, f in zip(jax.tree.leaves(half["workers"]),
                         jax.tree.leaves(want["workers"]),
                         jax.tree.leaves(full["workers"])):
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(w[0]),
                                   rtol=1e-5, atol=1e-6)  # straggler trunc'd
        np.testing.assert_array_equal(np.asarray(got[1]),
                                      np.asarray(f[1]))  # healthy untouched
    # at τ=1 the floor keeps every worker taking at least one step
    tr1 = _trainer(k=2, tau=1)
    s1 = tr1.init_state(jax.random.key(0))
    out, _, _ = tr1.local_phase(s1, _img_batches(1, 2), jax.random.key(1),
                                straggle=jnp.asarray([True, False]))
    assert any((np.asarray(a) != np.asarray(b)).any() for a, b in
               zip(jax.tree.leaves(out["workers"]),
                   jax.tree.leaves(s1["workers"])))


@pytest.mark.parametrize("comm_mode", ["sequential", "fused"])
def test_straggler_scores_against_stale_master(comm_mode):
    """Straggling workers measure u against the previous round's master
    snapshot; healthy workers see the live master. α=0 keeps the sequential
    scan's master frozen so both comm modes score against the same master."""
    tr = _trainer(k=2, comm_mode=comm_mode, alpha=0.0)
    state = tr.init_state(jax.random.key(0))
    state["workers"] = jax.tree.map(
        lambda x: x + jax.random.normal(jax.random.key(1), x.shape,
                                        x.dtype) * 0.1, state["workers"])
    state["master_prev"] = jax.tree.map(lambda x: x + 0.7, state["master"])
    straggle = jnp.asarray([True, False])
    _, m = tr.comm_phase(state, jnp.zeros(2, bool), straggle=straggle)
    w0 = jax.tree.map(lambda x: x[0], state["workers"])
    w1 = jax.tree.map(lambda x: x[1], state["workers"])
    np.testing.assert_allclose(
        float(m["u"][0]),
        float(dw.log_distance(w0, state["master_prev"])), rtol=1e-5)
    np.testing.assert_allclose(
        float(m["u"][1]),
        float(dw.log_distance(w1, state["master"])), rtol=1e-5)


def test_comm_phase_rolls_master_prev_snapshot():
    tr = _trainer(k=2)
    state = tr.init_state(jax.random.key(0))
    state["workers"] = jax.tree.map(lambda x: x + 0.1, state["workers"])
    new, _ = tr.comm_phase(state, jnp.zeros(2, bool))
    for a, b in zip(jax.tree.leaves(new["master_prev"]),
                    jax.tree.leaves(state["master"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_resets_params_keeps_score_history():
    tr = _trainer(k=2, opt="momentum")
    state = tr.init_state(jax.random.key(0))
    state["workers"] = jax.tree.map(lambda x: x + 1.0, state["workers"])
    state["opt"]["m"] = jax.tree.map(lambda x: x + 3.0, state["opt"]["m"])
    state["u_hist"] = jnp.asarray([[1.0, 2.0, 3.0, 4.0, 5.0]] * 2)
    restart = jnp.asarray([True, False])
    new = tr.apply_restarts(state, restart)
    for w, m in zip(jax.tree.leaves(new["workers"]),
                    jax.tree.leaves(state["master"])):
        np.testing.assert_allclose(np.asarray(w[0]), np.asarray(m),
                                   rtol=1e-6)  # rejoined ← master
    for w, old in zip(jax.tree.leaves(new["workers"]),
                      jax.tree.leaves(state["workers"])):
        np.testing.assert_array_equal(np.asarray(w[1]), np.asarray(old[1]))
    # optimizer accumulators and u-history survive the rejoin
    for a, b in zip(jax.tree.leaves(new["opt"]),
                    jax.tree.leaves(state["opt"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(new["u_hist"]),
                                  np.asarray(state["u_hist"]))


def test_restart_triggers_recovery_weights():
    """Post-rejoin the distance collapses against the recorded drift, so the
    dynamic score goes sharply negative: h1→1, h2→0 (§V-B recovery path)."""
    tr = _trainer(k=1, dynamic=True, score_k=-0.05)
    state = tr.init_state(jax.random.key(0))
    state["workers"] = jax.tree.map(lambda x: x + 2.0, state["workers"])
    state["u_hist"] = jnp.asarray([[6.0, 5.5, 5.0, 4.5, 4.0]])
    state = tr.apply_restarts(state, jnp.asarray([True]))
    state["workers"] = jax.tree.map(lambda x: x + 1e-4, state["workers"])
    _, m = tr.comm_phase(state, jnp.zeros(1, bool))
    assert float(m["score"][0]) < -0.05
    assert float(m["h1"][0]) == pytest.approx(1.0)
    assert float(m["h2"][0]) == pytest.approx(0.0)


def test_round_step_accepts_scenario_masks():
    tr = _trainer(k=2, tau=2)
    state = tr.init_state(jax.random.key(0))
    state, m = tr.round_step(state, RoundInputs(
        batches=_img_batches(2, 2), rng=jax.random.key(1),
        fail=jnp.asarray([False, True]), failed_recent=jnp.zeros(2, bool),
        straggle=jnp.asarray([True, False]),
        restart=jnp.asarray([False, True])))
    assert bool(jnp.isfinite(m["loss"]))
    assert int(state["round"]) == 1


def test_round_chunk_scans_stacked_inputs():
    """round_chunk over stacked (R, ...) inputs is bit-identical to R
    round_step calls (the jit-scanned multi-round core of ISSUE-3).

    Both paths donate their state buffers (ISSUE-4: no double-buffering of
    the (k × params) worker state), so this equality also asserts donation
    changes no results; the two runs start from independently-initialized
    (bit-identical) states because a donated state must not be reused."""
    tr = _trainer(k=2, tau=2)
    R = 3
    rng = np.random.default_rng(0)
    batches = {k: jnp.stack([v + i for i in range(R)])
               for k, v in _img_batches(2, 2).items()}
    fail = jnp.asarray(rng.random((R, 2)) < 0.5)
    recent = jnp.zeros((R, 2), bool)
    keys = jnp.stack([jax.random.key(r) for r in range(R)])
    restart = jnp.asarray(rng.random((R, 2)) < 0.3)

    state = tr.init_state(jax.random.key(0))
    want = tr.init_state(jax.random.key(0))
    for r in range(R):
        want, wm = tr.round_step(want, RoundInputs(
            batches={k: v[r] for k, v in batches.items()}, rng=keys[r],
            fail=fail[r], failed_recent=recent[r], restart=restart[r]))
    got, gm = tr.round_chunk(state, RoundInputs(
        batches=batches, rng=keys, fail=fail, failed_recent=recent,
        restart=restart))
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert gm["loss"].shape == (R,) and gm["h2"].shape == (R, 2)
    np.testing.assert_array_equal(np.asarray(wm["h2"]),
                                  np.asarray(gm["h2"][-1]))


# ---------------------------------------------------------------------------
# scenario regression: the paper's core claim, machine-checked per regime
# ---------------------------------------------------------------------------

# Short synthetic runs (k=4, τ=2, 10 communication rounds on 256 images).
# Seed and tolerance calibrated over seeds 1–3: the observed degradation gap
# stays within ±0.27 nats, so 0.5 flags regressions without flaking.
REG_KW = dict(k=4, tau=2, rounds=10, batch_size=8, n_data=256, n_test=128,
              eval_every=5, seed=1)
REG_TOL = 0.5


@functools.lru_cache(maxsize=None)
def _final_master_loss(method, scenario):
    """Master test-loss averaged over the last two evals; scenario=None is
    the no-failure control."""
    from repro.experiments.paper_repro import run_one

    kw = dict(REG_KW)
    if scenario is None:
        kw["failure_prob"] = 0.0
    else:
        kw["failure_scenario"] = scenario
    if scenario == "byzantine":
        # frac=0.5 guarantees corrupt slots at this seed (the default 0.25
        # draws none); the clip is what keeps the dynamic arm finite —
        # weights_for exempts the fixed-α arm, which is the point
        kw["byzantine_frac"] = 0.5
        kw["score_clip"] = 0.5
    res = run_one(method, **kw)
    return float(np.mean(res["curves"]["test_loss"][-2:]))


# per-scenario (relative tol, absolute DEAHES blow-up guard). hetero is
# wider: persistent slow slots hug the master, the dynamic maps read that
# as "nothing to merge" and the master trains on fewer effective samples —
# measured gap 0.93 worst-case over seeds 1–3 vs EASGD's ≈ 0.
_REG_BOUNDS = {"hetero": (1.2, 1.5)}


@pytest.mark.parametrize("scenario", [
    "burst",
    "crash_restart",
    "hetero",
    pytest.param("iid", marks=pytest.mark.slow),
    pytest.param("correlated", marks=pytest.mark.slow),
    pytest.param("straggler", marks=pytest.mark.slow),
])
def test_dynamic_weighting_degrades_no_more_than_easgd(scenario):
    """The paper's core claim, per failure regime: failures cost DEAHES-O no
    more master loss than they cost fixed-α EASGD (each measured against its
    own no-failure control, so the optimizer difference cancels out)."""
    tol, guard = _REG_BOUNDS.get(scenario, (REG_TOL, 1.0))
    deg = {}
    for method in ("EASGD", "DEAHES-O"):
        clean = _final_master_loss(method, None)
        failed = _final_master_loss(method, scenario)
        assert np.isfinite(failed), f"{method} diverged under {scenario}"
        deg[method] = failed - clean
    # absolute blow-up guard: a scenario must never wreck the dynamic method
    # outright (e.g. the crash-rejoin cold-start transient, now fixed)
    assert deg["DEAHES-O"] < guard
    assert deg["DEAHES-O"] <= deg["EASGD"] + tol


def test_byzantine_wrecks_easgd_but_not_clipped_deahes():
    """Adversarial regression (ISSUE-9): sign-flip gradient corruption is
    *lethal* to fixed-α EASGD — the corrupt workers diverge past float32
    range, h2 = α keeps merging them (a NaN score falls through both h2
    comparisons to the α branch), and the master NaN-poisons within ~4
    rounds. DEAHES-O with the score_clip clamp + quarantine stays finite:
    runaway slots are refused and re-seated. The degradation itself is
    large (the clip's warm-up freeze costs rounds, and the honest pool
    shrinks to half) — the committed claim is survival, not parity; the
    per-slot down-weighting numbers live in tests/test_adversarial.py."""
    easgd = _final_master_loss("EASGD", "byzantine")
    deahes = _final_master_loss("DEAHES-O", "byzantine")
    assert not np.isfinite(easgd), (
        "fixed-α EASGD now survives sign-flip corruption — if the maps "
        f"changed, re-measure and update this regression (got {easgd})")
    assert np.isfinite(deahes), "clipped DEAHES-O diverged under byzantine"
