"""Optimizer tests: Hutchinson exactness, AdaHessian vs oracle, convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _property_shim import given, strategies as st

from repro.configs.base import OptimizerConfig
from repro.optim.adahessian import spatial_average
from repro.optim.base import apply_updates, make_optimizer
from repro.optim.hutchinson import hessian_diag, hvp, rademacher_like


def quad(A):
    return lambda x: 0.5 * x @ A @ x


def test_hvp_exact_on_quadratic():
    A = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)))
    A = A @ A.T
    x = jnp.ones(8)
    z = jnp.asarray(np.random.default_rng(1).standard_normal(8))
    np.testing.assert_allclose(hvp(jax.grad(quad(A)), x, z), A @ z,
                               rtol=1e-5)


def test_hutchinson_exact_for_diagonal_hessian():
    d = jnp.linspace(0.5, 4.0, 16)
    A = jnp.diag(d)
    est = hessian_diag(jax.grad(quad(A)), jnp.ones(16), jax.random.key(0), 1)
    # Rademacher z: z ⊙ (Az) = z² ⊙ diag = diag exactly for diagonal A
    np.testing.assert_allclose(est, d, rtol=1e-5)


def test_hutchinson_unbiased_dense():
    rng = np.random.default_rng(2)
    A = jnp.asarray(rng.standard_normal((12, 12)))
    A = A @ A.T
    est = hessian_diag(jax.grad(quad(A)), jnp.zeros(12),
                       jax.random.key(3), num_samples=800)
    np.testing.assert_allclose(est, jnp.diag(A), rtol=0.35, atol=0.5)


@pytest.mark.parametrize("num_samples", [3, 4])
def test_hessian_diag_scan_matches_unrolled(num_samples):
    """The lax.scan probe accumulation (ISSUE-7) is bit-exact with the old
    unrolled Python loop — same keys, same left-to-right add order."""
    rng = np.random.default_rng(5)
    A = jnp.asarray(rng.standard_normal((12, 12)), jnp.float32)
    A = A @ A.T
    params = {"x": jnp.asarray(rng.standard_normal(12), jnp.float32),
              "y": jnp.asarray(rng.standard_normal((3, 4)), jnp.float32)}
    loss = lambda p: quad(A)(p["x"]) + jnp.sum(jnp.square(p["y"])) * 0.5
    gf = jax.grad(loss)
    key = jax.random.key(7)

    def unrolled(rng_, n):
        keys = jax.random.split(rng_, n)
        acc = None
        for k in keys:
            from repro.optim.hutchinson import rademacher_like as rl
            z = rl(k, params)
            hz = hvp(gf, params, z)
            cur = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) * b.astype(jnp.float32),
                z, hz)
            acc = cur if acc is None else jax.tree.map(jnp.add, acc, cur)
        return jax.tree.map(lambda x: x / n, acc)

    got = jax.jit(lambda: hessian_diag(gf, params, key, num_samples))()
    want = jax.jit(lambda: unrolled(key, num_samples))()
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hessian_diag_with_grad_matches_separate():
    """linearize-shared gradient + probes == value_and_grad + jvp probes,
    bitwise (the fused local phase relies on this)."""
    from repro.optim.hutchinson import hessian_diag_with_grad

    rng = np.random.default_rng(6)
    A = jnp.asarray(rng.standard_normal((10, 10)), jnp.float32)
    A = A @ A.T
    params = {"x": jnp.asarray(rng.standard_normal(10), jnp.float32)}
    loss = lambda p: quad(A)(p["x"])
    gf = jax.grad(loss)
    key = jax.random.key(11)
    for n in (1, 3):
        g1, d1 = jax.jit(
            lambda p, k: hessian_diag_with_grad(gf, p, k, n))(params, key)
        g2 = jax.jit(gf)(params)
        d2 = jax.jit(
            lambda p, k: hessian_diag(gf, p, k, n))(params, key)
        for a, b in zip(jax.tree.leaves((g1, d1)), jax.tree.leaves((g2, d2))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rademacher_values():
    z = rademacher_like(jax.random.key(0), {"a": jnp.zeros((100,))})
    assert set(np.unique(np.asarray(z["a"]))) <= {-1.0, 1.0}


@given(block=st.integers(1, 64), d=st.integers(1, 96))
def test_spatial_average_preserves_mean_abs(block, d):
    x = jnp.asarray(np.random.default_rng(d).standard_normal((3, d)))
    y = spatial_average(x, block)
    assert y.shape == x.shape
    np.testing.assert_allclose(jnp.mean(y), jnp.mean(jnp.abs(x)), rtol=1e-4)
    assert (np.asarray(y) >= 0).all()


def test_spatial_average_block_constant():
    x = jnp.arange(8.0).reshape(1, 8)
    y = spatial_average(x, 4)
    np.testing.assert_allclose(y[0, :4], jnp.full(4, jnp.mean(x[0, :4])))


@pytest.mark.parametrize("name,lr", [("sgd", 0.05), ("momentum", 0.03),
                                     ("adam", 0.1), ("adahessian", 0.3)])
def test_optimizers_converge_on_quadratic(name, lr):
    d = jnp.linspace(1.0, 5.0, 10)
    A = jnp.diag(d)
    loss = quad(A)
    gf = jax.grad(loss)
    cfg = OptimizerConfig(name=name, lr=lr, spatial_block=1)
    opt = make_optimizer(cfg)
    x = jnp.ones(10)
    st_ = opt.init(x)
    for i in range(150):
        extras = None
        if opt.needs_hessian:
            extras = {"hess_diag": hessian_diag(gf, x, jax.random.key(i), 1)}
        u, st_ = opt.update(gf(x), st_, x, extras)
        x = apply_updates(x, u)
    assert float(loss(x)) < 1e-3


def test_adahessian_requires_hessian():
    opt = make_optimizer(OptimizerConfig(name="adahessian"))
    x = jnp.ones(4)
    with pytest.raises(AssertionError):
        opt.update(x, opt.init(x), x, None)


def test_adahessian_scale_invariant_step_on_quadratic():
    """Second-order preconditioning ⇒ ill-conditioning barely matters."""
    for cond in (1.0, 100.0):
        d = jnp.linspace(1.0, cond, 10)
        loss = quad(jnp.diag(d))
        gf = jax.grad(loss)
        cfg = OptimizerConfig(name="adahessian", lr=0.5, spatial_block=1)
        opt = make_optimizer(cfg)
        x = jnp.ones(10)
        s = opt.init(x)
        for i in range(100):
            ex = {"hess_diag": hessian_diag(gf, x, jax.random.key(i), 1)}
            u, s = opt.update(gf(x), s, x, ex)
            x = apply_updates(x, u)
        assert float(loss(x)) < 1e-2, f"cond={cond}"
