"""Per-kernel allclose vs pure-jnp oracles (interpret mode), with
shape/dtype sweeps as required for every Pallas kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig

pytestmark = pytest.mark.pallas  # interpret-mode kernel checks


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import flash_attention_bshd
from repro.kernels.flash_attention.ref import mha_reference


def _qkv(seed, B, H, KVH, S, D, dtype):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, H, S, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, KVH, S, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, KVH, S, D)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("B,H,KVH,S,D", [
    (1, 2, 2, 128, 64),     # MHA
    (2, 4, 2, 256, 64),     # GQA
    (1, 8, 1, 128, 128),    # MQA, 128 lanes
])
def test_flash_shape_dtype_sweep(B, H, KVH, S, D, dtype, tol):
    q, k, v = _qkv(0, B, H, KVH, S, D, dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    ref = mha_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("mask_kw", [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=96),
    dict(causal=True, window=17),
    dict(causal=True, chunk=64),
])
def test_flash_mask_variants(mask_kw):
    q, k, v = _qkv(1, 2, 2, 2, 256, 64, jnp.float32)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True,
                          **mask_kw)
    ref = mha_reference(q, k, v, **mask_kw)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_flash_bshd_wrapper_matches_layers_layout():
    q, k, v = _qkv(2, 2, 4, 2, 128, 64, jnp.float32)
    o1 = flash_attention_bshd(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                              jnp.moveaxis(v, 1, 2), block_q=64, block_k=64)
    o2 = mha_reference(q, k, v)
    np.testing.assert_allclose(jnp.moveaxis(o1, 2, 1), o2, rtol=3e-5,
                               atol=3e-5)


# ---------------------------------------------------------------------------
# fused elastic update
# ---------------------------------------------------------------------------

from repro.core.elastic import elastic_update
from repro.kernels.elastic.ops import elastic_update_pallas


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shapes", [
    [(128,)], [(300, 17), (41,)], [(1000, 130), (5, 5, 5), ()],
])
def test_elastic_kernel_sweep(dtype, shapes):
    kw = jax.random.split(jax.random.key(0), 2 * len(shapes))
    w = {f"p{i}": jax.random.normal(kw[2 * i], s).astype(dtype)
         for i, s in enumerate(shapes)}
    m = {f"p{i}": jax.random.normal(kw[2 * i + 1], s).astype(dtype)
         for i, s in enumerate(shapes)}
    w1, m1 = elastic_update_pallas(w, m, 0.25, 0.07)
    w2, m2 = elastic_update(w, m, 0.25, 0.07)
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    for key in w:
        np.testing.assert_allclose(np.asarray(w1[key], np.float32),
                                   np.asarray(w2[key], np.float32),
                                   rtol=tol, atol=tol)
        np.testing.assert_allclose(np.asarray(m1[key], np.float32),
                                   np.asarray(m2[key], np.float32),
                                   rtol=tol, atol=tol)


def test_elastic_kernel_identity_cases():
    w = {"a": jnp.ones((256, 128))}
    m = {"a": jnp.zeros((256, 128))}
    # h1=1, h2=0: worker snaps to master, master untouched
    w1, m1 = elastic_update_pallas(w, m, 1.0, 0.0)
    np.testing.assert_allclose(w1["a"], 0.0)
    np.testing.assert_allclose(m1["a"], 0.0)
    # h1=0, h2=0: no-op
    w1, m1 = elastic_update_pallas(w, m, 0.0, 0.0)
    np.testing.assert_allclose(w1["a"], 1.0)


# ---------------------------------------------------------------------------
# fused adahessian
# ---------------------------------------------------------------------------

from repro.kernels.adahessian.ops import adahessian_step_pallas
from repro.kernels.adahessian.ref import adahessian_step_ref


@pytest.mark.parametrize("n", [100, 32768, 50000])
@pytest.mark.parametrize("t", [1, 100])
def test_adahessian_kernel_sweep(n, t):
    cfg = OptimizerConfig(lr=0.02, betas=(0.9, 0.999))
    r = lambda i: jax.random.normal(jax.random.key(i), (n,))
    p, g, h, m = r(1), r(2), r(3), r(4)
    v = jnp.abs(r(5))
    out_k = adahessian_step_pallas(p, g, h, m, v, cfg, t)
    out_r = adahessian_step_ref(p, g, h, m, v, cfg, t)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-6)


def test_adahessian_kernel_hessian_power():
    cfg = OptimizerConfig(lr=0.02, hessian_power=0.5)
    n = 1000
    r = lambda i: jax.random.normal(jax.random.key(i), (n,))
    p, g, h, m = r(1), r(2), r(3), r(4)
    v = jnp.abs(r(5))
    out_k = adahessian_step_pallas(p, g, h, m, v, cfg, 3)
    out_r = adahessian_step_ref(p, g, h, m, v, cfg, 3)
    np.testing.assert_allclose(out_k[0], out_r[0], rtol=2e-5, atol=2e-6)
