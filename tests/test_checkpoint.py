"""Checkpoint roundtrip tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint


def _tree():
    return {
        "params": {
            "w": jnp.arange(12.0).reshape(3, 4),
            "b": jnp.ones((4,), jnp.bfloat16),
            "nested": {"scale": jnp.asarray(2.5)},
        },
        "opt": {"count": jnp.asarray(7, jnp.int32),
                "m": [jnp.zeros(3), jnp.ones(2)]},
    }


def test_roundtrip_structure_and_values(tmp_path):
    t = _tree()
    checkpoint.save(str(tmp_path / "ck"), t, metadata={"round": 3})
    restored, meta = checkpoint.restore(str(tmp_path / "ck"), like=t)
    assert meta["round"] == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_restore_without_like(tmp_path):
    t = _tree()
    checkpoint.save(str(tmp_path / "ck"), t)
    restored, _ = checkpoint.restore(str(tmp_path / "ck"))
    np.testing.assert_allclose(restored["params"]["w"], t["params"]["w"])
    assert isinstance(restored["opt"]["m"], list)
    assert len(restored["opt"]["m"]) == 2


def test_model_params_roundtrip(tmp_path):
    from repro.configs.base import get_config
    from repro.models.registry import build_model
    from repro.nn.param import init_tree

    model = build_model(get_config("stablelm_3b", smoke=True))
    p = init_tree(jax.random.key(0), model.spec)
    checkpoint.save(str(tmp_path / "ck"), p)
    r, _ = checkpoint.restore(str(tmp_path / "ck"), like=p)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
