"""Checkpoint roundtrip tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint


def _tree():
    return {
        "params": {
            "w": jnp.arange(12.0).reshape(3, 4),
            "b": jnp.ones((4,), jnp.bfloat16),
            "nested": {"scale": jnp.asarray(2.5)},
        },
        "opt": {"count": jnp.asarray(7, jnp.int32),
                "m": [jnp.zeros(3), jnp.ones(2)]},
    }


def test_roundtrip_structure_and_values(tmp_path):
    t = _tree()
    checkpoint.save(str(tmp_path / "ck"), t, metadata={"round": 3})
    restored, meta = checkpoint.restore(str(tmp_path / "ck"), like=t)
    assert meta["round"] == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_restore_without_like(tmp_path):
    t = _tree()
    checkpoint.save(str(tmp_path / "ck"), t)
    restored, _ = checkpoint.restore(str(tmp_path / "ck"))
    np.testing.assert_allclose(restored["params"]["w"], t["params"]["w"])
    assert isinstance(restored["opt"]["m"], list)
    assert len(restored["opt"]["m"]) == 2


def test_large_leaf_chunks_across_shards(tmp_path):
    """ISSUE-5 satellite: a leaf bigger than MAX_SHARD_BYTES is split into
    flat chunks spread over >= 2 npz shards and reassembled bit-exactly,
    with smaller leaves packed around it and dtype restoration intact."""
    tree = {
        "big": np.arange(5000, dtype=np.float32).reshape(50, 100),  # 20 kB
        "small": jnp.ones((7,), jnp.bfloat16),
        "scalar": np.asarray(3, np.int32),
    }
    path = str(tmp_path / "ck")
    with pytest.MonkeyPatch.context() as mp:
        mp.setattr(checkpoint, "MAX_SHARD_BYTES", 4096)
        checkpoint.save(path, tree, metadata={"round": 9})
    shards = sorted(p.name for p in tmp_path.glob("ck/shard_*.npz"))
    assert len(shards) >= 2
    import json

    with open(tmp_path / "ck" / "manifest.json") as f:
        manifest = json.load(f)
    assert len(manifest["keys"]["big"]["parts"]) >= 2
    assert "shard" in manifest["keys"]["small"]

    restored, meta = checkpoint.restore(path)
    assert meta["round"] == 9
    np.testing.assert_array_equal(restored["big"], tree["big"])
    assert restored["big"].shape == (50, 100)
    # like-restore reassembles and casts identically
    r2, _ = checkpoint.restore(path, like=tree)
    assert r2["small"].dtype == jnp.bfloat16
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(r2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_elastic_manifest_reseat_different_capacity():
    """The membership manifest re-seats live slots' u-histories into pools
    of any capacity: live rows map onto the new active slots in order,
    everything else is blank fill."""
    hist = np.arange(20, dtype=np.float32).reshape(4, 5)
    el = checkpoint.elastic_manifest(np.array([1, 0, 1, 0], bool), hist)
    assert el["capacity"] == 4

    # grow: 2 live rows land in the first 2 of 3 active slots of 8
    out = checkpoint.reseat_u_hist(el, 8, np.arange(8) < 3, window=5)
    np.testing.assert_array_equal(out[0], hist[0])
    np.testing.assert_array_equal(out[1], hist[2])
    assert (out[2:] == checkpoint.U_HIST_FILL).all()

    # shrink: only the first live row fits a 1-slot pool
    out = checkpoint.reseat_u_hist(el, 1, np.ones(1, bool), window=5)
    np.testing.assert_array_equal(out[0], hist[0])

    # window change aligns on the newest entries
    out = checkpoint.reseat_u_hist(el, 4, np.ones(4, bool), window=3)
    np.testing.assert_array_equal(out[0], hist[0, 2:])

    # missing/garbled manifests degrade to blank histories
    assert (checkpoint.reseat_u_hist(None, 4, np.ones(4, bool), 5)
            == checkpoint.U_HIST_FILL).all()
    assert (checkpoint.reseat_u_hist({"active": [1]}, 4, np.ones(4, bool), 5)
            == checkpoint.U_HIST_FILL).all()


def test_read_metadata_is_cheap(tmp_path):
    path = str(tmp_path / "ck")
    checkpoint.save(path, _tree(), metadata={"arch": "paper-cnn"})
    assert checkpoint.read_metadata(path)["arch"] == "paper-cnn"


def test_model_params_roundtrip(tmp_path):
    from repro.configs.base import get_config
    from repro.models.registry import build_model
    from repro.nn.param import init_tree

    model = build_model(get_config("stablelm_3b", smoke=True))
    p = init_tree(jax.random.key(0), model.spec)
    checkpoint.save(str(tmp_path / "ck"), p)
    r, _ = checkpoint.restore(str(tmp_path / "ck"), like=p)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
