"""Coordinator semantics: EASGD fixed-α equivalence, failure suppression,
dynamic-weight reaction, u-history bookkeeping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ElasticConfig, OptimizerConfig, get_config
from repro.core.coordinator import ElasticTrainer, tree_stack_copies
from repro.core.elastic import elastic_update
from repro.models.registry import build_model


def _trainer(k=2, **kw):
    model = build_model(get_config("paper_cnn"))
    defaults = dict(num_workers=k, tau=1, alpha=0.1, dynamic=False)
    defaults.update(kw)
    return ElasticTrainer(model, OptimizerConfig(name="sgd", lr=0.01),
                          ElasticConfig(**defaults))


def _get(workers, i):
    return jax.tree.map(lambda x: x[i], workers)


def test_comm_phase_fixed_alpha_matches_manual():
    tr = _trainer(k=2)
    state = tr.init_state(jax.random.key(0))
    # desync the workers so the elastic pull is non-trivial
    state["workers"] = jax.tree.map(
        lambda x: x + jax.random.normal(jax.random.key(1), x.shape,
                                        x.dtype) * 0.1, state["workers"])
    fail = jnp.zeros(2, bool)
    new, m = tr.comm_phase(state, fail)
    # manual sequential EASGD with α=0.1
    master = state["master"]
    for i in range(2):
        w_i = _get(state["workers"], i)
        w_new, master = elastic_update(w_i, master, 0.1, 0.1)
        got = _get(new["workers"], i)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(w_new)):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(new["master"]), jax.tree.leaves(master)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_failed_worker_exchanges_nothing():
    tr = _trainer(k=2)
    state = tr.init_state(jax.random.key(0))
    state["workers"] = jax.tree.map(
        lambda x: x + 0.5, state["workers"])  # force distance
    fail = jnp.asarray([True, False])
    new, m = tr.comm_phase(state, fail)
    # worker 0 params unchanged; master got no pull from worker 0
    w0_before = _get(state["workers"], 0)
    w0_after = _get(new["workers"], 0)
    for a, b in zip(jax.tree.leaves(w0_before), jax.tree.leaves(w0_after)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(m["h1"][0]) == 0.0 and float(m["h2"][0]) == 0.0
    assert float(m["h2"][1]) == pytest.approx(0.1)
    # but its u-history still advanced (worker-worker estimation, §V-B)
    assert float(new["u_hist"][0, -1]) != float(state["u_hist"][0, -1])


def test_dynamic_weight_reacts_to_shrinking_distance():
    """Post-failure recovery: distance dropping fast ⇒ negative score ⇒
    h1→1 (snap back), h2→0 (master protects itself) — paper §V-B."""
    tr = _trainer(k=1, dynamic=True, score_k=-0.05)
    state = tr.init_state(jax.random.key(0))
    # history says the worker was far; now it is very close again → the
    # appended u drops sharply (recovery signature)
    state["u_hist"] = jnp.asarray([[6.0, 5.0, 4.0, 3.0, 2.0]])
    state["workers"] = jax.tree.map(lambda x: x + 1e-4, state["workers"])
    new, m = tr.comm_phase(state, jnp.zeros(1, bool))
    assert float(m["score"][0]) < -0.05
    assert float(m["h1"][0]) == pytest.approx(1.0)
    assert float(m["h2"][0]) == pytest.approx(0.0)


def test_dynamic_weight_healthy_is_easgd():
    tr = _trainer(k=1, dynamic=True)
    state = tr.init_state(jax.random.key(0))
    state["u_hist"] = jnp.asarray([[0.0, 0.01, 0.02, 0.03, 0.04]])
    # keep the real u from moving the trend negative: tiny drift
    state["workers"] = jax.tree.map(
        lambda x: x + 1.0, state["workers"])  # large distance → u rises
    new, m = tr.comm_phase(state, jnp.zeros(1, bool))
    assert float(m["score"][0]) > 0
    assert float(m["h1"][0]) == pytest.approx(0.1)
    assert float(m["h2"][0]) == pytest.approx(0.1)


def test_round_counter_and_hist_roll():
    tr = _trainer(k=2)
    state = tr.init_state(jax.random.key(0))
    new, _ = tr.comm_phase(state, jnp.zeros(2, bool))
    assert int(new["round"]) == 1
    assert new["u_hist"].shape == (2, 5)


def test_local_phase_trains_each_worker_independently():
    tr = _trainer(k=2, tau=2)
    state = tr.init_state(jax.random.key(0))
    b = {"images": jax.random.normal(jax.random.key(1), (2, 2, 8, 28, 28, 1)),
         "labels": jnp.zeros((2, 2, 8), jnp.int32)}
    new, loss, loss_w = tr.local_phase(state, b, jax.random.key(2))
    assert bool(jnp.isfinite(loss))
    assert loss_w.shape == (2,) and bool(jnp.all(jnp.isfinite(loss_w)))
    # workers diverge (different data), master untouched
    w0 = jax.tree.leaves(_get(new["workers"], 0))
    w1 = jax.tree.leaves(_get(new["workers"], 1))
    assert any(float(jnp.abs(a - b).max()) > 0 for a, b in zip(w0, w1))
    for a, b in zip(jax.tree.leaves(new["master"]),
                    jax.tree.leaves(state["master"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tree_stack_copies():
    t = {"a": jnp.arange(3.0)}
    s = tree_stack_copies(t, 4)
    assert s["a"].shape == (4, 3)
    np.testing.assert_allclose(s["a"][2], t["a"])
