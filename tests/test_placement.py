"""Sharded placement (ISSUE-4): mesh axis shapes, config/trainer
validation, state donation, and the core acceptance property — the
shard_mapped worker axis produces master params bit-exact with the
single-device fused path.

The multi-device checks run in a subprocess (the device count is locked at
jax init; ``--xla_force_host_platform_device_count=4`` forces a 4-device
CPU host). The in-process checks run on the default single device, where a
pod=1 mesh exercises the full shard_map code path.
"""
import os
import subprocess
import sys

import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ElasticConfig, OptimizerConfig, get_config
from repro.core.coordinator import ElasticTrainer
from repro.models.registry import build_model

ROOT = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# mesh builders: axis shapes
# ---------------------------------------------------------------------------

def test_production_mesh_axis_shapes(monkeypatch):
    """Both production meshes request the documented (shape, axes) pairs —
    checked by capturing the jax.make_mesh call, since building them needs
    256/512 real devices."""
    import repro.launch.mesh as mesh_mod

    calls = []
    monkeypatch.setattr(mesh_mod.jax, "make_mesh",
                        lambda shape, axes: calls.append((shape, axes)))
    mesh_mod.make_production_mesh()
    mesh_mod.make_production_mesh(multi_pod=True)
    assert calls[0] == ((16, 16), ("data", "model"))
    assert calls[1] == ((2, 16, 16), ("pod", "data", "model"))


def test_host_mesh_axis_shapes(monkeypatch):
    import repro.launch.mesh as mesh_mod

    calls = []
    monkeypatch.setattr(mesh_mod.jax, "make_mesh",
                        lambda shape, axes: calls.append((shape, axes)))
    mesh_mod.make_host_mesh()
    mesh_mod.make_host_mesh(pod=4)
    mesh_mod.make_host_mesh(pod=2, data=3, model=5)
    assert calls == [((1, 1, 1), ("pod", "data", "model")),
                     ((4, 1, 1), ("pod", "data", "model")),
                     ((2, 3, 5), ("pod", "data", "model"))]


def test_host_mesh_real_single_device():
    """On the default 1-device host the trivial mesh actually builds, with
    all three axes present (uniform axis names across host/production)."""
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    assert dict(mesh.shape) == {"pod": 1, "data": 1, "model": 1}


# ---------------------------------------------------------------------------
# config / trainer validation
# ---------------------------------------------------------------------------

def test_placement_validated():
    with pytest.raises(ValueError):
        ElasticConfig(placement="nope")


def test_sharded_requires_fused_comm():
    with pytest.raises(ValueError, match="fused"):
        ElasticConfig(placement="sharded", comm_mode="sequential")
    ElasticConfig(placement="sharded", comm_mode="fused")  # ok


def _sharded_trainer(k, mesh):
    model = build_model(get_config("paper_cnn"))
    return ElasticTrainer(
        model, OptimizerConfig(name="sgd", lr=0.01),
        ElasticConfig(num_workers=k, comm_mode="fused",
                      placement="sharded"), mesh=mesh)


def test_sharded_trainer_requires_mesh():
    with pytest.raises(ValueError, match="mesh"):
        _sharded_trainer(4, None)


def test_sharded_trainer_requires_pod_axis():
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="pod"):
        _sharded_trainer(4, mesh)


def test_sharded_trainer_requires_divisible_workers():
    class FakeMesh:
        shape = {"pod": 3}
        axis_names = ("pod",)

    with pytest.raises(ValueError, match="divide"):
        _sharded_trainer(4, FakeMesh())


def test_session_rejects_mesh_under_single_placement():
    """A mesh passed to a single-placement session would be silently
    ignored — that's a misconfiguration, surfaced at construction."""
    from repro.api import ElasticSession, RunSpec
    from repro.launch.mesh import make_host_mesh

    spec = RunSpec(arch="paper-cnn",
                   elastic=ElasticConfig(num_workers=2))
    with pytest.raises(ValueError, match="placement"):
        ElasticSession(spec, mesh=make_host_mesh())


# ---------------------------------------------------------------------------
# donation: round state buffers are single-buffered
# ---------------------------------------------------------------------------

def test_round_state_donated():
    """round_step donates its state: the input buffers are consumed (reuse
    raises), so chunked runs stop double-buffering the (k × params) worker
    state. Result-equality under donation is asserted by
    tests/test_scenarios.py::test_round_chunk_scans_stacked_inputs and the
    session equivalence suite."""
    from repro.core.coordinator import RoundInputs

    model = build_model(get_config("paper_cnn"))
    tr = ElasticTrainer(model, OptimizerConfig(name="sgd", lr=0.01),
                        ElasticConfig(num_workers=2, tau=1))
    state = tr.init_state(jax.random.key(0))
    probe = jax.tree.leaves(state["workers"])[0]
    batches = {
        "images": jnp.zeros((1, 2, 4, 28, 28, 1), jnp.float32),
        "labels": jnp.zeros((1, 2, 4), jnp.int32),
    }
    new_state, _ = tr.round_step(state, RoundInputs(
        batches=batches, rng=jax.random.key(1),
        fail=jnp.zeros(2, bool), failed_recent=jnp.zeros(2, bool)))
    assert probe.is_deleted()
    assert not jax.tree.leaves(new_state["workers"])[0].is_deleted()


# ---------------------------------------------------------------------------
# pod=1 shard_map path on the default single device
# ---------------------------------------------------------------------------

def test_sharded_pod1_matches_single_bit_exact():
    """placement='sharded' over a trivial pod=1 mesh runs the whole
    shard_map machinery on one device and must match single placement
    bit-for-bit (k_loc == k, so even the vmap widths agree)."""
    from repro.api import ElasticSession, RunSpec

    def run(placement):
        spec = RunSpec(
            arch="paper-cnn", optimizer=OptimizerConfig(name="sgd", lr=0.01),
            elastic=ElasticConfig(num_workers=2, tau=1, dynamic=True,
                                  comm_mode="fused", placement=placement),
            rounds=2, seed=1, batch_size=4, n_data=64, n_test=32)
        sess = ElasticSession(spec)
        return sess, sess.run()

    s1, r1 = run("single")
    s2, r2 = run("sharded")
    for a, b in zip(jax.tree.leaves(s1.master_params),
                    jax.tree.leaves(s2.master_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(r1, r2):
        assert a.loss == b.loss
        np.testing.assert_array_equal(a.h2, b.h2)


# ---------------------------------------------------------------------------
# the acceptance property, on a real 4-device host mesh (subprocess)
# ---------------------------------------------------------------------------

_SUBPROCESS_EQUIV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import jax
import numpy as np
from repro.api import ElasticSession, RunSpec
from repro.configs.base import ElasticConfig, OptimizerConfig

assert jax.device_count() == 4

def run(placement, k, scenario, rpc):
    spec = RunSpec(
        arch="paper-cnn", optimizer=OptimizerConfig(name="sgd", lr=0.01),
        elastic=ElasticConfig(num_workers=k, tau=2, dynamic=True,
                              comm_mode="fused", placement=placement,
                              failure_scenario=scenario),
        rounds=4, rounds_per_call=rpc, seed=1, batch_size=4,
        n_data=96, n_test=32)
    sess = ElasticSession(spec)
    return sess, sess.run()

cases = ([(4, s, rpc) for s in ("iid", "crash_restart") for rpc in (1, 2)]
         + [(8, "straggler", 2)])
for k, scenario, rpc in cases:
    s1, r1 = run("single", k, scenario, rpc)
    s2, r2 = run("sharded", k, scenario, rpc)
    assert s2.mesh.shape["pod"] == 4
    for a, b in zip(jax.tree.leaves(s1.master_params),
                    jax.tree.leaves(s2.master_params)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            (k, scenario, rpc, "master not bit-exact")
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(a.h2, b.h2)
        np.testing.assert_array_equal(a.u, b.u)
        # the scalar mean-loss metric may differ in the last ulp (its
        # totals are psum-reduced per shard, re-associating the sum); the
        # state itself is exact
        np.testing.assert_allclose(a.loss, b.loss, rtol=1e-6)
    print("OK", k, scenario, rpc)
print("EQUIV_OK")
"""

_SUBPROCESS_LOWERING = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import jax
import repro.launch.dryrun as dr
from repro.configs.base import ShapeConfig, get_config
from repro.launch.mesh import make_host_mesh

# the real dryrun elastic branch, shrunk: 2 pods x 2-way model axis, smoke
# config, tiny train shape
dr.make_production_mesh = lambda multi_pod=False: make_host_mesh(
    pod=2, data=1, model=2)
dr.get_config = lambda arch, smoke=False: get_config(arch, smoke=True)
dr.INPUT_SHAPES["tiny_train"] = ShapeConfig("tiny_train", 64, 4, "train")
out = dr.dryrun_one("qwen3_4b", "tiny_train", multi_pod=True)
assert out["status"] == "ok", out
assert out["lowered_kind"] == "elastic_round_step_sharded"
assert out["devices"] == 4
# capacity-padded pool (ISSUE-5): capacity 3 pads to 4 over the 2-way pod
# axis and lowers the membership-masked round (active/join inputs)
out = dr.dryrun_one("qwen3_4b", "tiny_train", multi_pod=True,
                    elastic_capacity=3)
assert out["status"] == "ok", out
print("LOWERING_OK")
"""


def _run_sub(code, timeout):
    return subprocess.run([sys.executable, "-c", code], cwd=ROOT,
                          capture_output=True, text=True, timeout=timeout)


def test_sharded_master_bit_exact_vs_single_4dev():
    """The ISSUE-4 acceptance bar: on a forced 4-device host mesh, sharded
    placement reproduces the single-device fused master bit-for-bit across
    {iid, crash_restart} (k=4, both per-round and chunked execution) and
    under straggler stale-master scoring at k=8 (two workers per shard)."""
    out = _run_sub(_SUBPROCESS_EQUIV, timeout=540)
    assert "EQUIV_OK" in out.stdout, out.stdout + out.stderr[-3000:]


def test_dryrun_elastic_branch_lowers_sharded_fn():
    """launch/dryrun's multi-pod train branch lowers the *real*
    ``ElasticTrainer._round_sharded`` (no dryrun-private round lowering),
    here against a shrunk 2-pod mesh with a nontrivial 'model' axis."""
    out = _run_sub(_SUBPROCESS_LOWERING, timeout=540)
    assert "LOWERING_OK" in out.stdout, out.stdout + out.stderr[-3000:]
