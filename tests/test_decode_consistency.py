"""Prefill+decode == full forward, across ALL family types (the dense/rwkv/
hybrid cases live in test_models; this file covers enc-dec, VLM and MoE)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.registry import build_model
from repro.nn.param import init_tree


def test_encdec_prefill_decode_matches_forward():
    cfg = get_config("seamless_m4t_large_v2", smoke=True)
    model = build_model(cfg)
    params = init_tree(jax.random.key(0), model.spec)
    T, Se = 8, 16
    src = jax.random.normal(jax.random.key(1), (2, Se, cfg.d_model),
                            jnp.float32)
    toks = jax.random.randint(jax.random.key(2), (2, T), 0, cfg.vocab_size,
                              jnp.int32)
    full, _ = model.forward(params, {"src": src, "tokens": toks})
    cache = model.init_cache(2, T)
    # enc_len must match the cache's cross-KV slot
    cache = model.init_cache(2, T)
    se = model.enc_len(T)
    src_fit = jax.random.normal(jax.random.key(1), (2, se, cfg.d_model),
                                jnp.float32)
    full, _ = model.forward(params, {"src": src_fit, "tokens": toks})
    pre, cache = model.prefill(params, {"src": src_fit,
                                        "tokens": toks[:, :T - 1]}, cache)
    step, _ = model.decode_step(params, {"tokens": toks[:, T - 1:]}, cache,
                                T - 1)
    np.testing.assert_allclose(np.asarray(step[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=0.05, atol=0.05)


def test_vlm_prefill_decode_matches_forward():
    cfg = get_config("qwen2_vl_7b", smoke=True)
    model = build_model(cfg)
    params = init_tree(jax.random.key(0), model.spec)
    Np, Tt = cfg.num_patch_tokens, 8
    patches = jax.random.normal(jax.random.key(1), (2, Np, cfg.d_model),
                                jnp.bfloat16)
    toks = jax.random.randint(jax.random.key(2), (2, Tt), 0, cfg.vocab_size,
                              jnp.int32)
    full, _ = model.forward(params, {"patches": patches, "tokens": toks})
    S = Np + Tt
    cache = model.init_cache(2, S)
    pre, cache = model.prefill(
        params, {"patches": patches, "tokens": toks[:, :Tt - 1]}, cache)
    step, _ = model.decode_step(params, {"tokens": toks[:, Tt - 1:]}, cache,
                                S - 1)
    np.testing.assert_allclose(np.asarray(step[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=0.06, atol=0.06)


@pytest.mark.parametrize("arch", ["mixtral_8x22b", "moonshot_v1_16b_a3b"])
def test_moe_prefill_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True).replace(capacity_factor=8.0)
    model = build_model(cfg)
    params = init_tree(jax.random.key(0), model.spec)
    T = 8
    toks = jax.random.randint(jax.random.key(1), (2, T), 0, cfg.vocab_size,
                              jnp.int32)
    full, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(2, T)
    pre, cache = model.prefill(params, {"tokens": toks[:, :T - 1]}, cache)
    step, _ = model.decode_step(params, {"tokens": toks[:, T - 1:]}, cache,
                                T - 1)
    np.testing.assert_allclose(np.asarray(step[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=0.06, atol=0.06)
