"""End-to-end behaviour tests for the paper's system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ElasticConfig, OptimizerConfig, get_config
from repro.core.coordinator import ElasticTrainer, RoundInputs
from repro.data.pipeline import WorkerBatcher
from repro.data.synthetic import SyntheticImages
from repro.models.registry import build_model


@pytest.fixture(scope="module")
def ds():
    return SyntheticImages(n=1200, n_test=300, seed=0)


def _run(ds, method_kw, opt="adahessian", rounds=6, k=2, tau=1, seed=0,
         fail=None):
    model = build_model(get_config("paper_cnn"))
    ecfg = ElasticConfig(num_workers=k, tau=tau, alpha=0.1,
                         overlap_ratio=0.25, **method_kw)
    tr = ElasticTrainer(model, OptimizerConfig(name=opt, lr=0.01), ecfg)
    state = tr.init_state(jax.random.key(seed))
    wb = WorkerBatcher(ds.images, ds.labels, ecfg, batch_size=32, seed=seed)
    test = {k2: jnp.asarray(v) for k2, v in ds.test_batch().items()}
    acc0 = float(tr.master_accuracy(state, test))
    for r in range(rounds):
        batches = {k2: jnp.asarray(v) for k2, v in wb.round_batches().items()}
        fm = jnp.zeros(k, bool) if fail is None else jnp.asarray(fail[r])
        state, m = tr.round_step(state, RoundInputs(
            batches=batches, rng=jax.random.key(r), fail=fm,
            failed_recent=jnp.zeros(k, bool)))
    return acc0, float(tr.master_accuracy(state, test)), state, m


def test_elastic_training_improves_master(ds):
    acc0, acc1, _, m = _run(ds, dict(dynamic=False), rounds=6)
    assert acc1 > acc0 + 0.1, (acc0, acc1)
    assert bool(jnp.isfinite(m["loss"]))


def test_dynamic_training_improves_master(ds):
    acc0, acc1, state, m = _run(ds, dict(dynamic=True), rounds=6)
    assert acc1 > acc0 + 0.1
    # healthy training: dynamic weights stay near α (EASGD regime)
    assert float(m["h2"].max()) <= 0.1 + 1e-5


def test_training_survives_failures(ds):
    rng = np.random.default_rng(0)
    fail = rng.random((6, 2)) < 0.34
    fail[-1] = False  # final syncs happen
    acc0, acc1, _, _ = _run(ds, dict(dynamic=True), rounds=6, fail=fail)
    assert acc1 > acc0 + 0.08


def test_master_protected_during_recovery(ds):
    """Post-outage recovery: the distance history collapses, the score goes
    negative, and the master must take (almost) nothing from that worker
    while the worker is snapped back (paper §V-B intent)."""
    model = build_model(get_config("paper_cnn"))
    ecfg = ElasticConfig(num_workers=2, tau=1, alpha=0.1, dynamic=True)
    tr = ElasticTrainer(model, OptimizerConfig(name="sgd", lr=0.01), ecfg)
    state = tr.init_state(jax.random.key(0))
    # worker 0 was far for several rounds (outage) and is now nearly back
    state["u_hist"] = state["u_hist"].at[0].set(
        jnp.asarray([6.0, 5.0, 4.0, 3.0, 2.0]))
    state["workers"] = jax.tree.map(
        lambda x: x.at[0].add(1e-4), state["workers"])
    new, m = tr.comm_phase(state, jnp.zeros(2, bool))
    assert float(m["score"][0]) < -0.05
    assert float(m["h2"][0]) < 0.02  # master takes (almost) nothing
    assert float(m["h1"][0]) > 0.9   # worker snapped back to master
