"""Serving engine: batched generate, EOS handling, cache consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.registry import build_model
from repro.nn.param import init_tree
from repro.serving.engine import ServeEngine


@pytest.fixture(scope="module")
def engine():
    model = build_model(get_config("qwen3_4b", smoke=True))
    params = init_tree(jax.random.key(0), model.spec)
    return ServeEngine(model, params, max_len=64)


def test_generate_shapes(engine):
    prompts = np.random.default_rng(0).integers(0, 100, (3, 8)).astype("int32")
    out = engine.generate(prompts, steps=10)
    assert out.shape == (3, 10)
    assert (out >= 0).all() and (out < 256).all()


def test_generate_deterministic(engine):
    prompts = np.random.default_rng(1).integers(0, 100, (2, 8)).astype("int32")
    a = engine.generate(prompts, steps=6)
    b = engine.generate(prompts, steps=6)
    np.testing.assert_array_equal(a, b)


def test_generate_matches_forward_greedy(engine):
    """Token 1 from generate == argmax of full forward's last position."""
    prompts = np.random.default_rng(2).integers(0, 100, (2, 8)).astype("int32")
    out = engine.generate(prompts, steps=2)
    logits, _ = engine.model.forward(engine.params,
                                     {"tokens": jnp.asarray(prompts)})
    want = np.asarray(jnp.argmax(logits[:, -1], -1))
    np.testing.assert_array_equal(out[:, 0], want)


def test_rwkv_generate():
    model = build_model(get_config("rwkv6_3b", smoke=True))
    params = init_tree(jax.random.key(0), model.spec)
    eng = ServeEngine(model, params, max_len=32)
    prompts = np.random.default_rng(3).integers(0, 100, (2, 5)).astype("int32")
    out = eng.generate(prompts, steps=5)
    assert out.shape == (2, 5)
