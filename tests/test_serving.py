"""Serving engine: batched generate, EOS handling, cache consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.registry import build_model
from repro.nn.param import init_tree
from repro.serving.engine import ServeEngine


@pytest.fixture(scope="module")
def engine():
    model = build_model(get_config("qwen3_4b", smoke=True))
    params = init_tree(jax.random.key(0), model.spec)
    return ServeEngine(model, params, max_len=64)


def test_generate_shapes(engine):
    prompts = np.random.default_rng(0).integers(0, 100, (3, 8)).astype("int32")
    out = engine.generate(prompts, steps=10)
    assert out.shape == (3, 10)
    assert (out >= 0).all() and (out < 256).all()


def test_generate_deterministic(engine):
    prompts = np.random.default_rng(1).integers(0, 100, (2, 8)).astype("int32")
    a = engine.generate(prompts, steps=6)
    b = engine.generate(prompts, steps=6)
    np.testing.assert_array_equal(a, b)


def test_generate_matches_forward_greedy(engine):
    """Token 1 from generate == argmax of full forward's last position."""
    prompts = np.random.default_rng(2).integers(0, 100, (2, 8)).astype("int32")
    out = engine.generate(prompts, steps=2)
    logits, _ = engine.model.forward(engine.params,
                                     {"tokens": jnp.asarray(prompts)})
    want = np.asarray(jnp.argmax(logits[:, -1], -1))
    np.testing.assert_array_equal(out[:, 0], want)


def test_generate_rejects_cache_overrun(engine):
    """S0 + steps must fit in the KV cache up front — before the fix the
    guard lived mid-loop and only fired when eos_id was set, so an
    eos_id=None request decoded straight past max_len."""
    prompts = np.zeros((2, 8), "int32")
    with pytest.raises(ValueError, match="max_len"):
        engine.generate(prompts, steps=57)  # 8 + 57 > 64
    with pytest.raises(ValueError, match="max_len"):
        engine.generate(prompts, steps=57, eos_id=0)
    # the boundary itself is fine
    out = engine.generate(prompts, steps=56)
    assert out.shape == (2, 56)


def test_generate_eos_rows_stay_pinned(engine):
    """After a row emits eos_id, every later position of that row is
    eos_id — finished rows must not keep generating while other rows run
    on (the pre-fix loop only stopped when *all* rows finished)."""
    prompts = np.random.default_rng(4).integers(0, 100, (4, 8)).astype("int32")
    free = engine.generate(prompts, steps=24)
    # pick an eos_id that actually occurs mid-stream for some row but not
    # at every row's first token, so the pinning (not the early break) is
    # what's being exercised
    vals, counts = np.unique(free[:, 1:], return_counts=True)
    eos = int(vals[np.argmax(counts)])
    out = engine.generate(prompts, steps=24, eos_id=eos)
    hit = False
    for row in out:
        idx = np.nonzero(row == eos)[0]
        if idx.size:
            hit = True
            assert (row[idx[0]:] == eos).all(), row
    assert hit, f"eos_id={eos} never emitted; test vacuous"
    # rows agree with the unpinned run up to and including their first EOS
    for r_free, r_pin in zip(free[:, :out.shape[1]], out):
        idx = np.nonzero(r_pin == eos)[0]
        upto = idx[0] + 1 if idx.size else r_pin.size
        np.testing.assert_array_equal(r_free[:upto], r_pin[:upto])


def test_rwkv_generate():
    model = build_model(get_config("rwkv6_3b", smoke=True))
    params = init_tree(jax.random.key(0), model.spec)
    eng = ServeEngine(model, params, max_len=32)
    prompts = np.random.default_rng(3).integers(0, 100, (2, 5)).astype("int32")
    out = eng.generate(prompts, steps=5)
    assert out.shape == (2, 5)
