"""docs/paper_map.md anti-rot check (ISSUE-4).

Every backticked ``repro.…`` reference in the paper→code map must resolve:
the longest importable module prefix is imported and the remainder is
walked with getattr (classes, methods, dataclass fields with defaults all
resolve this way). Backticked repo paths (anything with a ``/``) must
exist. So renaming a symbol without updating the map fails CI."""
import importlib
import os
import re

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
DOC = os.path.join(ROOT, "docs", "paper_map.md")

_REF = re.compile(r"`([^`]+)`")


def _doc_refs():
    with open(DOC) as f:
        text = f.read()
    dotted, paths = set(), set()
    for ref in _REF.findall(text):
        if re.fullmatch(r"repro(\.\w+)+", ref):
            dotted.add(ref)
        elif re.fullmatch(r"[\w.-]+/[\w./-]+", ref):
            paths.add(ref)
    return sorted(dotted), sorted(paths)


DOTTED, PATHS = _doc_refs()


def test_map_has_references():
    """The extractor actually finds the table's references (guards against
    a formatting change silently emptying the parametrization)."""
    assert len(DOTTED) >= 25, DOTTED
    assert any("docs/" in p for p in PATHS) and any("tests/" in p
                                                    for p in PATHS)


def _resolve(ref: str):
    parts = ref.split(".")
    for cut in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:cut]))
        except ImportError:
            continue
        for attr in parts[cut:]:
            obj = getattr(obj, attr)  # AttributeError = broken reference
        return obj
    raise ImportError(f"no importable module prefix in {ref!r}")


@pytest.mark.parametrize("ref", DOTTED)
def test_symbol_reference_resolves(ref):
    assert _resolve(ref) is not None


@pytest.mark.parametrize("path", PATHS)
def test_path_reference_exists(path):
    assert os.path.exists(os.path.join(ROOT, path)), path
