"""Data pipeline tests: determinism, overlap wiring, batch shapes."""
import numpy as np
import pytest

from repro.configs.base import ElasticConfig
from repro.data.pipeline import TokenWorkerBatcher, WorkerBatcher
from repro.data.synthetic import SyntheticImages, SyntheticTokens


def test_synthetic_images_deterministic():
    a = SyntheticImages(n=200, n_test=50, seed=5)
    b = SyntheticImages(n=200, n_test=50, seed=5)
    np.testing.assert_array_equal(a.images, b.images)
    np.testing.assert_array_equal(a.labels, b.labels)


def test_synthetic_images_learnable_structure():
    ds = SyntheticImages(n=500, n_test=10, seed=0)
    # within-class distance < between-class distance (on average)
    imgs = ds.images.reshape(len(ds.images), -1)
    mus = np.stack([imgs[ds.labels == c].mean(0) for c in range(10)])
    d_between = np.linalg.norm(mus[None] - mus[:, None], axis=-1)
    off = d_between[~np.eye(10, dtype=bool)]
    assert off.min() > 1.0  # classes are separated


def test_worker_batcher_shapes_and_overlap():
    ds = SyntheticImages(n=400, n_test=10)
    ecfg = ElasticConfig(num_workers=4, tau=3, overlap_ratio=0.25)
    wb = WorkerBatcher(ds.images, ds.labels, ecfg, batch_size=8)
    b = wb.round_batches()
    assert b["images"].shape == (3, 4, 8, 28, 28, 1)
    assert b["labels"].shape == (3, 4, 8)
    # worker index sets share exactly the overlap fraction
    sets = [set(ix.tolist()) for ix in wb.indices.values()]
    shared = set.intersection(*sets)
    assert len(shared) == round(0.25 * 400)


def test_worker_batcher_epoch_wraps():
    ds = SyntheticImages(n=100, n_test=10)
    ecfg = ElasticConfig(num_workers=2, tau=1, overlap_ratio=0.0)
    wb = WorkerBatcher(ds.images, ds.labels, ecfg, batch_size=32)
    for _ in range(10):  # 10 rounds × 32 > 50 per worker → wraps
        b = wb.round_batches()
        assert b["images"].shape == (1, 2, 32, 28, 28, 1)


def test_token_stream_and_batcher():
    ts = SyntheticTokens(vocab=128, n_tokens=5000, seed=1)
    assert ts.tokens.min() >= 0 and ts.tokens.max() < 128
    ecfg = ElasticConfig(num_workers=2, tau=2, overlap_ratio=0.125)
    tb = TokenWorkerBatcher(ts.tokens, ecfg, batch_size=4, seq_len=16)
    b = tb.round_batches()
    assert b["tokens"].shape == (2, 2, 4, 16)
    np.testing.assert_array_equal(b["tokens"][..., 1:], b["targets"][..., :-1])


def test_capacity_padded_batcher_pads_vacant_slots():
    """(ISSUE-5) A capacity-padded batcher emits (τ, cap, B, ...) stacks:
    live slots carry real data, vacant slots a zero pad, and membership
    changes redeal the unique shards while the overlap O stays put."""
    ds = SyntheticImages(n=400, n_test=10)
    ecfg = ElasticConfig(num_workers=2, capacity=4, tau=2,
                         overlap_ratio=0.25)
    wb = WorkerBatcher(ds.images, ds.labels, ecfg, batch_size=8)
    b = wb.round_batches()
    assert b["images"].shape == (2, 4, 8, 28, 28, 1)
    assert (b["images"][:, 2:] == 0).all() and (b["images"][:, :2] != 0).any()
    overlap_before = set.intersection(*[set(ix.tolist())
                                        for ix in wb.indices.values()])

    wb.set_active([0, 1, 3])  # slot 3 joins
    b = wb.round_batches()
    assert (b["images"][:, 2] == 0).all() and (b["images"][:, 3] != 0).any()
    assert sorted(wb.indices) == [0, 1, 3]
    overlap_after = set.intersection(*[set(ix.tolist())
                                       for ix in wb.indices.values()])
    assert overlap_before == overlap_after  # O is membership-invariant

    with pytest.raises(ValueError, match="slot"):
        wb.set_active([0, 9])
    with pytest.raises(ValueError, match="slot"):
        wb.set_active([])


def test_token_batcher_membership_repartition():
    ts = SyntheticTokens(vocab=128, n_tokens=5000, seed=1)
    ecfg = ElasticConfig(num_workers=2, capacity=3, tau=1,
                         overlap_ratio=0.125)
    tb = TokenWorkerBatcher(ts.tokens, ecfg, batch_size=4, seq_len=16)
    b = tb.round_batches()
    assert b["tokens"].shape == (1, 3, 4, 16)
    assert (b["tokens"][:, 2] == 0).all()
    tb.set_active_mask(np.array([True, True, True]))
    b = tb.round_batches()
    assert sorted(tb.starts) == [0, 1, 2]
    assert b["tokens"].shape == (1, 3, 4, 16)


def test_token_stream_has_structure():
    ts = SyntheticTokens(vocab=64, n_tokens=20000, seed=2)
    # planted bigrams: successor prediction beats chance massively
    succ_hits = np.mean(ts.tokens[1:] == ts.succ[ts.tokens[:-1]])
    assert succ_hits > 0.5
