"""Optional-hypothesis shim for mixed test modules.

``from _property_shim import given, settings, st`` behaves exactly like the
hypothesis imports when hypothesis is installed; without it, ``@given`` marks
just that test as skipped so the module's plain tests still run (a
module-level ``pytest.importorskip`` would silently drop them all).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*args, **kwargs):
        del args, kwargs
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        del args, kwargs
        return lambda f: f

    class _AnyStrategy:
        """Stands in for ``strategies``: every attribute is a no-op factory
        so module-level ``st.integers(...)`` decorator arguments evaluate."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

strategies = st
