"""Multi-process scale-out smoke (ISSUE-10): two `jax.distributed`
processes run the same hierarchical training session and must agree.

On the CPU backend jax supports distributed *initialization* (global
device visibility, process ids) but not cross-process XLA computations,
so ``make_distributed_mesh`` deliberately falls back to a process-local
mesh and each process runs the identical deterministic program — the
smoke asserts the coordination layer works end-to-end (coordinator
handshake, per-process mesh build, rank-gated logging) and that the two
processes produce bit-identical final masters, which is exactly the
property a TPU/GPU deployment relies on when it *does* span hosts.
"""
import os
import re
import socket
import subprocess
import sys

import pytest


def _free_port():
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _spawn(process_id, port, env):
    return subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train",
         "--smoke", "--rounds", "2", "--workers", "4", "--tau", "1",
         "--batch-size", "4", "--optimizer", "sgd",
         "--comm-mode", "fused", "--placement", "sharded",
         "--groups", "2", "--global-period", "2",
         "--coordinator-address", f"127.0.0.1:{port}",
         "--num-processes", "2", "--process-id", str(process_id)],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env)


def test_two_process_hierarchical_smoke_agrees():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)            # plain 1-device CPU per process
    env.setdefault("JAX_PLATFORMS", "cpu")
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    port = _free_port()
    procs = [_spawn(i, port, env) for i in range(2)]
    outs = []
    for p in procs:
        out, _ = p.communicate(timeout=840)
        outs.append(out)
        assert p.returncode == 0, out[-2000:]

    l2s = []
    for i, out in enumerate(outs):
        m = re.search(r"final master l2=([0-9.e+-]+)", out)
        assert m, f"process {i} printed no final-master line:\n{out[-2000:]}"
        l2s.append(m.group(1))
        # CPU backend: the mesh must announce the process-local fallback
        assert "process-local mesh" in out
    # deterministic identical programs -> bit-identical masters, printed
    # at full float64 precision by launch/train.py
    assert l2s[0] == l2s[1], f"masters diverged: {l2s}"
    assert float(l2s[0]) > 0 and float(l2s[0]) < 1e6
    # per-round logs are rank-gated to process 0
    assert "round" in outs[0]
    # process 1 may still print the mesh fallback + final line, but no
    # per-round records
    assert outs[1].count("g_h2") == 0
