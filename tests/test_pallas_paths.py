"""The kernels as first-class model/coordinator paths (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ElasticConfig, OptimizerConfig, get_config
from repro.core.coordinator import ElasticTrainer
from repro.models.registry import build_model
from repro.nn.param import init_tree

pytestmark = pytest.mark.pallas  # interpret-mode kernel paths


def test_model_pallas_attention_matches_jnp():
    cfg = get_config("h2o_danube_1_8b", smoke=True).replace(
        sliding_window=32, num_kv_heads=2)
    m_j = build_model(cfg)
    m_p = build_model(cfg.replace(use_pallas=True))
    params = init_tree(jax.random.key(0), m_j.spec)
    toks = jax.random.randint(jax.random.key(1), (2, 128), 0, cfg.vocab_size,
                              jnp.int32)
    lj, _ = m_j.forward(params, {"tokens": toks})
    lp, _ = m_p.forward(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lj, np.float32),
                               np.asarray(lp, np.float32), rtol=0.08,
                               atol=0.08)


def test_coordinator_pallas_elastic_matches_jnp():
    model = build_model(get_config("paper_cnn"))
    ecfg = ElasticConfig(num_workers=2, tau=1, alpha=0.1, dynamic=False)
    tr_j = ElasticTrainer(model, OptimizerConfig(name="sgd"), ecfg)
    tr_p = ElasticTrainer(model, OptimizerConfig(name="sgd"), ecfg,
                          use_pallas=True)
    state = tr_j.init_state(jax.random.key(0))
    state["workers"] = jax.tree.map(
        lambda x: x + jax.random.normal(jax.random.key(1), x.shape,
                                        x.dtype) * 0.1, state["workers"])
    nj, mj = tr_j.comm_phase(dict(state), jnp.zeros(2, bool))
    np_, mp = tr_p.comm_phase(dict(state), jnp.zeros(2, bool))
    for a, b in zip(jax.tree.leaves(nj["workers"]),
                    jax.tree.leaves(np_["workers"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                                   atol=2e-5)
    for a, b in zip(jax.tree.leaves(nj["master"]),
                    jax.tree.leaves(np_["master"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                                   atol=2e-5)
