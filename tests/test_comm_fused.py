"""Fused batched communication backend vs the sequential event-ordered scan.

Covers the ISSUE-1 acceptance surface: master equivalence under uniform h2,
batched-kernel-vs-ref allclose in interpret mode, and fail-mask suppression
parity between the two comm modes — plus (ISSUE-2) the same equivalence
under every failure scenario from the engine, not just the i.i.d. mask.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (FAILURE_SCENARIOS, ElasticConfig,
                                OptimizerConfig, get_config)
from repro.core import dynamic_weight as dw
from repro.core.coordinator import ElasticTrainer
from repro.core.elastic import elastic_update, elastic_update_batched
from repro.core.scenarios import make_scenario
from repro.kernels.elastic.ops import elastic_update_batched_pallas
from repro.models.registry import build_model


def _trainer(k, comm_mode, use_pallas=False, **kw):
    model = build_model(get_config("paper_cnn"))
    defaults = dict(num_workers=k, tau=1, alpha=0.1, dynamic=False,
                    comm_mode=comm_mode)
    defaults.update(kw)
    return ElasticTrainer(model, OptimizerConfig(name="sgd", lr=0.01),
                          ElasticConfig(**defaults), use_pallas=use_pallas)


def _desynced_state(tr, seed=0, scale=0.1):
    state = tr.init_state(jax.random.key(seed))
    state["workers"] = jax.tree.map(
        lambda x: x + jax.random.normal(jax.random.key(seed + 1), x.shape,
                                        x.dtype) * scale, state["workers"])
    return state


def _stacked_tree(k, shapes, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), len(shapes))
    return {f"p{i}": jax.random.normal(ks[i], (k,) + s).astype(dtype)
            for i, s in enumerate(shapes)}


def _master_tree(shapes, dtype, seed=99):
    ks = jax.random.split(jax.random.key(seed), len(shapes))
    return {f"p{i}": jax.random.normal(ks[i], s).astype(dtype)
            for i, s in enumerate(shapes)}


# ---------------------------------------------------------------------------
# config / schedule weights
# ---------------------------------------------------------------------------

def test_comm_mode_validated():
    with pytest.raises(ValueError):
        ElasticConfig(comm_mode="nope")


def test_master_schedule_weights_match_sequential_unroll():
    h2 = jnp.asarray([0.3, 0.0, 0.2, 0.1])
    g = np.asarray(dw.master_schedule_weights(h2))
    # manual: g_i = h2_i * prod_{j>i} (1 - h2_j)
    h = np.asarray(h2)
    for i in range(4):
        expect = h[i] * np.prod(1.0 - h[i + 1:])
        np.testing.assert_allclose(g[i], expect, rtol=1e-6)
    # master coefficient identity: 1 - sum(g) == prod(1 - h2)
    np.testing.assert_allclose(1.0 - g.sum(), np.prod(1.0 - h), rtol=1e-6)


def test_batched_scores_match_per_worker():
    cfg = ElasticConfig(num_workers=3)
    ws = _stacked_tree(3, [(8, 4), (5,)], jnp.float32)
    m = _master_tree([(8, 4), (5,)], jnp.float32)
    hist = jnp.asarray(np.random.RandomState(0).randn(3, 5), jnp.float32)
    u, hist_new, a, w1, w2 = dw.comm_scores_batched(cfg, ws, m, hist)
    for i in range(3):
        w_i = jax.tree.map(lambda x: x[i], ws)
        u_i = dw.log_distance(w_i, m)
        np.testing.assert_allclose(u[i], u_i, rtol=1e-6)
        h_i = dw.push_history(hist[i], u_i)
        np.testing.assert_allclose(hist_new[i], h_i, rtol=1e-6)
        np.testing.assert_allclose(a[i], dw.raw_score(h_i, cfg.score_weights),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# batched kernel vs jnp reference (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.pallas
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("k,shapes", [
    (2, [(128,)]),
    (5, [(300, 17), (41,)]),
    (32, [(1000, 13), (5, 5, 5)]),
])
def test_batched_kernel_matches_ref(k, shapes, dtype):
    ws = _stacked_tree(k, [tuple(s) for s in shapes], dtype)
    m = _master_tree([tuple(s) for s in shapes], dtype)
    rng = np.random.RandomState(k)
    h1 = jnp.asarray(rng.uniform(0, 1, k), jnp.float32)
    h2 = jnp.asarray(rng.uniform(0, 0.3, k), jnp.float32)
    wk, mk = elastic_update_batched_pallas(ws, m, h1, h2, interpret=True)
    wr, mr = elastic_update_batched(ws, m, h1, h2)
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    for key in m:
        np.testing.assert_allclose(np.asarray(wk[key], np.float32),
                                   np.asarray(wr[key], np.float32),
                                   rtol=tol, atol=tol)
        np.testing.assert_allclose(np.asarray(mk[key], np.float32),
                                   np.asarray(mr[key], np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.pallas
def test_batched_kernel_zero_weights_noop():
    ws = _stacked_tree(4, [(256, 128)], jnp.float32)
    m = _master_tree([(256, 128)], jnp.float32)
    z = jnp.zeros(4)
    wk, mk = elastic_update_batched_pallas(ws, m, z, z, interpret=True)
    np.testing.assert_array_equal(np.asarray(wk["p0"]), np.asarray(ws["p0"]))
    np.testing.assert_array_equal(np.asarray(mk["p0"]), np.asarray(m["p0"]))


def test_batched_ref_matches_sequential_master_with_schedule_weights():
    """Batched reduction with g = master_schedule_weights(h2) reproduces the
    sequential per-worker master updates for arbitrary non-uniform h2."""
    k = 6
    ws = _stacked_tree(k, [(64, 3)], jnp.float32)
    m = _master_tree([(64, 3)], jnp.float32)
    rng = np.random.RandomState(7)
    h1 = jnp.asarray(rng.uniform(0, 1, k), jnp.float32)
    h2 = jnp.asarray(rng.uniform(0, 0.4, k), jnp.float32)
    _, mb = elastic_update_batched(ws, m, h1, dw.master_schedule_weights(h2))
    ms = m
    for i in range(k):
        w_i = jax.tree.map(lambda x: x[i], ws)
        _, ms = elastic_update(w_i, ms, float(h1[i]), float(h2[i]))
    np.testing.assert_allclose(np.asarray(mb["p0"]), np.asarray(ms["p0"]),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# coordinator: fused vs sequential comm phase
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True])
def test_fused_master_matches_sequential_uniform_h2(use_pallas):
    """Fixed-α (uniform h2) and no failures: the fused master must equal the
    event-ordered sequential master."""
    k = 4
    trs = _trainer(k, "sequential")
    trf = _trainer(k, "fused", use_pallas=use_pallas)
    state = _desynced_state(trs)
    fail = jnp.zeros(k, bool)
    ns, _ = trs.comm_phase(state, fail)
    nf, _ = trf.comm_phase(state, fail)
    for a, b in zip(jax.tree.leaves(ns["master"]),
                    jax.tree.leaves(nf["master"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_fused_fail_mask_parity_with_sequential():
    """A suppressed worker exchanges nothing in either mode and the fused
    master still matches the sequential one (uniform h2 on the survivors)."""
    k = 4
    trs = _trainer(k, "sequential")
    trf = _trainer(k, "fused")
    state = _desynced_state(trs)
    fail = jnp.asarray([False, True, False, True])
    ns, ms = trs.comm_phase(state, fail)
    nf, mf = trf.comm_phase(state, fail)
    for i in (1, 3):
        before = jax.tree.leaves(jax.tree.map(lambda x: x[i],
                                              state["workers"]))
        for new in (ns, nf):
            after = jax.tree.leaves(jax.tree.map(lambda x: x[i],
                                                 new["workers"]))
            for a, b in zip(before, after):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert float(mf["h1"][i]) == 0.0 and float(mf["h2"][i]) == 0.0
    for a, b in zip(jax.tree.leaves(ns["master"]),
                    jax.tree.leaves(nf["master"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)
    # suppressed workers' u-history still advances in both modes (§V-B)
    for new in (ns, nf):
        assert float(new["u_hist"][1, -1]) != float(state["u_hist"][1, -1])


def test_fused_dynamic_mode_runs_and_reacts():
    """Dynamic h1/h2 in fused mode: recovery signature (sharply dropping u)
    snaps the worker to the master and shields the master."""
    tr = _trainer(1, "fused", dynamic=True, score_k=-0.05)
    state = tr.init_state(jax.random.key(0))
    state["u_hist"] = jnp.asarray([[6.0, 5.0, 4.0, 3.0, 2.0]])
    state["workers"] = jax.tree.map(lambda x: x + 1e-4, state["workers"])
    _, m = tr.comm_phase(state, jnp.zeros(1, bool))
    assert float(m["score"][0]) < -0.05
    assert float(m["h1"][0]) == pytest.approx(1.0)
    assert float(m["h2"][0]) == pytest.approx(0.0)


@pytest.fixture(scope="module")
def scenario_rig():
    """One jitted trainer pair shared by every scenario param (the scenario
    shapes only the schedule, not the comm trace). An all-False straggle
    mask takes the stale-scoring code path but scores against the live
    master bit-for-bit."""
    trs = _trainer(4, "sequential")
    trf = _trainer(4, "fused")
    return (
        trs,
        jax.jit(lambda st, f, sg: trs.comm_phase(st, f, straggle=sg)),
        jax.jit(lambda st, f, sg: trf.comm_phase(st, f, straggle=sg)),
        jax.jit(trs.apply_restarts),
    )


@pytest.mark.parametrize("scenario", FAILURE_SCENARIOS)
def test_fused_master_matches_sequential_under_scenario(scenario, scenario_rig):
    """Sequential and fused comm produce the same master under every failure
    regime (uniform h2): per round, from a common state — including restart
    resets and straggler stale-master scoring — the two backends' masters
    agree and suppressed workers exchange nothing in either mode."""
    k, rounds = 4, 6
    trs, comm_s, comm_f, restarts = scenario_rig
    sched = make_scenario(
        ElasticConfig(num_workers=k, failure_scenario=scenario)
    ).schedule(5, rounds, k)
    # hetero/byzantine events live in the speed/corrupt channels; for
    # those the comm phases below still exercise the clean-mask path
    # (speed only shapes the local phase, which both backends share).
    assert (sched.fail.any() or sched.straggle.any()
            or sched.has_hetero or sched.has_corruption), \
        "scenario schedule has no events — test is vacuous"
    state = _desynced_state(trs)
    for r in range(rounds):
        fail = jnp.asarray(sched.fail[r])
        straggle = jnp.asarray(sched.straggle[r])
        if sched.has_restarts:
            state = restarts(state, jnp.asarray(sched.restart[r]))
        ns, _ = comm_s(state, fail, straggle)
        nf, _ = comm_f(state, fail, straggle)
        for a, b in zip(jax.tree.leaves(ns["master"]),
                        jax.tree.leaves(nf["master"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-5, atol=1e-6)
        for i in np.flatnonzero(sched.fail[r]):
            before = jax.tree.leaves(
                jax.tree.map(lambda x: x[i], state["workers"]))
            for new in (ns, nf):
                after = jax.tree.leaves(
                    jax.tree.map(lambda x: x[i], new["workers"]))
                for a, b in zip(before, after):
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))
        # advance canonically on the sequential state, re-desynced so the
        # next round's distances stay non-trivial (stands in for the
        # mode-independent local phase)
        state = dict(ns)
        state["workers"] = jax.tree.map(
            lambda x: x + jax.random.normal(
                jax.random.key(100 + r), x.shape, x.dtype) * 0.05,
            state["workers"])


def test_fused_round_counter_and_hist_shapes():
    tr = _trainer(3, "fused")
    state = tr.init_state(jax.random.key(0))
    new, m = tr.comm_phase(state, jnp.zeros(3, bool))
    assert int(new["round"]) == 1
    assert new["u_hist"].shape == (3, 5)
    assert m["score"].shape == (3,)
