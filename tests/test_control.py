"""Closed-loop control tests (ISSUE-6): detector, policy, actuator, the
``apply(ControlAction)`` session API, and the acceptance criteria for the
detector-blind closed loop.

Layout:

- unit tests of the detector state machine on synthetic record streams
  (hysteresis, cold-start resets, dark-slot flag ageing) — fast;
- unit tests of the policy guardrails and action validation — fast;
- the no-oracle-leakage contract, enforced twice: a static scan of every
  ``repro/control/*`` source for ground-truth mask access, and a runtime
  run of the detector over records whose mask fields *raise* on access;
- session-level API redesign tests (apply is the one entrypoint,
  deprecated wrappers warn, observers fire, telemetry fields populate,
  detector_blind echoes zeroed masks bit-exactly) — small runs;
- slow acceptance runs on the separable control regime (α=0.5, τ=4 —
  see ``repro/control/detector.py``'s calibration notes): on
  ``crash_restart`` and ``straggler`` (k=4, seeds 1–3) the detector-blind
  closed loop flags every live-onset failure within 3 rounds (modulo the
  documented concurrent-failure carve-out), probes recovered slots back
  in, and lands within 10% mean final master eval loss of an
  oracle-scheduled controller; plus a five-scenario detector-blind
  precision/recall sweep with per-scenario floors.
"""
import dataclasses
import re
import warnings
from pathlib import Path

import numpy as np
import pytest

from repro.api import (ControlAction, ElasticSession, MembershipPolicy,
                       RunSpec, SessionObserver)
from repro.configs.base import ElasticConfig, OptimizerConfig
from repro.control.actions import ACTION_KINDS
from repro.control.actuator import Actuator, RuleController, make_controller
from repro.control.detector import (FAILED_SUSPECT, HEALTHY,
                                    STRAGGLER_SUSPECT, DetectorConfig,
                                    FailureDetector)
from repro.control.policy import PolicyConfig, RulePolicy, make_policy

CONTROL_DIR = Path(__file__).resolve().parent.parent / "src/repro/control"


# ---------------------------------------------------------------------------
# synthetic record streams for the detector
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FakeRecord:
    round: int
    u: np.ndarray
    active: np.ndarray
    loss_w: np.ndarray = None
    round_ms: float = 0.0


def feed(det, u_rows, active=None, loss_rows=None):
    """Feed rows of u (and optional masks/losses) as successive rounds."""
    k = len(u_rows[0])
    for r, row in enumerate(u_rows):
        det.observe(FakeRecord(
            round=r, u=np.asarray(row, float),
            active=(np.ones(k, bool) if active is None
                    else np.asarray(active[r], bool)),
            loss_w=(None if loss_rows is None
                    else np.asarray(loss_rows[r], float))))


def healthy_then_adrift(rounds, k, slot, onset, drift=0.6, seed=0):
    """A mobile pool where ``slot`` stops being pulled back at ``onset``.

    Healthy workers hover: every explore move is undone by the elastic
    pull next round, so their du alternates +/-0.4 and never trends —
    the equilibrium signature the detector's calibration is built
    around. The cut slot climbs ``drift`` per round after ``onset``
    (monotone ascent = no pullback), with ``drift`` above the hover
    amplitude so it clears the pool-median check even when the hoverers
    happen to move up in phase."""
    rng = np.random.default_rng(seed)
    phase = rng.integers(0, 2, size=k)
    u = np.where(phase, 0.2, -0.2) * np.ones((rounds, k))
    u[1::2] *= -1.0
    for r in range(max(onset, 1), rounds):
        u[r, slot] = u[r - 1, slot] + drift
    return u


class TestDetectorRules:
    def test_adrift_flags_after_k_rounds(self):
        det = FailureDetector(4)
        u = healthy_then_adrift(10, 4, slot=2, onset=4)
        feed(det, u)
        assert det.verdict(2) == FAILED_SUSPECT
        flag_rounds = [r for r, s, v in det.events
                       if s == 2 and v == FAILED_SUSPECT]
        # evidence from round 4; drift_rounds=3 -> flag by round 6
        assert flag_rounds and flag_rounds[0] <= 4 + det.cfg.drift_rounds

    def test_silent_flags_frozen_slot_in_mobile_pool(self):
        det = FailureDetector(4)
        u = healthy_then_adrift(10, 4, slot=1, onset=3, drift=0.0)
        feed(det, u)  # drift=0: |du|=0 while the pool moves by 0.4
        assert det.verdict(1) == FAILED_SUSPECT
        flag_rounds = [r for r, s, v in det.events
                       if s == 1 and v == FAILED_SUSPECT]
        assert flag_rounds and flag_rounds[0] <= 3 + det.cfg.suspect_rounds

    def test_single_noisy_round_does_not_flap(self):
        det = FailureDetector(4)
        u = healthy_then_adrift(12, 4, slot=0, onset=99, seed=3)
        u[6, 0] = u[5, 0] + 0.001  # one frozen-looking round...
        u[7::2, 0] = u[6, 0] + 0.4  # ...then the hover resumes from the
        u[8::2, 0] = u[6, 0]        # new level (no second quiet beat)
        feed(det, u)
        assert det.verdicts() == [HEALTHY] * 4
        assert det.events == []

    def test_quiet_converged_pool_never_mass_flags(self):
        det = FailureDetector(4)
        u = np.cumsum(0.001 * np.ones((12, 4)), axis=0)  # everyone quiet
        feed(det, u)
        assert det.verdicts() == [HEALTHY] * 4

    def test_flag_clears_after_calm_rounds(self):
        det = FailureDetector(4)
        u1 = healthy_then_adrift(8, 4, slot=2, onset=3)
        # recovery: the restored worker is pulled back toward the pool
        # (monotone descent), then hovers in phase with the rest at a
        # slightly smaller amplitude; everyone else keeps hovering
        u2 = np.tile(u1[-1], (8, 1))
        u2 += np.where(np.arange(8)[:, None] % 2, -0.2, 0.2)
        drop = u1[-1, 2] - 0.7 * np.minimum(np.arange(1, 9), 4)
        u2[:, 2] = drop
        u2[4:, 2] = drop[3] + 0.15 * np.where(np.arange(4, 8) % 2, -1, 1)
        feed(det, np.concatenate([u1, u2]))
        assert det.verdict(2) == HEALTHY
        kinds = [v for _, s, v in det.events if s == 2]
        assert kinds == [FAILED_SUSPECT, HEALTHY]

    def test_dark_slot_flag_ages_out_for_probing(self):
        det = FailureDetector(4)
        u = healthy_then_adrift(8, 4, slot=2, onset=3)
        feed(det, u)
        assert det.verdict(2) == FAILED_SUSPECT
        # evict slot 2: its telemetry goes dark; after readmit_cooldown
        # dark rounds the flag ages out -> probe-ready
        act = np.ones(4, bool)
        act[2] = False
        frozen = u[-1]
        for r in range(8, 8 + det.cfg.readmit_cooldown + 1):
            det.observe(FakeRecord(round=r, u=frozen, active=act))
        assert det.verdict(2) == HEALTHY

    def test_rejoin_cold_start_is_not_evidence(self):
        det = FailureDetector(4)
        u = healthy_then_adrift(6, 4, slot=2, onset=99)
        act = np.ones((6, 4), bool)
        act[2:4, 1] = False  # slot 1 out rounds 2-3, back at 4
        u = u.copy()
        u[4, 1] = u[3, 1] + 5.0  # huge re-seat jump on rejoin
        feed(det, u, active=act)
        # the jump lands on the reset round -> du unknown -> no evidence
        assert det.verdict(1) == HEALTHY

    def test_straggler_rule_is_conservative(self):
        # mild loss wobble on a healthy pool must not flag anyone
        det = FailureDetector(4)
        rng = np.random.default_rng(7)
        u = healthy_then_adrift(14, 4, slot=0, onset=99, seed=11)
        loss = 2.3 + 0.15 * rng.standard_normal((14, 4))
        feed(det, u, loss_rows=loss)
        assert det.verdicts() == [HEALTHY] * 4

    def test_persistent_laggard_flags_straggler(self):
        det = FailureDetector(
            4, DetectorConfig(slow_z=2.0, slow_loss_z=2.0))
        rng = np.random.default_rng(9)
        rounds = 14
        u = np.zeros((rounds, 4))
        loss = np.ones((rounds, 4))
        for r in range(1, rounds):
            u[r] = 2.0 + 0.3 * rng.choice([-1.0, 1.0], size=4)
            loss[r] = 1.0 + 0.02 * rng.standard_normal(4)
            u[r, 3] = -1.5 + 0.3 * rng.choice([-1.0, 1.0])  # hugs master
            loss[r, 3] = 2.5  # and its loss lags far behind
        feed(det, u, loss_rows=loss)
        assert det.verdict(3) == STRAGGLER_SUSPECT


# ---------------------------------------------------------------------------
# policy and actions
# ---------------------------------------------------------------------------

class TestPolicy:
    def test_evicts_failed_suspect(self):
        pol = RulePolicy()
        acts = pol.decide([HEALTHY, FAILED_SUSPECT, HEALTHY, HEALTHY],
                          np.ones(4, bool), round=5)
        assert [a.kind for a in acts] == ["evict"]
        assert acts[0].slots == (1,)

    def test_min_pool_floor(self):
        pol = RulePolicy(PolicyConfig(min_pool=3))
        acts = pol.decide([FAILED_SUSPECT, FAILED_SUSPECT, HEALTHY,
                           HEALTHY], np.ones(4, bool), round=5)
        evicted = [s for a in acts if a.kind == "evict" for s in a.slots]
        assert len(evicted) == 1  # floor leaves 3 live

    def test_never_empties_pool(self):
        pol = RulePolicy(PolicyConfig(min_pool=2, max_actions=8))
        acts = pol.decide([FAILED_SUSPECT] * 4, np.ones(4, bool), round=5)
        evicted = [s for a in acts if a.kind == "evict" for s in a.slots]
        assert len(evicted) <= 2

    def test_action_budget(self):
        pol = RulePolicy(PolicyConfig(min_pool=1, max_actions=1))
        acts = pol.decide([FAILED_SUSPECT] * 4, np.ones(4, bool), round=5)
        assert sum(1 for a in acts if a.kind != "noop") == 1

    def test_probe_readmit_after_verdict_clears(self):
        pol = RulePolicy(PolicyConfig(slot_cooldown=2))
        acts = pol.decide([HEALTHY, FAILED_SUSPECT, HEALTHY, HEALTHY],
                          np.ones(4, bool), round=5)
        assert acts[0].kind == "evict"
        active = np.array([True, False, True, True])
        # still flagged -> no readmit
        acts = pol.decide([HEALTHY, FAILED_SUSPECT, HEALTHY, HEALTHY],
                          active, round=6)
        assert all(a.kind == "noop" for a in acts)
        # verdict healthy again + cooldown elapsed -> probe
        acts = pol.decide([HEALTHY] * 4, active, round=8)
        assert [a.kind for a in acts] == ["readmit"]
        assert acts[0].slots == (1,)

    def test_slot_cooldown_rate_limits_flapping(self):
        pol = RulePolicy(PolicyConfig(slot_cooldown=3))
        pol.decide([FAILED_SUSPECT, HEALTHY, HEALTHY, HEALTHY],
                   np.ones(4, bool), round=5)
        active = np.array([False, True, True, True])
        acts = pol.decide([HEALTHY] * 4, active, round=6)  # too soon
        assert all(a.kind == "noop" for a in acts)

    def test_straggler_eviction_is_optional(self):
        pol = RulePolicy(PolicyConfig(evict_stragglers=False))
        acts = pol.decide([STRAGGLER_SUSPECT, HEALTHY, HEALTHY, HEALTHY],
                          np.ones(4, bool), round=5)
        assert all(a.kind == "noop" for a in acts)

    def test_make_policy_rejects_unknown(self):
        with pytest.raises(ValueError, match="unknown policy"):
            make_policy("gradient-descent")
        assert isinstance(make_policy("rules"), MembershipPolicy)


class TestActions:
    def test_kinds_and_validation(self):
        assert set(ACTION_KINDS) == {"evict", "readmit", "resize",
                                     "set_membership", "noop"}
        with pytest.raises(ValueError):
            ControlAction.evict([])
        with pytest.raises(ValueError):
            ControlAction.evict([-1])
        with pytest.raises(ValueError):
            ControlAction("resize")  # default k=0: no valid target
        with pytest.raises(ValueError):
            ControlAction("set_membership")
        with pytest.raises(ValueError):
            ControlAction("transmogrify")

    def test_describe_mentions_payload(self):
        assert "2" in ControlAction.evict([2], reason="x").describe()
        assert "5" in ControlAction.resize(5).describe()

    def test_make_controller_rejects_unknown(self):
        with pytest.raises(ValueError):
            make_controller("nope", capacity=4)
        ctl = make_controller("rules", capacity=4)
        assert isinstance(ctl, RuleController)
        assert isinstance(ctl.actuator, Actuator)


# ---------------------------------------------------------------------------
# no-oracle-leakage contract
# ---------------------------------------------------------------------------

class TestNoOracleLeakage:
    def test_control_sources_never_touch_truth_masks(self):
        """Static scan: no module under repro/control/ reads the schedule's
        ground-truth fields or the oracle feed."""
        forbidden = re.compile(
            r"\.(fail|straggle|restart|corrupt|failed_recent)\b")
        for src in sorted(CONTROL_DIR.glob("*.py")):
            for n, line in enumerate(src.read_text().splitlines(), 1):
                code = line.split("#", 1)[0]
                assert not forbidden.search(code), (
                    f"{src.name}:{n} touches a ground-truth mask: "
                    f"{line.strip()}")

    def test_detector_runs_on_truth_poisoned_records(self):
        """Runtime proof: records whose mask fields raise on access flow
        through the whole detector unharmed."""

        class PoisonedRecord:
            def __init__(self, round, u, active):
                self.round = round
                self.u = u
                self.active = active
                self.loss_w = None
                self.round_ms = 1.0

            @property
            def fail(self):
                raise AssertionError("detector read ground truth: fail")

            @property
            def straggle(self):
                raise AssertionError("detector read ground truth: straggle")

            @property
            def restart(self):
                raise AssertionError("detector read ground truth: restart")

            @property
            def corrupt(self):
                raise AssertionError("detector read ground truth: corrupt")

        det = FailureDetector(4)
        u = healthy_then_adrift(10, 4, slot=2, onset=4)
        for r in range(10):
            det.observe(PoisonedRecord(r, u[r], np.ones(4, bool)))
        assert det.verdict(2) == FAILED_SUSPECT


# ---------------------------------------------------------------------------
# session API redesign
# ---------------------------------------------------------------------------

def small_spec(**kw):
    kw.setdefault("elastic", ElasticConfig(num_workers=2, capacity=4,
                                           tau=1, alpha=0.1))
    kw.setdefault("rounds", 3)
    return RunSpec(arch="paper-cnn", smoke=True, seed=0,
                   optimizer=OptimizerConfig(name="sgd", lr=0.01),
                   batch_size=4, n_data=64, n_test=32, **kw)


@pytest.fixture(scope="module")
def small_session():
    sess = ElasticSession(small_spec())
    records = sess.run()
    return sess, records


class TestSessionControlAPI:
    def test_runspec_validation(self):
        with pytest.raises(ValueError, match="controller"):
            RunSpec(controller="nope")
        with pytest.raises(ValueError, match="plain"):
            RunSpec(plain=True, controller="rules")
        with pytest.raises(ValueError, match="oracle"):
            RunSpec(detector_blind=True,
                    elastic=ElasticConfig(num_workers=2, oracle=True))

    def test_apply_is_typed(self, small_session):
        sess, _ = small_session
        with pytest.raises(TypeError, match="ControlAction"):
            sess.apply("evict 2")

    def test_apply_evict_readmit_roundtrip(self):
        sess = ElasticSession(small_spec(rounds=4))
        sess.run(rounds=1)
        assert sess.num_active == 2
        with pytest.raises(ValueError, match="vacant"):
            sess.apply(ControlAction.evict([3]))  # slot 3 is vacant
        with pytest.raises(ValueError, match="live"):
            sess.apply(ControlAction.readmit([0]))  # slot 0 is live
        sess.apply(ControlAction.readmit([2]))
        assert sess.num_active == 3
        sess.apply(ControlAction.evict([0]))
        assert sess.num_active == 2
        assert not sess.active_mask[0] and sess.active_mask[2]
        sess.run()  # completes without error on the edited pool

    def test_deprecated_wrappers_warn_and_delegate(self):
        sess = ElasticSession(small_spec(rounds=4))
        sess.run(rounds=1)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            sess.resize(3)
            assert sess.num_active == 3
            sess.set_membership([True, True, False, False])
            assert sess.num_active == 2
        assert [x.category for x in w] == [DeprecationWarning] * 2
        assert "apply" in str(w[0].message)

    def test_observer_hooks_fire(self):
        seen = {"rounds": [], "chunks": 0}

        class Obs:
            def on_round(self, record):
                seen["rounds"].append(record.round)

            def on_chunk_end(self, session):
                seen["chunks"] += 1

        assert isinstance(Obs(), SessionObserver)
        sess = ElasticSession(small_spec(rounds=4, rounds_per_call=2))
        sess.add_observer(Obs())
        sess.run()
        assert seen["rounds"] == [0, 1, 2, 3]
        assert seen["chunks"] == 2

    def test_round_records_carry_telemetry(self, small_session):
        _, records = small_session
        for rec in records:
            assert rec.loss_w is not None and rec.loss_w.shape == (4,)
            live = np.asarray(rec.active, bool)
            assert np.all(np.isfinite(np.asarray(rec.loss_w)[live]))
            assert rec.round_ms > 0.0
            assert rec.dispatch_ms >= 0.0

    def test_detector_blind_echo_is_zeroed_and_bit_exact(self):
        ec = ElasticConfig(num_workers=2, capacity=2, tau=1,
                           failure_prob=0.5)
        open_sess = ElasticSession(small_spec(elastic=ec))
        open_recs = open_sess.run()
        blind_sess = ElasticSession(small_spec(elastic=ec,
                                               detector_blind=True))
        blind_recs = blind_sess.run()
        assert any(r.fail.any() for r in open_recs)  # faults really fired
        for rec in blind_recs:
            assert not rec.fail.any()
            assert not rec.straggle.any()
            assert not rec.restart.any()
            assert not rec.corrupt.any()
        # blinding the echo must not perturb the run itself
        np.testing.assert_array_equal(
            np.asarray(open_recs[-1].u), np.asarray(blind_recs[-1].u))

    def test_controller_field_wires_rule_controller(self):
        sess = ElasticSession(small_spec(controller="rules"))
        assert isinstance(sess.controller, RuleController)
        sess.run()
        # nothing suspicious in 3 healthy rounds -> journal has no applies
        assert all(not a.applied or a.action.kind == "noop"
                   for a in sess.controller.actuator.log)


# ---------------------------------------------------------------------------
# acceptance: detector-blind closed loop vs oracle-scheduled controller
# ---------------------------------------------------------------------------

def _control_bench():
    import sys

    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks import control_bench

    return control_bench


_ACCEPT_CACHE = {}


def accept_run(scenario, seed, arm, **spec_kw):
    """One cached acceptance-regime run; arm in {open, oracle, closed}.

    ``spec_kw`` forwards extra ElasticConfig knobs through
    ``control_spec`` (the adversarial sweep's byzantine/score_clip setup).
    """
    cb = _control_bench()
    key = (scenario, seed, arm, tuple(sorted(spec_kw.items())))
    if key in _ACCEPT_CACHE:
        return _ACCEPT_CACHE[key]
    if arm == "closed":
        sess = ElasticSession(cb.control_spec(
            scenario, seed, controller="rules", blind=True, **spec_kw))
        records = sess.run()
    elif arm == "oracle":
        sess = ElasticSession(cb.control_spec(scenario, seed, **spec_kw))
        sess.add_observer(cb.OracleController(sess.schedule))
        records = sess.run()
    else:
        sess = ElasticSession(cb.control_spec(scenario, seed, **spec_kw))
        records = sess.run()
    _ACCEPT_CACHE[key] = (sess, records)
    return sess, records


@pytest.mark.slow
@pytest.mark.parametrize("scenario", ["crash_restart", "straggler"])
class TestClosedLoopAcceptance:
    ROUNDS = 20
    SEEDS = (1, 2, 3)

    def test_flags_every_live_onset_failure_within_3_rounds(self, scenario):
        cb = _control_bench()
        for seed in self.SEEDS:
            sess, records = accept_run(scenario, seed, "closed")
            fail = np.asarray(sess.schedule.fail[:self.ROUNDS], bool)
            live = np.array([np.asarray(r.active, bool) for r in records])
            flags = {}
            for r, slot, v in sess.controller.detector.events:
                if v == FAILED_SUSPECT:
                    flags.setdefault(slot, []).append(r)
            for slot, onset, end in cb.fail_episodes(sess.schedule,
                                                     self.ROUNDS):
                if not live[onset, slot]:
                    continue  # onset while already evicted: telemetry dark
                hits = [r for r in flags.get(slot, [])
                        if onset <= r <= end + 2]
                assert hits, (scenario, seed, slot, onset)
                # the ≤3 guarantee holds while a strict minority of the
                # live pool is faulty; concurrent failures (>=half the
                # pool) may detect later but never go unseen
                window = fail[onset:min(onset + 3, self.ROUNDS)]
                contaminated = bool(
                    (2 * window.sum(axis=1) >= fail.shape[1]).any())
                if not contaminated:
                    assert hits[0] - onset <= 3, (scenario, seed, slot,
                                                  onset, hits)

    def test_readmits_on_recovery(self, scenario):
        cb = _control_bench()
        for seed in self.SEEDS:
            sess, _ = accept_run(scenario, seed, "closed")
            met = cb.closed_loop_metrics(sess, self.ROUNDS)
            fail = np.asarray(sess.schedule.fail[:self.ROUNDS], bool)
            act = np.asarray(sess.active_mask, bool)
            evicted = {s for a in sess.controller.actuator.log
                       if a.applied and a.action.kind == "evict"
                       for s in a.action.slots}
            cooldown = sess.controller.detector.cfg.readmit_cooldown
            for slot in range(fail.shape[1]):
                # a slot still out at the end must still be truly failed;
                # every evicted slot whose failure cleared with enough
                # rounds left for the probe cycle must be live again
                if not act[slot]:
                    assert slot in evicted
                    assert fail[-1, slot], (scenario, seed, slot)
                elif slot in evicted:
                    assert met["readmissions"] >= 1, (scenario, seed)
                if (slot in evicted and not fail[-(cooldown + 3):,
                                                 slot].any()):
                    assert act[slot], (scenario, seed, slot)

    def test_loss_degradation_vs_oracle_within_10pct(self, scenario):
        cb = _control_bench()
        degs = []
        for seed in self.SEEDS:
            _, orc_recs = accept_run(scenario, seed, "oracle")
            _, cl_recs = accept_run(scenario, seed, "closed")
            lo = cb.final_eval(orc_recs)
            lc = cb.final_eval(cl_recs)
            degs.append((lc - lo) / abs(lo) * 100.0)
        # mean over the seed set is the acceptance bar; individual seeds
        # may wobble (single-eval noise at this scale) but never wildly
        assert float(np.mean(degs)) <= 10.0, (scenario, degs)
        assert max(degs) <= 25.0, (scenario, degs)

    def test_straggler_runs_have_no_true_failures(self, scenario):
        if scenario != "straggler":
            pytest.skip("crash_restart covered above")
        cb = _control_bench()
        for seed in self.SEEDS:
            sess, _ = accept_run(scenario, seed, "closed")
            assert not cb.fail_episodes(sess.schedule, self.ROUNDS)
            # and the loop never shrinks the pool below the policy floor
            assert sess.num_active >= 2


@pytest.mark.slow
class TestDetectorSweep:
    """Five-generator detector-blind precision/recall sweep, offline: the
    detector replays each scenario's open-loop record stream. Floors are
    per scenario — transient regimes (iid 1-round blips, whole-rack
    correlated drops) are *designed* to stay below the hysteresis, so
    their floor is precision-only."""

    SEEDS = (1, 2, 3)
    ROUNDS = 20
    # per-scenario floors: (min recall on long live-onset episodes,
    #                       max false flags per run)
    FLOORS = {"crash_restart": (1.0, 1), "straggler": (None, 2),
              "iid": (None, 1), "burst": (0.5, 1), "correlated": (None, 1)}

    @pytest.mark.parametrize("scenario", sorted(FLOORS))
    def test_precision_recall_floor(self, scenario):
        cb = _control_bench()
        min_recall, max_fp = self.FLOORS[scenario]
        long_total, long_hit = 0, 0
        for seed in self.SEEDS:
            sess, records = accept_run(scenario, seed, "open")
            det = FailureDetector(4)
            for rec in records:
                det.observe(rec)  # reads observable fields only (proved
                # by TestNoOracleLeakage's poisoned-record run)
            flags = [(r, s) for r, s, v in det.events
                     if v == FAILED_SUSPECT]
            fail = np.asarray(sess.schedule.fail[:self.ROUNDS], bool)
            fps = [(r, s) for r, s in flags
                   if not fail[max(0, r - 4):r + 1, s].any()]
            assert len(fps) <= max_fp, (scenario, seed, fps)
            for slot, onset, end in cb.fail_episodes(sess.schedule,
                                                     self.ROUNDS):
                if end - onset < 4:
                    continue  # sub-hysteresis transients: not targets
                long_total += 1
                if any(s == slot and onset <= r <= end + 2
                       for r, s in flags):
                    long_hit += 1
        if min_recall is not None and long_total:
            assert long_hit / long_total >= min_recall, (
                scenario, long_hit, long_total)

    # adversarial/heterogeneous extension (ISSUE-9). Byzantine runs use
    # noise-mode corruption + score_clip: the clamp converts "polluting
    # the master" into the cut-drift signature adrift is built for (see
    # repro/control/detector.py docstring; without the clip the full-α
    # elastic pull parks the noisy worker at a fixed elevated distance
    # and almost nothing is flagged). frac=0.5 guarantees corrupt slots
    # on every sweep seed (the default 0.25 draws none on seeds 1–2).
    ADV = {
        "byzantine": (dict(byzantine_mode="noise", byzantine_frac=0.5,
                           score_clip=0.5),
                      1.0, 3),   # (spec kw, min corrupt-slot recall, max fp)
        "hetero": ({}, None, 2),
    }

    @pytest.mark.parametrize("scenario", sorted(ADV))
    def test_adversarial_precision_recall_floor(self, scenario):
        spec_kw, min_recall, max_fp = self.ADV[scenario]
        tot_c = hit_c = 0
        for seed in self.SEEDS:
            sess, records = accept_run(scenario, seed, "open", **spec_kw)
            det = FailureDetector(4)
            for rec in records:
                det.observe(rec)
            flags = [(r, s) for r, s, v in det.events
                     if v == FAILED_SUSPECT]
            sch = sess.schedule
            fail = np.asarray(sch.fail[:self.ROUNDS], bool)
            corrupt = (np.asarray(sch.corrupt[0], bool)
                       if sch.corrupt is not None
                       else np.zeros(fail.shape[1], bool))
            # truth for false-flag counting = fail ∪ corrupt: a flag on a
            # corrupt slot is never false, whenever it lands — the slot is
            # poisoned for the whole run
            fps = [(r, s) for r, s in flags
                   if not corrupt[s]
                   and not fail[max(0, r - 4):r + 1, s].any()]
            assert len(fps) <= max_fp, (scenario, seed, fps)
            tot_c += int(corrupt.sum())
            hit_c += sum(1 for c in np.where(corrupt)[0]
                         if any(s == c for _, s in flags))
        if min_recall is not None:
            assert tot_c > 0, "sweep drew no corrupt slots — raise frac"
            assert hit_c / tot_c >= min_recall, (scenario, hit_c, tot_c)
