"""benchmarks/compare.py — the BENCH_*.json regression gate (ISSUE-10).

Pure-host tests: every case feeds --records/--fresh fixtures through
``main(argv)`` directly, so no benchmark is actually re-run and nothing
touches jax. The gate's contract: exit 0 when every shared timing key is
within threshold, exit 1 when any regresses, only ``*_ms``/``*_us``-style
keys are gated (counts, ratios, metadata never are), and malformed or
runner-less sections are skipped rather than failed.
"""
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import compare  # noqa: E402 — needs the repo root on path


def _write(path, doc):
    path.write_text(json.dumps(doc))
    return str(path)


COMMITTED = {
    "what": "hierarchy",
    "arch": "paper-cnn",
    "k16_flat_comm_ms": 100.0,
    "k16_flat_global_syncs": 12,
    "k16_gp4_over_gp1": 0.93,
    "e2e_tau4_flat_ms_per_round": 1000.0,
}


def test_identical_fresh_run_passes(tmp_path, capsys):
    rec = _write(tmp_path / "BENCH_x.json", COMMITTED)
    fresh = _write(tmp_path / "fresh.json", COMMITTED)
    assert compare.main(["--records", rec, "--fresh", fresh]) == 0
    assert "[ ok ]" in capsys.readouterr().out


def test_inflated_timing_fails_and_names_the_key(tmp_path, capsys):
    rec = _write(tmp_path / "BENCH_x.json", COMMITTED)
    bad = dict(COMMITTED, k16_flat_comm_ms=200.0)
    fresh = _write(tmp_path / "fresh.json", bad)
    assert compare.main(["--records", rec, "--fresh", fresh]) == 1
    out = capsys.readouterr().out
    assert "REGRESSED" in out and "k16_flat_comm_ms" in out
    assert "[FAIL]" in out


def test_non_timing_keys_are_never_gated(tmp_path):
    rec = _write(tmp_path / "BENCH_x.json", COMMITTED)
    # syncs count and the gp ratio blow up 100x; timing keys stay put
    bad = dict(COMMITTED, k16_flat_global_syncs=1200, k16_gp4_over_gp1=93.0)
    fresh = _write(tmp_path / "fresh.json", bad)
    assert compare.main(["--records", rec, "--fresh", fresh]) == 0


def test_threshold_is_respected(tmp_path):
    rec = _write(tmp_path / "BENCH_x.json", COMMITTED)
    fresh = _write(tmp_path / "fresh.json",
                   dict(COMMITTED, k16_flat_comm_ms=180.0))
    assert compare.main(["--records", rec, "--fresh", fresh]) == 1
    assert compare.main(["--records", rec, "--fresh", fresh,
                         "--threshold", "2.0"]) == 0


def test_wrapper_document_csv_and_nested_sections(tmp_path, capsys):
    doc = {
        "date": "2026-08-08",
        "sections": {
            "kernels": [
                {"name": "elastic_k4", "us_per_call": 10.0},
                {"name": "elastic_k8", "us_per_call": 20.0},
            ],
            "scenarios": {"what": "scenarios",
                          "arms": {"clean": {"k4_ms_per_round": 5.0}}},
        },
    }
    rec = _write(tmp_path / "BENCH_w.json", doc)
    # nested arms regress through the dot-joined flattening
    fresh = _write(tmp_path / "fresh.json",
                   {"what": "scenarios",
                    "arms": {"clean": {"k4_ms_per_round": 50.0}}})
    assert compare.main(["--records", rec, "--fresh", fresh]) == 1
    out = capsys.readouterr().out
    assert "arms.clean.k4_ms_per_round" in out
    # with --fresh, csv sections are not re-run — they're skipped silently
    assert "elastic_k4" not in out


def test_malformed_and_runnerless_records_are_skipped(tmp_path, capsys):
    broken = tmp_path / "BENCH_broken.json"
    broken.write_text("{not json")
    unknown = _write(tmp_path / "BENCH_unknown.json",
                     {"what": "no_such_bench", "x_ms": 1.0})
    # no --fresh: the unknown section has no registered runner, so it is
    # skipped (and nothing else is runnable, so no bench executes)
    assert compare.main(["--records", str(broken), unknown]) == 0
    out = capsys.readouterr().out
    assert "not valid JSON" in out
    assert "no runner registered" in out
    assert out.count("[skip]") == 2


def test_no_records_is_a_pass(tmp_path, capsys):
    assert compare.main(["--records"]) == 0
    assert "no committed" in capsys.readouterr().out


def test_committed_bench_files_parse_into_sections():
    # the records actually committed at the repo root must all be
    # readable by the gate and expose at least one gated timing key
    import glob
    import os

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    assert paths, "no committed BENCH_*.json records"
    for path in paths:
        with open(path) as f:
            doc = json.load(f)
        sections = list(compare.committed_sections(doc))
        assert sections, path
        timed = [k for _, _, rec in sections for k in rec
                 if k.endswith(compare.TIMING_SUFFIXES)]
        assert timed, path
