"""Session API (ISSUE-3): RunSpec validation, legacy-loop equivalence.

The acceptance surface: ``ElasticSession`` — both per-round
(``rounds_per_call=1``) and jit-chunked (``rounds_per_call>1``) — must
reproduce the legacy hand-rolled per-round loop's master params
*bit-exactly*, across comm modes and failure scenarios; chunk boundaries
must not disturb the eval cadence; and every session checkpoint carries the
unified ``{"rounds", "arch", "scenario"}`` metadata.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ElasticSession, RoundRecord, RunSpec
from repro.configs.base import ElasticConfig, OptimizerConfig, get_config
from repro.core.coordinator import ElasticTrainer, RoundInputs
from repro.core.scenarios import ScenarioSchedule, make_scenario
from repro.data.pipeline import WorkerBatcher
from repro.data.synthetic import SyntheticImages
from repro.models.registry import build_model

ROUNDS, K = 4, 2


def _spec(comm_mode="sequential", scenario="iid", rpc=1, **kw):
    ecfg = ElasticConfig(num_workers=K, tau=2, alpha=0.1, dynamic=True,
                         failure_prob=0.4, comm_mode=comm_mode,
                         failure_scenario=scenario)
    defaults = dict(arch="paper-cnn",
                    optimizer=OptimizerConfig(name="sgd", lr=0.01),
                    elastic=ecfg, rounds=ROUNDS, rounds_per_call=rpc,
                    seed=1, batch_size=4, n_data=96, n_test=32)
    defaults.update(kw)
    return RunSpec(**defaults)


def _legacy_master(spec):
    """The pre-ISSUE-3 hand-rolled per-round loop (launch/train.py shape),
    replicating the session's data/schedule/rng conventions: one
    ``round_step`` jit call per round, masks converted row by row."""
    model = build_model(get_config(spec.arch))
    trainer = ElasticTrainer(model, spec.optimizer, spec.elastic)
    state = trainer.init_state(jax.random.key(spec.seed))
    ds = SyntheticImages(n=spec.n_data, n_test=spec.n_test,
                         seed=spec.data_seed)
    wb = WorkerBatcher(ds.images, ds.labels, spec.elastic,
                       batch_size=spec.batch_size, seed=spec.seed)
    sched = make_scenario(spec.elastic).schedule(spec.seed + 7, spec.rounds,
                                                 spec.elastic.num_workers)
    base = jax.random.key(spec.seed)
    for r in range(spec.rounds):
        inputs = RoundInputs(
            batches={k: jnp.asarray(v) for k, v in
                     wb.round_batches().items()},
            rng=jax.random.fold_in(base, r),
            fail=jnp.asarray(sched.fail[r]),
            failed_recent=jnp.asarray(sched.failed_recent(r)),
            straggle=(jnp.asarray(sched.straggle[r])
                      if sched.has_stragglers else None),
            restart=(jnp.asarray(sched.restart[r])
                     if sched.has_restarts else None))
        state, m = trainer.round_step(state, inputs)
    return state["master"]


def _assert_trees_bit_exact(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# equivalence: session (per-round and chunked) == legacy loop, bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("comm_mode", ["sequential", "fused"])
@pytest.mark.parametrize("scenario", ["iid", "crash_restart"])
def test_session_bit_exact_vs_legacy_loop(comm_mode, scenario):
    spec = _spec(comm_mode, scenario)
    want = _legacy_master(spec)

    per_round = ElasticSession(spec)
    recs = per_round.run()
    assert len(recs) == ROUNDS and per_round.round == ROUNDS
    _assert_trees_bit_exact(per_round.master_params, want)

    # rounds_per_call=3 over 4 rounds: one full chunk + a remainder chunk
    chunked = ElasticSession(spec.replace(rounds_per_call=3))
    crecs = chunked.run()
    assert len(crecs) == ROUNDS
    _assert_trees_bit_exact(chunked.master_params, want)

    # per-round diagnostics also agree between chunkings
    for a, b in zip(recs, crecs):
        assert a.round == b.round
        np.testing.assert_array_equal(a.h2, b.h2)
        np.testing.assert_array_equal(np.float32(a.loss), np.float32(b.loss))


def test_session_records_echo_schedule():
    spec = _spec(scenario="crash_restart", rpc=2)
    sess = ElasticSession(spec)
    recs = sess.run()
    for rec in recs:
        assert isinstance(rec, RoundRecord)
        np.testing.assert_array_equal(rec.fail, sess.schedule.fail[rec.round])
        np.testing.assert_array_equal(rec.restart,
                                      sess.schedule.restart[rec.round])
        assert np.isfinite(rec.loss)
    assert [r.round for r in recs] == list(range(ROUNDS))


def test_chunked_eval_matches_per_round_eval():
    """Chunk boundaries snap to eval rounds, so the eval cadence and values
    are independent of rounds_per_call."""
    a = ElasticSession(_spec(rpc=1, eval_every=2)).run()
    b = ElasticSession(_spec(rpc=3, eval_every=2)).run()
    evals_a = [(r.round, r.eval_loss, r.eval_acc) for r in a
               if r.eval_loss is not None]
    evals_b = [(r.round, r.eval_loss, r.eval_acc) for r in b
               if r.eval_loss is not None]
    assert [e[0] for e in evals_a] == [0, 2, 3]
    assert evals_a == evals_b


def test_run_iter_partial_then_resume():
    spec = _spec(rpc=2)
    sess = ElasticSession(spec)
    first = sess.run(1)
    assert len(first) == 1 and sess.round == 1
    rest = sess.run()
    assert [r.round for r in rest] == [1, 2, 3]
    with pytest.raises(ValueError):
        sess.run(1)  # past RunSpec.rounds

    # a split run lands on the same params as an uninterrupted one
    full = ElasticSession(spec)
    want = full.run()
    _assert_trees_bit_exact(sess.master_params, full.master_params)
    np.testing.assert_array_equal(np.float32(rest[-1].loss),
                                  np.float32(want[-1].loss))


# ---------------------------------------------------------------------------
# RunSpec validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(rounds=0),
    dict(rounds_per_call=0),
    dict(batch_size=0),
    dict(eval_every=-1),
    dict(n_data=0),
])
def test_runspec_rejects_bad_values(kw):
    with pytest.raises(ValueError):
        _spec(**kw)


def test_runspec_rejects_mismatched_schedule():
    z = np.zeros((ROUNDS + 1, K), bool)
    with pytest.raises(ValueError, match="schedule shape"):
        _spec(schedule=ScenarioSchedule(z, z, z))


def test_runspec_rejects_schedule_in_plain_mode():
    z = np.zeros((ROUNDS, K), bool)
    with pytest.raises(ValueError, match="plain"):
        _spec(plain=True, schedule=ScenarioSchedule(z, z, z))


def test_runspec_rejects_bad_elastic_config():
    with pytest.raises(ValueError):
        _spec(elastic=ElasticConfig(comm_mode="nope"))


# ---------------------------------------------------------------------------
# custom schedules, plain mode, checkpoints
# ---------------------------------------------------------------------------

def test_session_accepts_custom_schedule():
    fail = np.zeros((ROUNDS, K), bool)
    fail[1:3, 0] = True
    z = np.zeros_like(fail)
    sess = ElasticSession(_spec(schedule=ScenarioSchedule(fail, z, z)))
    recs = sess.run()
    assert [tuple(r.fail) for r in recs] == [tuple(row) for row in fail]
    # previous-round-only oracle feed, from the injected schedule
    np.testing.assert_array_equal(sess.schedule.failed_recent(2), fail[1])


def test_plain_mode_runs_and_saves_params(tmp_path):
    path = str(tmp_path / "ck")
    spec = _spec(plain=True, rpc=2, save_path=path, rounds=3)
    sess = ElasticSession(spec)
    recs = sess.run()
    assert len(recs) == 3 and all(np.isfinite(r.loss) for r in recs)
    from repro.checkpoint import checkpoint

    tree, meta = checkpoint.restore(path)
    assert meta == {"rounds": 3, "arch": "paper-cnn", "scenario": "none"}
    assert "conv1" in tree


def test_elastic_checkpoint_metadata_unified(tmp_path):
    path = str(tmp_path / "ck")
    sess = ElasticSession(_spec(scenario="burst", save_path=path))
    sess.run()
    from repro.checkpoint import checkpoint

    tree, meta = checkpoint.restore(path)
    assert meta["rounds"] == ROUNDS
    assert meta["arch"] == "paper-cnn"
    assert meta["scenario"] == "burst"
    # the saved tree is the master, restorable against it
    _assert_trees_bit_exact(tree, jax.tree.map(np.asarray,
                                               sess.master_params))
