"""Blockwise (XLA-native flash) attention vs naive oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.flash import blockwise_attention, naive_attention


def _inputs(seed, B, Sq, Skv, H, KVH, D):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D))
    k = jax.random.normal(ks[1], (B, Skv, KVH, D))
    v = jax.random.normal(ks[2], (B, Skv, KVH, D))
    qp = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Skv), (B, Skv))
    return q, k, v, qp, kp


@pytest.mark.parametrize("kw", [
    dict(causal=True), dict(causal=False),
    dict(causal=True, window=40), dict(causal=True, chunk=32),
    dict(causal=True, window=7, chunk=16),
])
def test_blockwise_matches_naive(kw):
    q, k, v, qp, kp = _inputs(0, 2, 128, 128, 4, 2, 32)
    o1 = blockwise_attention(q, k, v, q_pos=qp, kv_pos=kp, block_q=32,
                             block_k=32, **kw)
    o2 = naive_attention(q, k, v, q_pos=qp, kv_pos=kp, **kw)
    np.testing.assert_allclose(o1, o2, rtol=3e-5, atol=3e-5)


def test_blockwise_decode_positions():
    """Single query at arbitrary position against a long cache."""
    q, k, v, _, kp = _inputs(1, 2, 512, 512, 4, 4, 16)
    # emulate a cache: query block of 512 where only row pos matters
    qp = jnp.broadcast_to(jnp.arange(512), (2, 512)) + 7
    o1 = blockwise_attention(q, k, v, q_pos=qp, kv_pos=kp, block_q=256,
                             block_k=128, causal=True)
    o2 = naive_attention(q, k, v, q_pos=qp, kv_pos=kp, causal=True)
    np.testing.assert_allclose(o1, o2, rtol=3e-5, atol=3e-5)


def test_blockwise_gradients_match_naive():
    q, k, v, qp, kp = _inputs(2, 1, 64, 64, 2, 2, 16)
    f1 = lambda q: blockwise_attention(q, k, v, q_pos=qp, kv_pos=kp,
                                       block_q=16, block_k=16).sum()
    f2 = lambda q: naive_attention(q, k, v, q_pos=qp, kv_pos=kp).sum()
    np.testing.assert_allclose(jax.grad(f1)(q), jax.grad(f2)(q), rtol=2e-4,
                               atol=2e-4)


def test_blockwise_masked_rows_zero():
    """Rows with no visible keys (window fully past) produce zeros."""
    q, k, v, qp, kp = _inputs(3, 1, 32, 32, 1, 1, 8)
    o = blockwise_attention(q, k, v, q_pos=qp + 1000, kv_pos=kp,
                            block_q=16, block_k=16, causal=True, window=10)
    np.testing.assert_allclose(o, 0.0)
