"""Sharding rules: divisibility dropping, axis-uniqueness, mesh handling.

Pure PartitionSpec logic runs on the default single device; an 8-device
integration lowering runs in a subprocess (device count is locked at jax
init)."""
import json
import os
import subprocess
import sys

import pytest
from _property_shim import given, strategies as st
from jax.sharding import PartitionSpec as P

import jax

from repro.nn.param import ParamSpec
from repro.nn.sharding import batch_spec, physical_spec

ROOT = os.path.join(os.path.dirname(__file__), "..")


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape

    @property
    def devices(self):
        import numpy as np

        return np.empty(tuple(self.shape.values()))


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_divisible_dims_shard():
    spec = physical_spec((4096, 2560), ("mlp", "embed"), MESH)
    assert spec == P("model", "data")


def test_non_divisible_axis_dropped():
    # 8 kv heads on a 16-way model axis → replicated
    spec = physical_spec((8, 128), ("kv_heads", None), MESH)
    assert spec == P()


def test_axis_used_once():
    # both dims want 'model'; first wins, second replicates
    spec = physical_spec((32, 32), ("heads", "mlp"), MESH)
    assert spec == P("model")


def test_tuple_axis_partial_divisibility():
    # seq wants ('data','model'); 16 divides, 256 doesn't fit twice? 512 does
    spec = physical_spec((512, 4), ("seq_shard", None), MESH)
    assert spec == P(("data", "model"))
    spec = physical_spec((16, 4), ("seq_shard", None), MESH)
    assert spec == P("data")


def test_pod_axis_ignored_on_single_pod_mesh():
    spec = physical_spec((2, 100), ("worker", None), MESH)
    assert spec == P()
    spec3 = physical_spec((2, 100), ("worker", None), MESH3)
    assert spec3 == P("pod")


@given(b=st.sampled_from([1, 2, 4, 16, 32, 256, 100, 3]))
def test_batch_spec_always_valid(b):
    spec = batch_spec(b, MESH3)
    prod = 1
    for ax in (spec[0] if isinstance(spec[0], tuple) else
               ([spec[0]] if spec[0] else [])):
        prod *= MESH3.shape[ax]
    assert b % prod == 0


@pytest.mark.slow
def test_eight_device_lowering_subprocess():
    """Real NamedSharding lowering on an 8-device host mesh (2×4)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_config, OptimizerConfig, ShapeConfig
from repro.models.registry import build_model
from repro.nn.param import abstract_tree
from repro.nn.sharding import tree_pspecs
from repro.train.steps import (abstract_train_state, make_train_step,
                               train_state_pspecs)

mesh = jax.make_mesh((2, 4), ("data", "model"))
model = build_model(get_config("qwen3_4b", smoke=True))
ocfg = OptimizerConfig(name="adahessian")
shape = ShapeConfig("t", 64, 4, "train")
state = abstract_train_state(model, ocfg)
named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                               is_leaf=lambda x: isinstance(x, P))
state_sh = named(train_state_pspecs(model, ocfg, mesh))
specs = model.input_specs(shape)
batch = {k: jax.ShapeDtypeStruct(s.shape, s.dtype) for k, s in specs.items()}
batch_sh = {k: NamedSharding(mesh, P("data")) for k in specs}
step = make_train_step(model, ocfg)
with mesh:
    lowered = jax.jit(step, in_shardings=(state_sh, batch_sh,
                                          NamedSharding(mesh, P()))).lower(
        state, batch, jax.ShapeDtypeStruct((2,), jnp.uint32))
    compiled = lowered.compile()
ca = compiled.cost_analysis()
if isinstance(ca, list):  # older jax returns [dict] per device
    ca = ca[0]
print("COMPILED_OK", ca["flops"] > 0)
"""
    out = subprocess.run([sys.executable, "-c", code], cwd=ROOT,
                         capture_output=True, text=True, timeout=540)
    assert "COMPILED_OK True" in out.stdout, out.stderr[-2000:]
