import os
import sys

# Tests see the default single CPU device (the dry-run sets its own flags in
# a subprocess). Keep plenty of hypothesis examples but bound runtime.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")
