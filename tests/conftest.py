import os
import sys

# Tests see the default single CPU device (the dry-run sets its own flags in
# a subprocess). Keep plenty of hypothesis examples but bound runtime.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# hypothesis is an optional test dep (the `test` extra); collection must not
# hard-fail without it — property-based modules importorskip it themselves.
try:
    from hypothesis import settings
except ImportError:
    pass
else:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
