"""HLO text parsers: collective accounting + loop-aware cost model."""
import numpy as np
import pytest

from repro.analysis.hlo import collective_bytes
from repro.analysis.hlo_cost import loop_aware_costs

SIMPLE = """
HloModule test

ENTRY %main (p0: f32[128,64]) -> f32[128,64] {
  %p0 = f32[128,64]{1,0} parameter(0)
  %ar = f32[128,64]{1,0} all-reduce(%p0), replica_groups={}
  %ag = bf16[256,64]{1,0} all-gather(%p0), dimensions={0}
  ROOT %out = f32[128,64]{1,0} add(%ar, %ar)
}
"""


def test_collective_bytes_simple():
    c = collective_bytes(SIMPLE)
    assert c["all-reduce"] == 128 * 64 * 4
    assert c["all-gather"] == 256 * 64 * 2
    assert c["total"] == 128 * 64 * 4 + 256 * 64 * 2


LOOPED = """
HloModule test

%cond (arg: (s32[], f32[8,8])) -> pred[] {
  %arg = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %limit = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %limit), direction=LT
}

%body (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %arg = (s32[], f32[8,8]{1,0}) parameter(0)
  %x = f32[8,8]{1,0} get-tuple-element(%arg), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %arx = f32[8,8]{1,0} all-reduce(%d), replica_groups={}
  %i = s32[] get-tuple-element(%arg), index=0
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%i2, %arx)
}

ENTRY %main (p: f32[8,8]) -> f32[8,8] {
  %p = f32[8,8]{1,0} parameter(0)
  %big = f32[16,8]{1,0} dot(%p, %p), lhs_contracting_dims={0}, rhs_contracting_dims={0}
  %init = (s32[], f32[8,8]{1,0}) tuple(%p, %p)
  %w = (s32[], f32[8,8]{1,0}) while(%init), condition=%cond, body=%body
  ROOT %o = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_loop_aware_flops_multiplied():
    c = loop_aware_costs(LOOPED)
    body_dot = 2 * 8 * 8 * 8          # one 8×8×8 dot per iteration
    entry_dot = 2 * 16 * 8 * 8        # dims are parsed from result+lhs
    assert c["dot_flops"] == pytest.approx(entry_dot + 12 * body_dot)
    assert c["dot_flops_trip1"] == pytest.approx(entry_dot + body_dot)
    # collective inside the loop is ×12
    assert c["coll_total"] == pytest.approx(12 * 8 * 8 * 4)
    assert c["coll_total_trip1"] == pytest.approx(8 * 8 * 4)
    # multipliers feed the calibration
    assert c["coll_total"] / c["coll_total_trip1"] == pytest.approx(12.0)


def test_loop_aware_bytes_positive_and_scaled():
    c = loop_aware_costs(LOOPED)
    assert c["bytes"] > c["bytes_trip1"] > 0


def test_collective_done_not_double_counted():
    txt = """
ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %s = f32[64]{0} all-gather-start(%p0), dimensions={0}
  ROOT %d = f32[64]{0} all-gather-done(%s)
}
"""
    c = collective_bytes(txt)
    assert c["all-gather"] == 64 * 4  # start counted once, done skipped


def test_real_compiled_module_roundtrip():
    """End-to-end: compile a tiny scanned model, check loop multiplication."""
    import jax
    import jax.numpy as jnp

    def step(x, _):
        return x @ w, None

    w = jnp.ones((32, 32))

    def f(x):
        y, _ = jax.lax.scan(step, x, None, length=7)
        return y

    compiled = jax.jit(f).lower(jnp.ones((4, 32))).compile()
    c = loop_aware_costs(compiled.as_text())
    one_dot = 2 * 4 * 32 * 32
    assert c["dot_flops"] == pytest.approx(7 * one_dot, rel=0.01)


FUSED_SLICE = """
HloModule test

%fused_computation.1 (param_0.1: f32[64,128], param_1.1: s32[]) -> f32[1,128] {
  %param_0.1 = f32[64,128]{1,0} parameter(0)
  %param_1.1 = s32[] parameter(1)
  %c0 = s32[] constant(0)
  ROOT %ds = f32[1,128]{1,0} dynamic-slice(%param_0.1, %param_1.1, %c0), dynamic_slice_sizes={1,128}
}

ENTRY %main (p: f32[64,128], i: s32[]) -> f32[1,128] {
  %p = f32[64,128]{1,0} parameter(0)
  %i = s32[] parameter(1)
  ROOT %f = f32[1,128]{1,0} fusion(%p, %i), kind=kLoop, calls=%fused_computation.1
}
"""


def test_fusion_sliced_param_charged_slice_bytes():
    """A fusion whose param is consumed by an internal dynamic-slice reads
    only the slice from HBM — the parser must not charge the full 64×128."""
    c = loop_aware_costs(FUSED_SLICE)
    full = 64 * 128 * 4
    slice_b = 1 * 128 * 4
    # result + sliced param (not full) + s32 index
    assert c["bytes"] < full, c["bytes"]
    assert c["bytes"] >= 2 * slice_b
