"""Two-level hierarchical elastic averaging (ISSUE-10): rack-level
sub-masters, two-period sync, and the degenerate collapse to the flat
fused phase.

Covers the acceptance surface: config validation, grouped
event-order-equivalent schedule weights vs a per-rack sequential unroll,
groups=1/global_period=1 bit-exactness with flat fused, the two-period
global-sync cadence, uneven hierarchy shapes (capacity not divisible by
groups, a fully dark rack, membership growth across a group boundary),
and checkpoint restore at a different group count. Sharded-placement
bit-exactness of the hierarchy lives with the other forced-device
subprocess tests in tests/test_placement.py idiom — here as a subprocess
too, since the parent pytest process pins a single CPU device.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ElasticSession, RunSpec
from repro.configs.base import ElasticConfig, OptimizerConfig, get_config
from repro.core import dynamic_weight as dw
from repro.core.coordinator import ElasticTrainer
from repro.models.registry import build_model


def _trainer(k, *, groups=1, global_period=1, force_hier=False, **kw):
    model = build_model(get_config("paper_cnn"))
    defaults = dict(num_workers=k, tau=1, alpha=0.1, dynamic=True,
                    comm_mode="fused", groups=groups,
                    global_period=global_period)
    defaults.update(kw)
    tr = ElasticTrainer(model, OptimizerConfig(name="sgd", lr=0.01),
                        ElasticConfig(**defaults))
    if force_hier:
        tr.hierarchical = True
        tr.__post_init__()
    return tr


def _desynced_state(tr, seed=0, scale=0.1, desync_submasters=False):
    state = tr.init_state(jax.random.key(seed))
    state["workers"] = jax.tree.map(
        lambda x: x + jax.random.normal(jax.random.key(seed + 1), x.shape,
                                        x.dtype) * scale, state["workers"])
    if desync_submasters:
        state["submasters"] = jax.tree.map(
            lambda x: x + jax.random.normal(jax.random.key(seed + 2),
                                            x.shape, x.dtype) * scale,
            state["submasters"])
    return state


def _comm(tr, state, rounds=1, **kw):
    metrics = []
    fail = jnp.zeros((tr.ecfg.cap,), bool)
    fr = jnp.zeros((tr.ecfg.cap,), bool)
    for _ in range(rounds):
        state, m = tr.comm_phase(state, kw.pop("fail_mask", fail),
                                 kw.pop("failed_recent", fr), **kw)
        metrics.append(m)
    return state, metrics


# ---------------------------------------------------------------------------
# config validation + group assignment
# ---------------------------------------------------------------------------

def test_hierarchy_config_validation():
    with pytest.raises(ValueError):
        ElasticConfig(groups=0)
    with pytest.raises(ValueError):
        ElasticConfig(num_workers=4, groups=5)      # more racks than slots
    with pytest.raises(ValueError):
        ElasticConfig(global_period=0)
    with pytest.raises(ValueError):
        ElasticConfig(groups=2, comm_mode="sequential")
    with pytest.raises(ValueError):
        ElasticConfig(global_period=2, comm_mode="sequential")
    with pytest.raises(ValueError):
        ElasticConfig(groups=2, comm_mode="fused", staleness=1)
    with pytest.raises(ValueError):
        ElasticConfig(u_zclip=-1.0)
    # trivial topology is not "hierarchical" and needs no fused backend
    assert not ElasticConfig(groups=1, global_period=1).hierarchical
    assert ElasticConfig(num_workers=4, groups=2,
                         comm_mode="fused").hierarchical
    assert ElasticConfig(global_period=2, comm_mode="fused").hierarchical


@pytest.mark.parametrize("cap,groups", [(8, 4), (7, 3), (10, 3), (4, 4),
                                        (5, 1), (4, 9)])
def test_group_assignment_contiguous_and_covering(cap, groups):
    grp = dw.group_assignment(cap, groups)
    assert grp.shape == (cap,) and grp.dtype == np.int32
    eff = min(groups, cap)
    # contiguous, non-decreasing, every rack non-empty
    assert np.all(np.diff(grp) >= 0)
    assert set(grp.tolist()) == set(range(eff))
    # balanced: rack sizes differ by at most one
    sizes = np.bincount(grp)
    assert sizes.max() - sizes.min() <= 1


def test_grouped_schedule_weights_match_sequential_unroll():
    """Each rack's reduction must equal a sequential event-ordered scan of
    its own members: g_i = h2_i · Π_{j>i, same rack} (1 − h2_j), with no
    cross-rack discounting."""
    rng = np.random.default_rng(0)
    w2 = rng.uniform(0.0, 0.4, size=9).astype(np.float32)
    grp = dw.group_assignment(9, 3)
    got = np.asarray(dw.master_schedule_weights_grouped(
        jnp.asarray(w2), jnp.asarray(grp)))
    want = np.empty_like(w2)
    for i in range(9):
        acc = w2[i]
        for j in range(i + 1, 9):
            if grp[j] == grp[i]:
                acc *= 1.0 - w2[j]
        want[i] = acc
    np.testing.assert_allclose(got, want, rtol=1e-6)
    # degenerate single rack == the flat schedule weights
    flat = np.asarray(dw.master_schedule_weights(jnp.asarray(w2)))
    one = np.asarray(dw.master_schedule_weights_grouped(
        jnp.asarray(w2), jnp.zeros((9,), jnp.int32)))
    np.testing.assert_allclose(one, flat, rtol=1e-6)


# ---------------------------------------------------------------------------
# degenerate collapse + two-period cadence
# ---------------------------------------------------------------------------

def test_degenerate_hierarchy_bit_exact_with_flat_fused():
    """groups=1, global_period=1 forced through the hierarchical state must
    reproduce the flat fused master bit-for-bit, with the lone sub-master
    mirroring it."""
    flat = _trainer(6)
    hier = _trainer(6, force_hier=True)
    s_flat = _desynced_state(flat)
    s_hier = _desynced_state(hier)
    assert "submasters" in s_hier and "g_u_hist" in s_hier
    s_flat, _ = _comm(flat, s_flat, rounds=3)
    s_hier, ms = _comm(hier, s_hier, rounds=3)
    for a, b in zip(jax.tree.leaves(s_flat["master"]),
                    jax.tree.leaves(s_hier["master"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for m, sm in zip(jax.tree.leaves(s_hier["master"]),
                     jax.tree.leaves(s_hier["submasters"])):
        np.testing.assert_array_equal(np.asarray(m), np.asarray(sm[0]))
    # degenerate metrics exist but are zero placeholders
    assert np.asarray(ms[-1]["g_h2"]).shape == (1,)
    assert float(np.asarray(ms[-1]["g_h2"]).sum()) == 0.0


def test_two_period_global_sync_cadence():
    """The global phase fires exactly on rounds divisible by global_period;
    off-cycle rounds leave the master and g_u_hist untouched."""
    tr = _trainer(6, groups=3, global_period=2)
    state = _desynced_state(tr, desync_submasters=True)
    masters, g_hists, metrics = [], [], []
    for r in range(4):
        state, ms = _comm(tr, state)
        masters.append(jax.tree.leaves(state["master"])[0])
        g_hists.append(np.asarray(state["g_u_hist"]))
        metrics.append(ms[0])
    for r in range(4):
        synced = ((r + 1) % 2) == 0
        # g_u diagnostics are zeroed by the skip branch, recorded on sync
        assert bool(np.any(np.asarray(metrics[r]["g_u"]) != 0.0)) == synced
        prev_hist = np.full_like(g_hists[r], -30.0) if r == 0 else \
            g_hists[r - 1]
        if synced:
            assert not np.array_equal(g_hists[r], prev_hist)
        else:
            np.testing.assert_array_equal(g_hists[r], prev_hist)
    # off-cycle round 3 (index 2) must not move the master
    np.testing.assert_array_equal(np.asarray(masters[2]),
                                  np.asarray(masters[1]))
    assert not np.array_equal(np.asarray(masters[3]), np.asarray(masters[2]))


# ---------------------------------------------------------------------------
# uneven shapes: indivisible capacity, dark rack, growth across a boundary
# ---------------------------------------------------------------------------

def test_uneven_capacity_runs_finite():
    """capacity=7 over 3 racks (3+2+2): everything stays finite and every
    rack's sub-master moves at the global sync."""
    tr = _trainer(7, groups=3, global_period=2)
    state = _desynced_state(tr, desync_submasters=True)
    before = [np.asarray(x).copy()
              for x in jax.tree.leaves(state["submasters"])]
    state, _ = _comm(tr, state, rounds=2)
    for leaf in jax.tree.leaves(state["master"]) + jax.tree.leaves(
            state["submasters"]):
        assert np.isfinite(np.asarray(leaf)).all()
    after = [np.asarray(x) for x in jax.tree.leaves(state["submasters"])]
    for g in range(3):
        assert not np.array_equal(before[0][g], after[0][g])


def test_dark_rack_is_down_weighted_at_the_global_sync():
    """A rack whose every member failed this round syncs nothing: its
    sub-master is untouched by both levels, its g_h2 is 0, while live
    racks exchange — the dead-worker rule lifted to rack granularity."""
    tr = _trainer(6, groups=3, global_period=1)
    grp = dw.group_assignment(6, 3)
    dark = 1
    fail = jnp.asarray(grp == dark)          # kill every member of rack 1
    state = _desynced_state(tr, desync_submasters=True)
    sm_before = [np.asarray(x).copy()
                 for x in jax.tree.leaves(state["submasters"])]
    state, ms = _comm(tr, state, fail_mask=fail)
    g_h2 = np.asarray(ms[0]["g_h2"])
    assert g_h2.shape == (3,)
    assert g_h2[dark] == 0.0
    sm_after = [np.asarray(x) for x in jax.tree.leaves(state["submasters"])]
    np.testing.assert_array_equal(sm_before[0][dark], sm_after[0][dark])
    for g in (0, 2):
        assert not np.array_equal(sm_before[0][g], sm_after[0][g])
    # a dark rack still records its drift: g_u_hist advanced for all racks
    assert not np.array_equal(np.asarray(state["g_u_hist"][dark]),
                              np.full_like(np.asarray(
                                  state["g_u_hist"][dark]), -30.0))


def test_membership_growth_across_group_boundary():
    """Start with only rack 0 populated; grow the live pool across the
    group boundary. The vacant rack's g_u_hist stays frozen until it gains
    a live member, then starts advancing."""
    tr = _trainer(8, groups=2, global_period=1)
    state = _desynced_state(tr, desync_submasters=True)
    small = jnp.arange(8) < 3                 # rack 0 only (slots 0–2)
    grown = jnp.arange(8) < 6                 # crosses into rack 1
    state, ms1 = _comm(tr, state, active=small)
    hist1 = np.asarray(state["g_u_hist"])
    np.testing.assert_array_equal(hist1[1], np.full_like(hist1[1], -30.0))
    assert np.asarray(ms1[0]["g_u"])[1] == 0.0      # vacant rack: zeroed
    assert not np.array_equal(hist1[0], np.full_like(hist1[0], -30.0))
    state, ms2 = _comm(tr, state, active=grown)
    hist2 = np.asarray(state["g_u_hist"])
    assert not np.array_equal(hist2[1], np.full_like(hist2[1], -30.0))
    for leaf in jax.tree.leaves(state["master"]):
        assert np.isfinite(np.asarray(leaf)).all()


# ---------------------------------------------------------------------------
# session + checkpoint threading
# ---------------------------------------------------------------------------

def _hier_spec(groups=2, global_period=2, k=5, rounds=3, seed=1):
    return RunSpec(
        arch="paper-cnn", optimizer=OptimizerConfig(name="sgd", lr=0.01),
        elastic=ElasticConfig(num_workers=k, tau=1, dynamic=True,
                              comm_mode="fused", groups=groups,
                              global_period=global_period),
        rounds=rounds, seed=seed, batch_size=4, n_data=64, n_test=32)


def test_session_records_carry_group_metrics_with_cadence():
    sess = ElasticSession(_hier_spec(rounds=4))
    recs = sess.run()
    for r in recs:
        assert r.g_u is not None and r.g_u.shape == (2,)
        synced = bool(np.any(r.g_u != 0.0))
        assert synced == (((r.round + 1) % 2) == 0)
    # flat sessions carry no group diagnostics
    flat = ElasticSession(RunSpec(
        arch="paper-cnn", optimizer=OptimizerConfig(name="sgd", lr=0.01),
        elastic=ElasticConfig(num_workers=3, tau=1, dynamic=True,
                              comm_mode="fused"),
        rounds=1, seed=0, batch_size=4, n_data=64, n_test=32))
    assert flat.run()[0].g_u is None


def test_checkpoint_restore_at_different_group_count(tmp_path):
    """Racks saved at groups=2 are carried into a groups=3 session (the
    extra rack seeds from the master); a flat session restores the same
    checkpoint ignoring the hierarchy; a hierarchical session restores a
    flat checkpoint with every rack seeded from the master."""
    sess = ElasticSession(_hier_spec(groups=2))
    sess.run()
    path = os.path.join(str(tmp_path), "ck")
    sess.save(path)

    same = ElasticSession(_hier_spec(groups=2))
    meta = same.restore(path)
    assert meta["elastic"]["groups"] == 2
    assert meta["elastic"]["global_period"] == 2
    for a, b in zip(jax.tree.leaves(sess.state["submasters"]),
                    jax.tree.leaves(same.state["submasters"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(sess.state["g_u_hist"]),
                                  np.asarray(same.state["g_u_hist"]))

    grown = ElasticSession(_hier_spec(groups=3))
    grown.restore(path)
    sm_old = jax.tree.leaves(sess.state["submasters"])[0]
    sm_new = jax.tree.leaves(grown.state["submasters"])[0]
    m_new = jax.tree.leaves(grown.state["master"])[0]
    assert sm_new.shape[0] == 3
    np.testing.assert_array_equal(np.asarray(sm_new[:2]), np.asarray(sm_old))
    np.testing.assert_array_equal(np.asarray(sm_new[2]), np.asarray(m_new))
    assert np.asarray(grown.state["g_u_hist"]).shape[0] == 3

    flat = ElasticSession(RunSpec(
        arch="paper-cnn", optimizer=OptimizerConfig(name="sgd", lr=0.01),
        elastic=ElasticConfig(num_workers=5, tau=1, dynamic=True,
                              comm_mode="fused"),
        rounds=2, seed=1, batch_size=4, n_data=64, n_test=32))
    flat.restore(path)
    for a, b in zip(jax.tree.leaves(sess.state["master"]),
                    jax.tree.leaves(flat.state["master"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert "submasters" not in flat.state

    flat_path = os.path.join(str(tmp_path), "ck_flat")
    flat.save(flat_path)
    rehier = ElasticSession(_hier_spec(groups=2))
    rehier.restore(flat_path)
    sm = jax.tree.leaves(rehier.state["submasters"])[0]
    m = jax.tree.leaves(rehier.state["master"])[0]
    for g in range(2):
        np.testing.assert_array_equal(np.asarray(sm[g]), np.asarray(m))


# ---------------------------------------------------------------------------
# sharded placement bit-exactness (forced-device subprocess)
# ---------------------------------------------------------------------------

_SHARDED_EQUIV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ElasticConfig, OptimizerConfig, get_config
from repro.core.coordinator import ElasticTrainer, RoundInputs
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model


def mk(placement, mesh=None, k=8):
    model = build_model(get_config("paper_cnn"))
    return ElasticTrainer(
        model, OptimizerConfig(name="sgd", lr=0.01),
        ElasticConfig(num_workers=k, tau=2, alpha=0.1, dynamic=True,
                      comm_mode="fused", placement=placement,
                      groups=3, global_period=2),
        mesh=mesh)


def batches(k, tau, rng):
    x = jax.random.normal(rng, (tau, k, 2, 28, 28, 1), jnp.float32)
    y = jax.random.randint(jax.random.fold_in(rng, 1), (tau, k, 2), 0, 10)
    return {"images": x, "labels": y}


def run(tr, sharded, n_rounds=4, seed=0):
    k = tr.ecfg.cap
    state = tr.init_state(jax.random.key(seed))
    if sharded:
        from jax.sharding import NamedSharding
        specs = tr.state_shard_specs()
        state = {kk: jax.device_put(v, NamedSharding(tr.mesh, specs[kk]))
                 for kk, v in state.items()}
    mets = []
    for r in range(n_rounds):
        rng = jax.random.fold_in(jax.random.key(seed + 100), r)
        inp = RoundInputs(batches=batches(k, tr.ecfg.tau,
                                          jax.random.fold_in(rng, 2)),
                          rng=rng,
                          fail=jnp.zeros((k,), bool),
                          failed_recent=jnp.zeros((k,), bool))
        step = tr.round_step_sharded if sharded else tr.round_step
        state, m = step(state, inp)
        mets.append(m)
    return state, mets


st1, m1 = run(mk("single"), sharded=False)
mesh = make_host_mesh(pod=4)
st2, m2 = run(mk("sharded", mesh), sharded=True)
for key in ("master", "submasters"):
    for a, b in zip(jax.tree.leaves(st1[key]), jax.tree.leaves(st2[key])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
for a, b in zip(m1, m2):
    np.testing.assert_array_equal(np.asarray(a["h2"]), np.asarray(b["h2"]))
    np.testing.assert_array_equal(np.asarray(a["g_h2"]),
                                  np.asarray(b["g_h2"]))
print("HIER_SHARDED_BIT_EXACT")
"""


def test_sharded_hierarchy_matches_single_bit_exact():
    """Master, sub-masters and rack diagnostics agree bit-for-bit between
    single and 4-way sharded placement (uneven 3-rack topology over 8
    slots, two-period sync) — run in a subprocess so the forced device
    count applies before jax initializes."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SHARDED_EQUIV],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "HIER_SHARDED_BIT_EXACT" in out.stdout
