"""Fused local phase (ISSUE-7): batched multi-worker AdaHessian kernel
parity, use_pallas plumbing, delayed averaging (staleness), and full-run
equivalence of the fused vs plain local paths.

Bitwise comparisons run both sides under ``jax.jit`` with all array inputs
traced: eager per-op dispatch and closure constant-folding both perturb
mul+add contraction in the last ulp, which is numerics noise, not a kernel
property.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ElasticConfig, OptimizerConfig, get_config
from repro.core.coordinator import ElasticTrainer, RoundInputs
from repro.models.registry import build_model

SHAPES = [(7,), (3, 3, 2, 5), (33, 130)]  # bias, conv kernel, d % 128 != 0


def _stacked_tree(k, seed, scale=1.0):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(SHAPES))
    return {f"p{i}": scale * jax.random.normal(kk, (k,) + s, jnp.float32)
            for i, (kk, s) in enumerate(zip(keys, SHAPES))}


def _problem(k):
    p, g, h = _stacked_tree(k, 1), _stacked_tree(k, 2), _stacked_tree(k, 3)
    m = _stacked_tree(k, 4, scale=0.1)
    v = jax.tree.map(jnp.abs, _stacked_tree(k, 5, scale=0.1))
    count = jnp.arange(1, k + 1, dtype=jnp.int32) * 2 + 1  # distinct per-worker t
    return p, g, h, {"count": count, "m": m, "v": v}


# ---------------------------------------------------------------------------
# kernel vs oracle, interpret mode
# ---------------------------------------------------------------------------

@pytest.mark.pallas
@pytest.mark.parametrize("k", [1, 4, 8])
@pytest.mark.parametrize("wd", [0.0, 1e-4])
def test_batched_kernel_matches_ref_bitwise(k, wd):
    """The multi-worker kernel == the vmapped single-worker oracle, bit for
    bit, across odd shapes and per-worker step counts."""
    from repro.kernels.adahessian.ops import adahessian_update_batched
    from repro.kernels.adahessian.ref import adahessian_step_batched_ref

    cfg = OptimizerConfig(name="adahessian", lr=1e-3, weight_decay=wd)
    p, g, h, state = _problem(k)
    fk = jax.jit(functools.partial(adahessian_update_batched, cfg=cfg,
                                   use_kernel=True, interpret=True))
    new_p, new_s = fk(p, g, h, state)
    fr = jax.jit(lambda p, g, h, m, v, t: {
        n: adahessian_step_batched_ref(p[n], g[n], h[n], m[n], v[n], cfg, t)
        for n in p})
    refs = fr(p, g, h, state["m"], state["v"], state["count"] + 1)
    np.testing.assert_array_equal(np.asarray(new_s["count"]),
                                  np.asarray(state["count"] + 1))
    for name in p:
        rp, rm, rv = refs[name]
        for got, want in ((new_p[name], rp), (new_s["m"][name], rm),
                          (new_s["v"][name], rv)):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.pallas
@pytest.mark.parametrize("k", [1, 4])
def test_batched_kernel_matches_jnp_path_bitwise(k):
    """use_kernel=True == use_kernel=False (the vmapped moment_update path
    used per shard under sharded placement), bit for bit."""
    from repro.kernels.adahessian.ops import adahessian_update_batched

    cfg = OptimizerConfig(name="adahessian", lr=1e-3, weight_decay=1e-4)
    p, g, h, state = _problem(k)
    outs = {}
    for use_kernel in (True, False):
        f = jax.jit(functools.partial(adahessian_update_batched, cfg=cfg,
                                      use_kernel=use_kernel, interpret=True))
        outs[use_kernel] = f(p, g, h, state)
    for a, b in zip(jax.tree.leaves(outs[True]), jax.tree.leaves(outs[False])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# trainer: fused local phase == plain local phase
# ---------------------------------------------------------------------------

def _round_once(tr, k, seed=0):
    state = tr.init_state(jax.random.key(0))
    batches = {
        "images": jax.random.normal(jax.random.key(5 + seed),
                                    (2, k, 4, 28, 28, 1), jnp.float32),
        "labels": jnp.zeros((2, k, 4), jnp.int32),
    }
    new_state, _ = tr.round_step(state, RoundInputs(
        batches=batches, rng=jax.random.key(1),
        fail=jnp.zeros(k, bool), failed_recent=jnp.zeros(k, bool)))
    return new_state


@pytest.mark.pallas
def test_fused_local_phase_workers_bitwise():
    """Plain vmapped per-worker steps, the fused jnp structure, and the
    fused Pallas kernel all produce bit-identical worker params after a
    τ=2 round (the comm phase is shared, so workers are the local-phase
    comparison)."""
    model = build_model(get_config("paper_cnn"))
    ecfg = ElasticConfig(num_workers=2, tau=2, comm_mode="fused")
    ocfg = OptimizerConfig(name="adahessian", lr=1e-3)
    mk = lambda **kw: ElasticTrainer(model, ocfg, ecfg, **kw)
    plain = _round_once(mk(), 2)
    fused_jnp = _round_once(mk(fused_local=True), 2)
    fused_pallas = _round_once(mk(use_pallas=True), 2)
    for variant in (fused_jnp, fused_pallas):
        for a, b in zip(jax.tree.leaves(plain["workers"]),
                        jax.tree.leaves(variant["workers"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(plain["opt"]),
                        jax.tree.leaves(variant["opt"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_local_requires_adahessian():
    """The fused path is AdaHessian-only — other optimizers silently fall
    back to the plain per-worker step (use_pallas still gates the elastic
    comm kernel)."""
    model = build_model(get_config("paper_cnn"))
    tr = ElasticTrainer(model, OptimizerConfig(name="sgd", lr=0.01),
                        ElasticConfig(num_workers=2, tau=1), use_pallas=True)
    assert tr._fused_local is False


# ---------------------------------------------------------------------------
# full runs: use_pallas=True vs False
# ---------------------------------------------------------------------------

@pytest.mark.pallas
@pytest.mark.parametrize("comm_mode,placement", [
    ("sequential", "single"), ("fused", "single"), ("fused", "sharded")])
def test_full_run_pallas_vs_jnp(comm_mode, placement):
    """A full multi-round AdaHessian run with use_pallas=True tracks the
    jnp run: worker params bit-exact (the fused local phase is bitwise),
    master allclose (the elastic comm kernel's flat layout re-associates
    the weighted reduction — same tolerance as its own parity tests)."""
    from repro.api import ElasticSession, RunSpec

    def run(use_pallas):
        spec = RunSpec(
            arch="paper-cnn",
            optimizer=OptimizerConfig(name="adahessian", lr=1e-3),
            elastic=ElasticConfig(num_workers=2, tau=1, dynamic=True,
                                  comm_mode=comm_mode, placement=placement),
            rounds=2, seed=1, batch_size=4, n_data=64, n_test=32,
            use_pallas=use_pallas)
        sess = ElasticSession(spec)
        recs = sess.run()
        return sess, recs

    s1, r1 = run(False)
    s2, r2 = run(True)
    for a, b in zip(jax.tree.leaves(s1.state["workers"]),
                    jax.tree.leaves(s2.state["workers"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(s1.master_params),
                    jax.tree.leaves(s2.master_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)
    for a, b in zip(r1, r2):
        np.testing.assert_allclose(a.loss, b.loss, rtol=1e-6)


# ---------------------------------------------------------------------------
# delayed averaging (ElasticConfig.staleness, DaSGD)
# ---------------------------------------------------------------------------

def test_staleness_validation():
    with pytest.raises(ValueError, match="staleness"):
        ElasticConfig(staleness=2)
    with pytest.raises(ValueError, match="fused"):
        ElasticConfig(staleness=1, comm_mode="sequential")
    ElasticConfig(staleness=1, comm_mode="fused")  # ok


def test_elastic_update_master_ref_semantics():
    """With master_ref, diffs are measured against the stale snapshot while
    the accumulation target stays the live master — checked against the
    hand-written DaSGD expressions."""
    from repro.core.elastic import elastic_update_batched

    k = 3
    ws = _stacked_tree(k, 8)
    master = {n: x[0] * 0.5 for n, x in _stacked_tree(1, 9).items()}
    ref = {n: x[0] * 0.25 for n, x in _stacked_tree(1, 10).items()}
    w1 = jnp.asarray([0.1, 0.3, 0.0])
    w2 = jnp.asarray([0.2, 0.0, 0.4])
    new_w, new_m = elastic_update_batched(ws, master, w1, w2,
                                          master_ref=ref)
    for n in ws:
        diff = ws[n] - ref[n][None]
        want_w = ws[n] - w1.reshape(-1, *([1] * (ws[n].ndim - 1))) * diff
        want_m = master[n] + jnp.sum(
            w2.reshape(-1, *([1] * (ws[n].ndim - 1))) * diff, axis=0)
        np.testing.assert_allclose(np.asarray(new_w[n]), np.asarray(want_w),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(new_m[n]), np.asarray(want_m),
                                   rtol=1e-6)


@pytest.mark.pallas
def test_elastic_pallas_master_ref_matches_jnp():
    """The batched elastic kernel's master_ref path tracks the jnp
    expression (same tolerance as the ref-less parity tests)."""
    from repro.core.elastic import elastic_update_batched
    from repro.kernels.elastic.ops import elastic_update_batched_pallas

    k = 4
    ws = _stacked_tree(k, 11)
    master = {n: x[0] * 0.5 for n, x in _stacked_tree(1, 12).items()}
    ref = {n: x[0] * 0.25 for n, x in _stacked_tree(1, 13).items()}
    w1 = jnp.asarray([0.1, 0.3, 0.0, 0.7])
    w2 = jnp.asarray([0.2, 0.0, 0.4, 0.1])
    wj, mj = elastic_update_batched(ws, master, w1, w2, master_ref=ref)
    wp, mp = elastic_update_batched_pallas(ws, master, w1, w2,
                                           master_ref=ref, interpret=True)
    for a, b in zip(jax.tree.leaves((wj, mj)), jax.tree.leaves((wp, mp))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


def _staleness_trainer(staleness):
    model = build_model(get_config("paper_cnn"))
    return ElasticTrainer(
        model, OptimizerConfig(name="sgd", lr=0.01),
        ElasticConfig(num_workers=2, tau=1, comm_mode="fused",
                      staleness=staleness))


def _run_rounds(tr, rounds):
    state = tr.init_state(jax.random.key(0))
    states = []
    for r in range(rounds):
        batches = {
            "images": jax.random.normal(jax.random.key(20 + r),
                                        (1, 2, 4, 28, 28, 1), jnp.float32),
            "labels": jnp.zeros((1, 2, 4), jnp.int32),
        }
        state, _ = tr.round_step(state, RoundInputs(
            batches=batches, rng=jax.random.key(40 + r),
            fail=jnp.zeros(2, bool), failed_recent=jnp.zeros(2, bool)))
        states.append(jax.tree.map(np.asarray, state))
    return states


def test_staleness_first_round_coincides_then_diverges():
    """Round 1: master_prev == the init master, so staleness=1 must match
    staleness=0 exactly. Round 2: ref becomes M_0 (two rounds behind the
    live master) and the trajectories split — DaSGD's one-round-deeper
    delay, not a no-op flag."""
    s0 = _run_rounds(_staleness_trainer(0), 2)
    s1 = _run_rounds(_staleness_trainer(1), 2)
    for a, b in zip(jax.tree.leaves(s0[0]), jax.tree.leaves(s1[0])):
        np.testing.assert_array_equal(a, b)
    m0 = jax.tree.leaves(s0[1]["master"])
    m1 = jax.tree.leaves(s1[1]["master"])
    assert any(not np.array_equal(a, b) for a, b in zip(m0, m1))


def test_staleness_round2_uses_round0_master():
    """The round-2 exchange of a staleness=1 run reproduces exactly when
    recomputed with the *init* master as the elastic reference — the
    mechanism, not just divergence."""
    from repro.core.elastic import elastic_update_batched

    tr = _staleness_trainer(1)
    states = _run_rounds(tr, 2)
    init_master = tr.init_state(jax.random.key(0))["master"]

    # replay round 2's comm phase by hand: local phase of round 2, scores
    # against M_0, elastic update with ref = M_0
    import repro.core.dynamic_weight as dw

    state1 = {k: jax.tree.map(jnp.asarray, v)
              for k, v in states[0].items()}
    batches = {
        "images": jax.random.normal(jax.random.key(21),
                                    (1, 2, 4, 28, 28, 1), jnp.float32),
        "labels": jnp.zeros((1, 2, 4), jnp.int32),
    }
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(state1["master_prev"])[0]),
        np.asarray(jax.tree.leaves(init_master)[0]))

    @jax.jit
    def replay(state1, batches, rng):
        mid, _, _ = tr.local_phase(state1, batches, rng)
        ref = state1["master_prev"]  # == M_0 after round 1
        u, hist, a, w1, w2 = dw.comm_scores_batched(
            tr.ecfg, mid["workers"], ref, mid["u_hist"],
            failed_recently=jnp.zeros(2, bool))
        g2 = dw.master_schedule_weights(w2)
        return elastic_update_batched(mid["workers"], mid["master"], w1, g2,
                                      master_ref=ref)

    want_w, want_m = replay(state1, batches, jax.random.key(41))
    for a_, b_ in zip(jax.tree.leaves(want_m),
                      jax.tree.leaves(states[1]["master"])):
        np.testing.assert_array_equal(np.asarray(a_), np.asarray(b_))


# ---------------------------------------------------------------------------
# use_pallas plumbing: one flag, every kernel path
# ---------------------------------------------------------------------------

def test_session_coerces_model_cfg_use_pallas():
    """RunSpec.use_pallas is the single source of truth: a model config
    that disagrees is coerced, so the model-internal and trainer kernel
    paths can't split."""
    from repro.api import ElasticSession, RunSpec

    cfg = get_config("paper_cnn").replace(use_pallas=True)
    spec = RunSpec(arch="paper-cnn", model_cfg=cfg,
                   elastic=ElasticConfig(num_workers=2),
                   rounds=1, batch_size=4, n_data=64, n_test=32,
                   use_pallas=False)
    sess = ElasticSession(spec)
    assert sess.model_cfg.use_pallas is False
    assert sess.trainer.use_pallas is False

    spec2 = RunSpec(arch="paper-cnn",
                    elastic=ElasticConfig(num_workers=2),
                    rounds=1, batch_size=4, n_data=64, n_test=32,
                    use_pallas=True)
    sess2 = ElasticSession(spec2)
    assert sess2.model_cfg.use_pallas is True
    assert sess2.trainer.use_pallas is True


@pytest.mark.pallas
def test_use_pallas_reaches_both_kernel_paths(monkeypatch):
    """With use_pallas=True, one round drives BOTH the batched AdaHessian
    local kernel and the batched elastic comm kernel — asserted by
    spying on the two kernel entry points the coordinator calls."""
    import repro.kernels.adahessian.ops as aops
    import repro.kernels.elastic.ops as eops

    called = set()
    real_local = aops.adahessian_update_batched
    real_comm = eops.elastic_update_batched_pallas

    def spy_local(*a, **kw):
        called.add("adahessian")
        return real_local(*a, **kw)

    def spy_comm(*a, **kw):
        called.add("elastic")
        return real_comm(*a, **kw)

    monkeypatch.setattr(aops, "adahessian_update_batched", spy_local)
    monkeypatch.setattr(eops, "elastic_update_batched_pallas", spy_comm)

    model = build_model(get_config("paper_cnn"))
    tr = ElasticTrainer(model, OptimizerConfig(name="adahessian", lr=1e-3),
                        ElasticConfig(num_workers=2, tau=1,
                                      comm_mode="fused"), use_pallas=True)
    _round_once(tr, 2)
    assert called == {"adahessian", "elastic"}
