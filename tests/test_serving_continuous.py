"""Continuous batching subsystem: parity, recompiles, lifecycle, hot-swap.

The ISSUE-8 acceptance criteria live here:

- the continuous engine is token-bitwise-identical to the static
  ``ServeEngine`` reference on the degenerate all-arrive-at-t0 batch,
  across two archs;
- requests joining/finishing mid-flight trigger zero recompiles after
  warmup (asserted on the jit trace-cache sizes, as the PR 5 membership
  tests do for training chunks);
- an engine watching a running ``ElasticSession``'s checkpoint dir picks
  up a new master without dropping in-flight requests, and post-swap
  outputs match a fresh engine restored from the same checkpoint.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.registry import build_model
from repro.nn.param import init_tree
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import ServeEngine
from repro.serving.hotswap import CheckpointWatcher
from repro.serving.scheduler import Request, Scheduler
from repro.serving.traffic import TrafficConfig, synthetic_traffic

ARCHS = ["qwen3_4b", "stablelm_3b"]


@pytest.fixture(scope="module", params=ARCHS)
def served(request):
    cfg = get_config(request.param, smoke=True)
    model = build_model(cfg)
    params = init_tree(jax.random.key(0), model.spec)
    return cfg, model, params


def _prompts(n, length, vocab, seed=0):
    return np.random.default_rng(seed).integers(
        0, vocab, (n, length)).astype("int32")


# ---------------------------------------------------------------------------
# bit-exactness vs the static reference
# ---------------------------------------------------------------------------

def test_degenerate_static_batch_bitwise_identical(served):
    """All requests at t=0, identical lengths: tokens must match
    ``ServeEngine.generate`` bit for bit (both archs)."""
    cfg, model, params = served
    prompts = _prompts(3, 8, cfg.vocab_size)
    want = ServeEngine(model, params, max_len=64).generate(prompts, steps=10)
    eng = ContinuousEngine(model, params, capacity=3, max_len=64,
                           prefill_len=8)
    for i in range(3):
        eng.admit(prompts[i], max_new=10, rid=i)
    done = []
    while eng.num_active:
        done += eng.step()
    got = np.stack([f.tokens for f in sorted(done, key=lambda f: f.rid)])
    np.testing.assert_array_equal(got, want)
    assert all(f.reason == "length" for f in done)


def test_midflight_join_matches_solo_run(served):
    """A short (bucket-padded) prompt admitted while two other requests
    are five tokens deep decodes exactly what it would decode alone."""
    cfg, model, params = served
    prompts = _prompts(2, 8, cfg.vocab_size, seed=1)
    late = _prompts(1, 5, cfg.vocab_size, seed=2)
    eng = ContinuousEngine(model, params, capacity=3, max_len=64,
                           prefill_len=8)
    eng.admit(prompts[0], max_new=30, rid=0)
    eng.admit(prompts[1], max_new=30, rid=1)
    for _ in range(5):
        eng.step()
    eng.admit(late[0], max_new=8, rid=2)
    done = []
    while 2 not in {f.rid for f in done}:
        done += eng.step()
    got = next(f for f in done if f.rid == 2).tokens
    solo = ServeEngine(model, params, max_len=64).generate(late, steps=8)
    np.testing.assert_array_equal(got, solo[0])


# ---------------------------------------------------------------------------
# zero recompiles across joins/finishes/swaps
# ---------------------------------------------------------------------------

def test_no_recompile_on_join_finish_swap(served):
    """After one admit + one step, every further admit (any length, any
    slot), finish, evict and param swap reuses the two compiled traces."""
    cfg, model, params = served
    eng = ContinuousEngine(model, params, capacity=4, max_len=32,
                           prefill_len=8)
    eng.admit(_prompts(1, 8, cfg.vocab_size)[0], max_new=4, rid=0)
    eng.step()
    warm = eng.jit_cache_sizes()
    assert warm == {"admit": 1, "decode": 1}
    eng.admit(_prompts(1, 3, cfg.vocab_size, 5)[0], max_new=20, rid=1)
    eng.admit(_prompts(1, 6, cfg.vocab_size, 6)[0], max_new=5, rid=2)
    for _ in range(6):
        eng.step()  # rid 0 and 2 finish mid-flight here
    eng.evict(eng.active_slots()[0])
    eng.admit(_prompts(1, 1, cfg.vocab_size, 7)[0], max_new=3, rid=3)
    swapped = jax.tree.map(lambda x: x * 1, eng.params)
    eng.swap_params(swapped)
    while eng.num_active:
        eng.step()
    assert eng.jit_cache_sizes() == warm


# ---------------------------------------------------------------------------
# slot lifecycle
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen3_4b", smoke=True)
    model = build_model(cfg)
    return cfg, model, init_tree(jax.random.key(0), model.spec)


def test_eos_frees_slot_and_slot_is_reused(qwen):
    cfg, model, params = qwen
    eng = ContinuousEngine(model, params, capacity=1, max_len=32,
                           prefill_len=8)
    p = _prompts(1, 8, cfg.vocab_size)[0]
    # learn what the model will emit, then use token 2 as the EOS id
    eng.admit(p, max_new=4, rid=0)
    done = []
    while eng.num_active:
        done += eng.step()
    eos = int(done[0].tokens[1])
    eng2 = ContinuousEngine(model, params, capacity=1, max_len=32,
                            prefill_len=8, eos_id=eos)
    slot = eng2.admit(p, max_new=10, rid=0)
    done = []
    while not done:
        done = eng2.step()
    assert done[0].reason == "eos"
    assert done[0].tokens[-1] == eos
    assert done[0].tokens.size == 2
    assert eng2.num_active == 0
    # the freed slot is immediately reusable and decodes correctly
    slot2 = eng2.admit(_prompts(1, 4, cfg.vocab_size, 9)[0], max_new=3,
                       rid=1, eos_id=None)
    assert slot2 == slot
    done = []
    while not done:
        done = eng2.step()
    assert done[0].reason == "length" and done[0].tokens.size == 3


def test_finish_at_admit_max_new_one(qwen):
    """max_new=1 finishes inside admit — the first token comes from the
    prefill, no decode tick needed."""
    cfg, model, params = qwen
    eng = ContinuousEngine(model, params, capacity=2, max_len=32,
                           prefill_len=8)
    eng.admit(_prompts(1, 8, cfg.vocab_size)[0], max_new=1, rid=7)
    assert eng.num_active == 0
    (f,) = eng.drain_finished()
    assert f.rid == 7 and f.reason == "length" and f.tokens.size == 1


def test_validation_errors(qwen):
    cfg, model, params = qwen
    with pytest.raises(ValueError, match="capacity"):
        ContinuousEngine(model, params, capacity=0)
    with pytest.raises(ValueError, match="prefill_len"):
        ContinuousEngine(model, params, max_len=8, prefill_len=16)
    eng = ContinuousEngine(model, params, capacity=1, max_len=16,
                           prefill_len=8)
    p = _prompts(1, 8, cfg.vocab_size)[0]
    with pytest.raises(ValueError, match="prompt length"):
        eng.admit(np.zeros(9, np.int32), max_new=2)
    with pytest.raises(ValueError, match="max_new"):
        eng.admit(p, max_new=0)
    with pytest.raises(ValueError, match="overruns"):
        eng.admit(p, max_new=9)  # 8 + 9 > 16
    eng.admit(p, max_new=2)
    with pytest.raises(RuntimeError, match="pool full"):
        eng.admit(p, max_new=2)
    eng.evict(0)
    assert eng.drain_finished()[-1].reason == "evicted"
    with pytest.raises(ValueError, match="not live"):
        eng.evict(0)  # already freed


def test_unsupported_family_rejected():
    cfg = get_config("rwkv6_3b", smoke=True)
    model = build_model(cfg)
    params = init_tree(jax.random.key(0), model.spec)
    with pytest.raises(NotImplementedError, match="family"):
        ContinuousEngine(model, params, capacity=2, max_len=16,
                         prefill_len=8)


def test_swap_params_rejects_shape_mismatch(qwen):
    cfg, model, params = qwen
    eng = ContinuousEngine(model, params, capacity=1, max_len=16,
                           prefill_len=8)
    bad = jax.tree.map(lambda x: x[..., :1], eng.params)
    with pytest.raises(ValueError, match="swap_params"):
        eng.swap_params(bad)
    assert eng.swaps == 0


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_scheduler_serves_bursty_trace_to_completion(qwen):
    cfg, model, params = qwen
    eng = ContinuousEngine(model, params, capacity=3, max_len=32,
                           prefill_len=8)
    trace = synthetic_traffic(TrafficConfig(
        num_requests=12, prompt_lens=(4, 8), max_new=6,
        vocab_size=cfg.vocab_size, seed=3))
    sched = Scheduler(eng)
    results = sched.run(trace)
    assert len(results) == 12
    assert sorted(r.rid for r in results) == list(range(12))
    assert all(r.reason == "length" and r.num_tokens == 6 for r in results)
    assert all(r.finished_at >= r.admitted_at >= r.arrival for r in results)
    # every request decodes what it would decode alone (in-flight batching
    # never perturbs a neighbour)
    ref = ServeEngine(model, params, max_len=32)
    by_rid = {r.rid: r for r in results}
    for req in trace[:3]:
        solo = ref.generate(req.prompt[None, :], steps=6)
        np.testing.assert_array_equal(by_rid[req.rid].tokens, solo[0])


def test_scheduler_deadline_evicts(qwen):
    cfg, model, params = qwen
    eng = ContinuousEngine(model, params, capacity=2, max_len=32,
                           prefill_len=8)
    reqs = [Request(rid=0, prompt=_prompts(1, 8, cfg.vocab_size)[0],
                    max_new=20, arrival=0.0, deadline=0.0),
            Request(rid=1, prompt=_prompts(1, 8, cfg.vocab_size, 4)[0],
                    max_new=3, arrival=0.0)]
    results = Scheduler(eng).run(reqs)
    by_rid = {r.rid: r for r in results}
    assert by_rid[0].reason == "evicted"
    assert by_rid[0].num_tokens < 20  # partial output still delivered
    assert by_rid[1].reason == "length" and by_rid[1].num_tokens == 3


def test_scheduler_sheds_load_at_max_queue(qwen):
    cfg, model, params = qwen
    eng = ContinuousEngine(model, params, capacity=1, max_len=32,
                           prefill_len=8)
    reqs = [Request(rid=i, prompt=_prompts(1, 4, cfg.vocab_size, i)[0],
                    max_new=2, arrival=0.0) for i in range(4)]
    sched = Scheduler(eng, max_queue=1)
    results = sched.run(reqs)
    assert len(results) == 4
    reasons = [r.reason for r in results]
    assert sched.rejected == reasons.count("rejected") >= 1
    assert all(r.num_tokens == 0 for r in results if r.reason == "rejected")
    assert any(r.reason == "length" for r in results)


def test_scheduler_admission_bounded_per_tick(qwen):
    """A burst bigger than max_admissions_per_tick drains over several
    ticks instead of starving the pool's decode loop."""
    cfg, model, params = qwen
    eng = ContinuousEngine(model, params, capacity=4, max_len=32,
                           prefill_len=8)
    sched = Scheduler(eng, max_admissions_per_tick=1)
    for i in range(3):
        sched.submit(Request(rid=i, prompt=_prompts(1, 4, cfg.vocab_size,
                                                    i)[0],
                             max_new=8, arrival=0.0))
    sched.tick()
    assert eng.num_active == 1 and len(sched.queue) == 2
    sched.tick()
    assert eng.num_active == 2 and len(sched.queue) == 1


# ---------------------------------------------------------------------------
# traffic generator
# ---------------------------------------------------------------------------

def test_traffic_deterministic_and_well_formed():
    cfg = TrafficConfig(num_requests=50, prompt_lens=(4, 8, 12),
                        vocab_size=100, seed=11)
    a = synthetic_traffic(cfg)
    b = synthetic_traffic(cfg)
    assert len(a) == 50
    for ra, rb in zip(a, b):
        assert ra.arrival == rb.arrival
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
    arr = [r.arrival for r in a]
    assert arr == sorted(arr) and arr[0] >= 0.0
    assert {r.prompt.size for r in a} <= {4, 8, 12}
    assert all(0 <= r.prompt.min() and r.prompt.max() < 100 for r in a)
    # a different seed is a different trace
    c = synthetic_traffic(TrafficConfig(num_requests=50,
                                        prompt_lens=(4, 8, 12),
                                        vocab_size=100, seed=12))
    assert [r.arrival for r in c] != arr


def test_traffic_validation():
    with pytest.raises(ValueError, match="num_requests"):
        synthetic_traffic(TrafficConfig(num_requests=0))
    with pytest.raises(ValueError, match="prompt_lens"):
        synthetic_traffic(TrafficConfig(prompt_lens=()))


# ---------------------------------------------------------------------------
# hot swap from a live training session's checkpoint dir
# ---------------------------------------------------------------------------

def _lm_session(save_path, seed=1, rounds=4):
    from repro.api import ElasticSession, RunSpec
    from repro.configs.base import ElasticConfig, OptimizerConfig

    return ElasticSession(RunSpec(
        arch="stablelm-3b", smoke=True,
        optimizer=OptimizerConfig(name="sgd", lr=0.01),
        elastic=ElasticConfig(num_workers=2, tau=1, dynamic=True),
        rounds=rounds, seed=seed, n_tokens=4000, seq_len=16, batch_size=2,
        save_path=save_path))


def test_hotswap_tracks_running_session(tmp_path):
    """The acceptance scenario: an engine serving traffic watches the dir
    a live ``ElasticSession`` checkpoints into; when a new master lands
    mid-flight the watcher swaps it in without dropping requests, and
    post-swap outputs match a fresh engine restored from that same
    checkpoint. Forced multi-shard (tiny MAX_SHARD_BYTES) so the standby
    restore exercises shard reassembly."""
    from repro.checkpoint import checkpoint

    ck = str(tmp_path / "ck")
    sess = _lm_session(ck)
    sess.run(2)
    sess.save()

    cfg = get_config("stablelm-3b", smoke=True)
    model = build_model(cfg)
    params = init_tree(jax.random.key(0), model.spec)
    eng = ContinuousEngine(model, params, capacity=2, max_len=32,
                           prefill_len=8)
    watcher = CheckpointWatcher(eng, ck)
    assert watcher.poll() is False  # baseline fingerprint, nothing new

    prompt = _prompts(1, 8, cfg.vocab_size)[0]
    eng.admit(prompt, max_new=12, rid=0)
    eng.step()
    eng.step()
    pre_swap = [int(t) for t in eng._slots[eng.active_slots()[0]].tokens]

    sess.run(2)  # the session keeps training...
    old_shard = checkpoint.MAX_SHARD_BYTES
    checkpoint.MAX_SHARD_BYTES = 4096
    try:
        sess.save()  # ...and drops a new multi-shard master
    finally:
        checkpoint.MAX_SHARD_BYTES = old_shard
    import os
    assert len([f for f in os.listdir(ck) if f.endswith(".npz")]) > 1

    assert watcher.poll() is True
    assert eng.swaps == 1 and watcher.swaps_applied == 1
    assert watcher.log[-1].applied and watcher.log[-1].rounds == 4

    # the in-flight request was not dropped: it drains to its full budget
    # and its pre-swap tokens are untouched
    done = []
    while eng.num_active:
        done += eng.step()
    (f,) = done
    assert f.rid == 0 and f.tokens.size == 12
    assert [int(t) for t in f.tokens[:len(pre_swap)]] == pre_swap

    # post-swap outputs match a fresh engine restored from the checkpoint
    fresh_params, _ = checkpoint.restore(ck, like=params)
    fresh = ContinuousEngine(model, fresh_params, capacity=2, max_len=32,
                             prefill_len=8)
    p2 = _prompts(1, 6, cfg.vocab_size, 8)[0]
    eng.admit(p2, max_new=5, rid=1)
    fresh.admit(p2, max_new=5, rid=1)
    got = want = []
    while eng.num_active:
        got = eng.step()
    while fresh.num_active:
        want = fresh.step()
    np.testing.assert_array_equal(got[0].tokens, want[0].tokens)


def test_hotswap_rejects_arch_mismatch(tmp_path):
    """A checkpoint from a different arch is journalled and skipped — the
    served params keep working."""
    from repro.api import ElasticSession, RunSpec
    from repro.configs.base import ElasticConfig, OptimizerConfig

    ck = str(tmp_path / "ck")
    cnn = ElasticSession(RunSpec(
        arch="paper-cnn", optimizer=OptimizerConfig(name="sgd", lr=0.01),
        elastic=ElasticConfig(num_workers=2, tau=1, dynamic=True),
        rounds=1, seed=0, batch_size=4, n_data=64, n_test=32,
        save_path=ck))
    cfg = get_config("qwen3_4b", smoke=True)
    model = build_model(cfg)
    params = init_tree(jax.random.key(0), model.spec)
    eng = ContinuousEngine(model, params, capacity=1, max_len=16,
                           prefill_len=8)
    watcher = CheckpointWatcher(eng, ck)  # dir doesn't exist yet → None fp
    cnn.run()
    cnn.save()
    assert watcher.poll() is False
    assert eng.swaps == 0
    (ev,) = watcher.log
    assert not ev.applied and "arch mismatch" in ev.note
    assert watcher.poll() is False  # same bad checkpoint isn't re-read
    assert len(watcher.log) == 1


def test_scheduler_polls_watcher(tmp_path):
    """The scheduler's poll_every cadence drives the watcher: a checkpoint
    landing mid-trace is swapped in during the run."""
    ck = str(tmp_path / "ck")
    sess = _lm_session(ck, rounds=2)
    sess.run()
    cfg = get_config("stablelm-3b", smoke=True)
    model = build_model(cfg)
    params = init_tree(jax.random.key(0), model.spec)
    eng = ContinuousEngine(model, params, capacity=2, max_len=48,
                           prefill_len=8)
    watcher = CheckpointWatcher(eng, ck)
    sess.save()  # lands after the watcher's baseline → first poll swaps
    sched = Scheduler(eng, watcher=watcher, poll_every=2)
    trace = synthetic_traffic(TrafficConfig(
        num_requests=6, prompt_lens=(4, 8), max_new=16,
        vocab_size=cfg.vocab_size, seed=5))
    results = sched.run(trace)
    assert len(results) == 6
    assert watcher.swaps_applied == 1
