"""Adversarial & heterogeneous scenario engine (ISSUE-9): trace replay,
persistent-speed workers, byzantine slots, and the score_clip robustness
clamp.

Committed calibration facts this file asserts (paper-cnn smoke, sgd
lr=0.01, k=4, τ=2, byzantine_frac=0.5, score_clip=0.5, 12 rounds,
both comm backends, seeds 1–3):

- mean h2 of corrupt slots over rounds 4+ is exactly 0.0 (refused);
  honest slots get 0.013–0.028 — the dynamic maps + clamp down-weight
  poisoned workers to nothing while the pool keeps exchanging.
- master params stay finite even though sign-flip corruption drives the
  corrupt workers past float32 range every round: the quarantine re-seats
  any worker whose log-distance goes non-finite and pushes u = log(1e-30)
  so the telemetry (and the next-round score) stays finite.
- without the clamp, a NaN score falls through both h2 comparisons to the
  α branch and the master NaN-poisons within ~4 rounds — that measurement
  is the reason ``ElasticConfig.score_clip`` exists
  (tests/test_scenarios.py::test_byzantine_wrecks_easgd_but_not_clipped_deahes).

Property-based tests ride the optional-hypothesis shim like
tests/test_scenarios.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _property_shim import given, settings, st

from repro.api import ElasticSession, RunSpec
from repro.configs.base import ElasticConfig, OptimizerConfig, get_config
from repro.core import dynamic_weight as dw
from repro.core import scenarios as sc
from repro.core.coordinator import ElasticTrainer
from repro.models.registry import build_model


def _trainer(k=2, tau=1, opt="sgd", **kw):
    model = build_model(get_config("paper_cnn"))
    defaults = dict(num_workers=k, tau=tau, alpha=0.1, dynamic=False)
    defaults.update(kw)
    return ElasticTrainer(model, OptimizerConfig(name=opt, lr=0.01),
                          ElasticConfig(**defaults))


def _img_batches(tau, k, n=4, seed=0):
    return {"images": jax.random.normal(jax.random.key(seed),
                                        (tau, k, n, 28, 28, 1)),
            "labels": jnp.zeros((tau, k, n), jnp.int32)}


def _byz_spec(seed, mode="sequential", rounds=12, **ekw):
    ekw.setdefault("failure_scenario", "byzantine")
    return RunSpec(
        arch="paper-cnn", smoke=True, rounds=rounds, seed=seed,
        batch_size=4, n_data=96, n_test=32,
        optimizer=OptimizerConfig(name="sgd", lr=0.01),
        elastic=ElasticConfig(num_workers=4, tau=2, comm_mode=mode, **ekw))


# ---------------------------------------------------------------------------
# generators: persistence, disjointness, distributions
# ---------------------------------------------------------------------------

def test_hetero_speeds_are_persistent_and_bounded():
    sched = sc.HeteroScenario().schedule(3, rounds=40, k=6)
    assert sched.speed.shape == (40, 6)
    assert sched.speed.dtype == np.float32
    np.testing.assert_array_equal(sched.speed,
                                  np.tile(sched.speed[0], (40, 1)))
    assert (sched.speed > 0).all() and (sched.speed <= 1).all()
    assert not sched.fail.any() and not sched.straggle.any()


def test_hetero_bimodal_draws_the_two_levels():
    s = sc.HeteroScenario(dist="bimodal", slow_frac=0.5,
                          slow_scale=0.25).slot_speeds(0, 64)
    assert set(np.unique(s)) <= {np.float32(0.25), np.float32(1.0)}
    assert (s == 0.25).any() and (s == 1.0).any()


def test_byzantine_corrupt_is_persistent_and_disjoint_from_fail():
    sched = sc.ByzantineScenario(0.5, 1.0 / 3.0).schedule(1, rounds=60, k=4)
    assert sched.corrupt.any(), "seed 1 draws corrupt slots at frac=0.5"
    np.testing.assert_array_equal(sched.corrupt,
                                  np.tile(sched.corrupt[0], (60, 1)))
    assert not (sched.corrupt & sched.fail).any()
    # honest slots still see the iid fail floor
    assert sched.fail[:, ~sched.corrupt[0]].any()


def test_byzantine_always_leaves_an_honest_slot():
    for seed in range(40):
        bad = sc.ByzantineScenario(0.97).corrupt_slots(seed, 3)
        assert not bad.all()
    # corrupt_slots is the same draw the schedule tiles
    sched = sc.ByzantineScenario(0.5).schedule(9, rounds=5, k=4)
    np.testing.assert_array_equal(
        sched.corrupt[0], sc.ByzantineScenario(0.5).corrupt_slots(9, 4))


def test_blind_zeroes_corrupt_and_drops_speed():
    sched = sc.ByzantineScenario(0.5).schedule(1, rounds=8, k=4)
    sched = dataclasses.replace(
        sched, speed=np.full((8, 4), 0.5, np.float32))
    b = sched.blind()
    assert not b.corrupt.any() and b.speed is None
    assert not b.has_corruption and not b.has_hetero


# ---------------------------------------------------------------------------
# property-based (hypothesis shim: these skip without hypothesis)
# ---------------------------------------------------------------------------

@given(st.integers(min_value=0, max_value=2**31), st.floats(0.1, 0.9),
       st.floats(0.1, 1.0))
@settings(max_examples=20, deadline=None)
def test_prop_hetero_bimodal_stationary_slow_fraction(seed, frac, scale):
    s = sc.HeteroScenario(dist="bimodal", slow_frac=frac,
                          slow_scale=scale).slot_speeds(seed, 600)
    assert abs(float(np.mean(s < 1.0)) - frac * (scale < 1.0)) < 0.07


@given(st.integers(min_value=0, max_value=2**31))
@settings(max_examples=20, deadline=None)
def test_prop_hetero_lognormal_half_the_pool_at_full_speed(seed):
    # min(1, exp(σz)) pins exactly the z ≥ 0 half at 1.0
    s = sc.HeteroScenario(sigma=0.6).slot_speeds(seed, 600)
    assert abs(float(np.mean(s == 1.0)) - 0.5) < 0.07
    assert (s > 0).all() and (s <= 1).all()


@given(st.integers(min_value=0, max_value=2**31), st.floats(0.05, 0.9))
@settings(max_examples=20, deadline=None)
def test_prop_byzantine_corrupt_fail_disjoint(seed, frac):
    sched = sc.ByzantineScenario(frac, 0.5).schedule(seed, rounds=50, k=6)
    assert not (sched.corrupt & sched.fail).any()
    assert not sched.corrupt.all(axis=1).any()
    a = sc.ByzantineScenario(frac, 0.5).schedule(seed, rounds=50, k=6)
    np.testing.assert_array_equal(a.corrupt, sched.corrupt)  # deterministic
    np.testing.assert_array_equal(a.fail, sched.fail)


def _random_schedule(rng, rounds, k, with_corrupt, with_speed, with_active):
    fail = rng.random((rounds, k)) < 0.3
    sched = sc.ScenarioSchedule(fail,
                                rng.random((rounds, k)) < 0.2,
                                rng.random((rounds, k)) < 0.1)
    if with_corrupt:
        corrupt = (rng.random((rounds, k)) < 0.3) & ~fail
        sched = dataclasses.replace(sched, corrupt=corrupt)
    if with_speed:
        # mix persistent rows with per-round changes: both the hold and the
        # change-event paths of the writer get exercised
        speed = rng.uniform(0.05, 1.0, (rounds, k)).astype(np.float32)
        hold = rng.random((rounds, k)) < 0.7
        for r in range(1, rounds):
            speed[r] = np.where(hold[r], speed[r - 1], speed[r])
        sched = dataclasses.replace(sched, speed=speed)
    if with_active:
        counts = rng.integers(1, k + 1, rounds)
        active = np.arange(k)[None, :] < counts[:, None]
        sched = sched.with_membership(active)
    return sched


@given(st.integers(min_value=0, max_value=2**31),
       st.booleans(), st.booleans(), st.booleans())
@settings(max_examples=25, deadline=None)
def test_prop_trace_roundtrip_identity(seed, with_corrupt, with_speed,
                                       with_active):
    """write → parse reproduces every channel bit-exactly, including which
    optional channels exist at all (None-ness is part of the contract —
    the jit cache specializes on it)."""
    rng = np.random.default_rng(seed)
    sched = _random_schedule(rng, int(rng.integers(1, 25)),
                             int(rng.integers(1, 7)),
                             with_corrupt, with_speed, with_active)
    back = sc.parse_trace(sc.trace_lines(sched))
    for ch in ("fail", "straggle", "restart", "corrupt", "speed", "active"):
        a, b = getattr(sched, ch), getattr(back, ch)
        assert (a is None) == (b is None), ch
        if a is not None:
            np.testing.assert_array_equal(a, b, err_msg=ch)
            assert a.dtype == b.dtype, ch


# ---------------------------------------------------------------------------
# trace IO: files, validation, membership-plan compatibility
# ---------------------------------------------------------------------------

def test_write_read_trace_file_roundtrip(tmp_path):
    sched = sc.ByzantineScenario(0.5).schedule(1, rounds=10, k=4)
    sched = dataclasses.replace(
        sched, speed=np.tile(np.asarray([1.0, 0.5, 1.0, 0.25], np.float32),
                             (10, 1)))
    p = tmp_path / "run.jsonl"
    sc.write_trace(p, sched)
    back = sc.read_trace(p)
    np.testing.assert_array_equal(back.fail, sched.fail)
    np.testing.assert_array_equal(back.corrupt, sched.corrupt)
    np.testing.assert_array_equal(back.speed, sched.speed)
    assert back.active is None


def test_trace_scenario_replays_and_validates_shape(tmp_path):
    sched = sc.IIDScenario(0.3).schedule(5, rounds=8, k=3)
    p = tmp_path / "t.jsonl"
    sc.write_trace(p, sched)
    scen = sc.TraceScenario(p)
    assert scen.name == "trace"
    got = scen.schedule(seed=123, rounds=8, k=3)  # seed is ignored
    np.testing.assert_array_equal(got.fail, sched.fail)
    with pytest.raises(ValueError):
        scen.schedule(seed=0, rounds=9, k=3)
    with pytest.raises(ValueError):
        scen.schedule(seed=0, rounds=8, k=4)


def test_trace_membership_steps_speak_the_plan_vocabulary():
    rows = np.ones((9, 4), bool)
    rows[3:7, 3] = False
    rows[5:7, 2] = False
    sched = sc.IIDScenario(0.2).schedule(0, rounds=9, k=4)
    sched = sched.with_membership(rows)
    steps = sc.trace_membership_steps(sched)
    assert steps == ((0, 4), (3, 3), (5, 2), (7, 4))
    plan = ",".join(f"{r}:{k}" for r, k in steps)
    assert sc.parse_membership_plan(plan) == steps[1:] or \
        sc.parse_membership_plan(plan) == steps
    # and the full trace round-trips the membership exactly
    back = sc.parse_trace(sc.trace_lines(sched))
    np.testing.assert_array_equal(back.active, rows)


def test_trace_non_prefix_membership_survives_via_active_lists():
    rows = np.ones((4, 3), bool)
    rows[2, 0] = False  # slot 0 down, slots 1-2 live: not a prefix mask
    sched = sc.IIDScenario(0.2).schedule(0, rounds=4, k=3)
    sched = sched.with_membership(rows)
    with pytest.raises(ValueError):
        sc.trace_membership_steps(sched)
    back = sc.parse_trace(sc.trace_lines(sched))
    np.testing.assert_array_equal(back.active, rows)


def test_parse_trace_rejects_malformed():
    good = sc.trace_lines(sc.IIDScenario(0.3).schedule(0, rounds=4, k=2))
    with pytest.raises(ValueError):
        sc.parse_trace([])
    with pytest.raises(ValueError):
        sc.parse_trace(['{"kind": "other", "version": 1}'])
    with pytest.raises(ValueError):
        sc.parse_trace([good[0].replace('"version": 1', '"version": 99')])
    with pytest.raises(ValueError):
        sc.parse_trace(list(good) +
                       ['{"round": 99, "slot": 0, "ch": "fail"}'])
    with pytest.raises(ValueError):
        sc.parse_trace(list(good) +
                       ['{"round": 0, "slot": 7, "ch": "fail"}'])
    with pytest.raises(ValueError):
        sc.parse_trace(list(good) +
                       ['{"round": 0, "slot": 0, "ch": "gamma_rays"}'])


# ---------------------------------------------------------------------------
# corruption unit tests: the _poison modes, inside the local phase
# ---------------------------------------------------------------------------

def _phase_delta(tr, state, b, corrupt):
    out, _, _ = tr.local_phase(state, b, jax.random.key(1), corrupt=corrupt)
    return [np.asarray(w) - np.asarray(s)
            for w, s in zip(jax.tree.leaves(out["workers"]),
                            jax.tree.leaves(state["workers"]))]


def test_sign_flip_negates_the_sgd_step():
    """One sign-flipped SGD step walks exactly opposite the clean step —
    and the honest slot in the same batched phase is untouched bit-for-bit."""
    tr = _trainer(k=2, byzantine_mode="sign_flip")
    state = tr.init_state(jax.random.key(0))
    b = _img_batches(1, 2)
    clean = _phase_delta(tr, state, b, None)
    bad = _phase_delta(tr, state, b, jnp.asarray([True, False]))
    for c, d in zip(clean, bad):
        np.testing.assert_allclose(d[0], -c[0], rtol=1e-5, atol=1e-7)
        np.testing.assert_array_equal(d[1], c[1])


def test_scale_mode_multiplies_the_sgd_step():
    tr = _trainer(k=2, byzantine_mode="scale", byzantine_scale=5.0)
    state = tr.init_state(jax.random.key(0))
    b = _img_batches(1, 2)
    clean = _phase_delta(tr, state, b, None)
    bad = _phase_delta(tr, state, b, jnp.asarray([True, False]))
    for c, d in zip(clean, bad):
        np.testing.assert_allclose(d[0], 5.0 * c[0], rtol=1e-4, atol=1e-6)
        np.testing.assert_array_equal(d[1], c[1])


def test_noise_mode_is_seed_deterministic_and_perturbs():
    tr = _trainer(k=2, byzantine_mode="noise", byzantine_scale=5.0)
    state = tr.init_state(jax.random.key(0))
    b = _img_batches(1, 2)
    a = _phase_delta(tr, state, b, jnp.asarray([True, False]))
    c = _phase_delta(tr, state, b, jnp.asarray([True, False]))
    clean = _phase_delta(tr, state, b, None)
    for x, y, z in zip(a, c, clean):
        np.testing.assert_array_equal(x[0], y[0])   # same rng → same noise
        assert np.abs(x[0] - z[0]).max() > 0        # and it really perturbs
        np.testing.assert_array_equal(x[1], z[1])


# ---------------------------------------------------------------------------
# hetero speeds thread through local_phase as per-slot effective τ
# ---------------------------------------------------------------------------

def test_speed_truncates_local_steps_like_a_shorter_stream():
    """speed=0.5 at τ=4 runs exactly round(0.5·4)=2 local steps: the slow
    slot's end-of-phase params match a clean run over the truncated batch
    stream, the full-speed slot matches the untruncated run bit-for-bit."""
    tr = _trainer(k=2, tau=4)
    state = tr.init_state(jax.random.key(0))
    b = _img_batches(4, 2)
    full, _, _ = tr.local_phase(state, b, jax.random.key(1))
    slow, _, _ = tr.local_phase(state, b, jax.random.key(1),
                                speed=jnp.asarray([0.5, 1.0], jnp.float32))
    trunc = {key: v[:2] for key, v in b.items()}
    want, _, _ = tr.local_phase(state, trunc, jax.random.key(1))
    for got, w, f in zip(jax.tree.leaves(slow["workers"]),
                         jax.tree.leaves(want["workers"]),
                         jax.tree.leaves(full["workers"])):
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(w[0]),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(f[1]))


def test_speed_floor_is_one_step():
    # even a near-zero speed runs one local step — a live slot never idles
    tr = _trainer(k=2, tau=3)
    state = tr.init_state(jax.random.key(0))
    b = _img_batches(3, 2)
    out, _, _ = tr.local_phase(state, b, jax.random.key(1),
                               speed=jnp.asarray([0.01, 1.0], jnp.float32))
    one = {key: v[:1] for key, v in b.items()}
    want, _, _ = tr.local_phase(state, one, jax.random.key(1))
    got0 = jax.tree.leaves(out["workers"])[0][0]
    want0 = jax.tree.leaves(want["workers"])[0][0]
    np.testing.assert_allclose(np.asarray(got0), np.asarray(want0),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# score_clip clamp + quarantine (the robustness mechanism itself)
# ---------------------------------------------------------------------------

def test_weights_for_clip_zeroes_runaway_and_nonfinite_scores():
    cfg = ElasticConfig(alpha=0.5, score_clip=0.5)
    a = jnp.asarray([-0.2, 0.3, 0.8, jnp.inf, jnp.nan], jnp.float32)
    w1, w2 = dw.weights_for(cfg, a)
    got = np.asarray(w2)
    assert got[1] == pytest.approx(0.5)   # below clip: paper's α branch
    assert got[2] == 0.0 and got[3] == 0.0 and got[4] == 0.0
    # h1 untouched: the worker may still pull itself back
    np.testing.assert_allclose(np.asarray(w1),
                               np.asarray(dw.h1(a, 0.5, cfg.score_k)))
    # clip=0 keeps the paper maps bit-identically — including the NaN→α
    # fall-through that motivated the clamp
    _, w2_paper = dw.weights_for(ElasticConfig(alpha=0.5), a)
    assert np.asarray(w2_paper)[4] == pytest.approx(0.5)


@pytest.mark.parametrize("mode", ["sequential", "fused"])
def test_byzantine_down_weighting_and_finite_master(mode):
    """The committed ISSUE-9 numbers, seed 1 (seeds 2–3 in the slow sweep):
    corrupt slots' mean master-schedule weight over rounds 4+ is exactly 0,
    honest slots keep exchanging, and the master never goes non-finite even
    though the corrupt workers blow past float32 range every round."""
    sess = ElasticSession(_byz_spec(1, mode, byzantine_frac=0.5,
                                    score_clip=0.5))
    recs = sess.run()
    corrupt = sess.schedule.corrupt[0]
    assert list(np.where(corrupt)[0]) == [0, 2]
    h2 = np.stack([r.h2 for r in recs])[4:]
    assert float(h2[:, corrupt].mean()) == 0.0
    assert float(h2[:, ~corrupt].mean()) > 0.01   # measured 0.0204
    for leaf in jax.tree.leaves(sess.state["master"]):
        assert bool(np.isfinite(np.asarray(leaf)).all())
    u = np.stack([r.u for r in recs])
    assert np.isfinite(u).all(), "quarantine must keep telemetry finite"
    # the records echo the ground-truth corrupt row
    for r in recs:
        np.testing.assert_array_equal(r.corrupt, corrupt)


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["sequential", "fused"])
def test_byzantine_down_weighting_across_seeds(mode):
    for seed, slots in ((2, [1]), (3, [1, 3])):
        sess = ElasticSession(_byz_spec(seed, mode, byzantine_frac=0.5,
                                        score_clip=0.5))
        recs = sess.run()
        corrupt = sess.schedule.corrupt[0]
        assert list(np.where(corrupt)[0]) == slots
        h2 = np.stack([r.h2 for r in recs])[4:]
        assert float(h2[:, corrupt].mean()) < float(h2[:, ~corrupt].mean())
        assert float(h2[:, corrupt].mean()) == 0.0
        u = np.stack([r.u for r in recs])
        assert np.isfinite(u).all()


# ---------------------------------------------------------------------------
# None-specialization: inactive channels must not perturb or recompile
# ---------------------------------------------------------------------------

def test_inactive_channels_keep_trace_and_bits(tmp_path):
    """An all-False corrupt channel + all-ones speed channel is gated to
    None before RoundInputs, so a pre-existing run is bit-exact and the jit
    cache sees the same single trace shape (satellite 4: the bugfix-class
    guarantee that merely *carrying* the channels costs nothing)."""
    base = sc.IIDScenario(0.3).schedule(8, rounds=5, k=3)
    decorated = dataclasses.replace(
        base, corrupt=np.zeros((5, 3), bool),
        speed=np.ones((5, 3), np.float32))
    assert not decorated.has_corruption and not decorated.has_hetero

    def run(sched):
        spec = RunSpec(arch="paper-cnn", smoke=True, rounds=5, seed=0,
                       batch_size=4, n_data=48, n_test=24,
                       optimizer=OptimizerConfig(name="sgd", lr=0.01),
                       elastic=ElasticConfig(num_workers=3, tau=2),
                       schedule=sched)
        sess = ElasticSession(spec)
        before = sess.trainer.round_step._cache_size()
        recs = sess.run()
        grew = sess.trainer.round_step._cache_size() - before
        return sess, recs, grew

    sess_a, recs_a, grew_a = run(base)
    sess_b, recs_b, grew_b = run(decorated)
    assert grew_a == grew_b == 1, "decorated schedule must not retrace"
    for la, lb in zip(jax.tree.leaves(sess_a.state["master"]),
                      jax.tree.leaves(sess_b.state["master"])):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    for ra, rb in zip(recs_a, recs_b):
        np.testing.assert_array_equal(ra.u, rb.u)
        np.testing.assert_array_equal(ra.corrupt, rb.corrupt)  # both zeros


# ---------------------------------------------------------------------------
# absolute-distance containment (ISSUE-10 satellite: u_zclip closes the
# parked-static-distance gap documented in docs/paper_map.md deviation #10)
# ---------------------------------------------------------------------------

def test_robust_zscore_live_pool_statistics():
    u = jnp.asarray([0.0, 0.1, -0.1, 50.0], jnp.float32)
    z = np.asarray(dw.robust_zscore(u))
    assert abs(z[0]) < 1.0 and abs(z[1]) < 2.0
    assert z[3] > 10.0                       # the parked outlier
    # live masking: the outlier is measured but never contaminates the
    # median/MAD of the pool
    live = jnp.asarray([True, True, True, False])
    z_live = np.asarray(dw.robust_zscore(u, live))
    assert z_live[3] > z[3]
    # all-equal live pool: MAD 0, eps keeps z finite and huge off-pool
    z_eq = np.asarray(dw.robust_zscore(
        jnp.asarray([1.0, 1.0, 1.0, 9.0]), jnp.asarray([1, 1, 1, 0], bool)))
    assert np.isfinite(z_eq[:3]).all() and z_eq[3] > 1e5
    # NaN u -> NaN z (refused downstream via comparison-fails-closed)
    assert np.isnan(np.asarray(dw.robust_zscore(
        jnp.asarray([0.0, jnp.nan]), jnp.asarray([1, 0], bool)))[1])


def test_weights_for_u_zclip_refuses_parked_distance():
    """A worker whose log-distance sits far above the live pool gets w2=0
    even though its *trend* score is tame (the score_clip blind spot);
    NaN u fails closed; h1 is untouched; u_zclip=0 and the paper's
    fixed-alpha/oracle modes ignore u entirely."""
    cfg = ElasticConfig(alpha=0.5, u_zclip=3.0)
    a = jnp.zeros((5,), jnp.float32)          # calm trend everywhere
    u = jnp.asarray([0.0, 0.1, -0.1, 20.0, jnp.nan], jnp.float32)
    w1, w2 = dw.weights_for(cfg, a, u=u)
    got = np.asarray(w2)
    assert got[0] > 0 and got[1] > 0 and got[2] > 0
    assert got[3] == 0.0                      # parked far from the pool
    assert got[4] == 0.0                      # non-finite u fails closed
    np.testing.assert_allclose(np.asarray(w1),
                               np.asarray(dw.h1(a, 0.5, cfg.score_k)))
    # u_zclip=0 (default) is bit-identical to ignoring u
    _, w2_off = dw.weights_for(ElasticConfig(alpha=0.5), a, u=u)
    _, w2_none = dw.weights_for(ElasticConfig(alpha=0.5), a)
    np.testing.assert_array_equal(np.asarray(w2_off), np.asarray(w2_none))
    # fixed-alpha mode is exempt: the paper's baselines stay untouched
    _, w2_fixed = dw.weights_for(
        ElasticConfig(alpha=0.5, dynamic=False, u_zclip=3.0), a, u=u)
    assert np.asarray(w2_fixed)[3] == pytest.approx(0.5)


def _park_spec(u_zclip, seed=1, rounds=12):
    """Noise-mode corruption under AdaHessian: the attack deviation #10
    documents as sailing under score_clip (huge but *static* distance,
    trend a ≈ 0)."""
    return RunSpec(
        arch="paper-cnn", smoke=True, rounds=rounds, seed=seed,
        batch_size=4, n_data=96, n_test=32,
        optimizer=OptimizerConfig(name="adahessian", lr=0.01),
        elastic=ElasticConfig(num_workers=6, tau=2, comm_mode="fused",
                              failure_scenario="byzantine",
                              byzantine_mode="noise", byzantine_scale=20.0,
                              byzantine_frac=0.34,
                              score_clip=0.5, u_zclip=u_zclip))


def test_noise_park_sails_under_score_clip_but_not_u_zclip():
    """The committed regression numbers (seed 1, k=6, two parked slots):
    with score_clip alone the parked workers keep h2 ~ 0.024 — 4x the
    honest pool's — because their distance is huge but static. With
    u_zclip=3 their mean h2 over rounds 4+ is exactly 0, the honest pool's
    weight rises, and the honest workers re-converge to the master
    (mean honest u drops from ~16 to ~0 once the master stops being
    dragged)."""
    unclipped = ElasticSession(_park_spec(u_zclip=0.0))
    recs0 = unclipped.run()
    corrupt = unclipped.schedule.corrupt[0]
    assert list(np.where(corrupt)[0]) == [0, 2]
    h2_0 = np.stack([r.h2 for r in recs0])[4:]
    assert float(h2_0[:, corrupt].mean()) > float(h2_0[:, ~corrupt].mean())

    clipped = ElasticSession(_park_spec(u_zclip=3.0))
    recs1 = clipped.run()
    np.testing.assert_array_equal(clipped.schedule.corrupt[0], corrupt)
    h2_1 = np.stack([r.h2 for r in recs1])[4:]
    assert float(h2_1[:, corrupt].mean()) == 0.0
    assert float(h2_1[:, ~corrupt].mean()) > 0.01    # measured 0.0286
    u_honest = np.stack([r.u for r in recs1])[4:, ~corrupt]
    assert float(u_honest.mean()) < 2.0              # measured ~ -0.08
    for leaf in jax.tree.leaves(clipped.state["master"]):
        assert bool(np.isfinite(np.asarray(leaf)).all())


@pytest.mark.slow
def test_noise_park_containment_across_seeds():
    for seed, slots in ((2, [1]),):
        sess = ElasticSession(_park_spec(u_zclip=3.0, seed=seed))
        recs = sess.run()
        corrupt = sess.schedule.corrupt[0]
        assert list(np.where(corrupt)[0]) == slots
        h2 = np.stack([r.h2 for r in recs])[4:]
        assert float(h2[:, corrupt].mean()) == 0.0
        assert float(h2[:, ~corrupt].mean()) > 0.01
