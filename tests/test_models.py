"""Per-architecture smoke tests (deliverable f): reduced same-family
variants run one forward/train step on CPU, asserting output shapes and no
NaNs; decode paths check prefill+decode consistency against the full
forward where the architecture permits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ARCH_IDS, OptimizerConfig, ShapeConfig,
                                get_config)
from repro.models.registry import build_model
from repro.nn.param import init_tree, param_count
from repro.train.steps import init_train_state, make_train_step

SMOKE_TRAIN = ShapeConfig("smoke_train", seq_len=32, global_batch=2,
                          kind="train")
SMOKE_DECODE = ShapeConfig("smoke_decode", seq_len=32, global_batch=2,
                           kind="decode")


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    arch = request.param
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = init_tree(jax.random.key(0), model.spec)
    batch = model.dummy_batch(jax.random.key(1), SMOKE_TRAIN)
    return arch, cfg, model, params, batch


def test_forward_shapes_and_finite(arch_setup):
    arch, cfg, model, params, batch = arch_setup
    logits, aux = model.forward(params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
    assert bool(jnp.isfinite(jnp.asarray(aux))), arch


def _assert_train_step_finite_and_moves(arch, model, batch):
    ocfg = OptimizerConfig(name="adahessian", lr=1e-3)
    state = init_train_state(model, ocfg, jax.random.key(0))
    step = jax.jit(make_train_step(model, ocfg))
    new_state, m = step(state, batch, jax.random.key(2))
    assert bool(jnp.isfinite(m["loss"])), arch
    # params actually changed
    moved = any(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) > 0
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(new_state["params"])))
    assert moved, arch


@pytest.mark.slow
def test_one_train_step_decreases_nothing_nan(arch_setup):
    arch, cfg, model, params, batch = arch_setup
    _assert_train_step_finite_and_moves(arch, model, batch)


def test_one_train_step_canary_dense():
    """Fast unmarked canary: one transformer train step stays finite, so the
    CI fast set (-m "not slow") keeps a NaN signal beyond paper-cnn."""
    cfg = get_config("stablelm_3b", smoke=True)
    model = build_model(cfg)
    batch = model.dummy_batch(jax.random.key(1), SMOKE_TRAIN)
    _assert_train_step_finite_and_moves("stablelm_3b", model, batch)


def test_decode_step_finite(arch_setup):
    arch, cfg, model, params, batch = arch_setup
    cache = model.init_cache(2, SMOKE_DECODE.seq_len)
    pb = {k: v for k, v in batch.items() if k != "targets"}
    logits, cache = model.prefill(params, pb, cache)
    tok = {"tokens": jnp.zeros((2, 1), jnp.int32)}
    dl, cache = model.decode_step(params, tok, cache,
                                  SMOKE_DECODE.seq_len - 1)
    assert dl.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(dl.astype(jnp.float32)).all()), arch


def test_param_count_positive(arch_setup):
    arch, cfg, model, params, batch = arch_setup
    assert param_count(model.spec) > 10_000


@pytest.mark.parametrize("arch", ["stablelm_3b", "qwen3_4b", "rwkv6_3b",
                                  "zamba2_7b"])
def test_prefill_decode_matches_forward(arch):
    """logits(prefill; decode t) == logits(full forward at t)."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = init_tree(jax.random.key(0), model.spec)
    T = 8
    toks = jax.random.randint(jax.random.key(1), (2, T), 0, cfg.vocab_size,
                              jnp.int32)
    full, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(2, T)
    pre, cache = model.prefill(params, {"tokens": toks[:, :T - 1]}, cache)
    step, _ = model.decode_step(params, {"tokens": toks[:, T - 1:]}, cache,
                                T - 1)
    np.testing.assert_allclose(
        np.asarray(step[:, 0], np.float32),
        np.asarray(full[:, -1], np.float32), rtol=0.05, atol=0.05)
    np.testing.assert_allclose(
        np.asarray(pre[:, -1], np.float32),
        np.asarray(full[:, -2], np.float32), rtol=0.05, atol=0.05)


def test_full_configs_build_abstract_only():
    """Full production configs must build specs without allocating."""
    from repro.nn.param import abstract_tree

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg)
        ab = abstract_tree(model.spec)
        n = param_count(model.spec)
        assert n > 1e9 or cfg.family in ("encdec", "cnn"), (arch, n)
