"""MoE layer: capacity dispatch vs dense oracle, aux loss, drops."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _property_shim import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.nn.moe import _capacity, apply_moe, moe_ref_dense, moe_specs
from repro.nn.param import init_tree


def _cfg(E=4, K=2, cf=8.0, shared=0):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=32, num_heads=4,
        d_ff=64, vocab_size=64, num_experts=E, top_k=K, expert_d_ff=48,
        capacity_factor=cf, num_shared_experts=shared, dtype="float32",
        param_dtype="float32")


@pytest.mark.parametrize("E,K,shared", [(4, 1, 0), (4, 2, 0), (8, 2, 1),
                                        (8, 6, 2)])
def test_capacity_dispatch_matches_dense_oracle(E, K, shared):
    cfg = _cfg(E=E, K=K, cf=float(E), shared=shared)  # capacity ≥ all tokens
    params = init_tree(jax.random.key(0), moe_specs(cfg))
    x = jax.random.normal(jax.random.key(1), (2, 16, 32))
    y1, aux = apply_moe(params, x, cfg)
    y2 = moe_ref_dense(params, x, cfg)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)
    assert float(aux) > 0


def test_low_capacity_drops_tokens_but_stays_finite():
    cfg = _cfg(E=4, K=2, cf=0.5)
    params = init_tree(jax.random.key(0), moe_specs(cfg))
    x = jax.random.normal(jax.random.key(1), (2, 64, 32))
    y, aux = apply_moe(params, x, cfg)
    assert bool(jnp.isfinite(y).all())
    # with drops, output differs from the oracle (some tokens zeroed)
    y2 = moe_ref_dense(params, x, cfg)
    assert float(jnp.abs(y - y2).max()) > 1e-4


def test_aux_loss_uniform_router_is_one():
    """Perfectly uniform routing ⇒ Switch aux = E · Σ (1/E)(1/E) = 1."""
    cfg = _cfg(E=4, K=1)
    params = init_tree(jax.random.key(0), moe_specs(cfg))
    params["router"] = jnp.zeros_like(params["router"])  # uniform probs
    x = jax.random.normal(jax.random.key(1), (2, 32, 32))
    _, aux = apply_moe(params, x, cfg)
    # frac counts argmax (=expert 0 under ties) so this lower-bounds at 1
    assert float(aux) >= 1.0 - 1e-5


@pytest.mark.slow
@given(S=st.integers(4, 64), cf=st.floats(0.25, 4.0))
@settings(max_examples=20)
def test_capacity_formula(S, cf):
    cfg = _cfg(E=4, K=2, cf=cf)
    C = _capacity(S, cfg)
    assert C >= cfg.top_k and C % 8 == 0


def test_grad_flows_through_dispatch():
    cfg = _cfg()
    params = init_tree(jax.random.key(0), moe_specs(cfg))
    x = jax.random.normal(jax.random.key(1), (1, 16, 32))

    def loss(p):
        y, aux = apply_moe(p, x, cfg)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    gn = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
