"""Launcher CLIs: train.py (plain + elastic) and serve.py smoke runs."""
import pytest

from repro.launch import serve as serve_cli
from repro.launch import train as train_cli


@pytest.mark.slow
def test_train_cli_elastic_cnn(capsys):
    train_cli.main([
        "--arch", "paper-cnn", "--rounds", "2", "--workers", "2",
        "--tau", "1", "--batch-size", "16"])
    out = capsys.readouterr().out
    assert "round 1" in out and "score=" in out


@pytest.mark.slow
def test_train_cli_chunked_rounds_per_call(capsys):
    """--rounds-per-call routes the CLI through round_chunk (one jit call
    for all three rounds) and still prints per-round records."""
    train_cli.main([
        "--arch", "paper-cnn", "--rounds", "3", "--workers", "2",
        "--batch-size", "8", "--rounds-per-call", "3"])
    out = capsys.readouterr().out
    assert "round 0" in out and "round 2" in out and "score=" in out


@pytest.mark.slow
def test_train_cli_plain_lm(capsys):
    train_cli.main([
        "--arch", "qwen3-4b", "--smoke", "--plain", "--rounds", "2",
        "--batch-size", "2", "--seq-len", "32"])
    out = capsys.readouterr().out
    assert "step 1" in out


@pytest.mark.slow
def test_serve_cli(capsys):
    serve_cli.main(["--arch", "stablelm-3b", "--batch", "2",
                    "--prompt-len", "8", "--steps", "4"])
    out = capsys.readouterr().out
    assert "tok/s" in out
    assert "incl. jit compile" in out  # trial 0 is labelled


@pytest.mark.slow
def test_serve_cli_eos_id_counts_real_tokens(capsys):
    """--eos-id reaches the engine's pinning path from the CLI, and the
    reported throughput excludes EOS-pinned padding (so it can only be
    ≤ batch × steps)."""
    serve_cli.main(["--arch", "qwen3-4b", "--batch", "4",
                    "--prompt-len", "8", "--steps", "12",
                    "--eos-id", "7"])
    import re

    out = capsys.readouterr().out
    toks = [int(m) for m in re.findall(r"(\d+) tokens", out)]
    assert toks and all(t <= 4 * 12 for t in toks)


@pytest.mark.slow
def test_serve_cli_continuous_traffic(capsys):
    serve_cli.main(["--arch", "qwen3-4b", "--prompt-len", "8",
                    "--steps", "6", "--capacity", "2", "--traffic", "6"])
    out = capsys.readouterr().out
    assert "req/s" in out and "p99" in out
    assert "served 6/6" in out


@pytest.mark.slow
def test_serve_cli_restore_roundtrip_multishard(tmp_path, capsys,
                                                monkeypatch):
    """An ElasticSession run saves a multi-shard elastic checkpoint; the
    serve CLI restores and serves it (no warning on the matching arch)."""
    from repro.api import ElasticSession, RunSpec
    from repro.checkpoint import checkpoint
    from repro.configs.base import ElasticConfig, OptimizerConfig

    ck = str(tmp_path / "ck")
    sess = ElasticSession(RunSpec(
        arch="stablelm-3b", smoke=True,
        optimizer=OptimizerConfig(name="sgd", lr=0.01),
        elastic=ElasticConfig(num_workers=2, tau=1, dynamic=True),
        rounds=2, seed=1, n_tokens=4000, seq_len=16, batch_size=2,
        save_path=ck))
    sess.run()
    monkeypatch.setattr(checkpoint, "MAX_SHARD_BYTES", 4096)
    sess.save()
    import os
    assert len([f for f in os.listdir(ck) if f.endswith(".npz")]) > 1

    serve_cli.main(["--arch", "stablelm-3b", "--restore", ck,
                    "--batch", "2", "--prompt-len", "8", "--steps", "4"])
    out = capsys.readouterr().out
    assert "restored" in out and "rounds=2" in out and "tok/s" in out
    assert "WARNING" not in out


@pytest.mark.slow
def test_serve_cli_restore_arch_mismatch_warns(tmp_path, capsys):
    """--restore with the wrong --arch prints the mismatch warning before
    the restore fails on the foreign parameter tree."""
    from repro.api import ElasticSession, RunSpec
    from repro.configs.base import ElasticConfig, OptimizerConfig

    ck = str(tmp_path / "ck")
    sess = ElasticSession(RunSpec(
        arch="paper-cnn", optimizer=OptimizerConfig(name="sgd", lr=0.01),
        elastic=ElasticConfig(num_workers=2, tau=1, dynamic=True),
        rounds=1, seed=0, batch_size=4, n_data=64, n_test=32,
        save_path=ck))
    sess.run()
    sess.save()
    with pytest.raises(Exception):
        serve_cli.main(["--arch", "qwen3-4b", "--restore", ck,
                        "--batch", "2", "--prompt-len", "8",
                        "--steps", "4"])
    out = capsys.readouterr().out
    assert "WARNING" in out and "paper-cnn" in out


@pytest.mark.slow
def test_train_cli_checkpoint_roundtrip(tmp_path, capsys):
    path = str(tmp_path / "ck")
    train_cli.main([
        "--arch", "paper-cnn", "--rounds", "1", "--workers", "2",
        "--batch-size", "8", "--save", path])
    from repro.checkpoint import checkpoint

    tree, meta = checkpoint.restore(path)
    assert meta["rounds"] == 1
    assert "conv1" in tree


@pytest.mark.slow
def test_train_cli_membership_plan(capsys):
    """--capacity/--membership-plan drive a live 2→1→3 resize through the
    CLI; the per-round line shows the live count against capacity."""
    train_cli.main([
        "--arch", "paper-cnn", "--rounds", "3", "--workers", "2",
        "--capacity", "4", "--batch-size", "8",
        "--membership-plan", "1:1,2:3"])
    out = capsys.readouterr().out
    assert "k=2/4" in out and "k=1/4" in out and "k=3/4" in out


@pytest.mark.slow
def test_train_cli_scale_up_defaults(capsys):
    """Regression: --membership-scenario scale_up with no explicit
    --capacity/--membership-k must default to a pool with headroom (2k)
    instead of crashing on k0 == k_to == capacity."""
    train_cli.main([
        "--arch", "paper-cnn", "--rounds", "2", "--workers", "2",
        "--batch-size", "8", "--membership-scenario", "scale_up"])
    out = capsys.readouterr().out
    assert "k=2/4" in out and "k=4/4" in out


@pytest.mark.slow
@pytest.mark.parametrize("scenario", ["iid", "burst", "correlated",
                                      "straggler", "crash_restart"])
def test_train_cli_failure_scenarios_end_to_end(capsys, scenario):
    """Every scenario is selectable from the CLI and drives a full round
    loop (τ=2 so straggler slowdown actually bites)."""
    train_cli.main([
        "--arch", "paper-cnn", "--rounds", "2", "--workers", "2",
        "--tau", "2", "--batch-size", "8", "--failure-scenario", scenario,
        "--seed", "3"])
    out = capsys.readouterr().out
    assert "round 1" in out and "score=" in out
    if scenario == "straggler":
        assert "straggle=" in out
