"""Launcher CLIs: train.py (plain + elastic) and serve.py smoke runs."""
import pytest

from repro.launch import serve as serve_cli
from repro.launch import train as train_cli


@pytest.mark.slow
def test_train_cli_elastic_cnn(capsys):
    train_cli.main([
        "--arch", "paper-cnn", "--rounds", "2", "--workers", "2",
        "--tau", "1", "--batch-size", "16"])
    out = capsys.readouterr().out
    assert "round 1" in out and "score=" in out


@pytest.mark.slow
def test_train_cli_chunked_rounds_per_call(capsys):
    """--rounds-per-call routes the CLI through round_chunk (one jit call
    for all three rounds) and still prints per-round records."""
    train_cli.main([
        "--arch", "paper-cnn", "--rounds", "3", "--workers", "2",
        "--batch-size", "8", "--rounds-per-call", "3"])
    out = capsys.readouterr().out
    assert "round 0" in out and "round 2" in out and "score=" in out


@pytest.mark.slow
def test_train_cli_plain_lm(capsys):
    train_cli.main([
        "--arch", "qwen3-4b", "--smoke", "--plain", "--rounds", "2",
        "--batch-size", "2", "--seq-len", "32"])
    out = capsys.readouterr().out
    assert "step 1" in out


@pytest.mark.slow
def test_serve_cli(capsys):
    serve_cli.main(["--arch", "stablelm-3b", "--batch", "2",
                    "--prompt-len", "8", "--steps", "4"])
    out = capsys.readouterr().out
    assert "tok/s" in out


@pytest.mark.slow
def test_train_cli_checkpoint_roundtrip(tmp_path, capsys):
    path = str(tmp_path / "ck")
    train_cli.main([
        "--arch", "paper-cnn", "--rounds", "1", "--workers", "2",
        "--batch-size", "8", "--save", path])
    from repro.checkpoint import checkpoint

    tree, meta = checkpoint.restore(path)
    assert meta["rounds"] == 1
    assert "conv1" in tree


@pytest.mark.slow
def test_train_cli_membership_plan(capsys):
    """--capacity/--membership-plan drive a live 2→1→3 resize through the
    CLI; the per-round line shows the live count against capacity."""
    train_cli.main([
        "--arch", "paper-cnn", "--rounds", "3", "--workers", "2",
        "--capacity", "4", "--batch-size", "8",
        "--membership-plan", "1:1,2:3"])
    out = capsys.readouterr().out
    assert "k=2/4" in out and "k=1/4" in out and "k=3/4" in out


@pytest.mark.slow
def test_train_cli_scale_up_defaults(capsys):
    """Regression: --membership-scenario scale_up with no explicit
    --capacity/--membership-k must default to a pool with headroom (2k)
    instead of crashing on k0 == k_to == capacity."""
    train_cli.main([
        "--arch", "paper-cnn", "--rounds", "2", "--workers", "2",
        "--batch-size", "8", "--membership-scenario", "scale_up"])
    out = capsys.readouterr().out
    assert "k=2/4" in out and "k=4/4" in out


@pytest.mark.slow
@pytest.mark.parametrize("scenario", ["iid", "burst", "correlated",
                                      "straggler", "crash_restart"])
def test_train_cli_failure_scenarios_end_to_end(capsys, scenario):
    """Every scenario is selectable from the CLI and drives a full round
    loop (τ=2 so straggler slowdown actually bites)."""
    train_cli.main([
        "--arch", "paper-cnn", "--rounds", "2", "--workers", "2",
        "--tau", "2", "--batch-size", "8", "--failure-scenario", scenario,
        "--seed", "3"])
    out = capsys.readouterr().out
    assert "round 1" in out and "score=" in out
    if scenario == "straggler":
        assert "straggle=" in out
