"""Serve a small model with batched requests: prefill + greedy decode over a
KV cache (the inference side of the framework; decode_32k / long_500k run
the same step functions under the production mesh via launch/dryrun.py).

    PYTHONPATH=src python examples/serve_batch.py --arch qwen3-4b
    PYTHONPATH=src python examples/serve_batch.py --arch rwkv6-3b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models.registry import build_model
from repro.nn.param import init_tree, param_count
from repro.serving.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="token id that finishes a row early (finished "
                         "rows are EOS-pinned; the loop short-circuits "
                         "once every row is done)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)  # reduced family variant on CPU
    model = build_model(cfg)
    params = init_tree(jax.random.key(0), model.spec)
    print(f"{cfg.name}: {param_count(model.spec):,} params "
          f"({cfg.family} family)")

    engine = ServeEngine(model, params,
                         max_len=args.prompt_len + args.steps + 1)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)
                           ).astype("int32")
    t0 = time.time()
    out = engine.generate(prompts, steps=args.steps, eos_id=args.eos_id)
    dt = time.time() - t0
    toks = out.size
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({toks/dt:.0f} tok/s incl. compile)")
    t0 = time.time()
    out = engine.generate(prompts, steps=args.steps, eos_id=args.eos_id)
    dt = time.time() - t0
    print(f"warm: {out.size/dt:.0f} tok/s")
    print("first request:", out[0][:12], "...")


if __name__ == "__main__":
    main()
