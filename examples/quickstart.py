"""Quickstart: the paper's system in ~20 lines via the session API.

Trains the paper's CNN with k=4 elastic AdaHessian workers under a 1/3
communication-failure rate, with dynamic weighting (DEAHES-O). Prints the
per-round raw scores and h1/h2 weights so you can watch the mechanism react.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import ElasticSession, RunSpec
from repro.configs.base import ElasticConfig, OptimizerConfig

spec = RunSpec(
    arch="paper-cnn",
    optimizer=OptimizerConfig(name="adahessian", lr=0.01),
    elastic=ElasticConfig(num_workers=4, tau=1, alpha=0.1,
                          overlap_ratio=0.25, failure_prob=1 / 3,
                          dynamic=True),
    rounds=10, seed=0, batch_size=32, n_data=4000, n_test=500,
    eval_every=1)

for rec in ElasticSession(spec).run_iter():
    print(f"round {rec.round:2d} | loss {rec.loss:6.3f} | "
          f"master acc {rec.eval_acc:.3f} | "
          f"fails {rec.fail.astype(int)} | "
          f"score {np.asarray(rec.score).round(3)} | "
          f"h2 {np.asarray(rec.h2).round(3)}")

print("\nDynamic weighting kept the master safe from suppressed workers;"
      " see EXPERIMENTS.md §Repro for the full paper grid.")
