"""Quickstart: the paper's system in ~40 lines.

Trains the paper's CNN with k=4 elastic AdaHessian workers under a 1/3
communication-failure rate, with dynamic weighting (DEAHES-O). Prints the
per-round raw scores and h1/h2 weights so you can watch the mechanism react.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ElasticConfig, OptimizerConfig, get_config
from repro.core.coordinator import ElasticTrainer
from repro.core.failure import failure_schedule_np
from repro.data.pipeline import WorkerBatcher
from repro.data.synthetic import SyntheticImages
from repro.models.registry import build_model

ROUNDS = 10

model = build_model(get_config("paper-cnn"))
ecfg = ElasticConfig(num_workers=4, tau=1, alpha=0.1, overlap_ratio=0.25,
                     failure_prob=1 / 3, dynamic=True)
trainer = ElasticTrainer(model, OptimizerConfig(name="adahessian", lr=0.01),
                         ecfg)

state = trainer.init_state(jax.random.key(0))
ds = SyntheticImages(n=4000, n_test=500)
batcher = WorkerBatcher(ds.images, ds.labels, ecfg, batch_size=32)
schedule = failure_schedule_np(7, ROUNDS, 4, ecfg.failure_prob)
test = {k: jnp.asarray(v) for k, v in ds.test_batch().items()}

for rnd in range(ROUNDS):
    batches = {k: jnp.asarray(v) for k, v in batcher.round_batches().items()}
    fails = jnp.asarray(schedule[rnd])
    state, m = trainer.round_step(
        state, batches, jax.random.key(rnd), fails, jnp.zeros(4, bool))
    acc = trainer.master_accuracy(state, test)
    print(f"round {rnd:2d} | loss {float(m['loss']):6.3f} | "
          f"master acc {float(acc):.3f} | "
          f"fails {np.asarray(fails).astype(int)} | "
          f"score {np.asarray(m['score']).round(3)} | "
          f"h2 {np.asarray(m['h2']).round(3)}")

print("\nDynamic weighting kept the master safe from suppressed workers;"
      " see EXPERIMENTS.md §Repro for the full paper grid.")
