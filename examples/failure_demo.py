"""Failure anatomy demo: inject a failure regime and print the full paper
mechanism — u (log distance), raw score a, and the h1/h2 weights — before,
during, and after each fault.

The default ``outage`` scenario is the hand-crafted original: worker 0 loses
master contact for rounds 4–8, injected as a custom ``ScenarioSchedule``
through ``RunSpec.schedule``. ``--scenario`` swaps in any regime from the
scenario engine (``repro.core.scenarios``) by name:

    PYTHONPATH=src python examples/failure_demo.py
    PYTHONPATH=src python examples/failure_demo.py --scenario burst
    PYTHONPATH=src python examples/failure_demo.py --scenario crash_restart

``--controller rules`` (ISSUE-6) closes the loop: the failure detector
watches the same u/loss telemetry this demo prints — never the ground-truth
masks — and the rule policy evicts suspect slots and probes them back in.
The per-round table gains a live-pool column and the demo ends with the
controller's action journal, so you can line up each eviction against the
drift that triggered it:

    PYTHONPATH=src python examples/failure_demo.py \
        --scenario crash_restart --controller rules --workers 4
"""
import argparse

import numpy as np

from repro.api import ElasticSession, RunSpec
from repro.configs.base import (FAILURE_SCENARIOS, ElasticConfig,
                                OptimizerConfig)
from repro.core.scenarios import ScenarioSchedule


def outage_schedule(rounds, k):
    """The original deterministic demo: worker 0 down for rounds 4–8."""
    fail = np.zeros((rounds, k), bool)
    fail[4:9, 0] = True
    z = np.zeros((rounds, k), bool)
    return ScenarioSchedule(fail, z, z)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="outage",
                    choices=("outage",) + FAILURE_SCENARIOS)
    ap.add_argument("--rounds", type=int, default=14)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--controller", default="none",
                    choices=("none", "rules"),
                    help="'rules' closes the loop: detector verdicts drive "
                         "evict/readmit through ElasticSession.apply")
    args = ap.parse_args(argv)
    controller = None if args.controller == "none" else args.controller

    ecfg = ElasticConfig(num_workers=args.workers, tau=1, alpha=0.1,
                         overlap_ratio=0.25, dynamic=True,
                         failure_scenario=(args.scenario
                                           if args.scenario != "outage"
                                           else "iid"))
    spec = RunSpec(
        arch="paper-cnn",
        optimizer=OptimizerConfig(name="adahessian", lr=0.01),
        elastic=ecfg, rounds=args.rounds, seed=args.seed,
        schedule=(outage_schedule(args.rounds, args.workers)
                  if args.scenario == "outage" else None),
        batch_size=32, n_data=2000, n_test=300, eval_every=1,
        controller=controller)
    sess = ElasticSession(spec)

    pool = " | live" if controller else ""
    print(f"scenario={args.scenario}  (F=comm fail, S=straggle, R=restart, "
          f"C=corrupt; worker-0 column shown)")
    if sess.schedule is not None and sess.schedule.has_hetero:
        print("persistent slot speeds: "
              f"{np.asarray(sess.schedule.speed[0]).round(3).tolist()}")
    print(f" rnd | F S R C |      u0      a0     h1_0   h2_0 |  master_acc"
          f"{pool}")
    for rec in sess.run_iter():
        pool = (f" | {rec.num_active}/{sess.capacity}" if controller else "")
        print(f"  {rec.round:2d} | {int(rec.fail[0])} "
              f"{int(rec.straggle[0])} {int(rec.restart[0])} "
              f"{int(rec.corrupt[0])} "
              f"| {float(rec.u[0]):8.3f} {float(rec.score[0]):8.4f} "
              f"{float(rec.h1[0]):6.3f} {float(rec.h2[0]):6.3f} |"
              f"    {rec.eval_acc:.3f}{pool}")
    if sess.controller is not None:
        applied = [a for a in sess.controller.actuator.log if a.applied]
        print(f"\ncontroller journal ({len(applied)} applied):")
        for a in applied:
            print(f"  round {a.round}: {a.action.describe()} "
                  f"-> {a.live_after} live")

    print("\nWhile a worker is cut off (or straggling) its u drifts; when it "
          "reconnects — or rejoins reset to the master after a crash — the "
          "distance collapses, the score goes negative, and h1→1 / h2→0 "
          "snap the worker back while protecting the master (paper §V-B).")


if __name__ == "__main__":
    main()
