"""Failure anatomy demo: inject a failure regime and print the full paper
mechanism — u (log distance), raw score a, and the h1/h2 weights — before,
during, and after each fault.

The default ``outage`` scenario is the hand-crafted original: worker 0 loses
master contact for rounds 4–8, injected as a custom ``ScenarioSchedule``
through ``RunSpec.schedule``. ``--scenario`` swaps in any regime from the
scenario engine (``repro.core.scenarios``) by name:

    PYTHONPATH=src python examples/failure_demo.py
    PYTHONPATH=src python examples/failure_demo.py --scenario burst
    PYTHONPATH=src python examples/failure_demo.py --scenario crash_restart
"""
import argparse

import numpy as np

from repro.api import ElasticSession, RunSpec
from repro.configs.base import (FAILURE_SCENARIOS, ElasticConfig,
                                OptimizerConfig)
from repro.core.scenarios import ScenarioSchedule


def outage_schedule(rounds, k):
    """The original deterministic demo: worker 0 down for rounds 4–8."""
    fail = np.zeros((rounds, k), bool)
    fail[4:9, 0] = True
    z = np.zeros((rounds, k), bool)
    return ScenarioSchedule(fail, z, z)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="outage",
                    choices=("outage",) + FAILURE_SCENARIOS)
    ap.add_argument("--rounds", type=int, default=14)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    ecfg = ElasticConfig(num_workers=args.workers, tau=1, alpha=0.1,
                         overlap_ratio=0.25, dynamic=True,
                         failure_scenario=(args.scenario
                                           if args.scenario != "outage"
                                           else "iid"))
    spec = RunSpec(
        arch="paper-cnn",
        optimizer=OptimizerConfig(name="adahessian", lr=0.01),
        elastic=ecfg, rounds=args.rounds, seed=args.seed,
        schedule=(outage_schedule(args.rounds, args.workers)
                  if args.scenario == "outage" else None),
        batch_size=32, n_data=2000, n_test=300, eval_every=1)
    sess = ElasticSession(spec)

    print(f"scenario={args.scenario}  (F=comm fail, S=straggle, R=restart; "
          f"worker-0 column shown)")
    print(" rnd | F S R |      u0      a0     h1_0   h2_0 |  master_acc")
    for rec in sess.run_iter():
        print(f"  {rec.round:2d} | {int(rec.fail[0])} "
              f"{int(rec.straggle[0])} {int(rec.restart[0])} "
              f"| {float(rec.u[0]):8.3f} {float(rec.score[0]):8.4f} "
              f"{float(rec.h1[0]):6.3f} {float(rec.h2[0]):6.3f} |"
              f"    {rec.eval_acc:.3f}")

    print("\nWhile a worker is cut off (or straggling) its u drifts; when it "
          "reconnects — or rejoins reset to the master after a crash — the "
          "distance collapses, the score goes negative, and h1→1 / h2→0 "
          "snap the worker back while protecting the master (paper §V-B).")


if __name__ == "__main__":
    main()
