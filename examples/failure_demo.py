"""Failure anatomy demo: force one worker to fail for a stretch of rounds
and print the full paper mechanism — u (log distance), raw score a, and the
h1/h2 weights — before, during, and after the outage.

    PYTHONPATH=src python examples/failure_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ElasticConfig, OptimizerConfig, get_config
from repro.core.coordinator import ElasticTrainer
from repro.data.pipeline import WorkerBatcher
from repro.data.synthetic import SyntheticImages
from repro.models.registry import build_model

ROUNDS = 14
OUTAGE = range(4, 9)  # worker 0 loses master contact in these rounds

model = build_model(get_config("paper-cnn"))
ecfg = ElasticConfig(num_workers=2, tau=1, alpha=0.1, overlap_ratio=0.25,
                     dynamic=True)
trainer = ElasticTrainer(model, OptimizerConfig(name="adahessian", lr=0.01),
                         ecfg)
state = trainer.init_state(jax.random.key(0))
ds = SyntheticImages(n=2000, n_test=300)
batcher = WorkerBatcher(ds.images, ds.labels, ecfg, batch_size=32)

print(" rnd | fail |      u0      a0     h1_0   h2_0 |  master_acc")
test = {k: jnp.asarray(v) for k, v in ds.test_batch().items()}
for rnd in range(ROUNDS):
    batches = {k: jnp.asarray(v) for k, v in batcher.round_batches().items()}
    fail = jnp.asarray([rnd in OUTAGE, False])
    state, m = trainer.round_step(state, batches, jax.random.key(rnd), fail,
                                  jnp.zeros(2, bool))
    acc = float(trainer.master_accuracy(state, test))
    print(f"  {rnd:2d} |  {int(fail[0])}   | {float(m['u'][0]):8.3f} "
          f"{float(m['score'][0]):8.4f} {float(m['h1'][0]):6.3f} "
          f"{float(m['h2'][0]):6.3f} |    {acc:.3f}")

print("\nDuring the outage u0 climbs (worker drifts); at recovery the "
      "distance collapses, the score goes negative, and h1→1 / h2→0 snap "
      "the worker back while protecting the master (paper §V-B).")
