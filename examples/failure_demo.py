"""Failure anatomy demo: inject a failure regime and print the full paper
mechanism — u (log distance), raw score a, and the h1/h2 weights — before,
during, and after each fault.

The default ``outage`` scenario is the hand-crafted original: worker 0 loses
master contact for rounds 4–8. ``--scenario`` swaps in any regime from the
scenario engine (``repro.core.scenarios``) by name:

    PYTHONPATH=src python examples/failure_demo.py
    PYTHONPATH=src python examples/failure_demo.py --scenario burst
    PYTHONPATH=src python examples/failure_demo.py --scenario crash_restart
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (FAILURE_SCENARIOS, ElasticConfig,
                                OptimizerConfig, get_config)
from repro.core.coordinator import ElasticTrainer
from repro.core.scenarios import ScenarioSchedule, make_scenario
from repro.data.pipeline import WorkerBatcher
from repro.data.synthetic import SyntheticImages
from repro.models.registry import build_model


def outage_schedule(rounds, k):
    """The original deterministic demo: worker 0 down for rounds 4–8."""
    fail = np.zeros((rounds, k), bool)
    fail[4:9, 0] = True
    z = np.zeros((rounds, k), bool)
    return ScenarioSchedule(fail, z, z)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="outage",
                    choices=("outage",) + FAILURE_SCENARIOS)
    ap.add_argument("--rounds", type=int, default=14)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    model = build_model(get_config("paper-cnn"))
    ecfg = ElasticConfig(num_workers=args.workers, tau=1, alpha=0.1,
                         overlap_ratio=0.25, dynamic=True,
                         failure_scenario=(args.scenario
                                           if args.scenario != "outage"
                                           else "iid"))
    trainer = ElasticTrainer(model,
                             OptimizerConfig(name="adahessian", lr=0.01),
                             ecfg)
    state = trainer.init_state(jax.random.key(args.seed))
    ds = SyntheticImages(n=2000, n_test=300)
    batcher = WorkerBatcher(ds.images, ds.labels, ecfg, batch_size=32)

    if args.scenario == "outage":
        sched = outage_schedule(args.rounds, args.workers)
    else:
        sched = make_scenario(ecfg).schedule(args.seed + 7, args.rounds,
                                             args.workers)

    print(f"scenario={args.scenario}  (F=comm fail, S=straggle, R=restart; "
          f"worker-0 column shown)")
    print(" rnd | F S R |      u0      a0     h1_0   h2_0 |  master_acc")
    test = {k: jnp.asarray(v) for k, v in ds.test_batch().items()}
    for rnd in range(args.rounds):
        batches = {k: jnp.asarray(v)
                   for k, v in batcher.round_batches().items()}
        fail = jnp.asarray(sched.fail[rnd])
        recent = jnp.asarray(sched.failed_recent(rnd, ecfg.score_window))
        straggle = (jnp.asarray(sched.straggle[rnd])
                    if sched.has_stragglers else None)
        restart = (jnp.asarray(sched.restart[rnd])
                   if sched.has_restarts else None)
        state, m = trainer.round_step(state, batches, jax.random.key(rnd),
                                      fail, recent, straggle, restart)
        acc = float(trainer.master_accuracy(state, test))
        print(f"  {rnd:2d} | {int(sched.fail[rnd, 0])} "
              f"{int(sched.straggle[rnd, 0])} {int(sched.restart[rnd, 0])} "
              f"| {float(m['u'][0]):8.3f} {float(m['score'][0]):8.4f} "
              f"{float(m['h1'][0]):6.3f} {float(m['h2'][0]):6.3f} |"
              f"    {acc:.3f}")

    print("\nWhile a worker is cut off (or straggling) its u drifts; when it "
          "reconnects — or rejoins reset to the master after a crash — the "
          "distance collapses, the score goes negative, and h1→1 / h2→0 "
          "snap the worker back while protecting the master (paper §V-B).")


if __name__ == "__main__":
    main()
