"""End-to-end driver: train a transformer LM under elastic averaging with
dynamic weighting — the paper's system applied to a real architecture,
driven through ``repro.api.ElasticSession``.

Default preset trains a ~10M-param qwen3-family model for 60 rounds on the
synthetic token stream (CPU-friendly). ``--preset 100m`` scales to a ~100M
model / 300 rounds for real hardware; ``--rounds-per-call`` amortizes the
per-round driver dispatch into jit-scanned chunks:

    PYTHONPATH=src python examples/train_lm_elastic.py              # CI-size
    PYTHONPATH=src python examples/train_lm_elastic.py --preset 100m
"""
import argparse
import time

import numpy as np

from repro.api import ElasticSession, RunSpec
from repro.configs.base import ElasticConfig, OptimizerConfig, get_config

PRESETS = {
    # name: (d_model, layers, heads, d_ff, seq, batch, rounds)
    "ci": (128, 4, 4, 256, 128, 8, 12),
    "10m": (256, 8, 8, 1024, 256, 8, 60),
    "100m": (768, 12, 12, 3072, 512, 16, 300),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="ci", choices=sorted(PRESETS))
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--rounds-per-call", type=int, default=1)
    ap.add_argument("--save", default=None)
    args = ap.parse_args()

    d, L, H, ff, seq, bsz, rounds = PRESETS[args.preset]
    cfg = get_config("qwen3-4b").replace(
        name=f"qwen3-{args.preset}", num_layers=L, d_model=d, num_heads=H,
        num_kv_heads=max(1, H // 4), head_dim=d // H, d_ff=ff,
        vocab_size=4096, dtype="float32", param_dtype="float32")

    spec = RunSpec(
        model_cfg=cfg,
        optimizer=OptimizerConfig(name="adahessian", lr=0.002),
        elastic=ElasticConfig(num_workers=args.workers, tau=args.tau,
                              alpha=0.1, overlap_ratio=0.25,
                              failure_prob=1 / 3, dynamic=True),
        rounds=rounds, rounds_per_call=args.rounds_per_call,
        seed=0, scenario_seed=3, batch_size=bsz, seq_len=seq,
        n_tokens=400_000)
    sess = ElasticSession(spec)
    from repro.nn.param import param_count

    print(f"model: {cfg.name}  params={param_count(sess.model.spec):,}")

    t0 = time.time()
    for rec in sess.run_iter():
        if rec.round % 5 == 0 or rec.round == rounds - 1:
            print(f"round {rec.round:3d} | worker loss {rec.loss:6.3f} | "
                  f"h2 {np.asarray(rec.h2).round(3)} | "
                  f"{time.time()-t0:6.1f}s", flush=True)
    if args.save:
        sess.save(args.save, extra_metadata={"preset": args.preset})
        print("saved:", args.save)


if __name__ == "__main__":
    main()
