"""End-to-end driver: train a transformer LM under elastic averaging with
dynamic weighting — the paper's system applied to a real architecture.

Default preset trains a ~10M-param qwen3-family model for 60 rounds on the
synthetic token stream (CPU-friendly). ``--preset 100m`` scales to a ~100M
model / 300 rounds for real hardware:

    PYTHONPATH=src python examples/train_lm_elastic.py              # CI-size
    PYTHONPATH=src python examples/train_lm_elastic.py --preset 100m
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint
from repro.configs.base import ElasticConfig, OptimizerConfig, get_config
from repro.core.coordinator import ElasticTrainer
from repro.core.failure import failure_schedule_np
from repro.data.pipeline import TokenWorkerBatcher
from repro.data.synthetic import SyntheticTokens
from repro.models.registry import build_model

PRESETS = {
    # name: (d_model, layers, heads, d_ff, seq, batch, rounds)
    "ci": (128, 4, 4, 256, 128, 8, 12),
    "10m": (256, 8, 8, 1024, 256, 8, 60),
    "100m": (768, 12, 12, 3072, 512, 16, 300),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="ci", choices=sorted(PRESETS))
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--save", default=None)
    args = ap.parse_args()

    d, L, H, ff, seq, bsz, rounds = PRESETS[args.preset]
    cfg = get_config("qwen3-4b").replace(
        name=f"qwen3-{args.preset}", num_layers=L, d_model=d, num_heads=H,
        num_kv_heads=max(1, H // 4), head_dim=d // H, d_ff=ff,
        vocab_size=4096, dtype="float32", param_dtype="float32")
    model = build_model(cfg)

    ecfg = ElasticConfig(num_workers=args.workers, tau=args.tau, alpha=0.1,
                         overlap_ratio=0.25, failure_prob=1 / 3,
                         dynamic=True)
    trainer = ElasticTrainer(model, OptimizerConfig(name="adahessian",
                                                    lr=0.002), ecfg)
    state = trainer.init_state(jax.random.key(0))
    from repro.nn.param import param_count

    print(f"model: {cfg.name}  params={param_count(model.spec):,}")

    stream = SyntheticTokens(vocab=cfg.vocab_size, n_tokens=400_000)
    batcher = TokenWorkerBatcher(stream.tokens, ecfg, batch_size=bsz,
                                 seq_len=seq)
    sched = failure_schedule_np(3, rounds, args.workers, ecfg.failure_prob)
    t0 = time.time()
    for rnd in range(rounds):
        batches = {k: jnp.asarray(v)
                   for k, v in batcher.round_batches().items()}
        state, m = trainer.round_step(
            state, batches, jax.random.key(rnd), jnp.asarray(sched[rnd]),
            jnp.zeros(args.workers, bool))
        if rnd % 5 == 0 or rnd == rounds - 1:
            print(f"round {rnd:3d} | worker loss {float(m['loss']):6.3f} | "
                  f"h2 {np.asarray(m['h2']).round(3)} | "
                  f"{time.time()-t0:6.1f}s", flush=True)
    if args.save:
        checkpoint.save(args.save, state["master"],
                        metadata={"rounds": rounds, "preset": args.preset})
        print("saved:", args.save)


if __name__ == "__main__":
    main()
